#!/usr/bin/env bash
# Fast test subset: everything except the multi-second `slow` tests
# (distributed subprocesses, reduced-model smoke runs).  Full suite:
#   PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -q -m "not slow" "$@"
