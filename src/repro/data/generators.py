"""Synthetic historical-trace generators mirroring the paper's datasets (§7).

* :func:`growing_network`  — Dataset 1 analogue: a growing-only
  co-authorship-style network (preferential attachment, nodes+edges only
  added, per-node attribute key-value pairs).
* :func:`churn_network`    — Dataset 2/3 analogue: a starting snapshot
  followed by interleaved edge additions and deletions (and optional
  attribute updates / transient "message" events).
* :func:`random_history`   — fully random small traces for property tests.

All generators return ``(universe, events)`` via the builder, with event
times drawn from a super-linear event-density g(t) when requested (§5.1).
"""
from __future__ import annotations

import numpy as np

from ..core.events import EventList, GraphHistoryBuilder, GraphUniverse

ATTR_NAMES = [f"attr{i}" for i in range(10)]


def _times(rng: np.ndarray, n: int, superlinear: bool) -> np.ndarray:
    if superlinear:
        # event density increasing over time: t ~ sqrt(uniform)
        u = np.sort(rng.uniform(0, 1, n))
        t = (np.sqrt(u) * n * 10).astype(np.int64)
    else:
        t = np.sort(rng.integers(0, n * 10, n).astype(np.int64))
    return t


def growing_network(n_events: int = 4000, seed: int = 0,
                    n_attrs: int = 3, attrs_on_add: bool = True,
                    superlinear: bool = False) -> tuple[GraphUniverse, EventList]:
    rng = np.random.default_rng(seed)
    b = GraphHistoryBuilder()
    times = _times(rng, n_events, superlinear)
    nodes: list[int] = []
    budget = n_events
    i = 0
    nid = 0
    while budget > 0:
        t = int(times[min(i, len(times) - 1)])
        if len(nodes) < 2 or rng.random() < 0.3:
            attrs = ({ATTR_NAMES[j]: float(rng.random())
                      for j in range(n_attrs)} if attrs_on_add else None)
            b.add_node(nid, t, attrs=attrs)
            nodes.append(nid)
            nid += 1
            budget -= 1 + (n_attrs if attrs_on_add else 0)
        else:
            # preferential-ish: bias toward recent nodes
            u = nodes[int(len(nodes) * rng.beta(2, 1)) - 1]
            v = nodes[rng.integers(0, len(nodes))]
            if u != v:
                b.add_edge(u, v, t, edge_id=("e", u, v, i))
                budget -= 1
        i += 1
    return b.finalize()


def churn_network(n_initial_edges: int = 500, n_events: int = 4000,
                  seed: int = 0, p_delete: float = 0.4,
                  p_attr_update: float = 0.1, p_transient: float = 0.02,
                  n_attrs: int = 2,
                  superlinear: bool = False) -> tuple[GraphUniverse, EventList]:
    rng = np.random.default_rng(seed)
    b = GraphHistoryBuilder()
    n_nodes = max(8, n_initial_edges // 3)
    for n in range(n_nodes):
        b.add_node(n, 0, attrs={ATTR_NAMES[j]: float(rng.random())
                                for j in range(n_attrs)})
    live: dict[tuple[int, int], int] = {}
    eid = 0
    for _ in range(n_initial_edges):
        u, v = rng.integers(0, n_nodes, 2)
        if u == v or (int(u), int(v)) in live or (int(v), int(u)) in live:
            continue
        live[(int(u), int(v))] = b.add_edge(int(u), int(v), 1,
                                            edge_id=("e", eid))
        eid += 1
    times = _times(rng, n_events, superlinear) + 2
    i = 0
    emitted = 0
    while emitted < n_events:
        t = int(times[min(i, len(times) - 1)])
        i += 1
        r = rng.random()
        if r < p_transient:
            u, v = rng.integers(0, n_nodes, 2)
            b.transient_edge(int(u), int(v), t)
            emitted += 1
        elif r < p_transient + p_attr_update:
            n = int(rng.integers(0, n_nodes))
            b.set_node_attr(n, ATTR_NAMES[int(rng.integers(0, n_attrs))],
                            float(rng.random()), t)
            emitted += 1
        elif live and r < p_transient + p_attr_update + p_delete:
            key = list(live.keys())[int(rng.integers(0, len(live)))]
            slot = live.pop(key)
            b.delete_edge_slot(slot, t)
            emitted += 1
        else:
            u, v = rng.integers(0, n_nodes, 2)
            if u == v or (int(u), int(v)) in live or (int(v), int(u)) in live:
                continue
            live[(int(u), int(v))] = b.add_edge(int(u), int(v), t,
                                                edge_id=("e", eid))
            eid += 1
            emitted += 1
    return b.finalize()


def dense_intervals(tmax: int, n: int, points: int,
                    window_frac: float = 0.05,
                    seed: int = 0) -> list[list[int]]:
    """``n`` evolutionary-query windows of ``points`` evenly spaced
    timepoints, each spanning ``window_frac`` of the history — the dense
    "daily snapshots over a period" dashboard workload that
    ``GraphManager.evolve`` / ``benchmarks/temporal_bench.py`` /
    ``serve --mode evolve`` drive."""
    rng = np.random.default_rng(seed)
    span = max(int(tmax * window_frac), points)
    starts = rng.integers(0, max(tmax - span, 1), n)
    return [[int(t) for t in np.linspace(s, s + span, points)]
            for s in starts]


def random_history(n_events: int, seed: int,
                   n_attrs: int = 2, p_node: float = 0.3,
                   p_delete: float = 0.3, p_attr: float = 0.2,
                   p_transient: float = 0.05,
                   max_time_step: int = 3) -> tuple[GraphUniverse, EventList]:
    """Small fully-random trace; duplicate timestamps on purpose (straddled
    leaf boundaries are a key edge case)."""
    rng = np.random.default_rng(seed)
    b = GraphHistoryBuilder()
    live_nodes: list[int] = []
    live_edges: list[tuple] = []
    t = 0
    nid = 0
    emitted = 0
    while emitted < n_events:
        t += int(rng.integers(0, max_time_step + 1))  # may repeat
        r = rng.random()
        if not live_nodes or r < p_node:
            b.add_node(nid, t)
            live_nodes.append(nid)
            nid += 1
        elif r < p_node + p_attr:
            n = live_nodes[int(rng.integers(0, len(live_nodes)))]
            b.set_node_attr(n, ATTR_NAMES[int(rng.integers(0, n_attrs))],
                            float(np.round(rng.random(), 3)), t)
        elif r < p_node + p_attr + p_transient and len(live_nodes) >= 2:
            u, v = rng.choice(len(live_nodes), 2, replace=False)
            b.transient_edge(live_nodes[u], live_nodes[v], t)
        elif live_edges and r < p_node + p_attr + p_transient + p_delete:
            j = int(rng.integers(0, len(live_edges)))
            slot = live_edges.pop(j)
            b.delete_edge_slot(slot, t)
        elif len(live_nodes) >= 2:
            u, v = rng.choice(len(live_nodes), 2, replace=False)
            live_edges.append(b.add_edge(live_nodes[u], live_nodes[v], t,
                                         edge_id=("e", emitted)))
        else:
            continue
        emitted += 1
    return b.finalize()
