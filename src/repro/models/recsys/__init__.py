from .din import DINConfig, din_forward, din_loss, din_param_defs  # noqa: F401
from .embedding import embedding_bag  # noqa: F401
