"""EmbeddingBag and friends — built from take + segment_sum per the
assignment (JAX has no native EmbeddingBag / CSR sparse).

The lookup is the recsys hot path: tables are sharded row-wise over the
'model' mesh axis (the paper's node-ID-space partitioner, reused), lookups
lower to gathers + segment reductions that XLA SPMD turns into
all-to-all-free per-shard gathers when indices are replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag(table: jnp.ndarray, indices: jnp.ndarray,
                  offsets: jnp.ndarray | None = None,
                  per_sample_weights: jnp.ndarray | None = None,
                  mode: str = "sum") -> jnp.ndarray:
    """torch.nn.EmbeddingBag semantics.

    * ``indices [M]`` flat indices; ``offsets [B]`` bag starts (first
      element must be 0) — or ``indices [B, L]`` with no offsets (fixed-
      size bags, padding id < 0 skipped).
    """
    if offsets is None:
        idx = indices
        valid = idx >= 0
        emb = jnp.take(table, jnp.where(valid, idx, 0), axis=0)
        emb = emb * valid[..., None]
        if per_sample_weights is not None:
            emb = emb * per_sample_weights[..., None]
        s = emb.sum(axis=-2)
        if mode == "sum":
            return s
        if mode == "mean":
            return s / jnp.maximum(valid.sum(-1, keepdims=True), 1)
        if mode == "max":
            neg = jnp.where(valid[..., None], emb, -jnp.inf)
            return neg.max(axis=-2)
        raise ValueError(mode)
    # ragged bags: segment ids from offsets
    M = indices.shape[0]
    B = offsets.shape[0]
    seg = jnp.cumsum(jnp.zeros(M, jnp.int32).at[offsets[1:]].add(1))
    emb = jnp.take(table, indices, axis=0)
    if per_sample_weights is not None:
        emb = emb * per_sample_weights[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(emb, seg, num_segments=B)
    if mode == "mean":
        s = jax.ops.segment_sum(emb, seg, num_segments=B)
        cnt = jax.ops.segment_sum(jnp.ones(M), seg, num_segments=B)
        return s / jnp.maximum(cnt, 1)[:, None]
    if mode == "max":
        return jax.ops.segment_max(emb, seg, num_segments=B)
    raise ValueError(mode)


def hash_embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Hash-trick lookup for open vocabularies: id → row via splitmix."""
    x = ids.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return jnp.take(table, (x % table.shape[0]).astype(jnp.int32), axis=0)
