"""DIN — Deep Interest Network [arXiv:1706.06978].

Exact assigned config: embed_dim=18, seq_len=100, target-attention MLP
80-40, output MLP 200-80, interaction = target attention over the user
behaviour sequence.  Tables (goods / category) are the hot path: row-
sharded over the 'model' mesh axis; lookups are ``jnp.take`` +
``segment_sum`` (see ``embedding.py``).

Shapes:
* ``train_batch``     batch=65,536 training step (binary CTR loss)
* ``serve_p99``       batch=512 online scoring
* ``serve_bulk``      batch=262,144 offline scoring
* ``retrieval_cand``  one user × 1,000,000 candidates — a single batched
  matmul of the user interest vector against candidate embeddings, NOT a
  loop.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..common import ParamDef
from .embedding import embedding_bag


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: tuple[int, ...] = (80, 40)
    out_mlp: tuple[int, ...] = (200, 80)
    n_goods: int = 10_000_000
    n_cates: int = 100_000
    kind: str = "din"

    @property
    def d_item(self) -> int:
        return 2 * self.embed_dim  # goods ⊕ category (paper's concat)


def din_param_defs(cfg: DINConfig) -> dict:
    d = cfg.d_item
    tree: dict = {
        "goods_emb": ParamDef((cfg.n_goods, cfg.embed_dim),
                              ("table_rows", None), jnp.float32),
        "cate_emb": ParamDef((cfg.n_cates, cfg.embed_dim),
                             ("table_rows", None), jnp.float32),
    }
    # target-attention MLP over [hist, target, hist-target, hist*target]
    dims = [4 * d] + list(cfg.attn_mlp) + [1]
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        tree[f"attn_w{i}"] = ParamDef((a, b), (None, None), jnp.float32)
        tree[f"attn_b{i}"] = ParamDef((b,), (None,), jnp.float32, "zeros")
    # output MLP over [user_interest, target, user_interest*target]
    dims = [3 * d] + list(cfg.out_mlp) + [1]
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        tree[f"out_w{i}"] = ParamDef((a, b), (None, None), jnp.float32)
        tree[f"out_b{i}"] = ParamDef((b,), (None,), jnp.float32, "zeros")
    return tree


def _mlp(p, name, x, n, act):
    for i in range(n):
        x = x @ p[f"{name}_w{i}"] + p[f"{name}_b{i}"]
        if i < n - 1:
            x = act(x)
    return x


def _item_embed(p, cfg, goods_ids, cate_ids):
    g = jnp.take(p["goods_emb"], goods_ids, axis=0)
    c = jnp.take(p["cate_emb"], cate_ids, axis=0)
    return jnp.concatenate([g, c], axis=-1)


def _interest(p, cfg: DINConfig, hist, hist_mask, target):
    """Target attention: weight history items by relevance to the target.
    hist [B, S, d]; target [B, d] → interest [B, d]."""
    B, S, d = hist.shape
    tgt = jnp.broadcast_to(target[:, None, :], hist.shape)
    feat = jnp.concatenate([hist, tgt, hist - tgt, hist * tgt], axis=-1)
    n_attn = len(cfg.attn_mlp) + 1
    scores = _mlp(p, "attn", feat, n_attn, jax.nn.sigmoid)[..., 0]  # [B, S]
    scores = jnp.where(hist_mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bs,bsd->bd", w, hist)


def din_forward(p, batch, cfg: DINConfig):
    """batch: hist_goods/hist_cates [B, S], hist_mask [B, S],
    target_goods/target_cates [B] → CTR logit [B]."""
    hist = _item_embed(p, cfg, batch["hist_goods"], batch["hist_cates"])
    target = _item_embed(p, cfg, batch["target_goods"], batch["target_cates"])
    interest = _interest(p, cfg, hist, batch["hist_mask"], target)
    x = jnp.concatenate([interest, target, interest * target], axis=-1)
    n_out = len(cfg.out_mlp) + 1
    return _mlp(p, "out", x, n_out, jax.nn.relu)[..., 0]


def din_retrieval(p, batch, cfg: DINConfig):
    """Score one user against N candidates with a single matmul: the user
    interest vector is computed once (against a mean-pooled pseudo-target)
    and dotted with every candidate embedding."""
    hist = _item_embed(p, cfg, batch["hist_goods"], batch["hist_cates"])
    mask = batch["hist_mask"]
    pseudo = embedding_bag(p["goods_emb"],
                           jnp.where(mask, batch["hist_goods"], -1),
                           mode="mean")
    pseudo = jnp.concatenate([
        pseudo, embedding_bag(p["cate_emb"],
                              jnp.where(mask, batch["hist_cates"], -1),
                              mode="mean")], axis=-1)
    interest = _interest(p, cfg, hist, mask, pseudo)        # [B, d]
    cand = _item_embed(p, cfg, batch["cand_goods"], batch["cand_cates"])
    return jnp.einsum("bd,bnd->bn", interest, cand)          # [B, N]


def din_loss(p, batch, cfg: DINConfig):
    logits = din_forward(p, batch, cfg)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss, {"loss": loss}
