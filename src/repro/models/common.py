"""Shared model substrate: parameter trees with logical sharding axes,
norms, rotary embeddings, activation helpers.

Parameters are declared once as :class:`ParamDef` trees carrying *logical*
axis names ('embed', 'heads', 'mlp', 'experts', 'vocab', 'layers', ...).
From one tree we derive (a) ShapeDtypeStructs for the multi-pod dry-run
(no allocation), (b) NamedShardings via per-config logical→mesh rules
(MaxText-style), (c) real initialized arrays for reduced-config smoke
tests.  No flax — pure pytrees of jnp arrays.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]        # logical axis per dim
    dtype: Any = jnp.bfloat16
    init: str = "normal"                # normal | zeros | ones | scaled

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Tree = dict[str, Any]  # nested dict of ParamDef


def tree_map_defs(fn: Callable[[ParamDef], Any], tree: Tree) -> Tree:
    out = {}
    for k, v in tree.items():
        out[k] = fn(v) if isinstance(v, ParamDef) else tree_map_defs(fn, v)
    return out


def abstract_params(tree: Tree) -> Tree:
    return tree_map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), tree)


def logical_to_spec(axes: tuple[str | None, ...],
                    rules: dict[str, Any]) -> P:
    return P(*[rules.get(a) if a is not None else None for a in axes])


def param_shardings(tree: Tree, rules: dict[str, Any], mesh: Mesh) -> Tree:
    return tree_map_defs(
        lambda d: NamedSharding(mesh, logical_to_spec(d.axes, rules)), tree)


def param_pspecs(tree: Tree, rules: dict[str, Any]) -> Tree:
    return tree_map_defs(lambda d: logical_to_spec(d.axes, rules), tree)


def init_params(tree: Tree, key: jax.Array) -> Tree:
    flat: list[tuple[str, ParamDef]] = []

    def walk(t, prefix):
        for k, v in t.items():
            if isinstance(v, ParamDef):
                flat.append((prefix + k, v))
            else:
                walk(v, prefix + k + "/")
    walk(tree, "")
    keys = jax.random.split(key, max(len(flat), 1))
    vals: dict[str, jnp.ndarray] = {}
    for (name, d), kk in zip(flat, keys):
        if d.init == "zeros":
            v = jnp.zeros(d.shape, d.dtype)
        elif d.init == "ones":
            v = jnp.ones(d.shape, d.dtype)
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
            v = (jax.random.normal(kk, d.shape, jnp.float32) * scale).astype(d.dtype)
        vals[name] = v

    def rebuild(t, prefix):
        out = {}
        for k, v in t.items():
            out[k] = vals[prefix + k] if isinstance(v, ParamDef) else rebuild(v, prefix + k + "/")
        return out
    return rebuild(tree, "")


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6,
            plus_one: bool = False) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (y * scale).astype(x.dtype)


def rope_freqs(head_dim: int, theta) -> jnp.ndarray:
    i = jnp.arange(0, head_dim, 2, dtype=jnp.float32)
    return 1.0 / (jnp.asarray(theta, jnp.float32) ** (i / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta) -> jnp.ndarray:
    """x: [..., S, D]; positions: [S] (or broadcastable).  theta may be a
    traced scalar (per-layer RoPE bases under scan)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                       # [D/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [S, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return rot.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray, inner_spec=None) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g) * u
    if inner_spec is not None:
        from jax.sharding import PartitionSpec as _P
        h = jax.lax.with_sharding_constraint(h, _P(*inner_spec))
    return jnp.einsum("...f,fd->...d", h, w_down)


def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """CE via a one-hot mask-sum rather than take_along_axis: the gather
    forces GSPMD to replicate the (huge, model-sharded) vocab dimension,
    while `where(iota == target)` stays elementwise → shard-local partial
    sums + one tiny all-reduce.  (Hillclimb #1, EXPERIMENTS.md §Perf.)"""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.where(vocab_iota == targets[..., None], logits, 0.0).sum(-1)
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
