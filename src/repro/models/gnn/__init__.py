from .models import (DimeNetConfig, GCNConfig, GINConfig,  # noqa: F401
                     MeshGraphNetConfig, gnn_forward, gnn_loss, gnn_param_defs)
