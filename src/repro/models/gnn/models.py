"""The four assigned GNN architectures, on segment-op message passing.

JAX has no CSR SpMM; message passing is gather (``x[src]``) → transform →
``jax.ops.segment_sum`` scatter over ``edge_index`` — per the assignment,
this IS the system (the Pallas `segment_sum` kernel is the TPU fast path
for the same contract).

* **gcn-cora**       [arXiv:1609.02907]  2 layers, d=16, symmetric norm.
* **gin-tu**         [arXiv:1810.00826]  5 layers, d=64, sum agg,
  learnable ε, graph-level readout for batched molecule graphs.
* **meshgraphnet**   [arXiv:2010.03409]  encode-process-decode, 15 MP
  steps, d=128, 2-layer MLPs, edge+node features, sum aggregation.
* **dimenet**        [arXiv:2003.03123]  directional message passing:
  radial Bessel + spherical basis over (kj → ji) edge-triplets, 6 blocks,
  d=128, 8 bilinear — the triplet-gather kernel regime.

Every config shares the batch contract: node features ``x [N, F]``,
``edge_index [2, E]`` (src, dst), optional per-graph ids for readout,
padding masks for static shapes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..common import ParamDef, cross_entropy


def seg_sum(data, ids, n):
    return jax.ops.segment_sum(data, ids, num_segments=n)


def _cg_impl(x, idx, n_chunks: int, out_spec):
    N, D = x.shape
    C = -(-N // n_chunks)
    Npad = C * n_chunks
    if Npad != N:
        x = jnp.pad(x, ((0, Npad - N), (0, 0)))

    def step(acc, c):
        chunk = jax.lax.dynamic_slice_in_dim(x, c * C, C)
        local = idx - c * C
        hit = (local >= 0) & (local < C)
        vals = jnp.take(chunk, jnp.clip(local, 0, C - 1), axis=0)
        if out_spec:
            vals = _c(vals, out_spec)
        return acc + jnp.where(hit[:, None], vals, 0), None

    acc0 = jnp.zeros((idx.shape[0], D), x.dtype)
    if out_spec:
        acc0 = _c(acc0, out_spec)
    acc, _ = jax.lax.scan(step, acc0, jnp.arange(n_chunks))
    return acc


def _css_impl(data, ids, num_segments: int, n_chunks: int, out_spec):
    C = -(-num_segments // n_chunks)

    def step(_, c):
        local = ids - c * C
        hit = (local >= 0) & (local < C)
        part = jax.ops.segment_sum(jnp.where(hit[:, None], data, 0),
                                   jnp.clip(local, 0, C - 1),
                                   num_segments=C)
        return None, part

    _, parts = jax.lax.scan(step, None, jnp.arange(n_chunks))
    out = parts.reshape(n_chunks * C, data.shape[1])[:num_segments]
    if out_spec:
        out = _c(out, out_spec)
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def chunked_gather(x, idx, n_chunks: int, out_spec=None, x_spec=None):
    """Gather ``x[idx]`` without materializing the full (sharded) operand:
    scan over operand chunks; each step all-gathers one |x|/n_chunks slice,
    selects hits, accumulates.  custom_vjp — backward is the adjoint
    :func:`chunked_segment_sum`, so *no per-chunk scan residuals* are saved
    (plain gathers kept 30+ full-node all-gathers live → 56-92 GB/device on
    meshgraphnet×ogb_products; EXPERIMENTS.md §Perf)."""
    return _cg_impl(x, idx, n_chunks, out_spec)


def _cg_fwd(x, idx, n_chunks, out_spec, x_spec):
    return _cg_impl(x, idx, n_chunks, out_spec), (x.shape[0], idx)


def _cg_bwd(n_chunks, out_spec, x_spec, res, g):
    N, idx = res
    dx = _css_impl(g, idx, N, n_chunks, x_spec)
    return dx, np.zeros(idx.shape, jax.dtypes.float0)


chunked_gather.defvjp(_cg_fwd, _cg_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def chunked_segment_sum(data, ids, num_segments: int, n_chunks: int,
                        out_spec=None):
    """segment_sum in destination chunks (adjoint of chunked_gather)."""
    return _css_impl(data, ids, num_segments, n_chunks, out_spec)


def _css_fwd(data, ids, num_segments, n_chunks, out_spec):
    return _css_impl(data, ids, num_segments, n_chunks, out_spec), ids


def _css_bwd(num_segments, n_chunks, out_spec, res, g):
    ids = res
    dd = _cg_impl(g, ids, n_chunks, None)
    return dd, np.zeros(ids.shape, jax.dtypes.float0)


chunked_segment_sum.defvjp(_css_fwd, _css_bwd)


def _gather(x, idx, n_chunks, spec, x_spec=None):
    if n_chunks and n_chunks > 1:
        return chunked_gather(x, idx, n_chunks, spec, x_spec)
    return _c(x[idx], spec)


def _c(x, spec):
    """Optional sharding constraint; spec names the first-dim mesh axes
    (() = explicitly replicated).  Gather/scatter chains otherwise let
    GSPMD replicate the (huge) edge tensors — measured 722 GB/device on
    dimenet minibatch_lg (baseline dry-run; EXPERIMENTS.md §Perf)."""
    if spec is None:
        return x
    if spec == ():
        return jax.lax.with_sharding_constraint(
            x, P(*([None] * x.ndim)))
    return jax.lax.with_sharding_constraint(
        x, P(spec, *([None] * (x.ndim - 1))))


def _mlp_defs(name: str, dims: list[int], dt=jnp.float32) -> dict:
    out = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        out[f"{name}_w{i}"] = ParamDef((a, b), (None, None), dt)
        out[f"{name}_b{i}"] = ParamDef((b,), (None,), dt, "zeros")
    return out


def _mlp(p, name: str, x, n_layers: int, act=jax.nn.relu, norm: bool = False):
    for i in range(n_layers):
        x = x @ p[f"{name}_w{i}"] + p[f"{name}_b{i}"]
        if i < n_layers - 1:
            x = act(x)
    if norm:
        mu = x.mean(-1, keepdims=True)
        var = x.var(-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + 1e-6)
    return x


# ---------------------------------------------------------------------------
# GCN
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn-cora"
    n_layers: int = 2
    d_hidden: int = 16
    d_in: int = 1433
    n_classes: int = 7
    kind: str = "gcn"
    node_spec: tuple | None = None
    edge_spec: tuple | None = None
    gather_chunks: int = 0


def _gcn_defs(cfg: GCNConfig) -> dict:
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    out = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        out[f"w{i}"] = ParamDef((a, b), (None, None), jnp.float32)
        out[f"b{i}"] = ParamDef((b,), (None,), jnp.float32, "zeros")
    return out


def _gcn_forward(p, batch, cfg: GCNConfig):
    x = batch["x"]
    src, dst = batch["edge_index"]
    N = x.shape[0]
    emask = batch.get("edge_mask")
    # edge_index carries both directions for undirected graphs; degree is
    # in-degree at dst (+1 for the implicit self loop, Kipf & Welling eq. 2)
    ones = jnp.ones(src.shape, jnp.float32)
    if emask is not None:
        ones = ones * emask
    deg = seg_sum(ones, dst, N) + 1.0
    norm = jax.lax.rsqrt(deg)
    for i in range(cfg.n_layers):
        h = x @ p[f"w{i}"]
        m = _gather(h, src, cfg.gather_chunks, cfg.edge_spec) \
            * norm[src, None]
        if emask is not None:
            m = m * emask[:, None]
        agg = _c(seg_sum(m, dst, N), cfg.node_spec) * norm[:, None] \
            + h * norm[:, None] ** 2
        x = _c(agg + p[f"b{i}"], cfg.node_spec)
        if i < cfg.n_layers - 1:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# GIN
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 16
    n_classes: int = 2
    mlp_layers: int = 2
    kind: str = "gin"
    node_spec: tuple | None = None
    edge_spec: tuple | None = None
    gather_chunks: int = 0


def _gin_defs(cfg: GINConfig) -> dict:
    out = {"eps": ParamDef((cfg.n_layers,), (None,), jnp.float32, "zeros")}
    d_prev = cfg.d_in
    for l in range(cfg.n_layers):
        out.update(_mlp_defs(f"mlp{l}", [d_prev] + [cfg.d_hidden] * cfg.mlp_layers))
        d_prev = cfg.d_hidden
    out.update(_mlp_defs("readout", [cfg.d_hidden, cfg.n_classes]))
    return out


def _gin_forward(p, batch, cfg: GINConfig):
    x = batch["x"]
    src, dst = batch["edge_index"]
    N = x.shape[0]
    emask = batch.get("edge_mask")
    for l in range(cfg.n_layers):
        m = _gather(x, src, cfg.gather_chunks, cfg.edge_spec)
        if emask is not None:
            m = m * emask[:, None]
        agg = _c(seg_sum(m, dst, N), cfg.node_spec)
        x = _mlp(p, f"mlp{l}", (1.0 + p["eps"][l]) * x + agg,
                 cfg.mlp_layers, norm=True)
        x = _c(jax.nn.relu(x), cfg.node_spec)
    if "graph_ids" in batch:  # graph-level readout (molecule batches)
        G = batch["n_graphs"]
        nm = batch.get("node_mask")
        xm = x if nm is None else x * nm[:, None]
        pooled = seg_sum(xm, batch["graph_ids"], G)
        return _mlp(p, "readout", pooled, 1)
    return _mlp(p, "readout", x, 1)


# ---------------------------------------------------------------------------
# MeshGraphNet
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshGraphNetConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_node_in: int = 8
    d_edge_in: int = 4
    d_out: int = 3
    kind: str = "meshgraphnet"
    node_spec: tuple | None = None
    edge_spec: tuple | None = None
    gather_chunks: int = 0
    act_dtype: Any = jnp.float32


def _mgn_defs(cfg: MeshGraphNetConfig) -> dict:
    h, m = cfg.d_hidden, cfg.mlp_layers
    out = {}
    out.update(_mlp_defs("enc_node", [cfg.d_node_in] + [h] * m))
    out.update(_mlp_defs("enc_edge", [cfg.d_edge_in] + [h] * m))
    for l in range(cfg.n_layers):
        out.update(_mlp_defs(f"edge{l}", [3 * h] + [h] * m))
        out.update(_mlp_defs(f"node{l}", [2 * h] + [h] * m))
    out.update(_mlp_defs("dec", [h] * m + [cfg.d_out]))
    return out


def _mgn_forward(p, batch, cfg: MeshGraphNetConfig):
    src, dst = batch["edge_index"]
    N = batch["x"].shape[0]
    m = cfg.mlp_layers
    h_n = _c(_mlp(p, "enc_node", batch["x"], m, norm=True),
             cfg.node_spec).astype(cfg.act_dtype)
    h_e = _c(_mlp(p, "enc_edge", batch["edge_attr"], m, norm=True),
             cfg.edge_spec).astype(cfg.act_dtype)
    def mp_layer(l, h_n, h_e):
        e_in = jnp.concatenate(
            [h_e, _gather(h_n, src, cfg.gather_chunks, cfg.edge_spec),
             _gather(h_n, dst, cfg.gather_chunks, cfg.edge_spec)], axis=-1)
        h_e = _c(h_e + _mlp(p, f"edge{l}", e_in, m, norm=True),
                 cfg.edge_spec)
        if cfg.gather_chunks:
            agg = chunked_segment_sum(h_e, dst, N, cfg.gather_chunks,
                                      cfg.node_spec)
        else:
            agg = _c(seg_sum(h_e, dst, N), cfg.node_spec)
        n_in = jnp.concatenate([h_n, agg], axis=-1)
        h_n = _c(h_n + _mlp(p, f"node{l}", n_in, m, norm=True),
                 cfg.node_spec)
        return h_n, h_e

    # remat per message-passing layer: the full-node gather operands are
    # recomputed in backward instead of 15 layers' residuals living at once
    for l in range(cfg.n_layers):
        h_n, h_e = jax.checkpoint(mp_layer, static_argnums=(0,))(l, h_n, h_e)
        h_n = h_n.astype(cfg.act_dtype)
        h_e = h_e.astype(cfg.act_dtype)
    return _mlp(p, "dec", h_n.astype(jnp.float32), m)


# ---------------------------------------------------------------------------
# DimeNet
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    d_out: int = 1
    kind: str = "dimenet"
    node_spec: tuple | None = None
    edge_spec: tuple | None = None
    gather_chunks: int = 0
    act_dtype: Any = jnp.float32


def _dimenet_defs(cfg: DimeNetConfig) -> dict:
    h = cfg.d_hidden
    out = {
        "emb_z": ParamDef((95, h), (None, None), jnp.float32),
        "rbf_w": ParamDef((cfg.n_radial, h), (None, None), jnp.float32),
        "sbf_w": ParamDef((cfg.n_spherical * cfg.n_radial, cfg.n_bilinear),
                          (None, None), jnp.float32),
    }
    out.update(_mlp_defs("edge_emb", [3 * h, h]))
    for b in range(cfg.n_blocks):
        out[f"bil{b}"] = ParamDef((h, cfg.n_bilinear, h), (None, None, None),
                                  jnp.float32)
        out.update(_mlp_defs(f"msg{b}", [h, h, h]))
        out.update(_mlp_defs(f"upd{b}", [h, h]))
        out.update(_mlp_defs(f"out{b}", [h, h]))
    out.update(_mlp_defs("head", [h, h, cfg.d_out]))
    return out


def _bessel_rbf(d, n_radial, cutoff):
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    dc = jnp.clip(d / cutoff, 1e-6, 1.0)
    return jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * dc[..., None]) / (
        d[..., None] + 1e-6)


def _angular_sbf(angle, d, n_spherical, n_radial, cutoff):
    ls = jnp.arange(n_spherical, dtype=jnp.float32)
    cosl = jnp.cos(angle[..., None] * (ls + 1.0))          # simplified basis
    rad = _bessel_rbf(d, n_radial, cutoff)                 # [T, n_radial]
    return (cosl[..., :, None] * rad[..., None, :]).reshape(
        angle.shape[0], n_spherical * n_radial)


def _dimenet_forward(p, batch, cfg: DimeNetConfig):
    """batch: z [N] atom types, pos [N, 3], edge_index [2, E],
    triplets (t_kj, t_ji) indices into edges with k→j→i wedges,
    graph_ids [N] for energy readout."""
    z, pos = batch["z"], batch["pos"]
    src, dst = batch["edge_index"]
    t_kj, t_ji = batch["triplet_kj"], batch["triplet_ji"]
    N, E = z.shape[0], src.shape[0]
    vec = pos[dst] - pos[src]
    dist = jnp.linalg.norm(vec + 1e-9, axis=-1)
    rbf = _bessel_rbf(dist, cfg.n_radial, cfg.cutoff)      # [E, R]
    h_z = p["emb_z"][z]
    m = jnp.concatenate([_c(h_z[src], cfg.edge_spec),
                         _c(h_z[dst], cfg.edge_spec),
                         rbf @ p["rbf_w"]], axis=-1)
    m = _c(jax.nn.silu(_mlp(p, "edge_emb", m, 1)),
           cfg.edge_spec).astype(cfg.act_dtype)  # [E, h]
    # triplet geometry: angle between edge ji and edge kj at vertex j
    v1 = vec[t_ji]
    v2 = -vec[t_kj]
    cosang = (v1 * v2).sum(-1) / (
        jnp.linalg.norm(v1 + 1e-9, axis=-1) * jnp.linalg.norm(v2 + 1e-9, -1))
    angle = jnp.arccos(jnp.clip(cosang, -1 + 1e-6, 1 - 1e-6))
    sbf = _angular_sbf(angle, dist[t_kj], cfg.n_spherical, cfg.n_radial,
                       cfg.cutoff)                          # [T, S*R]
    out_energy = 0.0
    G = batch.get("n_graphs", 1)
    gids = batch.get("graph_ids", jnp.zeros(N, jnp.int32))
    tspec = cfg.edge_spec  # triplets partitioned like edges

    def block(b, m, out_energy):
        mk = _c(jax.nn.silu(_mlp(p, f"msg{b}", m, 2)), cfg.edge_spec)
        w = _c(sbf @ p["sbf_w"], tspec)                     # [T, n_bilinear]
        inter = _c(jnp.einsum("th,hbk,tb->tk",
                              _gather(mk, t_kj, cfg.gather_chunks, tspec),
                              p[f"bil{b}"], w), tspec)
        if cfg.gather_chunks:
            agg = chunked_segment_sum(inter, t_ji, E, cfg.gather_chunks,
                                      cfg.edge_spec)
        else:
            agg = _c(seg_sum(inter, t_ji, E), cfg.edge_spec)
        m = _c(m + jax.nn.silu(_mlp(p, f"upd{b}", agg, 1)), cfg.edge_spec)
        mo = jax.nn.silu(_mlp(p, f"out{b}", m, 1))
        if cfg.gather_chunks:
            node_out = chunked_segment_sum(mo, dst, N, cfg.gather_chunks,
                                           cfg.node_spec)
        else:
            node_out = _c(seg_sum(mo, dst, N), cfg.node_spec)
        return m, out_energy + seg_sum(node_out, gids, G)

    for b in range(cfg.n_blocks):
        m, out_energy = jax.checkpoint(block, static_argnums=(0,))(
            b, m, out_energy)
        m = m.astype(cfg.act_dtype)
    return _mlp(p, "head", out_energy, 2)                   # [G, d_out]


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

GNNConfig = Any

_DEFS = {"gcn": _gcn_defs, "gin": _gin_defs, "meshgraphnet": _mgn_defs,
         "dimenet": _dimenet_defs}
_FWD = {"gcn": _gcn_forward, "gin": _gin_forward, "meshgraphnet": _mgn_forward,
        "dimenet": _dimenet_forward}


def gnn_param_defs(cfg: GNNConfig) -> dict:
    return _DEFS[cfg.kind](cfg)


def gnn_forward(params, batch, cfg: GNNConfig):
    return _FWD[cfg.kind](params, batch, cfg)


def gnn_loss(params, batch, cfg: GNNConfig):
    out = gnn_forward(params, batch, cfg)
    if cfg.kind in ("gcn", "gin"):
        labels = batch["labels"]
        mask = batch.get("label_mask")
        loss = cross_entropy(out, labels, mask)
        return loss, {"loss": loss}
    target = batch["target"]
    mask = batch.get("node_mask")
    err = (out - target) ** 2
    if mask is not None and err.shape[0] == mask.shape[0]:
        loss = (err * mask[:, None]).sum() / jnp.maximum(mask.sum() * err.shape[-1], 1)
    else:
        loss = err.mean()
    return loss, {"loss": loss}
