"""Config-driven LM transformer covering the five assigned architectures.

One parameterized block family expresses:

* llama-style GQA + RoPE + RMSNorm + SwiGLU      (yi-34b, stablelm-12b)
* 5:1 local:global sliding-window + RoPE-base switch + 262k tied vocab
  + logit softcap                                 (gemma3-1b)
* MLA (latent-compressed KV) + shared+routed fine-grained MoE with
  sigmoid aux-free routing + MTP                  (deepseek-v3-671b)
* dense-FFN ∥ 128-expert top-2 MoE hybrid         (arctic-480b)

Layers are grouped into homogeneous *layer groups* (dense prefix vs MoE
rest, etc.); each group is a single ``lax.scan`` over stacked params with
``jax.checkpoint`` remat — compile time and HLO size stay flat in depth.
Per-layer window sizes / RoPE bases ride along as scanned arrays, so the
gemma3 local/global pattern lives inside one scan.

Attention is the chunked online-softmax from ``repro.kernels``
(``impl='xla'`` for lowering/roofline; the Pallas kernel is the TPU path).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ...kernels.flash_attention.ops import attention
from ..common import (ParamDef, apply_rope, cross_entropy, rmsnorm, softcap,
                      swiglu)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router: str = "softmax"          # 'softmax' | 'sigmoid_aux_free'
    n_groups: int = 16               # dispatch groups (≡ data-axis shards)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    rope_theta: float = 1e4
    rope_theta_global: float | None = None   # gemma3 global layers
    norm_eps: float = 1e-6
    rmsnorm_plus_one: bool = False
    embed_scale: bool = False                # gemma multiplies by sqrt(d)
    tied_embeddings: bool = False
    logit_softcap: float | None = None
    window: int | None = None                # sliding window (local layers)
    local_global_pattern: int | None = None  # N local per 1 global
    moe: MoEConfig | None = None
    n_dense_layers: int = 0                  # leading dense layers (deepseek)
    moe_dense_parallel: bool = False         # arctic: dense ∥ MoE every layer
    mla: MLAConfig | None = None
    mtp: bool = False                        # deepseek multi-token prediction
    dtype: Any = jnp.bfloat16
    remat: bool = True
    # logical activation sharding (batch, seq, embed) — sequence parallelism
    # for the scan carry; None = no constraint (smoke tests)
    act_spec: tuple | None = None

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_groups(self) -> list[tuple[str, int]]:
        """Homogeneous (kind, count) groups scanned together."""
        if self.moe is None:
            return [("dense", self.n_layers)]
        if self.moe_dense_parallel:
            return [("hybrid", self.n_layers)]
        groups = []
        if self.n_dense_layers:
            groups.append(("dense", self.n_dense_layers))
        groups.append(("moe", self.n_layers - self.n_dense_layers))
        return groups

    def layer_meta(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        """(window, rope_theta) per layer — the scanned per-layer statics."""
        windows, thetas = [], []
        for i in range(self.n_layers):
            is_global = (self.local_global_pattern is None or
                         (i + 1) % (self.local_global_pattern + 1) == 0)
            if self.window is not None and not is_global:
                windows.append(self.window)
                thetas.append(self.rope_theta)
            else:
                windows.append(1 << 30)
                thetas.append(self.rope_theta_global or self.rope_theta)
        return (jnp.asarray(windows, jnp.int32),
                jnp.asarray(thetas, jnp.float32))


# ---------------------------------------------------------------------------
# parameter declaration
# ---------------------------------------------------------------------------

def _attn_defs(cfg: TransformerConfig, L: int) -> dict:
    dt = cfg.dtype
    d = cfg.d_model
    if cfg.mla is not None:
        m = cfg.mla
        qk = m.qk_nope + m.qk_rope
        return {
            "wq_a": ParamDef((L, d, m.q_lora), ("layers", "embed", None), dt),
            "q_norm": ParamDef((L, m.q_lora), ("layers", None), dt, "ones"),
            "wq_b": ParamDef((L, m.q_lora, cfg.n_heads * qk),
                             ("layers", None, "heads"), dt),
            "wkv_a": ParamDef((L, d, m.kv_lora + m.qk_rope),
                              ("layers", "embed", None), dt),
            "kv_norm": ParamDef((L, m.kv_lora), ("layers", None), dt, "ones"),
            "wkv_b": ParamDef((L, m.kv_lora, cfg.n_heads * (m.qk_nope + m.v_dim)),
                              ("layers", None, "heads"), dt),
            "wo": ParamDef((L, cfg.n_heads * m.v_dim, d),
                           ("layers", "heads", "embed"), dt),
        }
    return {
        "wq": ParamDef((L, d, cfg.q_dim), ("layers", "embed", "heads"), dt),
        "wk": ParamDef((L, d, cfg.kv_dim), ("layers", "embed", "kv"), dt),
        "wv": ParamDef((L, d, cfg.kv_dim), ("layers", "embed", "kv"), dt),
        "wo": ParamDef((L, cfg.q_dim, d), ("layers", "heads", "embed"), dt),
    }


def _ffn_defs(cfg: TransformerConfig, L: int, kind: str) -> dict:
    dt = cfg.dtype
    d = cfg.d_model
    out: dict = {}
    if kind in ("dense", "hybrid"):
        out.update({
            "w_gate": ParamDef((L, d, cfg.d_ff), ("layers", "embed", "mlp"), dt),
            "w_up": ParamDef((L, d, cfg.d_ff), ("layers", "embed", "mlp"), dt),
            "w_down": ParamDef((L, cfg.d_ff, d), ("layers", "mlp", "embed"), dt),
        })
    if kind in ("moe", "hybrid"):
        moe = cfg.moe
        E, de = moe.n_experts, moe.d_expert
        out.update({
            "router": ParamDef((L, d, E), ("layers", "embed", None),
                               jnp.float32),
            "e_gate": ParamDef((L, E, d, de), ("layers", "experts", "embed", None), dt),
            "e_up": ParamDef((L, E, d, de), ("layers", "experts", "embed", None), dt),
            "e_down": ParamDef((L, E, de, d), ("layers", "experts", None, "embed"), dt),
        })
        if moe.router == "sigmoid_aux_free":
            out["router_bias"] = ParamDef((L, E), ("layers", None),
                                          jnp.float32, "zeros")
        if moe.n_shared:
            ds = de * moe.n_shared
            out.update({
                "s_gate": ParamDef((L, d, ds), ("layers", "embed", "mlp"), dt),
                "s_up": ParamDef((L, d, ds), ("layers", "embed", "mlp"), dt),
                "s_down": ParamDef((L, ds, d), ("layers", "mlp", "embed"), dt),
            })
    return out


def param_defs(cfg: TransformerConfig) -> dict:
    dt = cfg.dtype
    d = cfg.d_model
    tree: dict = {
        "embed": ParamDef((cfg.vocab, d), ("vocab", "embed"), dt),
        "final_norm": ParamDef((d,), (None,), dt, "ones"),
    }
    if not cfg.tied_embeddings:
        tree["lm_head"] = ParamDef((d, cfg.vocab), ("embed", "vocab"), dt)
    for gi, (kind, L) in enumerate(cfg.layer_groups()):
        g = {"attn_norm": ParamDef((L, d), ("layers", None), dt, "ones"),
             "ffn_norm": ParamDef((L, d), ("layers", None), dt, "ones")}
        g.update(_attn_defs(cfg, L))
        g.update(_ffn_defs(cfg, L, kind))
        tree[f"group{gi}"] = g
    if cfg.mtp:
        g = {"attn_norm": ParamDef((1, d), ("layers", None), dt, "ones"),
             "ffn_norm": ParamDef((1, d), ("layers", None), dt, "ones"),
             "mtp_proj": ParamDef((1, 2 * d, d), ("layers", "embed", None), dt)}
        g.update(_attn_defs(cfg, 1))
        g.update(_ffn_defs(cfg, 1, "dense" if cfg.moe is None else "moe"))
        tree["mtp"] = g
    return tree


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _gqa_attention(p, x, cfg: TransformerConfig, positions, window, theta,
                   cache_kv=None, attn_impl: str = "xla"):
    B, S, d = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(B, S, Hkv, Dh)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(B, S, Hkv, Dh)
    q = apply_rope(q.transpose(0, 2, 1, 3), positions, theta)
    k = apply_rope(k.transpose(0, 2, 1, 3), positions, theta)
    v = v.transpose(0, 2, 1, 3)
    if cache_kv is not None:
        ck, cv, cache_len = cache_kv
        k = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, cache_len, 0))
        v = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, cache_len, 0))
        q_offset = cache_len
    else:
        q_offset = 0
    o = attention(q, k, v, causal=True, window=window, q_offset=q_offset,
                  impl=attn_impl)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * Dh)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    return out, (k, v)


def _mla_attention(p, x, cfg: TransformerConfig, positions, window, theta,
                   cache_kv=None, attn_impl: str = "xla"):
    """DeepSeek MLA: queries from a low-rank latent; K/V from a 512-dim
    compressed latent + a shared 64-dim RoPE key.  The cache is the latent
    — 576 B/token/layer.

    Two paths:

    * **prefill/train** — materialize per-head K/V from the latent (dense
      matmuls amortize over the whole sequence);
    * **decode (absorbed)** — the famous MLA absorption: fold ``W_uk`` into
      the query and ``W_uv`` into the output so attention runs *in latent
      space* against the cache directly.  Reconstructing K/V per step is
      O(S·H·(dk+dv)) = 17 GB/device at 32k context (measured, baseline
      dry-run); absorbed it is O(S·(c+rope)) — ~64× less.
    """
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.n_heads
    cq = rmsnorm(jnp.einsum("bsd,dq->bsq", x, p["wq_a"]), p["q_norm"],
                 cfg.norm_eps)
    q = jnp.einsum("bsq,qh->bsh", cq, p["wq_b"]).reshape(
        B, S, H, m.qk_nope + m.qk_rope)
    q_nope, q_pe = q[..., :m.qk_nope], q[..., m.qk_nope:]
    kv_a = jnp.einsum("bsd,dk->bsk", x, p["wkv_a"])
    c_kv_new = rmsnorm(kv_a[..., :m.kv_lora], p["kv_norm"], cfg.norm_eps)
    k_pe_new = kv_a[..., m.kv_lora:]                       # [B, S, rope]
    q_pe = apply_rope(q_pe.transpose(0, 2, 1, 3), positions, theta)
    scale = (m.qk_nope + m.qk_rope) ** -0.5

    if cache_kv is not None:
        # ---------------- absorbed decode path ----------------
        cc, ckpe, cache_len = cache_kv
        c_kv = jax.lax.dynamic_update_slice(cc, c_kv_new.astype(cc.dtype),
                                            (0, cache_len, 0))
        k_pe_lat = jax.lax.dynamic_update_slice(
            ckpe, k_pe_new.astype(ckpe.dtype), (0, cache_len, 0))
        Sk = c_kv.shape[1]
        kv_pos = jnp.arange(Sk)
        k_pe = apply_rope(k_pe_lat[:, None, :, :], kv_pos, theta)[:, 0]
        # W_uk per head: wkv_b[:, h*(nope+v) : ...nope] — absorb into q
        wkv = p["wkv_b"].reshape(m.kv_lora, H, m.qk_nope + m.v_dim)
        w_uk = wkv[:, :, : m.qk_nope]                      # [c, H, dk]
        w_uv = wkv[:, :, m.qk_nope:]                       # [c, H, dv]
        q_lat = jnp.einsum("bshk,chk->bhsc", q_nope, w_uk) # latent queries
        # attention in latent space: keys = [c_kv ; k_pe], dim c+rope
        q_cat = jnp.concatenate([q_lat, q_pe], axis=-1)    # [B,H,S,c+rope]
        k_cat = jnp.concatenate([c_kv, k_pe], axis=-1)[:, None]  # [B,1,Sk,·]
        o_lat = attention(q_cat, k_cat, c_kv[:, None], causal=True,
                          window=window, q_offset=cache_len, scale=scale,
                          impl=attn_impl)                  # [B,H,S,c]
        o = jnp.einsum("bhsc,chv->bshv", o_lat, w_uv).reshape(
            B, S, H * m.v_dim)
        out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
        return out, (c_kv, k_pe_lat)

    # ---------------- prefill / train path ----------------
    c_kv = c_kv_new
    Sk = c_kv.shape[1]
    kv = jnp.einsum("bsk,kh->bsh", c_kv, p["wkv_b"]).reshape(
        B, Sk, H, m.qk_nope + m.v_dim)
    k_nope, v = kv[..., :m.qk_nope], kv[..., m.qk_nope:]
    kv_pos = jnp.arange(Sk)
    k_pe = apply_rope(k_pe_new[:, None, :, :], kv_pos, theta)  # [B,1,Sk,r]
    qh = jnp.concatenate([q_nope.transpose(0, 2, 1, 3), q_pe], axis=-1)
    kh = jnp.concatenate([k_nope.transpose(0, 2, 1, 3),
                          jnp.broadcast_to(k_pe, (B, H, Sk, m.qk_rope))], -1)
    vh = v.transpose(0, 2, 1, 3)
    o = attention(qh, kh, vh, causal=True, window=window, q_offset=0,
                  scale=scale, impl=attn_impl)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * m.v_dim)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    return out, (c_kv, k_pe_new)


def _dispatch_group(xf_g, ids_g, w_g, E, K, C):
    """One dispatch group: sort assignments by expert, slot = rank within
    expert, drop beyond capacity, scatter to [E, C, d] buffers.  vmapped
    over groups so every scatter/gather carries an explicit batch dim that
    GSPMD shards (broadcast `gidx` fancy-indexing defeated its partitioner
    — 112 GB/device replicas; EXPERIMENTS.md §Perf)."""
    T, d = xf_g.shape
    flat_e = ids_g.reshape(T * K)
    flat_w = w_g.reshape(T * K)
    order = jnp.argsort(flat_e)
    se = flat_e[order]
    counts = jnp.bincount(se, length=E)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    slot = jnp.arange(T * K) - starts[se]
    keep = slot < C
    tok = order // K
    slot_c = jnp.where(keep, slot, 0).astype(jnp.int32)
    src = jnp.where(keep[:, None], xf_g[tok], 0)
    buf = jnp.zeros((E, C, d), xf_g.dtype).at[se, slot_c].add(src)
    comb_w = jnp.where(keep, flat_w[order], 0.0)
    return buf, se, slot_c, tok, comb_w


def _combine_group(h_g, se, slot_c, tok, comb_w, T):
    back = h_g[se, slot_c] * comb_w[:, None].astype(h_g.dtype)
    return jnp.zeros((T, h_g.shape[-1]), h_g.dtype).at[tok].add(back)


def _moe_ffn(p, x, cfg: TransformerConfig):
    """Grouped top-k MoE: vmapped sort-based dispatch (GShard grouping) →
    batched expert GEMMs (E sharded over 'model') → vmapped combine."""
    moe = cfg.moe
    B, S, d = x.shape
    E, K = moe.n_experts, moe.top_k
    G = moe.n_groups if B % max(moe.n_groups, 1) == 0 else 1
    T = (B // G) * S                                    # tokens per group
    bax = cfg.act_spec[0] if cfg.act_spec is not None else None

    def gc(t, *rest):  # constrain dim0 = groups to the batch axis
        if bax is None:
            return t
        return jax.lax.with_sharding_constraint(
            t, P(bax, *rest, *([None] * (t.ndim - 1 - len(rest)))))

    xf = gc(x.reshape(G, T, d))
    logits = jnp.einsum("gtd,de->gte", xf, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    logits = gc(logits)
    if moe.router == "sigmoid_aux_free":
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["router_bias"][None, None, :]
        _, ids = jax.lax.top_k(sel, K)                  # bias only routes
        w = jnp.take_along_axis(scores, ids, axis=-1)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    else:
        _, ids = jax.lax.top_k(logits, K)
        w = jax.nn.softmax(jnp.take_along_axis(logits, ids, axis=-1), -1)

    C = int(math.ceil(T * K * moe.capacity_factor / E))
    buf, se, slot_c, tok, comb_w = jax.vmap(
        functools.partial(_dispatch_group, E=E, K=K, C=C))(xf, ids, w)
    buf = gc(buf, "model")
    g = gc(jnp.einsum("gecd,edf->gecf", buf, p["e_gate"]), "model")
    u = gc(jnp.einsum("gecd,edf->gecf", buf, p["e_up"]), "model")
    h = gc(jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u, p["e_down"]),
           "model")
    out = gc(jax.vmap(functools.partial(_combine_group, T=T))(
        h, se, slot_c, tok, comb_w))
    me = jax.nn.softmax(logits, -1).mean((0, 1))
    ce = jnp.bincount(ids.reshape(-1), length=E) / (G * T * K)
    aux = E * jnp.sum(me * ce)
    out = out.reshape(B, S, d)
    if moe.n_shared:
        sh_spec = ((cfg.act_spec[0], None, "model")
                   if cfg.act_spec is not None else None)
        out = out + swiglu(x, p["s_gate"], p["s_up"], p["s_down"], sh_spec)
    return out, aux


def _layer(kind: str, cfg: TransformerConfig, attn_impl: str):
    attn_fn = _mla_attention if cfg.mla is not None else _gqa_attention

    def layer(x, p, positions, window, theta, cache_kv=None):
        h, new_kv = attn_fn(p, rmsnorm(x, p["attn_norm"], cfg.norm_eps,
                                       cfg.rmsnorm_plus_one),
                            cfg, positions, window, theta, cache_kv,
                            attn_impl)
        x = x + h
        y = rmsnorm(x, p["ffn_norm"], cfg.norm_eps, cfg.rmsnorm_plus_one)
        ff_spec = ((cfg.act_spec[0], None, "model")
                   if cfg.act_spec is not None else None)
        aux = 0.0
        if kind == "dense":
            f = swiglu(y, p["w_gate"], p["w_up"], p["w_down"], ff_spec)
        elif kind == "moe":
            f, aux = _moe_ffn(p, y, cfg)
        else:  # hybrid: dense residual FFN ∥ MoE (arctic)
            f1 = swiglu(y, p["w_gate"], p["w_up"], p["w_down"], ff_spec)
            f2, aux = _moe_ffn(p, y, cfg)
            f = f1 + f2
        return x + f, aux, new_kv

    return layer


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _scan_group(kind, cfg, params_g, x, positions, windows, thetas,
                cache_g=None, cache_len=None, attn_impl="xla",
                return_cache=False):
    layer = _layer(kind, cfg, attn_impl)

    def body(carry, xs):
        x, aux = carry
        if cache_g is not None:
            p, w, th, ck, cv = xs
            x2, a, new_kv = layer(x, p, positions, w, th, (ck, cv, cache_len))
        else:
            p, w, th = xs
            x2, a, new_kv = layer(x, p, positions, w, th, None)
        if cfg.act_spec is not None:
            x2 = jax.lax.with_sharding_constraint(x2, P(*cfg.act_spec))
        ys = new_kv if (return_cache or cache_g is not None) else None
        return (x2, aux + a), ys

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = (params_g, windows, thetas)
    if cache_g is not None:
        xs = xs + tuple(cache_g)
    (x, aux), ys = jax.lax.scan(body, (x, 0.0), xs)
    return x, aux, ys


def forward(params, tokens, cfg: TransformerConfig, *, attn_impl="xla",
            return_cache=False, cache=None, cache_len=None,
            positions=None):
    """tokens [B, S] → logits [B, S, V] (+ aux loss, + per-group caches)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    if positions is None:
        positions = jnp.arange(S)
    windows, thetas = cfg.layer_meta()
    aux_total = 0.0
    caches_out = []
    off = 0
    for gi, (kind, L) in enumerate(cfg.layer_groups()):
        g = params[f"group{gi}"]
        w_g, t_g = windows[off:off + L], thetas[off:off + L]
        cache_g = None if cache is None else cache[gi]
        x, aux, ys = _scan_group(kind, cfg, g, x, positions, w_g, t_g,
                                 cache_g, cache_len, attn_impl, return_cache)
        aux_total = aux_total + aux
        caches_out.append(ys)
        off += L
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps, cfg.rmsnorm_plus_one)
    head = (params["embed"].T if cfg.tied_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.dtype))
    if cfg.act_spec is not None:
        logits = jax.lax.with_sharding_constraint(
            logits, P(cfg.act_spec[0], None, "model"))
    logits = softcap(logits, cfg.logit_softcap)
    caches = caches_out if (return_cache or cache is not None) else None
    return logits, aux_total, caches, x


def loss_fn(params, batch, cfg: TransformerConfig, attn_impl="xla"):
    tokens = batch["tokens"]
    logits, aux, _, hidden = forward(params, tokens, cfg, attn_impl=attn_impl)
    loss = cross_entropy(logits[:, :-1], tokens[:, 1:])
    mtp_loss = 0.0
    if cfg.mtp:
        # DeepSeek-V3 MTP depth 1: combine hidden(t) with embed(t+1), run
        # one extra block, predict token t+2 through the shared head
        g = params["mtp"]
        emb_next = params["embed"][tokens[:, 1:]].astype(cfg.dtype)
        h = jnp.concatenate([hidden[:, :-1], emb_next], axis=-1)
        h = jnp.einsum("bsd,dk->bsk", h, g["mtp_proj"][0])
        kind = "dense" if cfg.moe is None else "moe"
        layer = _layer(kind, cfg, attn_impl)
        S1 = h.shape[1]
        p1 = jax.tree.map(lambda a: a[0], {k: v for k, v in g.items()
                                           if k != "mtp_proj"})
        windows, thetas = cfg.layer_meta()
        h, mtp_aux, _ = layer(h, p1, jnp.arange(S1), windows[-1], thetas[-1])
        head = (params["embed"].T if cfg.tied_embeddings else params["lm_head"])
        mtp_logits = softcap(jnp.einsum("bsd,dv->bsv", h, head.astype(cfg.dtype)),
                             cfg.logit_softcap)
        mtp_loss = cross_entropy(mtp_logits[:, :-1], tokens[:, 2:])
        aux = aux + mtp_aux
    total = loss + 0.01 * aux + 0.3 * mtp_loss
    return total, {"loss": loss, "aux": aux, "mtp": mtp_loss}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_len: int):
    """Per-group KV caches.  GQA: (k, v) [L, B, Hkv, Smax, Dh]; MLA:
    (c_kv, k_pe) latents."""
    caches = []
    for kind, L in cfg.layer_groups():
        if cfg.mla is not None:
            m = cfg.mla
            caches.append((
                jnp.zeros((L, batch, max_len, m.kv_lora), cfg.dtype),
                jnp.zeros((L, batch, max_len, m.qk_rope), cfg.dtype)))
        else:
            shape = (L, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
            caches.append((jnp.zeros(shape, cfg.dtype),
                           jnp.zeros(shape, cfg.dtype)))
    return caches


def cache_specs(cfg: TransformerConfig, batch: int, max_len: int):
    return _abstract_cache(cfg, batch, max_len)


def _abstract_cache(cfg, batch, max_len):
    caches = []
    for kind, L in cfg.layer_groups():
        if cfg.mla is not None:
            m = cfg.mla
            caches.append((
                jax.ShapeDtypeStruct((L, batch, max_len, m.kv_lora), cfg.dtype),
                jax.ShapeDtypeStruct((L, batch, max_len, m.qk_rope), cfg.dtype)))
        else:
            s = (L, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
            caches.append((jax.ShapeDtypeStruct(s, cfg.dtype),
                           jax.ShapeDtypeStruct(s, cfg.dtype)))
    return caches


def prefill_step(params, tokens, cfg: TransformerConfig, attn_impl="xla"):
    """Prefill: forward + return caches (stacked per group) + last logits."""
    logits, _, caches, _ = forward(params, tokens, cfg, attn_impl=attn_impl,
                                   return_cache=True)
    return logits[:, -1], caches


def decode_step(params, cache, tokens, cache_len, cfg: TransformerConfig,
                attn_impl="xla"):
    """One decode step: tokens [B, 1] against caches filled to cache_len."""
    positions = cache_len + jnp.arange(tokens.shape[1])  # absolute positions
    logits, _, new_cache, _ = forward(params, tokens, cfg,
                                      attn_impl=attn_impl, cache=cache,
                                      cache_len=cache_len,
                                      positions=positions)
    return logits[:, -1], new_cache
