from .model import (MLAConfig, MoEConfig, TransformerConfig,  # noqa: F401
                    decode_step, forward, init_cache, param_defs,
                    prefill_step)
