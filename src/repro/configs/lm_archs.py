"""The five assigned LM architectures — exact configs from the assignment.

``optimizer`` notes: adamw (fp32 master + moments) for ≤34B; adafactor for
the 480B/671B MoEs — Adam with fp32 state on 256×16 GB v5e is
arithmetically impossible for 671B params (9.4 TB of state vs 4 TB of pod
HBM); see DESIGN.md §5 and EXPERIMENTS.md.
"""
from __future__ import annotations

from ..models.transformer import MLAConfig, MoEConfig, TransformerConfig

# yi-34b [arXiv:2403.04652]: llama-arch GQA, 60L d=7168 56H kv=8 ff=20480
YI_34B = TransformerConfig(
    name="yi-34b", n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    head_dim=128, d_ff=20480, vocab=64000, rope_theta=5e6, norm_eps=1e-5)

# stablelm-12b [hf:stabilityai/stablelm-2-12b]: 40L d=5120 32H kv=8 ff=13824
STABLELM_12B = TransformerConfig(
    name="stablelm-12b", n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    head_dim=160, d_ff=13824, vocab=100352, rope_theta=1e4, norm_eps=1e-5)

# gemma3-1b [hf:google/gemma-3-1b-pt]: 26L d=1152 4H kv=1, 5:1 local:global
# (window 512), dual RoPE bases, tied 262k vocab, sqrt(d) embed scale
GEMMA3_1B = TransformerConfig(
    name="gemma3-1b", n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    head_dim=256, d_ff=6912, vocab=262144, rope_theta=1e4,
    rope_theta_global=1e6, window=512, local_global_pattern=5,
    tied_embeddings=True, embed_scale=True, rmsnorm_plus_one=True,
    logit_softcap=30.0)

# deepseek-v3-671b [arXiv:2412.19437]: MLA, 61L d=7168 128H, 3 dense layers
# then 1 shared + 256 routed experts (d_ff=2048) top-8, sigmoid aux-free
# router, MTP, vocab 129280
DEEPSEEK_V3_671B = TransformerConfig(
    name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
    n_kv_heads=128, head_dim=128, d_ff=18432, vocab=129280,
    rope_theta=1e4, n_dense_layers=3, mtp=True,
    mla=MLAConfig(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64,
                  v_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                  capacity_factor=1.25, router="sigmoid_aux_free"))

# arctic-480b [hf:Snowflake/snowflake-arctic-base]: 35L d=7168 56H kv=8,
# dense residual FFN (d_ff=4864 per assignment) ∥ 128-expert top-2 MoE
ARCTIC_480B = TransformerConfig(
    name="arctic-480b", n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    head_dim=128, d_ff=4864, vocab=32000, rope_theta=1e4,
    moe_dense_parallel=True,
    moe=MoEConfig(n_experts=128, top_k=2, d_expert=4864,
                  capacity_factor=1.25, router="softmax"))

LM_ARCHS = {
    "yi-34b": (YI_34B, "adamw"),
    "stablelm-12b": (STABLELM_12B, "adamw"),
    "gemma3-1b": (GEMMA3_1B, "adamw"),
    "deepseek-v3-671b": (DEEPSEEK_V3_671B, "adafactor"),
    "arctic-480b": (ARCTIC_480B, "adafactor"),
}

# long_500k applicability (DESIGN.md §4): needs a sub-quadratic/compressed
# KV path. gemma3 (5:1 sliding window) and deepseek (MLA latent cache) run;
# pure full-attention GQA archs skip.
LONG_CONTEXT_OK = {"gemma3-1b", "deepseek-v3-671b"}

# gradient-accumulation microbatching for train_4k — sized so the big-vocab
# CE logits + scan-saved activations fit 16 GB/device (measured via the
# dry-run memory analysis; see EXPERIMENTS.md §Dry-run)
TRAIN_ACCUM = {"gemma3-1b": 4, "deepseek-v3-671b": 8, "arctic-480b": 4,
               "yi-34b": 2, "stablelm-12b": 2}


def reduced_lm(cfg: TransformerConfig) -> TransformerConfig:
    """Smoke-test scale: same family/topology, tiny dims."""
    import dataclasses
    moe = cfg.moe
    if moe is not None:
        # capacity_factor large enough that no token ever drops — keeps the
        # prefill/decode consistency check exact at smoke scale
        moe = dataclasses.replace(moe, n_experts=4,
                                  top_k=min(moe.top_k, 2), d_expert=32,
                                  capacity_factor=8.0)
    mla = cfg.mla
    if mla is not None:
        mla = MLAConfig(q_lora=32, kv_lora=16, qk_nope=8, qk_rope=8, v_dim=8)
    return dataclasses.replace(
        cfg, n_layers=4 if cfg.n_dense_layers == 0 else 5,
        n_dense_layers=min(cfg.n_dense_layers, 1),
        d_model=64, n_heads=4, n_kv_heads=max(1, cfg.n_kv_heads // 14),
        head_dim=16, d_ff=128, vocab=256, window=cfg.window and 8,
        moe=moe, mla=mla)
