"""Assigned input shapes per architecture family (verbatim from the
assignment), plus padded static sizes for the GNN regimes."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # 'train' | 'prefill' | 'decode'


LM_SHAPES = {
    "train_4k": LMShape("train_4k", 4096, 256, "train"),
    "prefill_32k": LMShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": LMShape("decode_32k", 32768, 128, "decode"),
    "long_500k": LMShape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    n_nodes: int
    n_edges: int
    d_feat: int
    kind: str                      # 'full' | 'sampled' | 'batched'
    n_graphs: int = 1
    batch_nodes: int = 0
    fanouts: tuple[int, ...] = ()
    n_classes: int = 7

    def padded(self) -> tuple[int, int]:
        """Static (N, E) rounded to multiples of 512 for even sharding."""
        rnd = lambda v: -(-v // 512) * 512
        return rnd(self.n_nodes), rnd(self.n_edges)


GNN_SHAPES = {
    "full_graph_sm": GNNShape("full_graph_sm", 2_708, 10_556, 1_433, "full",
                              n_classes=7),
    # reddit-scale sampled training: fanout (15, 10) from 1,024 seeds
    "minibatch_lg": GNNShape("minibatch_lg", 232_965, 114_615_892, 602,
                             "sampled", batch_nodes=1_024, fanouts=(15, 10),
                             n_classes=41),
    "ogb_products": GNNShape("ogb_products", 2_449_029, 61_859_140, 100,
                             "full", n_classes=47),
    "molecule": GNNShape("molecule", 30, 64, 16, "batched", n_graphs=128,
                         n_classes=2),
}


@dataclasses.dataclass(frozen=True)
class RecSysShape:
    name: str
    batch: int
    kind: str                      # 'train' | 'serve' | 'retrieval'
    n_candidates: int = 0


RECSYS_SHAPES = {
    "train_batch": RecSysShape("train_batch", 65_536, "train"),
    "serve_p99": RecSysShape("serve_p99", 512, "serve"),
    "serve_bulk": RecSysShape("serve_bulk", 262_144, "serve"),
    "retrieval_cand": RecSysShape("retrieval_cand", 1, "retrieval",
                                  n_candidates=1_000_000),
}
