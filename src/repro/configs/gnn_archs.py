"""The four assigned GNN architectures + DIN recsys — exact configs."""
from __future__ import annotations

import dataclasses

from ..models.gnn import (DimeNetConfig, GCNConfig, GINConfig,
                          MeshGraphNetConfig)
from ..models.recsys import DINConfig

GCN_CORA = GCNConfig(name="gcn-cora", n_layers=2, d_hidden=16)
GIN_TU = GINConfig(name="gin-tu", n_layers=5, d_hidden=64, mlp_layers=2)
MESHGRAPHNET = MeshGraphNetConfig(name="meshgraphnet", n_layers=15,
                                  d_hidden=128, mlp_layers=2)
DIMENET = DimeNetConfig(name="dimenet", n_blocks=6, d_hidden=128,
                        n_bilinear=8, n_spherical=7, n_radial=6)
DIN = DINConfig(name="din", embed_dim=18, seq_len=100,
                attn_mlp=(80, 40), out_mlp=(200, 80))

GNN_ARCHS = {
    "gcn-cora": (GCN_CORA, "adamw"),
    "gin-tu": (GIN_TU, "adamw"),
    "meshgraphnet": (MESHGRAPHNET, "adamw"),
    "dimenet": (DIMENET, "adamw"),
}

RECSYS_ARCHS = {"din": (DIN, "adamw")}


def reduced_gnn(cfg):
    if isinstance(cfg, GCNConfig):
        return dataclasses.replace(cfg, d_in=12, d_hidden=8, n_classes=3)
    if isinstance(cfg, GINConfig):
        return dataclasses.replace(cfg, n_layers=2, d_hidden=8, d_in=6,
                                   n_classes=2)
    if isinstance(cfg, MeshGraphNetConfig):
        return dataclasses.replace(cfg, n_layers=3, d_hidden=16,
                                   d_node_in=4, d_edge_in=4, d_out=2)
    if isinstance(cfg, DimeNetConfig):
        return dataclasses.replace(cfg, n_blocks=2, d_hidden=16,
                                   n_bilinear=4, n_spherical=3, n_radial=3)
    raise TypeError(cfg)


def reduced_din(cfg: DINConfig) -> DINConfig:
    return dataclasses.replace(cfg, n_goods=1000, n_cates=50, seq_len=12)
