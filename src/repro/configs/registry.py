"""Architecture × shape registry — the glue the launcher, dry-run and
smoke tests share.

``get_cell(arch, shape, mesh, multi_pod)`` returns everything needed to
``jax.jit(fn, in_shardings=...).lower(*args)`` one cell: the step
function, abstract args (ShapeDtypeStruct trees — no allocation), and
PartitionSpec trees derived from each parameter's logical axes through the
per-family rules (MaxText-style logical→mesh indirection).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import common as mc
from ..models.gnn import gnn_loss, gnn_param_defs
from ..models.recsys import DINConfig
from ..models.recsys.din import (din_forward, din_loss, din_param_defs,
                                 din_retrieval)
from ..models.transformer import model as tm
from ..training.optim import OPTIMIZERS
from ..training.trainer import make_train_step
from .gnn_archs import (GNN_ARCHS, RECSYS_ARCHS, reduced_din, reduced_gnn)
from .lm_archs import (LM_ARCHS, LONG_CONTEXT_OK, TRAIN_ACCUM,
                       reduced_lm)
from .shapes import GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES

ARCH_IDS = list(LM_ARCHS) + list(GNN_ARCHS) + list(RECSYS_ARCHS)


def family_of(arch_id: str) -> str:
    if arch_id in LM_ARCHS:
        return "lm"
    if arch_id in GNN_ARCHS:
        return "gnn"
    if arch_id in RECSYS_ARCHS:
        return "recsys"
    raise KeyError(arch_id)


def shapes_for(arch_id: str) -> list[str]:
    return list({"lm": LM_SHAPES, "gnn": GNN_SHAPES,
                 "recsys": RECSYS_SHAPES}[family_of(arch_id)])


def get_arch(arch_id: str):
    fam = family_of(arch_id)
    table = {"lm": LM_ARCHS, "gnn": GNN_ARCHS, "recsys": RECSYS_ARCHS}[fam]
    return table[arch_id]


def reduced_config(arch_id: str):
    cfg, _ = get_arch(arch_id)
    fam = family_of(arch_id)
    if fam == "lm":
        return reduced_lm(cfg)
    if fam == "gnn":
        return reduced_gnn(cfg)
    return reduced_din(cfg)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def mesh_rules(mesh: Mesh, multi_pod: bool) -> dict[str, Any]:
    return {
        "vocab": "model", "heads": "model", "kv": "model", "mlp": "model",
        "experts": "model", "embed": "data", "table_rows": "model",
        "layers": None,
        "batch": ("pod", "data") if multi_pod else ("data",),
        "nodes": ("data", "model"), "edges": ("data", "model"),
    }


def _divides(shape: tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop mesh axes that don't divide the dimension evenly."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = math.prod(mesh.shape[a] for a in axes)
        out.append(ax if dim % n == 0 else None)
    return P(*out)


def _param_pspecs(defs: dict, rules: dict, mesh: Mesh):
    return mc.tree_map_defs(
        lambda d: _divides(d.shape, mc.logical_to_spec(d.axes, rules), mesh),
        defs)


def _opt_pspecs(defs: dict, opt_name: str, rules: dict, mesh: Mesh):
    """Optimizer-state PartitionSpecs derived from the ParamDef axes."""
    def pspec(d: mc.ParamDef) -> P:
        return _divides(d.shape, mc.logical_to_spec(d.axes, rules), mesh)

    if opt_name == "adamw":
        per = mc.tree_map_defs(pspec, defs)
        return {"step": P(), "m": per, "v": per, "master": per}
    if opt_name == "adafactor":
        def fac(d: mc.ParamDef):
            if len(d.shape) >= 2:
                return {"vr": _divides(d.shape[:-1],
                                       mc.logical_to_spec(d.axes[:-1], rules),
                                       mesh),
                        "vc": _divides(d.shape[:-2] + d.shape[-1:],
                                       mc.logical_to_spec(
                                           d.axes[:-2] + d.axes[-1:], rules),
                                       mesh)}
            return {"v": pspec(d)}
        return {"step": P(), "stats": mc.tree_map_defs(fac, defs)}
    if opt_name == "sgd":
        return {"step": P(), "mom": mc.tree_map_defs(pspec, defs)}
    raise KeyError(opt_name)


def _abstract_opt_state(opt_name: str, params_abs):
    init, _ = OPTIMIZERS[opt_name]()
    return jax.eval_shape(init, params_abs)


def ds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# cells
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    step_kind: str
    fn: Callable | None
    args: tuple | None
    pspecs: tuple | None
    skip_reason: str | None = None
    flops_model: float = 0.0          # MODEL_FLOPS (6·N_active·D etc.)
    n_params: float = 0.0
    n_params_active: float = 0.0


def _count_params(defs: dict) -> float:
    total = 0.0
    def walk(t):
        nonlocal total
        for v in t.values():
            if isinstance(v, mc.ParamDef):
                total += float(np.prod(v.shape))
            else:
                walk(v)
    walk(defs)
    return total


def _lm_active_params(cfg: tm.TransformerConfig) -> float:
    """Per-token active params (MoE: top-k + shared experts only)."""
    defs = tm.param_defs(cfg)
    total = _count_params(defs)
    if cfg.moe is None:
        return total
    moe = cfg.moe
    expert_full = 0.0
    for gi, (kind, L) in enumerate(cfg.layer_groups()):
        if kind in ("moe", "hybrid"):
            expert_full += L * moe.n_experts * 3 * cfg.d_model * moe.d_expert
    if cfg.mtp:  # the MTP block's experts are routed top-k as well
        expert_full += moe.n_experts * 3 * cfg.d_model * moe.d_expert
    active_frac = moe.top_k / moe.n_experts
    return total - expert_full * (1.0 - active_frac)


def _lm_attn_flops(cfg: tm.TransformerConfig, B: int, S: int,
                   kind: str) -> float:
    """Forward attention FLOPs (QKᵀ + AV), causal-halved, window-aware.
    MLA uses its per-head qk/v dims (prefill path; the absorbed decode path
    is strictly cheaper)."""
    if cfg.mla is not None:
        dqk, dv = cfg.mla.qk_nope + cfg.mla.qk_rope, cfg.mla.v_dim
    else:
        dqk = dv = cfg.head_dim
    H = cfg.n_heads
    total = 0.0
    for i in range(cfg.n_layers):
        is_global = (cfg.local_global_pattern is None or
                     (i + 1) % (cfg.local_global_pattern + 1) == 0)
        if kind == "decode":
            span = S if (is_global or cfg.window is None) else min(cfg.window, S)
            total += 2.0 * B * H * span * (dqk + dv)
        else:
            span = (S / 2 if (is_global or cfg.window is None)
                    else min(cfg.window, S))
            total += 2.0 * B * S * span * H * (dqk + dv)
    return total


def _lm_cell(arch_id: str, shape_id: str, mesh: Mesh, multi_pod: bool) -> Cell:
    cfg, opt_name = LM_ARCHS[arch_id]
    shape = LM_SHAPES[shape_id]
    if shape_id == "long_500k" and arch_id not in LONG_CONTEXT_OK:
        return Cell(arch_id, shape_id, shape.kind, None, None, None,
                    skip_reason="pure full-attention GQA arch: 500k-token "
                    "decode needs a sub-quadratic/compressed-KV path "
                    "(DESIGN.md §4)")
    rules = mesh_rules(mesh, multi_pod)
    batch_ax = rules["batch"]
    cfg = dataclasses.replace(cfg, act_spec=(batch_ax, "model", None))
    defs = tm.param_defs(cfg)
    params_abs = mc.abstract_params(defs)
    p_specs = _param_pspecs(defs, rules, mesh)
    B, S = shape.global_batch, shape.seq_len
    n_params = _count_params(defs)
    n_active = _lm_active_params(cfg)

    def bspec(*axes):
        return _divides(tuple(), P(), mesh) if not axes else None

    tok_spec = _divides((B, S), P(batch_ax, None), mesh)

    if shape.kind == "train":
        opt_abs = _abstract_opt_state(opt_name, params_abs)
        o_specs = _opt_pspecs(defs, opt_name, rules, mesh)
        loss = functools.partial(tm.loss_fn, cfg=cfg)
        step = make_train_step(lambda p, b: loss(p, b),
                               OPTIMIZERS[opt_name](),
                               accum_steps=TRAIN_ACCUM.get(arch_id, 1))
        args = (params_abs, opt_abs, {"tokens": ds((B, S), jnp.int32)})
        specs = (p_specs, o_specs, {"tokens": tok_spec})
        # train FLOPs = 6·N_active·tokens + 3× forward attention
        flops = 6.0 * n_active * B * S + 3.0 * _lm_attn_flops(cfg, B, S, "train")
        return Cell(arch_id, shape_id, "train", step, args, specs,
                    flops_model=flops, n_params=n_params,
                    n_params_active=n_active)

    if shape.kind == "prefill":
        fn = functools.partial(tm.prefill_step, cfg=cfg)
        args = (params_abs, ds((B, S), jnp.int32))
        specs = (p_specs, tok_spec)
        flops = 2.0 * n_active * B * S + _lm_attn_flops(cfg, B, S, "prefill")
        return Cell(arch_id, shape_id, "prefill", fn, args, specs,
                    flops_model=flops, n_params=n_params,
                    n_params_active=n_active)

    # decode: one token against a cache of seq_len
    cache_abs = tm.cache_specs(cfg, B, S)
    cache_specs_tree = []
    for kind, L in cfg.layer_groups():
        if cfg.mla is not None:
            cspec = _divides((L, B, S, cfg.mla.kv_lora),
                             P(None, batch_ax, "model", None), mesh)
            kspec = _divides((L, B, S, cfg.mla.qk_rope),
                             P(None, batch_ax, "model", None), mesh)
            cache_specs_tree.append((cspec, kspec))
        else:
            sp = _divides((L, B, cfg.n_kv_heads, S, cfg.head_dim),
                          P(None, batch_ax, None, "model", None), mesh)
            cache_specs_tree.append((sp, sp))
    fn = functools.partial(tm.decode_step, cfg=cfg)
    args = (params_abs, cache_abs, ds((B, 1), jnp.int32), ds((), jnp.int32))
    specs = (p_specs, cache_specs_tree,
             _divides((B, 1), P(batch_ax, None), mesh), P())
    flops = 2.0 * n_active * B + _lm_attn_flops(cfg, B, S, "decode")
    return Cell(arch_id, shape_id, "decode", fn, args, specs,
                flops_model=flops, n_params=n_params, n_params_active=n_active)


def _gnn_batch_abstract(cfg, shape, rules, mesh):
    """Abstract input batch + pspecs per GNN arch kind and shape."""
    kind = cfg.kind
    if shape.kind == "sampled":
        # sampled-training consumes the sampler's padded blocks, NOT the
        # full graph (the full 114M-edge edge list was the baseline bug —
        # 722 GB/device on dimenet; EXPERIMENTS.md §Perf)
        from ..graph.sampler import sampled_shapes
        n_raw, e_raw = sampled_shapes(shape.batch_nodes, list(shape.fanouts))
        rnd = lambda v: -(-v // 512) * 512
        Np, Ep = rnd(n_raw), rnd(e_raw)
    else:
        Np, Ep = shape.padded()
    node_sp = _divides((Np,), P(("data",)), mesh)  # see _gnn_cell
    edge_sp = _divides((Ep,), P(rules["edges"]), mesh)
    node2 = lambda d: _divides((Np, d), P(rules["nodes"], None), mesh)
    edge2 = lambda d: _divides((Ep, d), P(rules["edges"], None), mesh)
    ei_sp = _divides((2, Ep), P(None, rules["edges"]), mesh)

    batch: dict[str, Any] = {"edge_index": ds((2, Ep), jnp.int32),
                             "edge_mask": ds((Ep,), jnp.float32),
                             "node_mask": ds((Np,), jnp.float32)}
    specs: dict[str, Any] = {"edge_index": ei_sp, "edge_mask": edge_sp,
                             "node_mask": node_sp}
    G = shape.n_graphs
    if kind in ("gcn", "gin"):
        batch["x"] = ds((Np, cfg.d_in))
        specs["x"] = node2(cfg.d_in)
        if shape.kind == "batched" and kind == "gin":
            batch.update(graph_ids=ds((Np,), jnp.int32),
                         labels=ds((G,), jnp.int32),
                         label_mask=ds((G,), jnp.float32))
            specs.update(graph_ids=node_sp, labels=P(), label_mask=P())
            batch["n_graphs"] = G
            specs["n_graphs"] = None
        else:
            batch.update(labels=ds((Np,), jnp.int32),
                         label_mask=ds((Np,), jnp.float32))
            specs.update(labels=node_sp, label_mask=node_sp)
    elif kind == "meshgraphnet":
        batch.update(x=ds((Np, cfg.d_node_in)),
                     edge_attr=ds((Ep, cfg.d_edge_in)),
                     target=ds((Np, cfg.d_out)))
        specs.update(x=node2(cfg.d_node_in), edge_attr=edge2(cfg.d_edge_in),
                     target=node2(cfg.d_out))
    elif kind == "dimenet":
        T = 4 * Ep  # triplets capped at 4·E (cutoff-sampled; DESIGN.md)
        t_sp = _divides((T,), P(rules["edges"]), mesh)
        batch.update(z=ds((Np,), jnp.int32), pos=ds((Np, 3)),
                     x=ds((Np, 1)),
                     triplet_kj=ds((T,), jnp.int32),
                     triplet_ji=ds((T,), jnp.int32),
                     graph_ids=ds((Np,), jnp.int32),
                     target=ds((G, cfg.d_out)))
        specs.update(z=node_sp, pos=node2(3), x=node2(1),
                     triplet_kj=t_sp, triplet_ji=t_sp,
                     graph_ids=node_sp, target=P())
        batch["n_graphs"] = G
        specs["n_graphs"] = None
    return batch, specs


def _gnn_cell(arch_id: str, shape_id: str, mesh: Mesh, multi_pod: bool) -> Cell:
    cfg, opt_name = GNN_ARCHS[arch_id]
    shape = GNN_SHAPES[shape_id]
    rules = mesh_rules(mesh, multi_pod)
    # adapt io dims to the dataset shape
    if cfg.kind in ("gcn", "gin"):
        cfg = dataclasses.replace(cfg, d_in=shape.d_feat,
                                  n_classes=shape.n_classes)
    elif cfg.kind == "meshgraphnet":
        cfg = dataclasses.replace(cfg, d_node_in=shape.d_feat)
    # Edge tensors (the big side: |E| ≫ |N|·d) are 256-way sharded; node
    # tensors are sharded on 'data' only: the per-layer remat carries stay
    # 16-way sharded while the gather's transient all-gather is bounded to
    # a couple of live buffers.  (Full replication keeps 15 layers of node
    # state alive → 92 GB/device; 256-way node sharding makes every gather
    # materialize the full tensor *and* pre-remat kept them all → 56-73
    # GB/device.  Iteration log in EXPERIMENTS.md §Perf.)
    big_full = shape.kind == "full" and shape.n_nodes > 100_000
    extra = {}
    if big_full and cfg.kind in ("meshgraphnet", "dimenet"):
        import jax.numpy as _jnp
        extra["act_dtype"] = _jnp.bfloat16   # mixed precision at 62M edges
    cfg = dataclasses.replace(cfg, node_spec=("data",),
                              edge_spec=rules["edges"],
                              gather_chunks=32 if big_full else 0, **extra)
    defs = gnn_param_defs(cfg)
    params_abs = mc.abstract_params(defs)
    p_specs = _param_pspecs(defs, rules, mesh)
    opt_abs = _abstract_opt_state(opt_name, params_abs)
    o_specs = _opt_pspecs(defs, opt_name, rules, mesh)
    batch, b_specs = _gnn_batch_abstract(cfg, shape, rules, mesh)
    static = {k: v for k, v in batch.items() if isinstance(v, int)}

    def loss(p, b):
        return gnn_loss(p, {**b, **static}, cfg)

    step = make_train_step(loss, OPTIMIZERS[opt_name]())
    args = (params_abs, opt_abs,
            {k: v for k, v in batch.items() if not isinstance(v, int)})
    specs = (p_specs, o_specs,
             {k: v for k, v in b_specs.items()
              if not isinstance(batch[k], int)})
    # message passing flops ≈ 2 · E · d_hidden²-ish per layer: report
    # gather+matmul term (per-arch refined in benchmarks/roofline.py)
    Np, Ep = shape.padded()
    depth = getattr(cfg, "n_layers", getattr(cfg, "n_blocks", 1))
    dh = cfg.d_hidden
    flops = 2.0 * depth * (Ep * dh + Np * dh * dh) * 3  # fwd+bwd
    return Cell(arch_id, shape_id, "train", step, args, specs,
                flops_model=flops, n_params=_count_params(defs),
                n_params_active=_count_params(defs))


def _recsys_cell(arch_id: str, shape_id: str, mesh: Mesh,
                 multi_pod: bool) -> Cell:
    cfg, opt_name = RECSYS_ARCHS[arch_id]
    shape = RECSYS_SHAPES[shape_id]
    rules = mesh_rules(mesh, multi_pod)
    batch_ax = rules["batch"]
    defs = din_param_defs(cfg)
    params_abs = mc.abstract_params(defs)
    p_specs = _param_pspecs(defs, rules, mesh)
    B, S = shape.batch, cfg.seq_len
    bsp = lambda *dims: _divides((B,) + dims,
                                 P(batch_ax, *([None] * len(dims))), mesh)
    base = {"hist_goods": ds((B, S), jnp.int32),
            "hist_cates": ds((B, S), jnp.int32),
            "hist_mask": ds((B, S), jnp.bool_)}
    base_sp = {"hist_goods": bsp(S), "hist_cates": bsp(S),
               "hist_mask": bsp(S)}
    n_params = _count_params(defs)
    d = cfg.d_item
    if shape.kind == "train":
        batch = {**base, "target_goods": ds((B,), jnp.int32),
                 "target_cates": ds((B,), jnp.int32),
                 "labels": ds((B,), jnp.int32)}
        specs = {**base_sp, "target_goods": bsp(), "target_cates": bsp(),
                 "labels": bsp()}
        opt_abs = _abstract_opt_state(opt_name, params_abs)
        o_specs = _opt_pspecs(defs, opt_name, rules, mesh)
        step = make_train_step(lambda p, b: din_loss(p, b, cfg),
                               OPTIMIZERS[opt_name]())
        flops = 6.0 * B * (S * 4 * d * (80 + 80 * 40 // (4 * d) + 1)
                           + 3 * d * 200 + 200 * 80)
        return Cell(arch_id, shape_id, "train", step,
                    (params_abs, opt_abs, batch),
                    (p_specs, o_specs, specs), flops_model=flops,
                    n_params=n_params, n_params_active=n_params)
    if shape.kind == "serve":
        batch = {**base, "target_goods": ds((B,), jnp.int32),
                 "target_cates": ds((B,), jnp.int32)}
        specs = {**base_sp, "target_goods": bsp(), "target_cates": bsp()}
        fn = lambda p, b: din_forward(p, b, cfg)
        flops = 2.0 * B * (S * 4 * d * 80 + 3 * d * 200)
        return Cell(arch_id, shape_id, "serve", fn, (params_abs, batch),
                    (p_specs, specs), flops_model=flops,
                    n_params=n_params, n_params_active=n_params)
    # retrieval: 1 user × 1e6 candidates — batched dot, not a loop
    N = shape.n_candidates
    cand_sp = _divides((B, N), P(None, "data"), mesh)
    batch = {**base, "cand_goods": ds((B, N), jnp.int32),
             "cand_cates": ds((B, N), jnp.int32)}
    specs = {**base_sp, "cand_goods": cand_sp, "cand_cates": cand_sp}
    fn = lambda p, b: din_retrieval(p, b, cfg)
    flops = 2.0 * B * N * d
    return Cell(arch_id, shape_id, "retrieval", fn, (params_abs, batch),
                (p_specs, specs), flops_model=flops,
                n_params=n_params, n_params_active=n_params)


def get_cell(arch_id: str, shape_id: str, mesh: Mesh,
             multi_pod: bool = False) -> Cell:
    fam = family_of(arch_id)
    if fam == "lm":
        return _lm_cell(arch_id, shape_id, mesh, multi_pod)
    if fam == "gnn":
        return _gnn_cell(arch_id, shape_id, mesh, multi_pod)
    return _recsys_cell(arch_id, shape_id, mesh, multi_pod)


def list_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in shapes_for(a)]
