from .registry import (ARCH_IDS, get_arch, get_cell, list_cells,  # noqa: F401
                       reduced_config)
