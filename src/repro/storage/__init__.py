from . import columnar, kv  # noqa: F401
