"""Columnar (de)serialization of deltas and eventlists (paper §4.2).

Each delta is split into independently fetchable components so a
structure-only retrieval reads zero attribute bytes (paper fig 8d):

* ``struct``     — node_add / node_del / edge_add / edge_del index arrays
* ``nodeattr``   — (slot, col, new, old) quads
* ``edgeattr``   — (slot, col, new, old) quads

and each leaf-eventlist into:

* ``elist_struct``    — (time, etype, slot) of membership events
* ``elist_nodeattr``  — (time, slot, col, new, old) of UNA events
* ``elist_edgeattr``  — ... of UEA events
* ``elist_transient`` — (time, etype, slot) of transient events

The wire format is a tiny self-describing array bundle (name, dtype, shape,
raw bytes) — no pickling, so any language/storage system could read it.
"""
from __future__ import annotations

import struct as _struct

import numpy as np

from ..core.deltas import AttrDelta, Delta
from ..core.events import (EV_DEL_EDGE, EV_DEL_NODE, EV_NEW_EDGE, EV_NEW_NODE,
                           EV_TRANS_EDGE, EV_TRANS_NODE, EV_UPD_EDGE_ATTR,
                           EV_UPD_NODE_ATTR, EventList)

STRUCT = "struct"
NODEATTR = "nodeattr"
EDGEATTR = "edgeattr"
ELIST_STRUCT = "elist_struct"
ELIST_NODEATTR = "elist_nodeattr"
ELIST_EDGEATTR = "elist_edgeattr"
ELIST_TRANSIENT = "elist_transient"

DELTA_COMPONENTS = (STRUCT, NODEATTR, EDGEATTR)
ELIST_COMPONENTS = (ELIST_STRUCT, ELIST_NODEATTR, ELIST_EDGEATTR, ELIST_TRANSIENT)


# ---------------------------------------------------------------------------
# array-bundle wire format
# ---------------------------------------------------------------------------

def pack_arrays(arrays: dict[str, np.ndarray]) -> bytes:
    out = [_struct.pack("<I", len(arrays))]
    for name, a in arrays.items():
        a = np.ascontiguousarray(a)
        nb = name.encode()
        # dtype.str is '<V2' for ml_dtypes types (bfloat16 &c.) — the name
        # round-trips through np.dtype() once ml_dtypes is imported
        ds = a.dtype.str
        dt = (a.dtype.name if ds.startswith(("<V", "|V", ">V")) else ds).encode()
        out.append(_struct.pack("<I", len(nb)) + nb)
        out.append(_struct.pack("<I", len(dt)) + dt)
        out.append(_struct.pack("<I", a.ndim) + _struct.pack(f"<{a.ndim}q", *a.shape))
        raw = a.tobytes()
        out.append(_struct.pack("<Q", len(raw)) + raw)
    return b"".join(out)


def unpack_arrays(data: bytes) -> dict[str, np.ndarray]:
    pos = 0
    (n,) = _struct.unpack_from("<I", data, pos); pos += 4
    out: dict[str, np.ndarray] = {}
    for _ in range(n):
        (ln,) = _struct.unpack_from("<I", data, pos); pos += 4
        name = data[pos:pos + ln].decode(); pos += ln
        (ld,) = _struct.unpack_from("<I", data, pos); pos += 4
        dt = data[pos:pos + ld].decode(); pos += ld
        (nd,) = _struct.unpack_from("<I", data, pos); pos += 4
        shape = _struct.unpack_from(f"<{nd}q", data, pos); pos += 8 * nd
        (nraw,) = _struct.unpack_from("<Q", data, pos); pos += 8
        a = np.frombuffer(data[pos:pos + nraw], dtype=np.dtype(dt)).reshape(shape)
        pos += nraw
        out[name] = a
    return out


# ---------------------------------------------------------------------------
# delta components
# ---------------------------------------------------------------------------

def encode_delta_struct(d: Delta) -> bytes:
    return pack_arrays({"node_add": d.node_add, "node_del": d.node_del,
                        "edge_add": d.edge_add, "edge_del": d.edge_del})


def decode_delta_struct(b: bytes) -> dict[str, np.ndarray]:
    return unpack_arrays(b)


def encode_attr(a: AttrDelta) -> bytes:
    return pack_arrays({"slot": a.slot, "col": a.col, "new": a.new, "old": a.old})


def decode_attr(b: bytes) -> AttrDelta:
    d = unpack_arrays(b)
    return AttrDelta(d["slot"], d["col"], d["new"], d["old"])


def encode_delta(d: Delta) -> dict[str, bytes]:
    return {STRUCT: encode_delta_struct(d),
            NODEATTR: encode_attr(d.node_attr),
            EDGEATTR: encode_attr(d.edge_attr)}


def decode_delta(parts: dict[str, bytes]) -> Delta:
    s = decode_delta_struct(parts[STRUCT])
    na = decode_attr(parts[NODEATTR]) if NODEATTR in parts else AttrDelta.empty()
    ea = decode_attr(parts[EDGEATTR]) if EDGEATTR in parts else AttrDelta.empty()
    return Delta(s["node_add"], s["node_del"], s["edge_add"], s["edge_del"], na, ea)


# ---------------------------------------------------------------------------
# eventlist components
# ---------------------------------------------------------------------------

def encode_eventlist(ev: EventList) -> dict[str, bytes]:
    et = ev.etype
    m_struct = np.isin(et, (EV_NEW_NODE, EV_DEL_NODE, EV_NEW_EDGE, EV_DEL_EDGE))
    m_na = et == EV_UPD_NODE_ATTR
    m_ea = et == EV_UPD_EDGE_ATTR
    m_tr = np.isin(et, (EV_TRANS_EDGE, EV_TRANS_NODE))
    # `pos` = index within the full leaf-eventlist, so arbitrary prefixes can
    # be replayed per-component without a global merge.
    pos = np.arange(len(ev), dtype=np.int32)

    def sub(mask, with_attr: bool) -> bytes:
        arrays = {"pos": pos[mask], "time": ev.time[mask],
                  "etype": et[mask], "slot": ev.slot[mask]}
        if with_attr:
            arrays.update({"col": ev.attr_col[mask], "new": ev.value[mask],
                           "old": ev.old_value[mask]})
        return pack_arrays(arrays)

    return {ELIST_STRUCT: sub(m_struct, False),
            ELIST_NODEATTR: sub(m_na, True),
            ELIST_EDGEATTR: sub(m_ea, True),
            ELIST_TRANSIENT: sub(m_tr, False)}


def decode_eventlist(parts: dict[str, bytes]) -> dict[str, dict[str, np.ndarray]]:
    return {name: unpack_arrays(b) for name, b in parts.items()}
