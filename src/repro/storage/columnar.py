"""Columnar (de)serialization of deltas and eventlists (paper §4.2).

Each delta is split into independently fetchable components so a
structure-only retrieval reads zero attribute bytes (paper fig 8d):

* ``struct``     — node_add / node_del / edge_add / edge_del index arrays
* ``nodeattr``   — (slot, col, new, old) quads
* ``edgeattr``   — (slot, col, new, old) quads

and each leaf-eventlist into:

* ``elist_struct``    — (time, etype, slot) of membership events
* ``elist_nodeattr``  — (time, slot, col, new, old) of UNA events
* ``elist_edgeattr``  — ... of UEA events
* ``elist_transient`` — (time, etype, slot) of transient events

The wire format is owned by :mod:`repro.storage.codec`: a self-
describing array bundle, by default compressed + checksummed behind a
versioned header (``v2``), with the original raw bundle as the
always-decodable fallback.  ``pack_arrays``/``unpack_arrays`` are the
single (en|de)code chokepoint for every persisted payload — deltas,
eventlists, checkpoints, baselines, the skeleton.
"""
from __future__ import annotations

import numpy as np

from ..core.deltas import AttrDelta, Delta
from ..core.events import (EV_DEL_EDGE, EV_DEL_NODE, EV_NEW_EDGE, EV_NEW_NODE,
                           EV_TRANS_EDGE, EV_TRANS_NODE, EV_UPD_EDGE_ATTR,
                           EV_UPD_NODE_ATTR, EventList)
from . import codec

STRUCT = "struct"
NODEATTR = "nodeattr"
EDGEATTR = "edgeattr"
ELIST_STRUCT = "elist_struct"
ELIST_NODEATTR = "elist_nodeattr"
ELIST_EDGEATTR = "elist_edgeattr"
ELIST_TRANSIENT = "elist_transient"

DELTA_COMPONENTS = (STRUCT, NODEATTR, EDGEATTR)
ELIST_COMPONENTS = (ELIST_STRUCT, ELIST_NODEATTR, ELIST_EDGEATTR, ELIST_TRANSIENT)


# ---------------------------------------------------------------------------
# array-bundle wire format (delegates to the codec layer)
# ---------------------------------------------------------------------------

def pack_arrays(arrays: dict[str, np.ndarray]) -> bytes:
    """Encode an array bundle with the session's default codec
    (:func:`repro.storage.codec.get_default_codec`)."""
    return codec.encode_blob(arrays)


def unpack_arrays(data: bytes) -> dict[str, np.ndarray]:
    """Decode any blob ever written — v2 by magic sniff, raw fallback.
    Raises :class:`repro.storage.codec.CodecError` on corrupt input."""
    return codec.decode_blob(data)


def logical_nbytes(arrays: dict[str, np.ndarray]) -> int:
    """Decoded (in-memory) size of a bundle — the codec-independent half
    of the planner's stored-vs-logical cost split."""
    return int(sum(int(a.nbytes) for a in arrays.values()))


# ---------------------------------------------------------------------------
# delta components
# ---------------------------------------------------------------------------

def encode_delta_struct(d: Delta) -> bytes:
    return pack_arrays({"node_add": d.node_add, "node_del": d.node_del,
                        "edge_add": d.edge_add, "edge_del": d.edge_del})


def decode_delta_struct(b: bytes) -> dict[str, np.ndarray]:
    return unpack_arrays(b)


def encode_attr(a: AttrDelta) -> bytes:
    return pack_arrays({"slot": a.slot, "col": a.col, "new": a.new, "old": a.old})


def decode_attr(b: bytes) -> AttrDelta:
    d = unpack_arrays(b)
    return AttrDelta(d["slot"], d["col"], d["new"], d["old"])


def encode_delta(d: Delta) -> dict[str, bytes]:
    return {STRUCT: encode_delta_struct(d),
            NODEATTR: encode_attr(d.node_attr),
            EDGEATTR: encode_attr(d.edge_attr)}


def decode_delta(parts: dict[str, bytes]) -> Delta:
    s = decode_delta_struct(parts[STRUCT])
    na = decode_attr(parts[NODEATTR]) if NODEATTR in parts else AttrDelta.empty()
    ea = decode_attr(parts[EDGEATTR]) if EDGEATTR in parts else AttrDelta.empty()
    return Delta(s["node_add"], s["node_del"], s["edge_add"], s["edge_del"], na, ea)


# ---------------------------------------------------------------------------
# eventlist components
# ---------------------------------------------------------------------------

def eventlist_components(ev: EventList) -> dict[str, dict[str, np.ndarray]]:
    """Split a leaf-eventlist into its columnar component *arrays* (the
    pre-encode form: callers that re-key per attribute column slice these
    directly instead of decoding a just-encoded blob)."""
    et = ev.etype
    m_struct = np.isin(et, (EV_NEW_NODE, EV_DEL_NODE, EV_NEW_EDGE, EV_DEL_EDGE))
    m_na = et == EV_UPD_NODE_ATTR
    m_ea = et == EV_UPD_EDGE_ATTR
    m_tr = np.isin(et, (EV_TRANS_EDGE, EV_TRANS_NODE))
    # `pos` = index within the full leaf-eventlist, so arbitrary prefixes can
    # be replayed per-component without a global merge.
    pos = np.arange(len(ev), dtype=np.int32)

    def sub(mask, with_attr: bool) -> dict[str, np.ndarray]:
        arrays = {"pos": pos[mask], "time": ev.time[mask],
                  "etype": et[mask], "slot": ev.slot[mask]}
        if with_attr:
            arrays.update({"col": ev.attr_col[mask], "new": ev.value[mask],
                           "old": ev.old_value[mask]})
        return arrays

    return {ELIST_STRUCT: sub(m_struct, False),
            ELIST_NODEATTR: sub(m_na, True),
            ELIST_EDGEATTR: sub(m_ea, True),
            ELIST_TRANSIENT: sub(m_tr, False)}


def encode_eventlist(ev: EventList) -> dict[str, bytes]:
    return {name: pack_arrays(arrays)
            for name, arrays in eventlist_components(ev).items()}


def decode_eventlist(parts: dict[str, bytes]) -> dict[str, dict[str, np.ndarray]]:
    return {name: unpack_arrays(b) for name, b in parts.items()}
