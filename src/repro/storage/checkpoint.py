"""Fault-tolerant sharded checkpointing (no orbax; built on the KV layer).

Design goals, in order:

1. **Crash consistency** — every write lands in the append-only
   :class:`LogFileKV` log; the manifest (step metadata + pytree structure
   + data-pipeline cursor) is committed *last* via atomic rename.  A crash
   mid-checkpoint leaves the previous checkpoint intact (torn tails are
   truncated on recovery).
2. **Sharded** — each host writes only its address-able shards under keys
   ``(partition_id, step, "ckpt/<leaf-path>/<shard>")`` — the same
   ⟨partition, id, component⟩ key discipline as the DeltaGraph store.
3. **Elastic restore** — restore takes the *target* mesh/sharding; shards
   are re-assembled to full arrays and re-laid out, so a 256-chip
   checkpoint restores onto 128 or 512 chips (node failure /扩容).
4. **Delta chains (beyond-paper)** — optionally store parameter *deltas*
   against the previous checkpoint in the DeltaGraph columnar codec,
   making "params as of step s" a snapshot query over training time.
"""
from __future__ import annotations

import json
from typing import Any

import jax
import numpy as np

from .columnar import pack_arrays, unpack_arrays
from .kv import KVStore

MANIFEST = "manifest"


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def save_checkpoint(store: KVStore, step: int, tree, *,
                    extra: dict | None = None, n_shards: int = 1) -> None:
    """Write all leaves (row-sharded into ``n_shards``) then the manifest."""
    leaves = _flatten_with_paths(tree)
    names = []
    for name, leaf in leaves:
        arr = np.asarray(leaf)
        names.append({"name": name, "dtype": str(arr.dtype),
                      "shape": list(arr.shape)})
        if arr.ndim == 0 or n_shards == 1:
            store.put((0, step, f"ckpt/{name}/0"),
                      pack_arrays({"a": arr.reshape(arr.shape)}))
        else:
            parts = np.array_split(arr, n_shards, axis=0)
            for p, part in enumerate(parts):
                store.put((p, step, f"ckpt/{name}/{p}"),
                          pack_arrays({"a": part}))
    manifest = {"step": step, "leaves": names, "n_shards": n_shards,
                "extra": extra or {}}
    store.put((0, step, MANIFEST), json.dumps(manifest).encode())
    # commit marker: the "latest" pointer is the last thing written
    store.put((0, -2, "latest"), json.dumps({"step": step}).encode())
    store.flush()


def latest_step(store: KVStore) -> int | None:
    try:
        return json.loads(store.get((0, -2, "latest")))["step"]
    except KeyError:
        return None


def restore_checkpoint(store: KVStore, step: int | None = None, *,
                       shardings=None, like=None):
    """Re-assemble the pytree; optionally device_put onto ``shardings``
    (a pytree of NamedSharding for the *current* — possibly different —
    mesh: elastic restart)."""
    if step is None:
        step = latest_step(store)
        if step is None:
            raise FileNotFoundError("no checkpoint found")
    manifest = json.loads(store.get((0, step, MANIFEST)))
    arrays: dict[str, np.ndarray] = {}
    for meta in manifest["leaves"]:
        name = meta["name"]
        parts = []
        for p in range(manifest["n_shards"]):
            key = (p, step, f"ckpt/{name}/{p}")
            if key in store:
                parts.append(unpack_arrays(store.get(key))["a"])
        arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        arrays[name] = arr.astype(np.dtype(meta["dtype"])).reshape(meta["shape"])
    if like is not None:
        flat = _flatten_with_paths(like)
        leaves = [arrays[name] for name, _ in flat]
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), leaves)
    else:
        tree = arrays
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest["extra"], step


# ---------------------------------------------------------------------------
# beyond-paper: parameter history as a delta chain (DeltaGraph-over-steps)
# ---------------------------------------------------------------------------

def save_param_delta(store: KVStore, step: int, prev_step: int | None,
                     tree, prev_tree=None, atol: float = 0.0) -> int:
    """Store params as a sparse delta vs the previous checkpoint (changed
    entries only).  Returns bytes written.  ``atol`` thresholds 'changed'
    — >0 gives lossy-but-tiny incremental checkpoints."""
    written = 0
    for name, leaf in _flatten_with_paths(tree):
        arr = np.asarray(leaf).ravel()
        if prev_tree is None or prev_step is None:
            payload = pack_arrays({"full": np.asarray(leaf)})
        else:
            prev = np.asarray(dict(_flatten_with_paths(prev_tree))[name]).ravel()
            if arr.shape != prev.shape:
                payload = pack_arrays({"full": np.asarray(leaf)})
            else:
                diff = np.nonzero(~np.isclose(arr, prev, atol=atol, rtol=0))[0]
                payload = pack_arrays({"idx": diff.astype(np.int64),
                                       "val": arr[diff],
                                       "shape": np.asarray(np.asarray(leaf).shape)})
        store.put((0, step, f"pdelta/{name}"), payload)
        written += len(payload)
    store.put((0, step, "pdelta/manifest"),
              json.dumps({"prev": prev_step,
                          "names": [n for n, _ in _flatten_with_paths(tree)]}
                         ).encode())
    return written


def restore_param_history(store: KVStore, steps: list[int], like):
    """Reconstruct params at each step by walking the delta chain —
    'snapshot queries over training time'."""
    out = {}
    cur: dict[str, np.ndarray] | None = None
    for step in steps:
        man = json.loads(store.get((0, step, "pdelta/manifest")))
        nxt: dict[str, np.ndarray] = {}
        for name in man["names"]:
            d = unpack_arrays(store.get((0, step, f"pdelta/{name}")))
            if "full" in d:
                nxt[name] = d["full"].copy()
            else:
                base = cur[name].ravel().copy()
                base[d["idx"]] = d["val"]
                nxt[name] = base.reshape([int(x) for x in d["shape"]])
        cur = nxt
        flat = _flatten_with_paths(like)
        leaves = [cur[name] for name, _ in flat]
        out[step] = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), leaves)
    return out
