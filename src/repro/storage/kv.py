"""Persistent key-value backends (paper §4.2).

The paper stores every delta / eventlist component under the key
``⟨partition_id, delta_id, component⟩`` in Kyoto Cabinet, and notes that any
get/put store (HBase, Cassandra, ...) can be plugged in.  We keep exactly
that contract: keys are ``(partition_id: int, delta_id: int, component:
str)``, values are opaque bytes.  Four backends:

* :class:`MemKV` — dict-backed (the "cloud cache" stand-in; also used by
  unit tests).
* :class:`LogFileKV` — a single append-only log + JSON offset index per
  directory.  Append-only gives crash-safe writes (torn tails are dropped on
  recovery) — this is also what the fault-tolerant checkpointer builds on.
  Deletes and overwrites leave dead records; :meth:`LogFileKV.compact`
  rewrites the live set and atomically swaps the log (auto-triggered by a
  dead-bytes ratio), so the store no longer grows without bound.
* :class:`TieredKV` — a byte-budgeted hot in-memory blob cache over a cold
  backend (typically :class:`LogFileKV`).  Blobs stay compressed-at-rest in
  *both* tiers (the codec layer owns decompression), so the hot budget buys
  ``compression_ratio×`` more working set than caching decoded arrays
  would.  Admission is versioned: a get that races a concurrent overwrite
  can never install — or serve, once the put returned — a stale blob.
* :class:`PartitionedKV` — routes by ``partition_id`` to one backend per
  storage unit (the paper's one-Kyoto-instance-per-machine deployment).

All backends record byte-level read/write counters so benchmarks can report
fetched bytes (the planner's cost model is bytes fetched + decoded).

``store_from_env()`` builds the default store for
:class:`~repro.core.manager.GraphManager` from ``REPRO_KV``
(``mem`` | ``logfile`` | ``tiered``), ``REPRO_KV_DIR`` and
``REPRO_KV_HOT_MB`` — CI runs a test subset with ``REPRO_KV=logfile`` so
the disk tier is exercised on every push.
"""
from __future__ import annotations

import atexit
import json
import os
import shutil
import struct
import tempfile
import threading
from collections import OrderedDict
from typing import Iterable

Key = tuple[int, int, str]


def _key_str(key: Key) -> str:
    p, d, c = key
    return f"{p}/{d}/{c}"


def mget_optional(store: "KVStore", keys: list) -> list:
    """Batched get where a missing key yields ``None`` (a component created
    before its column existed).  One protocol shared by the synchronous
    executor path and the async prefetcher — they must decode identically.
    Delegates to :meth:`KVStore.mget` so batching-aware backends (a remote
    shard server, the tiered cache) answer the whole list in one round
    trip instead of a get per key."""
    return store.mget(keys)


class KVStats:
    """Byte/op counters, lock-protected: the async prefetcher
    (``runtime/executor.py``) drives gets from a thread pool, and unlocked
    ``+=`` would drop increments under contention.

    ``hot_hits`` / ``hot_misses`` are populated by tiered backends only:
    every get is exactly one of the two, so
    ``gets == hot_hits + hot_misses`` is a checkable invariant under
    concurrency (``tests/test_executor_stress.py``)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.gets = 0
        self.puts = 0
        self.bytes_read = 0
        self.bytes_written = 0
        self.hot_hits = 0
        self.hot_misses = 0

    def add_get(self, nbytes: int, hot: bool | None = None) -> None:
        with self._lock:
            self.gets += 1
            self.bytes_read += nbytes
            if hot is True:
                self.hot_hits += 1
            elif hot is False:
                self.hot_misses += 1

    def add_put(self, nbytes: int) -> None:
        with self._lock:
            self.puts += 1
            self.bytes_written += nbytes

    def reset(self) -> None:
        with self._lock:
            self.gets = self.puts = 0
            self.bytes_read = self.bytes_written = 0
            self.hot_hits = self.hot_misses = 0


class AggregateKVStats:
    """Read-only aggregating view over several backends' ``KVStats`` —
    ``PartitionedKV.stats``.  Summing on read (instead of double-counting
    at the router) means bytes fetched by code that talks to a backend
    directly are still reported, and there is no per-call router overhead."""

    def __init__(self, parts: list["KVStore"]) -> None:
        self._parts = parts

    def _sum(self, field: str) -> int:
        return sum(getattr(p.stats, field) for p in self._parts)

    @property
    def gets(self) -> int:
        return self._sum("gets")

    @property
    def puts(self) -> int:
        return self._sum("puts")

    @property
    def bytes_read(self) -> int:
        return self._sum("bytes_read")

    @property
    def bytes_written(self) -> int:
        return self._sum("bytes_written")

    @property
    def hot_hits(self) -> int:
        return self._sum("hot_hits")

    @property
    def hot_misses(self) -> int:
        return self._sum("hot_misses")

    def reset(self) -> None:
        for p in self._parts:
            p.stats.reset()


class KVStore:
    """get/put/contains/delete over (partition_id, delta_id, component)."""

    def __init__(self) -> None:
        self.stats = KVStats()

    def get(self, key: Key) -> bytes:
        raise NotImplementedError

    def put(self, key: Key, value: bytes) -> None:
        raise NotImplementedError

    def delete(self, key: Key) -> None:
        raise NotImplementedError

    def __contains__(self, key: Key) -> bool:
        raise NotImplementedError

    def keys(self) -> Iterable[Key]:
        raise NotImplementedError

    def multi_get(self, keys: list[Key]) -> list[bytes]:
        """Batched fetch — single round-trip in a real remote store."""
        return [self.get(k) for k in keys]

    def mget(self, keys: list[Key]) -> list:
        """Batched fetch with ``None`` for missing keys (the
        :func:`mget_optional` protocol).  Backends that can answer a whole
        batch in one round trip (remote stores, the tiered cache) override
        this; the default is a per-key loop."""
        out = []
        for k in keys:
            try:
                out.append(self.get(k))
            except KeyError:
                out.append(None)
        return out

    def total_bytes(self) -> int:
        return sum(len(self.get(k)) for k in self.keys())

    def flush(self) -> None:
        pass

    def sync(self) -> None:
        """Durability barrier: on return, every preceding ``put`` survives a
        process or power crash.  Unlike :meth:`flush` this does *not* have
        to persist derived metadata (e.g. the log index) — backends may
        implement it as a bare data fsync and rely on recovery to rebuild
        the rest.  Default delegates to :meth:`flush`."""
        self.flush()

    def put_group(self, pairs: Iterable[tuple[Key, bytes]]) -> None:
        """Group commit (§6 ingest): write every pair, then pay **one**
        durability barrier for the whole group — the write pipeline's
        fsync-per-group surface (vs. fsync-per-event via put+sync)."""
        for k, v in pairs:
            self.put(k, v)
        self.sync()

    def close(self) -> None:
        pass


class MemKV(KVStore):
    def __init__(self) -> None:
        super().__init__()
        self._d: dict[Key, bytes] = {}

    def get(self, key: Key) -> bytes:
        v = self._d[key]
        self.stats.add_get(len(v))
        return v

    def put(self, key: Key, value: bytes) -> None:
        self._d[key] = bytes(value)
        self.stats.add_put(len(value))

    def delete(self, key: Key) -> None:
        self._d.pop(key, None)

    def __contains__(self, key: Key) -> bool:
        return key in self._d

    def keys(self):
        return list(self._d.keys())

    def total_bytes(self) -> int:
        return sum(len(v) for v in self._d.values())


_MAGIC = b"RKV1"
_TOMBSTONE = 0xFFFFFFFFFFFFFFFF   # vallen sentinel: a delete record


class LogFileKV(KVStore):
    """Append-only log file + offset index.

    Record layout: ``[magic][u32 keylen][key utf8][u64 vallen][value]``;
    a ``vallen`` of ``_TOMBSTONE`` (no value bytes) records a delete, so
    a full log scan reconstructs the exact live set — deletes are as
    durable as puts and can never resurrect.  The index (`index.json`)
    is written on flush; on open, the log is scanned from the last
    indexed offset so an unflushed-but-complete tail is recovered and a
    torn (partially written) tail record is truncated — the
    crash-consistency story for checkpointing.

    Overwrites and deletes strand dead records in the log;
    ``_dead_bytes`` tracks the stranded volume and :meth:`compact`
    reclaims it: live records are rewritten into ``kv.log.compact``
    (fsynced), the on-disk index is *invalidated* (a stale index must
    never pair with the new log's offsets), then ``os.replace`` swaps
    the log in — the commit point — and a fresh index is written last.
    A crash before the swap leaves a log whose full scan yields the old
    live set (the stray ``.compact`` file is discarded on reopen); a
    crash after it leaves the new log with no index, which recovery
    rebuilds from a full scan — every window is crash-safe
    (``tests/test_storage.py``).
    """

    def __init__(self, directory: str, *, auto_compact: bool = True,
                 compact_ratio: float = 0.5,
                 compact_min_bytes: int = 1 << 20) -> None:
        super().__init__()
        os.makedirs(directory, exist_ok=True)
        self.dir = directory
        self.log_path = os.path.join(directory, "kv.log")
        self.index_path = os.path.join(directory, "index.json")
        self.auto_compact = bool(auto_compact)
        self.compact_ratio = float(compact_ratio)
        self.compact_min_bytes = int(compact_min_bytes)
        self.compactions = 0
        self._index: dict[str, tuple[int, int]] = {}  # key -> (offset, length)
        # compact() runs under the same lock put/delete hold when they
        # auto-trigger it — reentrant by design
        self._lock = threading.RLock()
        stray = self.log_path + ".compact"   # compaction that died pre-commit
        if os.path.exists(stray):
            os.remove(stray)
        self._recover()
        # high-water mark of bytes known durable (fsynced); bytes past it
        # would be lost by a power crash — tests/faultlib.py truncates to
        # this point to model one
        self._synced_size = self._log_size
        self._fh = open(self.log_path, "ab")
        self._rfh = open(self.log_path, "rb")

    def _recover(self) -> None:
        indexed_end = 0
        if os.path.exists(self.index_path):
            with open(self.index_path) as f:
                payload = json.load(f)
            self._index = {k: tuple(v) for k, v in payload["index"].items()}
            indexed_end = payload["log_end"]
        if not os.path.exists(self.log_path):
            open(self.log_path, "wb").close()
            self._log_size = 0
            self._dead_bytes = 0
            return
        size = os.path.getsize(self.log_path)
        if size < indexed_end:  # corrupt index — rebuild from scratch
            self._index = {}
            indexed_end = 0
        with open(self.log_path, "rb") as f:
            f.seek(indexed_end)
            pos = indexed_end
            good_end = indexed_end
            while True:
                hdr = f.read(8)
                if len(hdr) < 8:
                    break
                magic, klen = hdr[:4], struct.unpack("<I", hdr[4:8])[0]
                if magic != _MAGIC:
                    break
                kb = f.read(klen)
                vl = f.read(8)
                if len(kb) < klen or len(vl) < 8:
                    break
                vlen = struct.unpack("<Q", vl)[0]
                voff = pos + 8 + klen + 8
                if vlen == _TOMBSTONE:       # delete record: no value bytes
                    pos = voff
                    self._index.pop(kb.decode(), None)
                    good_end = pos
                    continue
                f.seek(vlen, os.SEEK_CUR)
                pos = voff + vlen
                if f.tell() != pos:
                    break
                self._index[kb.decode()] = (voff, vlen)
                good_end = pos
        if os.path.getsize(self.log_path) != good_end:
            with open(self.log_path, "r+b") as f:  # drop torn tail
                f.truncate(good_end)
        self._log_size = good_end
        self._dead_bytes = max(0, good_end - self._live_bytes())

    def _live_bytes(self) -> int:
        return sum(self._rec_len(k, ln) for k, (_, ln) in self._index.items())

    @staticmethod
    def _rec_len(key_str: str, vlen: int) -> int:
        return 8 + len(key_str.encode()) + 8 + vlen

    @property
    def dead_bytes(self) -> int:
        return self._dead_bytes

    def dead_ratio(self) -> float:
        with self._lock:
            return self._dead_bytes / max(self._log_size, 1)

    def put(self, key: Key, value: bytes) -> None:
        ks = _key_str(key)
        kb = ks.encode()
        with self._lock:
            self._fh.seek(0, os.SEEK_END)
            pos = self._fh.tell()
            self._fh.write(_MAGIC + struct.pack("<I", len(kb)) + kb
                           + struct.pack("<Q", len(value)) + value)
            old = self._index.get(ks)
            if old is not None:
                self._dead_bytes += self._rec_len(ks, old[1])
            self._index[ks] = (pos + 8 + len(kb) + 8, len(value))
            self._log_size = pos + 8 + len(kb) + 8 + len(value)
            self._maybe_compact()
        self.stats.add_put(len(value))

    def get(self, key: Key) -> bytes:
        # index lookup + file read under one lock: compact() swaps both
        # the offsets and the backing file atomically w.r.t. readers
        with self._lock:
            off, length = self._index[_key_str(key)]
            self._fh.flush()
            self._rfh.seek(off)
            v = self._rfh.read(length)
        self.stats.add_get(len(v))
        return v

    def delete(self, key: Key) -> None:
        ks = _key_str(key)
        kb = ks.encode()
        with self._lock:
            old = self._index.pop(ks, None)
            if old is None:
                return
            # tombstone record: a full log scan (index lost or rebuilt)
            # must not resurrect the deleted key
            self._fh.seek(0, os.SEEK_END)
            pos = self._fh.tell()
            self._fh.write(_MAGIC + struct.pack("<I", len(kb)) + kb
                           + struct.pack("<Q", _TOMBSTONE))
            self._log_size = pos + 8 + len(kb) + 8
            # both the dead record and the tombstone itself are reclaimable
            self._dead_bytes += (self._rec_len(ks, old[1])
                                 + 8 + len(kb) + 8)
            self._maybe_compact()

    def _maybe_compact(self) -> None:
        if (self.auto_compact
                and self._dead_bytes >= self.compact_min_bytes
                and self._dead_bytes >= self.compact_ratio
                * max(self._log_size, 1)):
            self.compact()

    def compact(self) -> dict:
        """Rewrite live records into a fresh log and atomically swap it in.
        Returns ``{"live_bytes", "reclaimed_bytes"}``.

        Runs synchronously under the store lock — readers stall for the
        duration.  Serving deployments with large stores should pass
        ``auto_compact=False`` and call this from a maintenance window
        instead of letting a routine ``put`` absorb the rewrite."""
        with self._lock:
            self._fh.flush()
            tmp_path = self.log_path + ".compact"
            new_index: dict[str, tuple[int, int]] = {}
            pos = 0
            with open(tmp_path, "wb") as out:
                for ks, (off, length) in sorted(self._index.items(),
                                                key=lambda kv: kv[1][0]):
                    self._rfh.seek(off)
                    val = self._rfh.read(length)
                    kb = ks.encode()
                    out.write(_MAGIC + struct.pack("<I", len(kb)) + kb
                              + struct.pack("<Q", length) + val)
                    new_index[ks] = (pos + 8 + len(kb) + 8, length)
                    pos += 8 + len(kb) + 8 + length
                out.flush()
                os.fsync(out.fileno())
            reclaimed = self._log_size - pos
            self._fh.close()
            self._rfh.close()
            committed = False
            try:
                # invalidate the on-disk index BEFORE the commit point: a
                # stale index paired with the new log would serve wrong
                # bytes at old offsets; with no index, recovery full-scans
                # the log (exact — deletes are tombstoned records)
                if os.path.exists(self.index_path):
                    os.remove(self.index_path)
                    self._fsync_dir()
                os.replace(tmp_path, self.log_path)   # commit point
                committed = True
                self._fsync_dir()
            finally:
                # a failed swap must not brick the live instance (an
                # ordinary put can auto-trigger compaction): adopt the new
                # state only past the commit point, and reopen handles on
                # whichever log file is current either way
                if committed:
                    self._index = new_index
                    self._log_size = pos
                    self._synced_size = pos
                    self._dead_bytes = 0
                self._fh = open(self.log_path, "ab")
                self._rfh = open(self.log_path, "rb")
            self.compactions += 1
            self._write_index_locked()
            return {"live_bytes": pos, "reclaimed_bytes": reclaimed}

    def __contains__(self, key: Key) -> bool:
        with self._lock:
            return _key_str(key) in self._index

    def keys(self):
        with self._lock:
            names = list(self._index)
        out = []
        for ks in names:
            p, d, c = ks.split("/", 2)
            out.append((int(p), int(d), c))
        return out

    def _fsync_dir(self) -> None:
        fd = os.open(self.dir, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def _write_index_locked(self) -> None:
        tmp = self.index_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"index": {k: list(v) for k, v in self._index.items()},
                       "log_end": self._log_size}, f)
        os.replace(tmp, self.index_path)  # atomic

    def flush(self) -> None:
        with self._lock:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._synced_size = self._log_size
            self._write_index_locked()

    def sync(self) -> None:
        """Data-only durability barrier: fsync the log without rewriting
        ``index.json``.  Recovery scans the log tail past the indexed end
        (:meth:`_recover`), so synced-but-unindexed records are safe — this
        is what makes group commit ~free compared to :meth:`flush`, which
        rewrites the whole index every call.

        The fsync itself runs *outside* the store lock: concurrent readers
        must not stall behind the disk for the duration of a barrier (the
        ingest pipeline fsyncs once per commit group while query threads
        keep reading payloads).  An append racing the fsync only means
        *more* bytes became durable than this call promised."""
        with self._lock:
            self._fh.flush()
            size = self._log_size
            fd = self._fh.fileno()
        os.fsync(fd)
        with self._lock:
            if size > self._synced_size:
                self._synced_size = size

    def close(self) -> None:
        if self._fh.closed:   # idempotent — managers close owned stores
            return
        self.flush()
        self._fh.close()
        self._rfh.close()


class TieredKV(KVStore):
    """Hot in-memory blob cache over a cold backend, byte-budgeted.

    * **Write-through**: ``put`` lands in the cold store first, then
      (re)admits the new blob into the hot tier — the cold tier is always
      the full, durable store and ``keys()``/``total_bytes()`` delegate
      to it.
    * **Compressed-at-rest**: values are the codec-layer blobs; the hot
      tier caches them verbatim (decode happens in the prefetcher
      threads), so the budget holds ``compression_ratio×`` more payloads.
    * **Versioned admission**: each overwrite/delete bumps a per-key
      version; a get that read the cold tier concurrently with an
      overwrite only admits its blob if the version is unchanged,
      otherwise it retries — after a ``put`` returns, no later ``get``
      can observe the previous blob.  Writers additionally serialize on
      one lock so cold-tier write order always matches admission order
      (racing puts cannot strand the losing blob in the hot tier).
    * **Accounting**: ``stats`` sees every logical get (each tagged
      hot-hit or hot-miss); the cold backend's own ``stats`` counts the
      physical reads the hot tier absorbed.
    """

    def __init__(self, cold: KVStore, hot_bytes: int = 64 << 20,
                 max_item_frac: float = 0.25) -> None:
        super().__init__()
        self.cold = cold
        self.hot_bytes = int(hot_bytes)
        self.max_item_bytes = max(1, int(self.hot_bytes * max_item_frac))
        self._hot: OrderedDict[Key, bytes] = OrderedDict()
        self._hot_size = 0
        # per-key write versions guard admission; entries live only for
        # keys that exist (bounded by the live set) — a delete reclaims
        # its entry unless a cold read is in flight, in which case a
        # tombstone version stays so the reader cannot admit stale bytes
        self._ver: dict[Key, int] = {}
        self._inflight: dict[Key, int] = {}
        # whole-cache generation, bumped by invalidate_hot(): per-key
        # versions only move on local put/delete, so a read-only replica
        # (shardd: writes happen at the origin) needs this to fence cold
        # reads that straddle an epoch invalidation — bytes fetched
        # before the bump must not be admitted after it
        self._gen = 0
        self._lock = threading.Lock()
        # writes hold this across the cold put/delete *and* the version
        # bump + admission, so cold-tier order == admission order — two
        # racing puts can never leave the hot tier serving the loser
        # (cold backends serialize writers internally anyway)
        self._write_lock = threading.Lock()
        self.evictions = 0

    # -- hot-tier plumbing (lock held) --------------------------------------
    def _drop(self, key: Key) -> None:
        old = self._hot.pop(key, None)
        if old is not None:
            self._hot_size -= len(old)

    def _admit(self, key: Key, value: bytes) -> None:
        self._drop(key)
        if len(value) > self.max_item_bytes:
            return
        self._hot[key] = value
        self._hot_size += len(value)
        while self._hot_size > self.hot_bytes and self._hot:
            _, v = self._hot.popitem(last=False)
            self._hot_size -= len(v)
            self.evictions += 1

    def _dec_inflight(self, key: Key) -> None:
        n = self._inflight.get(key, 0) - 1
        if n <= 0:
            self._inflight.pop(key, None)
        else:
            self._inflight[key] = n

    # -- KVStore API --------------------------------------------------------
    def get(self, key: Key) -> bytes:
        with self._lock:
            v = self._hot.get(key)
            if v is not None:
                self._hot.move_to_end(key)
        if v is not None:
            self.stats.add_get(len(v), hot=True)
            return v
        while True:
            with self._lock:
                ver = self._ver.get(key, 0)
                gen = self._gen
                self._inflight[key] = self._inflight.get(key, 0) + 1
            try:
                v = self.cold.get(key)        # may raise KeyError
            except BaseException:
                with self._lock:
                    self._dec_inflight(key)
                raise
            with self._lock:
                self._dec_inflight(key)
                if self._ver.get(key, 0) == ver:
                    if self._gen == gen:
                        self._admit(key, v)
                    # an invalidation landed mid-read: the bytes are fine
                    # for *this* caller (its epoch pin predates the
                    # publish) but must not enter the hot tier, where a
                    # newer-epoch reader would trust them
                    break
                newer = self._hot.get(key)
                if newer is not None:         # the racing put admitted it
                    self._hot.move_to_end(key)
                    v = newer
                    break
            # overwritten mid-read and not admitted (e.g. oversized) — retry
        self.stats.add_get(len(v), hot=False)
        return v

    def mget(self, keys: list[Key]) -> list:
        """Batched :func:`mget_optional` semantics: hot hits answered from
        the cache, all misses fetched from the cold tier in **one**
        ``cold.mget`` round trip (the batching that makes a remote cold
        tier — e.g. a shard server's origin — affordable), each admitted
        under the same per-key version guard as :meth:`get`."""
        out: list = [None] * len(keys)
        hit = [False] * len(keys)
        with self._lock:
            for i, k in enumerate(keys):
                v = self._hot.get(k)
                if v is not None:
                    self._hot.move_to_end(k)
                    out[i] = v
                    hit[i] = True
        miss_idx = []
        for i in range(len(keys)):
            if hit[i]:
                self.stats.add_get(len(out[i]), hot=True)
            else:
                miss_idx.append(i)
        if not miss_idx:
            return out
        miss_keys = [keys[i] for i in miss_idx]
        with self._lock:
            vers = [self._ver.get(k, 0) for k in miss_keys]
            gen = self._gen
            for k in miss_keys:
                self._inflight[k] = self._inflight.get(k, 0) + 1
        try:
            blobs = self.cold.mget(miss_keys)
        except BaseException:
            with self._lock:
                for k in miss_keys:
                    self._dec_inflight(k)
            raise
        racy: list[Key] = []
        with self._lock:
            for j, (i, k, ver) in enumerate(zip(miss_idx, miss_keys, vers)):
                self._dec_inflight(k)
                v = blobs[j]
                if v is None:
                    continue                  # absent in cold: stays None
                if self._ver.get(k, 0) == ver:
                    if self._gen == gen:      # see get(): no admission
                        self._admit(k, v)     # across an invalidation
                    out[i] = v
                elif self._hot.get(k) is not None:
                    self._hot.move_to_end(k)
                    out[i] = self._hot[k]
                else:
                    racy.append((i, k))       # overwritten mid-read — retry
        for i, k in racy:
            try:
                out[i] = self.get(k)
            except KeyError:
                out[i] = None
        for j, i in enumerate(miss_idx):
            if out[i] is not None and (i, keys[i]) not in racy:
                if blobs[j] is not None:
                    self.stats.add_get(len(out[i]), hot=False)
        return out

    def invalidate_hot(self) -> int:
        """Drop every hot entry (epoch-publish invalidation in a shard
        process: the coordinator announced a new index version, so any
        cached blob may have been superseded at the origin).  Returns the
        number of entries dropped; subsequent gets read through to the
        cold tier.  Also bumps the cache generation so a cold read that
        started *before* this call cannot admit its (possibly
        pre-publish) bytes after it — per-key versions never move in a
        read-only replica, so they alone cannot fence this race."""
        with self._lock:
            n = len(self._hot)
            self._hot.clear()
            self._hot_size = 0
            self._gen += 1
        return n

    def put(self, key: Key, value: bytes) -> None:
        value = bytes(value)
        with self._write_lock:
            self.cold.put(key, value)
            with self._lock:
                self._ver[key] = self._ver.get(key, 0) + 1
                self._admit(key, value)
        self.stats.add_put(len(value))

    def delete(self, key: Key) -> None:
        with self._write_lock:
            self.cold.delete(key)
            self._finish_delete(key)

    def _finish_delete(self, key: Key) -> None:
        with self._lock:
            if self._inflight.get(key):
                # a cold read is mid-flight: leave a bumped tombstone
                # version so it cannot admit the bytes it read
                self._ver[key] = self._ver.get(key, 0) + 1
            else:
                # no reader can hold a pre-delete version — reclaim the
                # entry so dead keys don't accumulate version state
                self._ver.pop(key, None)
            self._drop(key)

    def __contains__(self, key: Key) -> bool:
        with self._lock:
            if key in self._hot:
                return True
        return key in self.cold

    def keys(self):
        return self.cold.keys()

    def total_bytes(self) -> int:
        return self.cold.total_bytes()

    def hot_bytes_used(self) -> int:
        with self._lock:
            return self._hot_size

    def resize_hot(self, hot_bytes: int,
                   max_item_frac: float = 0.25) -> None:
        """Shrink/grow the hot budget in place (benchmarks set it relative
        to the store size measured after a build)."""
        with self._lock:
            self.hot_bytes = int(hot_bytes)
            self.max_item_bytes = max(1, int(self.hot_bytes * max_item_frac))
            while self._hot_size > self.hot_bytes and self._hot:
                _, v = self._hot.popitem(last=False)
                self._hot_size -= len(v)
                self.evictions += 1

    def flush(self) -> None:
        self.cold.flush()

    def sync(self) -> None:
        self.cold.sync()

    def close(self) -> None:
        self.cold.close()


class PartitionedKV(KVStore):
    """Routes by partition_id across per-unit backends (paper: one storage
    instance per machine; all deltas have k partitions).

    ``stats`` aggregates the per-backend counters on read — the router
    keeps no counters of its own, so traffic that reaches a backend
    directly (a partition-local reader, a prefetch thread pinned to one
    storage unit) is never under-reported.

    ``partitioner`` selects the backend for a partition id: a registered
    name from :mod:`repro.runtime.partition` (``"mod_hash"`` /
    ``"word_cyclic"``) or any ``(ids, P) -> backend indices`` callable, so
    the store's routing and the planner's shard assignment come from the
    same registry.  ``None`` (the default) keeps the legacy
    ``partition_id % len(parts)`` routing — stores written by earlier
    deployments stay readable."""

    def __init__(self, parts: list[KVStore], *,
                 partitioner=None) -> None:
        self.parts = parts
        self._agg = AggregateKVStats(parts)
        if isinstance(partitioner, str):
            from ..runtime.partition import get_partitioner
            partitioner = get_partitioner(partitioner)
        self._partitioner = partitioner
        # partition ids are small ints drawn from a fixed range; memoize
        # so routing stays a dict hit, not an ndarray round-trip per call
        self._route_memo: dict[int, int] = {}

    @property
    def stats(self) -> AggregateKVStats:
        return self._agg

    def _route(self, key: Key) -> KVStore:
        pid = key[0]
        if self._partitioner is None:
            return self.parts[pid % len(self.parts)]
        idx = self._route_memo.get(pid)
        if idx is None:
            import numpy as np
            idx = int(self._partitioner(np.asarray([pid], np.int64),
                                        len(self.parts))[0])
            self._route_memo[pid] = idx
        return self.parts[idx]

    def get(self, key: Key) -> bytes:
        return self._route(key).get(key)

    def mget(self, keys: list[Key]) -> list:
        """Route then batch: keys are grouped per backend so each storage
        unit answers one batched fetch (order preserved)."""
        groups: dict[int, list[int]] = {}
        for i, k in enumerate(keys):
            backend = self._route(k)
            groups.setdefault(id(backend), []).append(i)
        out: list = [None] * len(keys)
        for idxs in groups.values():
            backend = self._route(keys[idxs[0]])
            for i, v in zip(idxs, backend.mget([keys[i] for i in idxs])):
                out[i] = v
        return out

    def put(self, key: Key, value: bytes) -> None:
        self._route(key).put(key, value)

    def delete(self, key: Key) -> None:
        self._route(key).delete(key)

    def __contains__(self, key: Key) -> bool:
        return key in self._route(key)

    def keys(self):
        out = []
        for p in self.parts:
            out.extend(p.keys())
        return out

    def flush(self) -> None:
        for p in self.parts:
            p.flush()

    def sync(self) -> None:
        for p in self.parts:
            p.sync()

    def close(self) -> None:
        for p in self.parts:
            p.close()


# ---------------------------------------------------------------------------
# environment-driven store construction
# ---------------------------------------------------------------------------

_TMPDIRS: list[str] = []


def _cleanup_tmpdirs() -> None:  # pragma: no cover - process teardown
    for d in _TMPDIRS:
        shutil.rmtree(d, ignore_errors=True)


atexit.register(_cleanup_tmpdirs)


def make_store(spec: str | None, *, directory: str | None = None,
               hot_bytes: int = 64 << 20) -> KVStore:
    """``mem`` | ``logfile`` | ``tiered`` (hot cache over a logfile)."""
    spec = (spec or "mem").strip().lower()
    if spec == "mem":
        return MemKV()
    if directory is None:
        directory = tempfile.mkdtemp(prefix="repro-kv-")
        _TMPDIRS.append(directory)
    if spec == "logfile":
        return LogFileKV(directory)
    if spec == "tiered":
        return TieredKV(LogFileKV(directory), hot_bytes=hot_bytes)
    raise ValueError(f"unknown KV spec {spec!r} (mem | logfile | tiered)")


def store_from_env() -> KVStore | None:
    """Build the default store from ``REPRO_KV`` (None when unset/``mem``
    — the caller falls back to a plain :class:`MemKV`).  Each call makes
    an independent store; disk-backed ones live in fresh temp dirs under
    ``REPRO_KV_DIR`` (or the system tmp), removed at process exit."""
    spec = os.environ.get("REPRO_KV", "").strip().lower()
    if spec in ("", "mem"):
        return None
    base = os.environ.get("REPRO_KV_DIR") or None
    directory = tempfile.mkdtemp(prefix="repro-kv-", dir=base)
    _TMPDIRS.append(directory)
    hot = int(float(os.environ.get("REPRO_KV_HOT_MB", "64")) * 2**20)
    return make_store(spec, directory=directory, hot_bytes=hot)
