"""Persistent key-value backends (paper §4.2).

The paper stores every delta / eventlist component under the key
``⟨partition_id, delta_id, component⟩`` in Kyoto Cabinet, and notes that any
get/put store (HBase, Cassandra, ...) can be plugged in.  We keep exactly
that contract: keys are ``(partition_id: int, delta_id: int, component:
str)``, values are opaque bytes.  Three backends:

* :class:`MemKV` — dict-backed (the "cloud cache" stand-in; also used by
  unit tests).
* :class:`LogFileKV` — a single append-only log + JSON offset index per
  directory.  Append-only gives crash-safe writes (torn tails are dropped on
  recovery) — this is also what the fault-tolerant checkpointer builds on.
* :class:`PartitionedKV` — routes by ``partition_id`` to one backend per
  storage unit (the paper's one-Kyoto-instance-per-machine deployment).

All backends record byte-level read/write counters so benchmarks can report
fetched bytes (the planner's cost model is bytes fetched).
"""
from __future__ import annotations

import json
import os
import struct
import threading
from typing import Iterable

Key = tuple[int, int, str]


def _key_str(key: Key) -> str:
    p, d, c = key
    return f"{p}/{d}/{c}"


def mget_optional(store: "KVStore", keys: list) -> list:
    """Batched get where a missing key yields ``None`` (a component created
    before its column existed).  One protocol shared by the synchronous
    executor path and the async prefetcher — they must decode identically."""
    out = []
    for k in keys:
        try:
            out.append(store.get(k))
        except KeyError:
            out.append(None)
    return out


class KVStats:
    """Byte/op counters, lock-protected: the async prefetcher
    (``runtime/executor.py``) drives gets from a thread pool, and unlocked
    ``+=`` would drop increments under contention."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.gets = 0
        self.puts = 0
        self.bytes_read = 0
        self.bytes_written = 0

    def add_get(self, nbytes: int) -> None:
        with self._lock:
            self.gets += 1
            self.bytes_read += nbytes

    def add_put(self, nbytes: int) -> None:
        with self._lock:
            self.puts += 1
            self.bytes_written += nbytes

    def reset(self) -> None:
        with self._lock:
            self.gets = self.puts = 0
            self.bytes_read = self.bytes_written = 0


class AggregateKVStats:
    """Read-only aggregating view over several backends' ``KVStats`` —
    ``PartitionedKV.stats``.  Summing on read (instead of double-counting
    at the router) means bytes fetched by code that talks to a backend
    directly are still reported, and there is no per-call router overhead."""

    def __init__(self, parts: list["KVStore"]) -> None:
        self._parts = parts

    def _sum(self, field: str) -> int:
        return sum(getattr(p.stats, field) for p in self._parts)

    @property
    def gets(self) -> int:
        return self._sum("gets")

    @property
    def puts(self) -> int:
        return self._sum("puts")

    @property
    def bytes_read(self) -> int:
        return self._sum("bytes_read")

    @property
    def bytes_written(self) -> int:
        return self._sum("bytes_written")

    def reset(self) -> None:
        for p in self._parts:
            p.stats.reset()


class KVStore:
    """get/put/contains/delete over (partition_id, delta_id, component)."""

    def __init__(self) -> None:
        self.stats = KVStats()

    def get(self, key: Key) -> bytes:
        raise NotImplementedError

    def put(self, key: Key, value: bytes) -> None:
        raise NotImplementedError

    def delete(self, key: Key) -> None:
        raise NotImplementedError

    def __contains__(self, key: Key) -> bool:
        raise NotImplementedError

    def keys(self) -> Iterable[Key]:
        raise NotImplementedError

    def multi_get(self, keys: list[Key]) -> list[bytes]:
        """Batched fetch — single round-trip in a real remote store."""
        return [self.get(k) for k in keys]

    def total_bytes(self) -> int:
        return sum(len(self.get(k)) for k in self.keys())

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class MemKV(KVStore):
    def __init__(self) -> None:
        super().__init__()
        self._d: dict[Key, bytes] = {}

    def get(self, key: Key) -> bytes:
        v = self._d[key]
        self.stats.add_get(len(v))
        return v

    def put(self, key: Key, value: bytes) -> None:
        self._d[key] = bytes(value)
        self.stats.add_put(len(value))

    def delete(self, key: Key) -> None:
        self._d.pop(key, None)

    def __contains__(self, key: Key) -> bool:
        return key in self._d

    def keys(self):
        return list(self._d.keys())

    def total_bytes(self) -> int:
        return sum(len(v) for v in self._d.values())


_MAGIC = b"RKV1"


class LogFileKV(KVStore):
    """Append-only log file + offset index.

    Record layout: ``[u32 keylen][key utf8][u64 vallen][value bytes]``.
    The index (`index.json`) is written on flush; on open, the log is
    scanned from the last indexed offset so an unflushed-but-complete tail
    is recovered and a torn (partially written) tail record is truncated —
    the crash-consistency story for checkpointing.
    """

    def __init__(self, directory: str) -> None:
        super().__init__()
        os.makedirs(directory, exist_ok=True)
        self.dir = directory
        self.log_path = os.path.join(directory, "kv.log")
        self.index_path = os.path.join(directory, "index.json")
        self._index: dict[str, tuple[int, int]] = {}  # key -> (offset, length)
        self._lock = threading.Lock()
        self._recover()
        self._fh = open(self.log_path, "ab")

    def _recover(self) -> None:
        indexed_end = 0
        if os.path.exists(self.index_path):
            with open(self.index_path) as f:
                payload = json.load(f)
            self._index = {k: tuple(v) for k, v in payload["index"].items()}
            indexed_end = payload["log_end"]
        if not os.path.exists(self.log_path):
            open(self.log_path, "wb").close()
            return
        size = os.path.getsize(self.log_path)
        if size < indexed_end:  # corrupt index — rebuild from scratch
            self._index = {}
            indexed_end = 0
        with open(self.log_path, "rb") as f:
            f.seek(indexed_end)
            pos = indexed_end
            good_end = indexed_end
            while True:
                hdr = f.read(8)
                if len(hdr) < 8:
                    break
                magic, klen = hdr[:4], struct.unpack("<I", hdr[4:8])[0]
                if magic != _MAGIC:
                    break
                kb = f.read(klen)
                vl = f.read(8)
                if len(kb) < klen or len(vl) < 8:
                    break
                vlen = struct.unpack("<Q", vl)[0]
                voff = pos + 8 + klen + 8
                f.seek(vlen, os.SEEK_CUR)
                pos = voff + vlen
                if f.tell() != pos:
                    break
                self._index[kb.decode()] = (voff, vlen)
                good_end = pos
        if os.path.getsize(self.log_path) != good_end:
            with open(self.log_path, "r+b") as f:  # drop torn tail
                f.truncate(good_end)

    def put(self, key: Key, value: bytes) -> None:
        ks = _key_str(key).encode()
        with self._lock:
            self._fh.seek(0, os.SEEK_END)
            pos = self._fh.tell()
            self._fh.write(_MAGIC + struct.pack("<I", len(ks)) + ks
                           + struct.pack("<Q", len(value)) + value)
            self._index[ks.decode()] = (pos + 8 + len(ks) + 8, len(value))
        self.stats.add_put(len(value))

    def get(self, key: Key) -> bytes:
        off, length = self._index[_key_str(key)]
        with self._lock:
            self._fh.flush()
            with open(self.log_path, "rb") as f:
                f.seek(off)
                v = f.read(length)
        self.stats.add_get(len(v))
        return v

    def delete(self, key: Key) -> None:
        self._index.pop(_key_str(key), None)

    def __contains__(self, key: Key) -> bool:
        return _key_str(key) in self._index

    def keys(self):
        out = []
        for ks in self._index:
            p, d, c = ks.split("/", 2)
            out.append((int(p), int(d), c))
        return out

    def flush(self) -> None:
        with self._lock:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            tmp = self.index_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"index": {k: list(v) for k, v in self._index.items()},
                           "log_end": os.path.getsize(self.log_path)}, f)
            os.replace(tmp, self.index_path)  # atomic

    def close(self) -> None:
        self.flush()
        self._fh.close()


class PartitionedKV(KVStore):
    """Routes by partition_id across per-unit backends (paper: one storage
    instance per machine; all deltas have k partitions).

    ``stats`` aggregates the per-backend counters on read — the router
    keeps no counters of its own, so traffic that reaches a backend
    directly (a partition-local reader, a prefetch thread pinned to one
    storage unit) is never under-reported."""

    def __init__(self, parts: list[KVStore]) -> None:
        self.parts = parts
        self._agg = AggregateKVStats(parts)

    @property
    def stats(self) -> AggregateKVStats:
        return self._agg

    def _route(self, key: Key) -> KVStore:
        return self.parts[key[0] % len(self.parts)]

    def get(self, key: Key) -> bytes:
        return self._route(key).get(key)

    def put(self, key: Key, value: bytes) -> None:
        self._route(key).put(key, value)

    def delete(self, key: Key) -> None:
        self._route(key).delete(key)

    def __contains__(self, key: Key) -> bool:
        return key in self._route(key)

    def keys(self):
        out = []
        for p in self.parts:
            out.extend(p.keys())
        return out

    def flush(self) -> None:
        for p in self.parts:
            p.flush()

    def close(self) -> None:
        for p in self.parts:
            p.close()
