"""Pluggable payload codec: versioned, checksummed, compressed blobs.

Every payload the system persists — struct deltas, per-column attr
deltas, leaf eventlists, checkpoints, the skeleton — is an *array
bundle* (``dict[str, np.ndarray]``).  This module owns the wire format:

``raw``
    the original self-describing bundle (name, dtype, shape, raw bytes)
    — still written under ``REPRO_CODEC=raw`` and always readable.

``v2`` (default)
    a versioned header wrapping staged per-array encoders plus an
    optional whole-blob entropy stage::

        ┌──────────────────────────── header (20 B) ───────────────────────────┐
        │ magic "RBC2" │ u8 version │ u8 flags │ u16 rsvd │ u64 raw │ u32 csum │
        └──────────────────────────────────────────────────────────────────────┘
        body  = [zlib](  u32 n_arrays,
                         per array: name, dtype, shape, u8 method, params,
                                    encoded bytes )

    Integer columns choose the smallest of: zigzag **varint**, first-
    order **delta** varint (sorted slot/pos columns), second-order
    **delta-of-delta** varint (regularly spaced time columns), fixed-
    width **bitpack** (small-range op/etype codes), or raw.  Floats and
    exotic dtypes stay raw; the zlib stage applies only when it shrinks
    the body (``flags`` records it).  A crc32 checksum covers the stored
    body, so corrupt or truncated blobs raise a typed
    :class:`CodecError` instead of decoding into garbage arrays —
    crc32 because it is stdlib: every environment can *verify* the
    guarantee, never silently skip it.

Decoding sniffs the magic: blobs written before this layer existed (no
``RBC2`` prefix) fall back to the ``raw`` parser — old stores keep
decoding with zero migration (version-gated fallback, pinned by
``tests/test_codec.py``).

The default codec comes from ``REPRO_CODEC`` (``v2``/``raw``) and can
be overridden per call, via :func:`set_default_codec`, or the
:func:`using_codec` context manager.
"""
from __future__ import annotations

import contextlib
import os
import struct as _struct
import threading
import zlib
from collections import OrderedDict

import numpy as np

MAGIC = b"RBC2"
VERSION = 2
_HEADER = _struct.Struct("<4sBBHQI")          # magic, ver, flags, rsvd, raw, csum
_HEADER_LEN = _HEADER.size                     # 20 bytes

# header flags (bit 1 reserved for an alternate checksum algorithm —
# crc32 is the only one written: it is stdlib, so every environment can
# *verify*; an optional faster hash would silently skip verification
# wherever the module is missing, voiding the corruption guarantee)
F_ZLIB = 0x01

# per-array methods
M_RAW = 0          # verbatim array bytes
M_VARINT = 1       # zigzag varint of the values
M_DELTA = 2        # zigzag varint of first-order deltas
M_DOD = 3          # zigzag varint of second-order deltas
M_BITPACK = 4      # min-offset + fixed-width bitpack

_MIN_TRY = 8       # arrays smaller than this stay raw (overhead-bound)
_MIN_ZLIB = 64     # don't entropy-code trivial bodies
_PROBE_FROM = 1 << 16   # bodies above this probe a prefix before committing
ZLIB_LEVEL = int(os.environ.get("REPRO_CODEC_ZLIB_LEVEL", "6"))

KNOWN_CODECS = ("raw", "v2")


class CodecError(Exception):
    """A blob failed to decode: truncated header, unknown version,
    checksum mismatch, or a malformed stream.  Never returns garbage
    arrays — storage corruption surfaces as this typed error."""


# ---------------------------------------------------------------------------
# default-codec selection
# ---------------------------------------------------------------------------

_default_codec = os.environ.get("REPRO_CODEC", "v2").strip().lower() or "v2"


def get_default_codec() -> str:
    return _default_codec


def set_default_codec(name: str) -> None:
    if name not in KNOWN_CODECS:
        raise CodecError(f"unknown codec {name!r}; known: {KNOWN_CODECS}")
    global _default_codec
    _default_codec = name


@contextlib.contextmanager
def using_codec(name: str):
    """Scoped default-codec override (benchmarks compare raw vs v2)."""
    prev = _default_codec
    set_default_codec(name)
    try:
        yield
    finally:
        set_default_codec(prev)


# ---------------------------------------------------------------------------
# stage primitives (all vectorized)
# ---------------------------------------------------------------------------

def _zigzag(w: np.ndarray) -> np.ndarray:
    """int64 bit patterns -> uint64 with small magnitudes near zero."""
    w = np.ascontiguousarray(w, np.int64)
    return (np.left_shift(w, 1) ^ np.right_shift(w, 63)).view(np.uint64)


def _unzigzag(u: np.ndarray) -> np.ndarray:
    u = np.ascontiguousarray(u, np.uint64)
    half = (u >> np.uint64(1)).view(np.int64)
    sign = (u & np.uint64(1)).view(np.int64)
    return half ^ -sign


def varint_encode(u: np.ndarray) -> bytes:
    """LEB128 over uint64 values."""
    u = np.ascontiguousarray(u, np.uint64)
    n = u.size
    if n == 0:
        return b""
    umax = int(u.max())
    if umax < 0x80:
        # all single-byte (the common case: zigzagged deltas, small ids)
        return u.astype(np.uint8).tobytes()
    nb = np.ones(n, np.int64)
    # width passes only up to the widest value present, not all 10
    k = 1
    while k < 10 and umax >= (1 << (7 * k)):
        nb += (u >= (np.uint64(1) << np.uint64(7 * k))).astype(np.int64)
        k += 1
    out = np.zeros(int(nb.sum()), np.uint8)
    starts = np.concatenate([[0], np.cumsum(nb)[:-1]])
    for j in range(k):
        m = nb > j
        byte = ((u[m] >> np.uint64(7 * j)) & np.uint64(0x7F)).astype(np.uint8)
        cont = (nb[m] - 1 > j).astype(np.uint8) << 7
        out[starts[m] + j] = byte | cont
    return out.tobytes()


def varint_decode(data: bytes, n: int) -> np.ndarray:
    b = np.frombuffer(data, np.uint8)
    if n == 0:
        if b.size:
            raise CodecError("varint stream has trailing bytes")
        return np.zeros(0, np.uint64)
    term = np.flatnonzero(b < 0x80)
    if b.size == 0 or b[-1] >= 0x80 or term.size != n:
        raise CodecError(f"varint stream does not hold {n} terminated values")
    # gather per byte-position: most values are 1-2 bytes, so the active
    # set collapses after the first couple of rounds (no slow ufunc.at)
    starts = np.empty(n, np.int64)
    starts[0] = 0
    starts[1:] = term[:-1] + 1
    vals = np.zeros(n, np.uint64)
    idx = starts
    active = np.arange(n)
    cont = np.zeros(0, bool)
    for j in range(10):
        bj = b[idx]
        vals[active] |= (bj & 0x7F).astype(np.uint64) << np.uint64(7 * j)
        cont = bj >= 0x80
        if not cont.any():
            break
        idx = idx[cont] + 1
        active = active[cont]
    else:
        if cont.any():
            raise CodecError("varint value overflows 64 bits")
    return vals


def bitpack(vals: np.ndarray, width: int) -> bytes:
    """Fixed-width little-endian bitpack of uint64 values < 2**width."""
    vals = np.ascontiguousarray(vals, np.uint64)
    if width == 0 or vals.size == 0:
        return b""
    bits = ((vals[:, None] >> np.arange(width, dtype=np.uint64)[None, :])
            & np.uint64(1)).astype(np.uint8)
    return np.packbits(bits.ravel(), bitorder="little").tobytes()


def bitunpack(data: bytes, n: int, width: int) -> np.ndarray:
    if width == 0 or n == 0:
        return np.zeros(n, np.uint64)
    if len(data) * 8 < n * width:
        raise CodecError("bitpacked stream too short")
    # value i lives at bit offset i*width: gather the 8-byte window that
    # covers it and shift/mask — no per-bit expansion (width <= 32 < 57,
    # so one little-endian u64 window always spans a value)
    padded = np.zeros(len(data) + 8, np.uint8)
    padded[: len(data)] = np.frombuffer(data, np.uint8)
    starts = np.arange(n, dtype=np.int64) * width
    idx = (starts >> 3)[:, None] + np.arange(8, dtype=np.int64)
    words = padded[idx].view("<u8").ravel()
    return (words >> (starts & 7).astype(np.uint64)) \
        & np.uint64((1 << width) - 1)


# ---------------------------------------------------------------------------
# per-array encode/decode
# ---------------------------------------------------------------------------

def _dtype_token(a: np.ndarray) -> bytes:
    # dtype.str is '<V2' for ml_dtypes types (bfloat16 &c.) — the *name*
    # round-trips through np.dtype() once ml_dtypes is imported
    ds = a.dtype.str
    return (a.dtype.name if ds.startswith(("<V", "|V", ">V")) else ds).encode()


def _int_bits(a: np.ndarray) -> np.ndarray:
    """Any integer/bool array -> its int64 bit patterns (bijective per
    dtype: decode casts back, wrapping to the original bits)."""
    return a.ravel().astype(np.int64)


def _encode_array(a: np.ndarray) -> tuple[int, bytes, bytes]:
    """-> (method, params, payload), smallest candidate wins."""
    raw = a.tobytes()
    if a.dtype.kind not in "iub" or a.size < _MIN_TRY:
        return M_RAW, b"", raw
    w = _int_bits(a)
    cands: list[tuple[int, int, bytes, bytes]] = [(len(raw), M_RAW, b"", raw)]
    zz = varint_encode(_zigzag(w))
    cands.append((len(zz), M_VARINT, b"", zz))
    d = np.empty_like(w)
    d[0] = w[0]
    d[1:] = w[1:] - w[:-1]          # modular — wrap-around still roundtrips
    dz = varint_encode(_zigzag(d))
    cands.append((len(dz), M_DELTA, b"", dz))
    dd = np.empty_like(d)
    dd[0] = d[0]
    dd[1:] = d[1:] - d[:-1]
    ddz = varint_encode(_zigzag(dd))
    cands.append((len(ddz), M_DOD, b"", ddz))
    mn, mx = int(w.min()), int(w.max())
    width = (mx - mn).bit_length()
    if width <= 32:
        bp = bitpack((w - np.int64(mn)).view(np.uint64), width)
        cands.append((len(bp), M_BITPACK, _struct.pack("<qB", mn, width), bp))
    cands.sort(key=lambda c: (c[0], c[1]))
    _, method, params, payload = cands[0]
    return method, params, payload


def _decode_array(method: int, params: bytes, payload: bytes,
                  dtype: np.dtype, shape: tuple[int, ...]) -> np.ndarray:
    n = 1
    for s in shape:
        n *= s
    if method == M_RAW:
        if len(payload) != n * dtype.itemsize:
            raise CodecError("raw array payload has wrong length")
        return np.frombuffer(payload, dtype=dtype).reshape(shape)
    if method == M_BITPACK:
        if len(params) != 9:
            raise CodecError("bitpack params malformed")
        mn, width = _struct.unpack("<qB", params)
        w = (bitunpack(payload, n, width).view(np.int64)
             + np.int64(mn))
    else:
        u = varint_decode(payload, n)
        w = _unzigzag(u)
        if method == M_DOD:
            w = np.cumsum(w)
        if method in (M_DELTA, M_DOD):
            w = np.cumsum(w)
        elif method != M_VARINT:
            raise CodecError(f"unknown array method {method}")
    return w.astype(dtype, copy=False).reshape(shape)


# ---------------------------------------------------------------------------
# raw (legacy) bundle format — byte-compatible with pre-codec blobs
# ---------------------------------------------------------------------------

def _pack_raw(arrays: dict[str, np.ndarray]) -> bytes:
    out = [_struct.pack("<I", len(arrays))]
    for name, a in arrays.items():
        a = np.ascontiguousarray(a)
        nb = name.encode()
        dt = _dtype_token(a)
        out.append(_struct.pack("<I", len(nb)) + nb)
        out.append(_struct.pack("<I", len(dt)) + dt)
        out.append(_struct.pack("<I", a.ndim) + _struct.pack(f"<{a.ndim}q", *a.shape))
        raw = a.tobytes()
        out.append(_struct.pack("<Q", len(raw)) + raw)
    return b"".join(out)


def _unpack_raw(data: bytes) -> dict[str, np.ndarray]:
    try:
        pos = 0
        (n,) = _struct.unpack_from("<I", data, pos); pos += 4
        out: dict[str, np.ndarray] = {}
        for _ in range(n):
            (ln,) = _struct.unpack_from("<I", data, pos); pos += 4
            name = data[pos:pos + ln].decode(); pos += ln
            (ld,) = _struct.unpack_from("<I", data, pos); pos += 4
            dt = data[pos:pos + ld].decode(); pos += ld
            (nd,) = _struct.unpack_from("<I", data, pos); pos += 4
            shape = _struct.unpack_from(f"<{nd}q", data, pos); pos += 8 * nd
            (nraw,) = _struct.unpack_from("<Q", data, pos); pos += 8
            if pos + nraw > len(data):
                raise CodecError("raw bundle truncated mid-array")
            a = np.frombuffer(data[pos:pos + nraw],
                              dtype=np.dtype(dt)).reshape(shape)
            pos += nraw
            out[name] = a
        return out
    except CodecError:
        raise
    except Exception as e:
        raise CodecError(f"not a decodable raw array bundle: {e!r}") from e


# ---------------------------------------------------------------------------
# v2 blob
# ---------------------------------------------------------------------------

def _checksum(body: bytes) -> int:
    return zlib.crc32(body) & 0xFFFFFFFF


class _Reader:
    """Bounds-checked cursor — every overrun is a CodecError."""

    __slots__ = ("data", "pos")

    _structs: dict[str, _struct.Struct] = {}

    def __init__(self, data: bytes) -> None:
        self.data = data
        self.pos = 0

    def take(self, n: int) -> bytes:
        if self.pos + n > len(self.data):
            raise CodecError("blob body truncated")
        out = self.data[self.pos:self.pos + n]
        self.pos += n
        return out

    def unpack(self, fmt: str):
        s = self._structs.get(fmt)
        if s is None:
            s = self._structs[fmt] = _struct.Struct(fmt)
        if self.pos + s.size > len(self.data):
            raise CodecError("blob body truncated")
        out = s.unpack_from(self.data, self.pos)
        self.pos += s.size
        return out


# Cooperative-yield hook for background encoders.  A thread that encodes
# large bundles while latency-sensitive readers share the interpreter
# (the ingest fold worker) installs a per-thread hook; _encode_v2 calls
# it between arrays so no single pack_arrays() is a multi-ms GIL hold.
# Thread-local on purpose: readers and foreground builds are unaffected.
_nice_tl = threading.local()


def set_encode_nice(hook) -> None:
    """Install (or clear, with ``None``) this thread's between-array
    encode yield hook."""
    _nice_tl.hook = hook


def _encode_nice() -> None:
    hook = getattr(_nice_tl, "hook", None)
    if hook is not None:
        hook()


def set_decode_nice(hook) -> None:
    """Install (or clear, with ``None``) this thread's between-array
    *decode* yield hook — the read-side mirror of :func:`set_encode_nice`.
    Prefetcher workers decode payloads while the apply thread drives
    device kernels on the same interpreter; yielding between arrays keeps
    any single ``decode_blob`` from becoming a multi-ms GIL hold in the
    double-buffered pipeline."""
    _nice_tl.decode_hook = hook


def _decode_nice() -> None:
    hook = getattr(_nice_tl, "decode_hook", None)
    if hook is not None:
        hook()


def _encode_v2(arrays: dict[str, np.ndarray]) -> bytes:
    recs = [_struct.pack("<I", len(arrays))]
    raw_size = 0
    for name, a in arrays.items():
        _encode_nice()
        a = np.ascontiguousarray(a)
        raw_size += a.nbytes
        nb = name.encode()
        dt = _dtype_token(a)
        method, params, payload = _encode_array(a)
        recs.append(_struct.pack("<B", len(nb)) + nb)
        recs.append(_struct.pack("<B", len(dt)) + dt)
        recs.append(_struct.pack("<B", a.ndim)
                    + _struct.pack(f"<{a.ndim}q", *a.shape))
        recs.append(_struct.pack("<BB", method, len(params)) + params)
        recs.append(_struct.pack("<Q", len(payload)) + payload)
    body = b"".join(recs)
    flags = 0
    level = _entropy_level(body)
    if level is not None:
        comp = zlib.compress(body, level)
        if len(comp) < len(body):
            body = comp
            flags |= F_ZLIB
    header = _HEADER.pack(MAGIC, VERSION, flags, 0, raw_size,
                          _checksum(body))
    return header + body


def _entropy_level(body: bytes) -> int | None:
    """Pick the zlib effort for a body (None = skip the stage).  Large
    bodies probe a prefix at the fastest level first: float-heavy
    payloads (checkpoint shards, raw parameter tensors) shrink barely or
    not at all, and paying level-``ZLIB_LEVEL`` over hundreds of MB for
    a few percent would tax the checkpoint path — incompressible bodies
    skip the stage, marginal ones take the cheapest pass, and only
    clearly compressible bodies get the full effort."""
    if len(body) < _MIN_ZLIB:
        return None
    if len(body) <= _PROBE_FROM:
        return ZLIB_LEVEL
    sample = body[: _PROBE_FROM]
    ratio = len(zlib.compress(sample, 1)) / len(sample)
    if ratio >= 0.90:      # <10% win: not worth ~10 MB/s deflate cost
        return None
    if ratio >= 0.80:
        return 1
    return ZLIB_LEVEL


def _decode_v2(blob: bytes) -> dict[str, np.ndarray]:
    if len(blob) < _HEADER_LEN:
        raise CodecError("truncated blob header")
    magic, version, flags, _rsvd, _raw_size, csum = _HEADER.unpack_from(blob)
    if magic != MAGIC:  # pragma: no cover - callers sniff first
        raise CodecError("bad magic")
    if version != VERSION:
        raise CodecError(f"unknown codec version {version}")
    body = blob[_HEADER_LEN:]
    if _checksum(body) != csum:
        raise CodecError("blob checksum mismatch (corrupt or truncated)")
    if flags & F_ZLIB:
        try:
            body = zlib.decompress(body)
        except zlib.error as e:
            raise CodecError(f"entropy stage failed: {e}") from e
    r = _Reader(body)
    (n,) = r.unpack("<I")
    out: dict[str, np.ndarray] = {}
    for _ in range(n):
        _decode_nice()
        (ln,) = r.unpack("<B")
        name = r.take(ln).decode()
        (ld,) = r.unpack("<B")
        try:
            dtype = np.dtype(r.take(ld).decode())
        except TypeError as e:
            raise CodecError(f"unknown dtype in blob: {e}") from e
        (nd,) = r.unpack("<B")
        shape = r.unpack(f"<{nd}q") if nd else ()
        method, plen = r.unpack("<BB")
        params = r.take(plen)
        (enc_len,) = r.unpack("<Q")
        payload = r.take(enc_len)
        out[name] = _decode_array(method, params, payload, dtype,
                                  tuple(int(s) for s in shape))
    return out


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def encode_blob(arrays: dict[str, np.ndarray], codec: str | None = None) -> bytes:
    name = codec if codec is not None else _default_codec
    if name == "v2":
        return _encode_v2(arrays)
    if name == "raw":
        return _pack_raw(arrays)
    raise CodecError(f"unknown codec {name!r}; known: {KNOWN_CODECS}")


# ---------------------------------------------------------------------------
# decoded-payload cache (content-addressed)
# ---------------------------------------------------------------------------
# Hot payloads — the skeleton prefix every plan descends through — are
# decoded once, not once per retrieval.  The cache key is the *blob bytes
# themselves* (dict equality on hash match), so an overwritten payload can
# never serve its stale decode and no invalidation protocol exists at all.
# Cached bundles are marked read-only; every current consumer either reads
# or concatenates (copies) them, and a future mutating caller fails loudly
# instead of corrupting the cache.

_cache_max = int(float(os.environ.get("REPRO_CODEC_CACHE_MB", "64")) * 2**20)
_cache: "OrderedDict[bytes, dict[str, np.ndarray]]" = OrderedDict()
_cache_bytes = 0
_cache_lock = threading.Lock()
decode_cache_stats = {"hits": 0, "misses": 0}


def set_decode_cache_bytes(nbytes: int) -> None:
    """Resize (0 disables) and clear the decoded-payload cache."""
    global _cache_max, _cache_bytes
    with _cache_lock:
        _cache_max = int(nbytes)
        _cache.clear()
        _cache_bytes = 0
        decode_cache_stats["hits"] = decode_cache_stats["misses"] = 0


def _entry_bytes(blob: bytes, out: dict) -> int:
    return len(blob) + sum(int(a.nbytes) for a in out.values())


def _freeze(out: dict) -> dict:
    for a in out.values():
        a.flags.writeable = False
    return out


def decode_blob(blob: bytes) -> dict[str, np.ndarray]:
    """Decode any blob this system ever wrote.  Sniffs the v2 magic;
    anything else goes through the legacy raw parser (pre-codec blobs
    keep decoding).  Malformed input raises :class:`CodecError`.
    Returned arrays are read-only (they may be served from the decoded-
    payload cache); copy before mutating."""
    if _cache_max:
        with _cache_lock:
            hit = _cache.get(blob)
            if hit is not None:
                _cache.move_to_end(blob)
                decode_cache_stats["hits"] += 1
                return hit
            decode_cache_stats["misses"] += 1
    if len(blob) >= len(MAGIC) and blob[: len(MAGIC)] == MAGIC:
        out = _freeze(_decode_v2(blob))
    else:
        out = _freeze(_unpack_raw(blob))
    if _cache_max:
        nb = _entry_bytes(blob, out)
        if nb <= _cache_max // 8:
            global _cache_bytes
            with _cache_lock:
                if blob not in _cache:
                    _cache[blob] = out
                    _cache_bytes += nb
                    while _cache_bytes > _cache_max and _cache:
                        k, v = _cache.popitem(last=False)
                        _cache_bytes -= _entry_bytes(k, v)
    return out


def blob_info(blob: bytes) -> dict:
    """Cheap header-only inspection: codec, stored vs logical bytes."""
    if len(blob) >= len(MAGIC) and blob[: len(MAGIC)] == MAGIC:
        if len(blob) < _HEADER_LEN:
            raise CodecError("truncated blob header")
        _m, version, flags, _r, raw_size, _c = _HEADER.unpack_from(blob)
        return {"codec": "v2", "version": version,
                "stored_bytes": len(blob), "logical_bytes": int(raw_size),
                "zlib": bool(flags & F_ZLIB)}
    # legacy: skim the array headers, skip the payloads
    try:
        pos = 0
        (n,) = _struct.unpack_from("<I", blob, pos); pos += 4
        logical = 0
        for _ in range(n):
            (ln,) = _struct.unpack_from("<I", blob, pos); pos += 4 + ln
            (ld,) = _struct.unpack_from("<I", blob, pos); pos += 4 + ld
            (nd,) = _struct.unpack_from("<I", blob, pos); pos += 4 + 8 * nd
            (nraw,) = _struct.unpack_from("<Q", blob, pos); pos += 8 + nraw
            logical += nraw
        return {"codec": "raw", "version": 1, "stored_bytes": len(blob),
                "logical_bytes": logical, "zlib": False}
    except Exception as e:
        raise CodecError(f"unrecognized blob: {e!r}") from e
