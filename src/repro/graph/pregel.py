"""Pregel-like vertex-centric iteration (paper §3.2 / §7: "we have
implemented an iterative vertex-based message-passing system analogous to
Pregel").

``run_pregel`` executes supersteps of

    messages = msg_fn(state[src], state[dst], edge_live)
    agg      = segment_sum(messages, dst)
    state    = update_fn(state, agg, superstep)

on a masked snapshot; distribution comes for free by jitting with node-
sharded inputs (the paper's partition-per-machine).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..core import bitmaps as bm


def run_pregel(state0: jnp.ndarray, edge_src: jnp.ndarray,
               edge_dst: jnp.ndarray, edge_plane: jnp.ndarray,
               msg_fn: Callable, update_fn: Callable, *,
               num_supersteps: int, num_nodes: int,
               bidirectional: bool = True) -> jnp.ndarray:
    E = edge_src.shape[0]
    emask = bm.unpack(edge_plane, E)

    def superstep(state, step):
        m = msg_fn(state[edge_src], state[edge_dst], emask)
        agg = jax.ops.segment_sum(m, edge_dst, num_segments=num_nodes)
        if bidirectional:
            m2 = msg_fn(state[edge_dst], state[edge_src], emask)
            agg = agg + jax.ops.segment_sum(m2, edge_src,
                                            num_segments=num_nodes)
        return update_fn(state, agg, step), None

    state, _ = jax.lax.scan(superstep, state0,
                            jnp.arange(num_supersteps))
    return state


def run_pregel_until(state0: jnp.ndarray, edge_src: jnp.ndarray,
                     edge_dst: jnp.ndarray, edge_plane: jnp.ndarray,
                     msg_fn: Callable, update_fn: Callable, *,
                     max_supersteps: int, num_nodes: int,
                     tol: float = 0.0, bidirectional: bool = True
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Convergence-checked Pregel: supersteps run until the state's L1
    change drops to ``tol`` (or ``max_supersteps``).  This is the
    warm-start hook for interval analytics (:mod:`repro.core.temporal`):
    seeding ``state0`` with the previous snapshot's converged state makes
    the superstep count proportional to how much the snapshot actually
    changed, not to the graph's diameter.  Returns ``(state, steps_used)``."""
    E = edge_src.shape[0]
    emask = bm.unpack(edge_plane, E)

    def one(state, step):
        m = msg_fn(state[edge_src], state[edge_dst], emask)
        agg = jax.ops.segment_sum(m, edge_dst, num_segments=num_nodes)
        if bidirectional:
            m2 = msg_fn(state[edge_dst], state[edge_src], emask)
            agg = agg + jax.ops.segment_sum(m2, edge_src,
                                            num_segments=num_nodes)
        return update_fn(state, agg, step)

    def cond(carry):
        _, delta, i = carry
        return (delta > tol) & (i < max_supersteps)

    def body(carry):
        state, _, i = carry
        new = one(state, i)
        delta = jnp.abs(new.astype(jnp.float32)
                        - state.astype(jnp.float32)).sum()
        return new, delta, i + 1

    state, _, steps = jax.lax.while_loop(
        cond, body, (state0, jnp.float32(jnp.inf), jnp.int32(0)))
    return state, steps
