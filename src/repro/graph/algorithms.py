"""Graph analytics over GraphPool bitmap planes.

Every algorithm takes the union graph's edge list plus a *packed edge
bitmap* (one GraphPool plane) and runs on the masked subgraph — this is
the paper's "execute analyses against overlaid snapshots" path (§6,
bitmap-penalty experiment).  ``vmap`` over stacked planes evaluates many
snapshots at once (multipoint analytics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core import bitmaps as bm


def edge_mask_from_plane(plane: jnp.ndarray, num_edges: int) -> jnp.ndarray:
    return bm.unpack(plane, num_edges)


@functools.partial(jax.jit, static_argnames=("num_nodes", "iters"))
def pagerank(edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
             edge_plane: jnp.ndarray, node_plane: jnp.ndarray, *,
             num_nodes: int, iters: int = 20,
             damping: float = 0.85) -> jnp.ndarray:
    """Masked PageRank treating undirected edges as both directions."""
    E = edge_src.shape[0]
    emask = bm.unpack(edge_plane, E).astype(jnp.float32)
    nmask = bm.unpack(node_plane, num_nodes).astype(jnp.float32)
    deg = (jax.ops.segment_sum(emask, edge_src, num_segments=num_nodes)
           + jax.ops.segment_sum(emask, edge_dst, num_segments=num_nodes))
    inv_deg = jnp.where(deg > 0, 1.0 / jnp.maximum(deg, 1), 0.0)
    n_live = jnp.maximum(nmask.sum(), 1.0)

    def step(pr, _):
        contrib = pr * inv_deg
        agg = (jax.ops.segment_sum(contrib[edge_src] * emask, edge_dst,
                                   num_segments=num_nodes)
               + jax.ops.segment_sum(contrib[edge_dst] * emask, edge_src,
                                     num_segments=num_nodes))
        dangling = (pr * (deg == 0)).sum()
        pr2 = nmask * ((1 - damping) / n_live
                       + damping * (agg + dangling / n_live))
        return pr2, None

    pr0 = nmask / n_live
    pr, _ = jax.lax.scan(step, pr0, None, length=iters)
    return pr


@functools.partial(jax.jit, static_argnames=("num_nodes",))
def degrees_masked(edge_src, edge_dst, edge_plane, *, num_nodes: int):
    E = edge_src.shape[0]
    emask = bm.unpack(edge_plane, E).astype(jnp.int32)
    return (jax.ops.segment_sum(emask, edge_src, num_segments=num_nodes)
            + jax.ops.segment_sum(emask, edge_dst, num_segments=num_nodes))


@functools.partial(jax.jit, static_argnames=("num_nodes", "iters"))
def connected_components(edge_src, edge_dst, edge_plane, node_plane, *,
                         num_nodes: int, iters: int = 50):
    """Label propagation: min-label flooding (HashMin), masked."""
    E = edge_src.shape[0]
    emask = bm.unpack(edge_plane, E)
    nmask = bm.unpack(node_plane, num_nodes)
    big = jnp.iinfo(jnp.int32).max
    labels0 = jnp.where(nmask, jnp.arange(num_nodes, dtype=jnp.int32), big)

    def step(lab, _):
        src_l = jnp.where(emask, lab[edge_src], big)
        dst_l = jnp.where(emask, lab[edge_dst], big)
        m1 = jax.ops.segment_min(src_l, edge_dst, num_segments=num_nodes)
        m2 = jax.ops.segment_min(dst_l, edge_src, num_segments=num_nodes)
        new = jnp.minimum(lab, jnp.minimum(m1, m2))
        return jnp.where(nmask, new, big), None

    labels, _ = jax.lax.scan(step, labels0, None, length=iters)
    return labels


def triangle_count(edge_src: np.ndarray, edge_dst: np.ndarray,
                   edge_mask: np.ndarray, num_nodes: int) -> int:
    """Host-side exact triangle count on the masked subgraph (numpy;
    used by evolution analyses — 'how many new triangles this year')."""
    eid = np.nonzero(edge_mask)[0]
    s, d = edge_src[eid], edge_dst[eid]
    lo, hi = np.minimum(s, d), np.maximum(s, d)
    keep = lo != hi
    pairs = np.unique(np.stack([lo[keep], hi[keep]], 1), axis=0)
    adj: dict[int, set] = {}
    for a, b in pairs:
        adj.setdefault(int(a), set()).add(int(b))
    count = 0
    for a, nbrs in adj.items():
        for b in nbrs:
            count += len(nbrs & adj.get(b, set()))
    return count // 1  # each triangle counted once: a<b<c ordering


def multi_snapshot_pagerank(edge_src, edge_dst, edge_planes, node_planes, *,
                            num_nodes: int, iters: int = 20):
    """vmap over GraphPool planes: PageRank for G snapshots in one shot."""
    fn = functools.partial(pagerank, num_nodes=num_nodes, iters=iters)
    return jax.vmap(lambda ep, np_: fn(edge_src, edge_dst, ep, np_))(
        jnp.asarray(edge_planes), jnp.asarray(node_planes))


# ---------------------------------------------------------------------------
# incremental / warm-started variants (temporal analytics, core/temporal.py)
# ---------------------------------------------------------------------------
#
# The fixpoint solvers below iterate to a *convergence criterion* instead of
# a fixed step count, so a warm start (the previous timepoint's result with
# only the delta-touched frontier reset) buys real iterations: between two
# nearby snapshots the solution barely moves, and the solver exits after a
# couple of sweeps instead of re-running the full cold schedule.  Cold and
# warm starts converge to the same fixpoint, so incremental results match a
# per-snapshot recompute up to the tolerance.


def _edge_bucket(n: int) -> int:
    """Compact live-edge arrays are padded up to a multiple of 512 so the
    jit'd fixpoint kernels stay hot in the compile cache across
    timepoints (live counts drift every snapshot).  Scatter cost scales
    with the padded length, so the granularity trades wasted lanes
    (≤ 512 elements) against recompiles (one per crossed boundary)."""
    return max(512, -(-n // 512) * 512)


def _compact_edges(edge_src: np.ndarray, edge_dst: np.ndarray,
                   edge_mask: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Drop masked-out edge slots before solving: after churn, live edges
    are a small fraction of the slot universe, and XLA-CPU scatter cost
    scales with the number of *scattered elements*, masked or not.
    Padding rows are (0, 0) with live=0 — segment-summed with zero mass,
    exactly like a masked slot."""
    live = np.nonzero(edge_mask)[0]
    Ec = _edge_bucket(live.size)
    es = np.zeros(Ec, np.int32)
    ed = np.zeros(Ec, np.int32)
    lv = np.zeros(Ec, np.float32)
    es[: live.size] = edge_src[live]
    ed[: live.size] = edge_dst[live]
    lv[: live.size] = 1.0
    return es, ed, lv


@functools.partial(jax.jit, static_argnames=("num_nodes", "max_iters"))
def _pagerank_fixpoint_kernel(edge_src, edge_dst, edge_live, node_plane,
                              pr0, damping, tol, *, num_nodes: int,
                              max_iters: int):
    nmask = bm.unpack(node_plane, num_nodes).astype(jnp.float32)
    deg = (jax.ops.segment_sum(edge_live, edge_src, num_segments=num_nodes)
           + jax.ops.segment_sum(edge_live, edge_dst, num_segments=num_nodes))
    inv_deg = jnp.where(deg > 0, 1.0 / jnp.maximum(deg, 1), 0.0)
    n_live = jnp.maximum(nmask.sum(), 1.0)
    # project the start onto the live-node simplex (masks may have changed)
    pr0 = jnp.maximum(pr0, 0.0) * nmask
    s0 = pr0.sum()
    pr0 = jnp.where(s0 > 0, pr0 / jnp.maximum(s0, 1e-30), nmask / n_live)

    def step(pr):
        contrib = pr * inv_deg
        agg = (jax.ops.segment_sum(contrib[edge_src] * edge_live, edge_dst,
                                   num_segments=num_nodes)
               + jax.ops.segment_sum(contrib[edge_dst] * edge_live, edge_src,
                                     num_segments=num_nodes))
        dangling = (pr * (deg == 0)).sum()
        return nmask * ((1 - damping) / n_live
                        + damping * (agg + dangling / n_live))

    def cond(carry):
        _, delta, i = carry
        return (delta > tol) & (i < max_iters)

    def body(carry):
        pr, _, i = carry
        pr2 = step(pr)
        return pr2, jnp.abs(pr2 - pr).sum(), i + 1

    pr, _, iters = jax.lax.while_loop(
        cond, body, (pr0, jnp.float32(jnp.inf), jnp.int32(0)))
    return pr, iters


@functools.partial(jax.jit, static_argnames=("max_iters",))
def _pagerank_fixpoint_dense(A, nmask, pr0, damping, tol, *,
                             max_iters: int):
    """Dense-adjacency variant of the same iteration: ``agg = A @
    (pr/deg)`` with ``A[i, j]`` = live-edge multiplicity — identical math
    to the segment formulation, but a matvec instead of scatters (XLA-CPU
    scatter cost is per scattered element; for small N the N² matvec is
    an order of magnitude cheaper)."""
    deg = A.sum(1)
    inv_deg = jnp.where(deg > 0, 1.0 / jnp.maximum(deg, 1), 0.0)
    n_live = jnp.maximum(nmask.sum(), 1.0)
    pr0 = jnp.maximum(pr0, 0.0) * nmask
    s0 = pr0.sum()
    pr0 = jnp.where(s0 > 0, pr0 / jnp.maximum(s0, 1e-30), nmask / n_live)

    def body(carry):
        pr, _, i = carry
        agg = A @ (pr * inv_deg)
        dangling = (pr * (deg == 0)).sum()
        pr2 = nmask * ((1 - damping) / n_live
                       + damping * (agg + dangling / n_live))
        return pr2, jnp.abs(pr2 - pr).sum(), i + 1

    pr, _, iters = jax.lax.while_loop(
        lambda c: (c[1] > tol) & (c[2] < max_iters), body,
        (pr0, jnp.float32(jnp.inf), jnp.int32(0)))
    return pr, iters


# above this node count the dense [N, N] adjacency (4·N² bytes) stops
# paying for itself and the compact segment kernel takes over
DENSE_PAGERANK_MAX_NODES = 1024


def pagerank_fixpoint(edge_src, edge_dst, edge_plane, node_plane, pr0, *,
                      num_nodes: int, max_iters: int = 200,
                      damping: float = 0.85, tol: float = 1e-6,
                      force_impl: str | None = None
                      ) -> tuple[np.ndarray, int]:
    """Masked PageRank iterated until the L1 step change drops under
    ``tol`` (or ``max_iters``).  ``pr0`` is the starting vector — pass the
    previous snapshot's ranks (with the touched frontier reset) for the
    incremental path, or a uniform vector for a cold solve.  Returns
    ``(pr, iters_used)``; the fixpoint is unique, so the result does not
    depend on ``pr0`` beyond the tolerance.

    Host wrapper: compacts the edge list to the live slots and picks the
    dense-matvec kernel for small node universes
    (``DENSE_PAGERANK_MAX_NODES``) or the bucketed segment kernel above
    it — same semantics as solving over the full masked slot universe,
    at live-edge cost.  ``force_impl`` ("dense" | "segment") pins the
    kernel, for the equivalence tests."""
    edge_src = np.asarray(edge_src)
    edge_dst = np.asarray(edge_dst)
    E = edge_src.shape[0]
    emask = bm.np_unpack(np.asarray(edge_plane), E)
    impl = force_impl or ("dense" if num_nodes <= DENSE_PAGERANK_MAX_NODES
                          else "segment")
    nmask = bm.np_unpack(np.asarray(node_plane), num_nodes
                         ).astype(np.float32)
    if impl == "dense":
        live = np.nonzero(emask)[0]
        A = np.zeros((num_nodes, num_nodes), np.float32)
        np.add.at(A, (edge_src[live], edge_dst[live]), 1.0)
        np.add.at(A, (edge_dst[live], edge_src[live]), 1.0)
        pr, iters = _pagerank_fixpoint_dense(
            jnp.asarray(A), jnp.asarray(nmask),
            jnp.asarray(pr0, jnp.float32), jnp.float32(damping),
            jnp.float32(tol), max_iters=max_iters)
    else:
        es, ed, lv = _compact_edges(edge_src, edge_dst, emask)
        pr, iters = _pagerank_fixpoint_kernel(
            jnp.asarray(es), jnp.asarray(ed), jnp.asarray(lv),
            jnp.asarray(node_plane), jnp.asarray(pr0, jnp.float32),
            jnp.float32(damping), jnp.float32(tol),
            num_nodes=num_nodes, max_iters=max_iters)
    return np.asarray(pr), int(iters)


def pagerank_warm_start(prev_pr: np.ndarray, node_mask: np.ndarray,
                        touched: np.ndarray) -> np.ndarray:
    """Build a warm-start vector from the previous ranks: delta-touched
    nodes (endpoints of changed edges, added/removed nodes) are reset to
    the uniform baseline so stale mass does not slow convergence; every
    other live node keeps its rank."""
    n_live = max(int(node_mask.sum()), 1)
    pr0 = np.where(node_mask, np.maximum(prev_pr, 0.0), 0.0).astype(np.float32)
    if touched.size:
        t = touched[touched < pr0.size]
        pr0[t] = 1.0 / n_live
    pr0 *= node_mask
    s = pr0.sum()
    return (pr0 / s if s > 0
            else node_mask.astype(np.float32) / n_live)


@functools.partial(jax.jit, static_argnames=("num_nodes", "max_iters"))
def _cc_fixpoint_kernel(edge_src, edge_dst, edge_live, node_plane, labels0,
                        *, num_nodes: int, max_iters: int):
    nmask = bm.unpack(node_plane, num_nodes)
    big = jnp.iinfo(jnp.int32).max
    labels0 = jnp.where(nmask, labels0.astype(jnp.int32), big)
    emask = edge_live > 0

    def sweep(lab):
        src_l = jnp.where(emask, lab[edge_src], big)
        dst_l = jnp.where(emask, lab[edge_dst], big)
        m1 = jax.ops.segment_min(src_l, edge_dst, num_segments=num_nodes)
        m2 = jax.ops.segment_min(dst_l, edge_src, num_segments=num_nodes)
        new = jnp.minimum(lab, jnp.minimum(m1, m2))
        return jnp.where(nmask, new, big)

    def cond(carry):
        _, changed, i = carry
        return changed & (i < max_iters)

    def body(carry):
        lab, _, i = carry
        new = sweep(lab)
        return new, jnp.any(new != lab), i + 1

    labels, _, iters = jax.lax.while_loop(
        cond, body, (labels0, jnp.bool_(True), jnp.int32(0)))
    return labels, iters


def connected_components_fixpoint(edge_src, edge_dst, edge_plane, node_plane,
                                  labels0, *, num_nodes: int,
                                  max_iters: int = 4096
                                  ) -> tuple[np.ndarray, int]:
    """HashMin label flooding run to its fixpoint (no label changes).

    Starting labels must satisfy the warm-start contract: within every
    component the minimum starting label equals the component's true label
    (the min live node id), and no node starts below its component's true
    label.  ``arange`` (cold) and the incremental reset of
    :func:`cc_warm_labels` both satisfy it, and then the fixpoint is
    exactly the cold answer.  Returns ``(labels, iters_used)``.

    Host wrapper compacting to live edges, like
    :func:`pagerank_fixpoint`."""
    E = np.asarray(edge_src).shape[0]
    emask = bm.np_unpack(np.asarray(edge_plane), E)
    es, ed, lv = _compact_edges(np.asarray(edge_src), np.asarray(edge_dst),
                                emask)
    labels, iters = _cc_fixpoint_kernel(
        jnp.asarray(es), jnp.asarray(ed), jnp.asarray(lv),
        jnp.asarray(node_plane), jnp.asarray(labels0),
        num_nodes=num_nodes, max_iters=max_iters)
    return np.asarray(labels), int(iters)


def cc_warm_labels(prev_labels: np.ndarray, node_mask: np.ndarray,
                   quad_nodes: tuple[np.ndarray, np.ndarray],
                   quad_edges: tuple[np.ndarray, np.ndarray],
                   edge_src: np.ndarray, edge_dst: np.ndarray) -> np.ndarray:
    """Incremental starting labels for :func:`connected_components_fixpoint`.

    Only *affected* components are re-unioned: components that lost an edge
    or a node are reset to per-node singleton labels (a deletion may have
    split them, and their old minimum id may even be the deleted node's);
    components touched solely by additions keep their labels — added edges
    are pre-merged with a host union-find so a merge costs O(1) flooding
    sweeps instead of O(diameter).  Untouched components keep their
    converged labels and contribute nothing to the remaining sweeps."""
    node_add, node_del = quad_nodes
    edge_add, edge_del = quad_edges
    big = np.iinfo(np.int32).max
    labels = np.where(node_mask, prev_labels.astype(np.int64), big).copy()

    # 1. reset components affected by deletions (splits) to singletons
    affected = set()
    for e in np.asarray(edge_del, np.int64):
        for end in (edge_src[e], edge_dst[e]):
            if prev_labels[end] != big:
                affected.add(int(prev_labels[end]))
    for s in np.asarray(node_del, np.int64):
        if prev_labels[s] != big:
            affected.add(int(prev_labels[s]))
    if affected:
        reset = np.isin(prev_labels, list(affected)) & node_mask
        labels[reset] = np.nonzero(reset)[0]

    # 2. new nodes start as singletons
    na = np.asarray(node_add, np.int64)
    na = na[na < labels.size]
    labels[na[node_mask[na]]] = na[node_mask[na]]

    # 3. pre-merge added edges with a tiny union-find over labels
    parent: dict[int, int] = {}

    def find(x: int) -> int:
        r = x
        while parent.get(r, r) != r:
            r = parent[r]
        while parent.get(x, x) != x:
            parent[x], x = r, parent[x]
        return r

    merged = False
    for e in np.asarray(edge_add, np.int64):
        u, v = int(edge_src[e]), int(edge_dst[e])
        if not (node_mask[u] and node_mask[v]):
            continue
        ra, rb = find(int(labels[u])), find(int(labels[v]))
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)
            merged = True
    if merged:
        touched = np.fromiter(parent.keys(), np.int64)
        roots = np.array([find(int(t)) for t in touched], np.int64)
        remap = dict(zip(touched.tolist(), roots.tolist()))
        uniq, inv = np.unique(labels, return_inverse=True)
        uniq = np.array([remap.get(int(u), int(u)) for u in uniq], np.int64)
        labels = uniq[inv]

    labels = np.where(node_mask, labels, big)
    return np.clip(labels, None, big).astype(np.int32)


def incremental_degrees(deg: np.ndarray, edge_add: np.ndarray,
                        edge_del: np.ndarray, edge_src: np.ndarray,
                        edge_dst: np.ndarray) -> np.ndarray:
    """Advance a dense degree vector by a net inter-snapshot edge delta
    (``edge_add``/``edge_del`` are *net* slot sets — an edge added and
    deleted inside the slice appears in neither).  O(|delta|), matching
    :func:`degrees_masked`'s convention (live edges count both endpoints,
    node mask not consulted)."""
    out = deg.copy()
    for slots, sign in ((np.asarray(edge_add, np.int64), 1),
                       (np.asarray(edge_del, np.int64), -1)):
        if slots.size:
            np.add.at(out, edge_src[slots], sign)
            np.add.at(out, edge_dst[slots], sign)
    return out
