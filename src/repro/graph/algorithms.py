"""Graph analytics over GraphPool bitmap planes.

Every algorithm takes the union graph's edge list plus a *packed edge
bitmap* (one GraphPool plane) and runs on the masked subgraph — this is
the paper's "execute analyses against overlaid snapshots" path (§6,
bitmap-penalty experiment).  ``vmap`` over stacked planes evaluates many
snapshots at once (multipoint analytics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core import bitmaps as bm


def edge_mask_from_plane(plane: jnp.ndarray, num_edges: int) -> jnp.ndarray:
    return bm.unpack(plane, num_edges)


@functools.partial(jax.jit, static_argnames=("num_nodes", "iters"))
def pagerank(edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
             edge_plane: jnp.ndarray, node_plane: jnp.ndarray, *,
             num_nodes: int, iters: int = 20,
             damping: float = 0.85) -> jnp.ndarray:
    """Masked PageRank treating undirected edges as both directions."""
    E = edge_src.shape[0]
    emask = bm.unpack(edge_plane, E).astype(jnp.float32)
    nmask = bm.unpack(node_plane, num_nodes).astype(jnp.float32)
    deg = (jax.ops.segment_sum(emask, edge_src, num_segments=num_nodes)
           + jax.ops.segment_sum(emask, edge_dst, num_segments=num_nodes))
    inv_deg = jnp.where(deg > 0, 1.0 / jnp.maximum(deg, 1), 0.0)
    n_live = jnp.maximum(nmask.sum(), 1.0)

    def step(pr, _):
        contrib = pr * inv_deg
        agg = (jax.ops.segment_sum(contrib[edge_src] * emask, edge_dst,
                                   num_segments=num_nodes)
               + jax.ops.segment_sum(contrib[edge_dst] * emask, edge_src,
                                     num_segments=num_nodes))
        dangling = (pr * (deg == 0)).sum()
        pr2 = nmask * ((1 - damping) / n_live
                       + damping * (agg + dangling / n_live))
        return pr2, None

    pr0 = nmask / n_live
    pr, _ = jax.lax.scan(step, pr0, None, length=iters)
    return pr


@functools.partial(jax.jit, static_argnames=("num_nodes",))
def degrees_masked(edge_src, edge_dst, edge_plane, *, num_nodes: int):
    E = edge_src.shape[0]
    emask = bm.unpack(edge_plane, E).astype(jnp.int32)
    return (jax.ops.segment_sum(emask, edge_src, num_segments=num_nodes)
            + jax.ops.segment_sum(emask, edge_dst, num_segments=num_nodes))


@functools.partial(jax.jit, static_argnames=("num_nodes", "iters"))
def connected_components(edge_src, edge_dst, edge_plane, node_plane, *,
                         num_nodes: int, iters: int = 50):
    """Label propagation: min-label flooding (HashMin), masked."""
    E = edge_src.shape[0]
    emask = bm.unpack(edge_plane, E)
    nmask = bm.unpack(node_plane, num_nodes)
    big = jnp.iinfo(jnp.int32).max
    labels0 = jnp.where(nmask, jnp.arange(num_nodes, dtype=jnp.int32), big)

    def step(lab, _):
        src_l = jnp.where(emask, lab[edge_src], big)
        dst_l = jnp.where(emask, lab[edge_dst], big)
        m1 = jax.ops.segment_min(src_l, edge_dst, num_segments=num_nodes)
        m2 = jax.ops.segment_min(dst_l, edge_src, num_segments=num_nodes)
        new = jnp.minimum(lab, jnp.minimum(m1, m2))
        return jnp.where(nmask, new, big), None

    labels, _ = jax.lax.scan(step, labels0, None, length=iters)
    return labels


def triangle_count(edge_src: np.ndarray, edge_dst: np.ndarray,
                   edge_mask: np.ndarray, num_nodes: int) -> int:
    """Host-side exact triangle count on the masked subgraph (numpy;
    used by evolution analyses — 'how many new triangles this year')."""
    eid = np.nonzero(edge_mask)[0]
    s, d = edge_src[eid], edge_dst[eid]
    lo, hi = np.minimum(s, d), np.maximum(s, d)
    keep = lo != hi
    pairs = np.unique(np.stack([lo[keep], hi[keep]], 1), axis=0)
    adj: dict[int, set] = {}
    for a, b in pairs:
        adj.setdefault(int(a), set()).add(int(b))
    count = 0
    for a, nbrs in adj.items():
        for b in nbrs:
            count += len(nbrs & adj.get(b, set()))
    return count // 1  # each triangle counted once: a<b<c ordering


def multi_snapshot_pagerank(edge_src, edge_dst, edge_planes, node_planes, *,
                            num_nodes: int, iters: int = 20):
    """vmap over GraphPool planes: PageRank for G snapshots in one shot."""
    fn = functools.partial(pagerank, num_nodes=num_nodes, iters=iters)
    return jax.vmap(lambda ep, np_: fn(edge_src, edge_dst, ep, np_))(
        jnp.asarray(edge_planes), jnp.asarray(node_planes))
