"""CSR / edge-index utilities over the dense slot universe.

The union graph lives as flat ``edge_src``/``edge_dst`` arrays (universe
order, append-only).  Any snapshot is that array pair + a boolean edge
mask; CSR is built on demand for traversal APIs and host-side analytics,
while JAX-side analytics operate directly on (edge_index, mask) via
``segment_sum`` (JAX has no CSR SpMM — the scatter path *is* the system).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSR:
    indptr: np.ndarray   # int64[N+1]
    indices: np.ndarray  # int32[nnz] neighbor node slots
    edge_ids: np.ndarray # int32[nnz] edge slots (for attr lookup)

    @property
    def num_nodes(self) -> int:
        return self.indptr.size - 1

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]:self.indptr[u + 1]]

    def edge_slots(self, u: int) -> np.ndarray:
        return self.edge_ids[self.indptr[u]:self.indptr[u + 1]]


def build_csr(edge_src: np.ndarray, edge_dst: np.ndarray,
              num_nodes: int, edge_mask: np.ndarray | None = None,
              directed: np.ndarray | None = None) -> CSR:
    """CSR over the masked edge set; undirected edges appear both ways."""
    if edge_mask is None:
        edge_mask = np.ones(edge_src.shape, bool)
    eid = np.nonzero(edge_mask)[0].astype(np.int32)
    s, d = edge_src[eid], edge_dst[eid]
    if directed is None:
        directed = np.zeros(edge_src.shape, bool)
    bidir = ~directed[eid]
    # forward rows + reversed rows for undirected edges
    rows = np.concatenate([s, d[bidir]])
    cols = np.concatenate([d, s[bidir]])
    ids = np.concatenate([eid, eid[bidir]])
    order = np.argsort(rows, kind="stable")
    rows, cols, ids = rows[order], cols[order], ids[order]
    indptr = np.zeros(num_nodes + 1, np.int64)
    np.add.at(indptr, rows + 1, 1)
    np.cumsum(indptr, out=indptr)
    return CSR(indptr, cols.astype(np.int32), ids.astype(np.int32))


def degrees(edge_src: np.ndarray, edge_dst: np.ndarray, num_nodes: int,
            edge_mask: np.ndarray, directed: np.ndarray) -> np.ndarray:
    deg = np.zeros(num_nodes, np.int64)
    eid = np.nonzero(edge_mask)[0]
    np.add.at(deg, edge_src[eid], 1)
    bid = eid[~directed[eid]]
    np.add.at(deg, edge_dst[bid], 1)
    return deg
