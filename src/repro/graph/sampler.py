"""Layer-wise neighbor sampler (GraphSAGE-style) for ``minibatch_lg``.

Real sampler, not a stub: given CSR adjacency, sample ``fanout[i]``
neighbors per hop (with replacement when degree < fanout, as in DGL's
default), producing the padded block arrays the sampled-training step
consumes.  Output shapes are static per (batch_nodes, fanouts), so the
jitted train step never recompiles.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import CSR


@dataclasses.dataclass
class SampledBlocks:
    """Flattened multi-hop sample.  ``nodes`` are global ids of every node
    involved (seeds first); ``edge_index`` is (src, dst) into the *local*
    node numbering; ``seed_mask`` marks the loss rows."""

    nodes: np.ndarray        # int32[N_total]
    edge_index: np.ndarray   # int32[2, E_total]
    edge_mask: np.ndarray    # bool[E_total] (False = padding)
    node_mask: np.ndarray    # bool[N_total]
    n_seeds: int


def sample_blocks(csr: CSR, seeds: np.ndarray, fanouts: list[int],
                  rng: np.random.Generator) -> SampledBlocks:
    seeds = np.asarray(seeds, np.int64)
    local_of: dict[int, int] = {int(s): i for i, s in enumerate(seeds)}
    nodes: list[int] = list(map(int, seeds))
    srcs: list[int] = []
    dsts: list[int] = []
    emask: list[bool] = []
    frontier = seeds
    for f in fanouts:
        nxt: list[int] = []
        for u in frontier:
            nb = csr.neighbors(int(u))
            du = local_of[int(u)]
            if nb.size == 0:
                # pad with self-edges (masked out)
                for _ in range(f):
                    srcs.append(du)
                    dsts.append(du)
                    emask.append(False)
                continue
            take = rng.choice(nb, size=f, replace=nb.size < f)
            for v in take:
                v = int(v)
                lv = local_of.get(v)
                if lv is None:
                    lv = len(nodes)
                    local_of[v] = lv
                    nodes.append(v)
                    nxt.append(v)
                srcs.append(lv)
                dsts.append(du)
                emask.append(True)
        frontier = np.asarray(nxt, np.int64)
    return SampledBlocks(
        np.asarray(nodes, np.int32),
        np.stack([np.asarray(srcs, np.int32), np.asarray(dsts, np.int32)]),
        np.asarray(emask, bool),
        np.ones(len(nodes), bool),
        len(seeds))


def pad_blocks(b: SampledBlocks, n_nodes_pad: int, n_edges_pad: int
               ) -> SampledBlocks:
    """Pad to static shapes for jit (extra rows masked)."""
    N, E = b.nodes.size, b.edge_index.shape[1]
    assert N <= n_nodes_pad and E <= n_edges_pad, (N, E)
    nodes = np.zeros(n_nodes_pad, np.int32)
    nodes[:N] = b.nodes
    ei = np.zeros((2, n_edges_pad), np.int32)
    ei[:, :E] = b.edge_index
    em = np.zeros(n_edges_pad, bool)
    em[:E] = b.edge_mask
    nm = np.zeros(n_nodes_pad, bool)
    nm[:N] = True
    return SampledBlocks(nodes, ei, em, nm, b.n_seeds)


def sampled_shapes(batch_nodes: int, fanouts: list[int]) -> tuple[int, int]:
    """Static padded sizes for a fanout schedule (worst case: all new)."""
    n_nodes = batch_nodes
    n_edges = 0
    frontier = batch_nodes
    for f in fanouts:
        n_edges += frontier * f
        frontier = frontier * f
        n_nodes += frontier
    return n_nodes, n_edges
