"""Double-buffered host→device staging for chunked chain application.

The device retrieval path lands a ``[B, K, W]`` stack of delta bit-planes.
Built monolithically, the timeline serializes: decode/pack all K planes on
the host, one big ``device_put``, then the kernel.  :class:`DeviceStager`
chunks the K axis and pipelines the stages instead — while the kernel
applies chunk *i*, the host builds (codec-decode → ``np_from_indices``
pack) and ``device_put``s chunk *i+1*.  JAX dispatch is asynchronous, so
``apply`` returns as soon as the work is enqueued and the host immediately
moves on to staging the next chunk; with ``depth=2`` (double buffering)
exactly one chunk is ever in flight ahead of the compute stream, bounding
resident staging memory to two chunks.

Chunked application is exact: the delta chain is a left fold of bitwise
steps, so landing it ``chunk_k`` rows at a time produces bit-identical
masks (pinned by ``tests/test_device_pipeline.py``).
"""
from __future__ import annotations

import os
from collections import deque
from typing import Any, Callable, Sequence

import jax


def stream_chunk_k(default: int = 8) -> int:
    """Chunk length along K for the streamed path (``REPRO_STREAM_CHUNK``
    env override; values < 1 disable streaming — monolithic apply)."""
    try:
        return int(os.environ.get("REPRO_STREAM_CHUNK", default))
    except ValueError:
        return default


class DeviceStager:
    """Pipelines ``build → put → apply`` over a chunk sequence.

    ``put_fn`` is injectable so tests can substitute an instrumented fake
    and assert on :attr:`events` — the recorded call order proves chunk
    *i+1* is staged before chunk *i*'s apply result is consumed.
    """

    def __init__(self, depth: int = 2,
                 put_fn: Callable[[Any], Any] | None = None,
                 prefetcher=None) -> None:
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self.depth = int(depth)
        self.put_fn = put_fn if put_fn is not None else jax.device_put
        self.prefetcher = prefetcher
        self.events: list[tuple[str, int]] = []   # ("build"|"put"|"apply", i)

    def _put(self, host_chunk: Sequence[Any], idx: int) -> tuple:
        dev = tuple(self.put_fn(h) for h in host_chunk)
        self.events.append(("put", idx))
        return dev

    def _build(self, build_chunk, idx: int):
        host = build_chunk(idx)
        self.events.append(("build", idx))
        return host

    def stream(self, num_chunks: int, build_chunk: Callable[[int], Sequence],
               apply_chunk: Callable[[Any, tuple], Any], carry: Any) -> Any:
        """Fold ``apply_chunk`` over ``num_chunks`` staged chunks.

        ``build_chunk(i)`` produces the host arrays for chunk *i* (run on a
        prefetch worker when one is attached, overlapping the numpy pack
        with device compute); ``apply_chunk(carry, device_arrays)`` advances
        the chain.  Up to ``depth`` chunks are staged ahead of the apply
        cursor.
        """
        if num_chunks <= 0:
            return carry

        # one build kept in flight on a prefetch worker: consuming chunk
        # i's host arrays immediately kicks off chunk i+1's build, so the
        # numpy pack overlaps the put + kernel dispatch for chunk i
        ahead: tuple[int, Any] | None = None

        def kick(i: int) -> None:
            nonlocal ahead
            ahead = ((i, self.prefetcher.submit_fn(
                self._build, build_chunk, i))
                if self.prefetcher is not None and i < num_chunks else None)

        def obtain(i: int):
            nonlocal ahead
            if ahead is not None and ahead[0] == i:
                host = ahead[1].result()
            else:
                host = self._build(build_chunk, i)
            kick(i + 1)
            return host

        kick(0)
        staged: deque[tuple[int, tuple]] = deque()
        next_i = 0
        while staged or next_i < num_chunks:
            while next_i < num_chunks and len(staged) < self.depth:
                staged.append((next_i, self._put(obtain(next_i), next_i)))
                next_i += 1
            i, dev = staged.popleft()
            carry = apply_chunk(carry, dev)
            self.events.append(("apply", i))
        return carry
