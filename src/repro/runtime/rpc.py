"""Length-prefixed binary RPC over TCP sockets, shared by the shard
client and the shard/origin servers.

Wire format — one frame per request/response, all integers little-endian:

.. code-block:: text

    u32  frame length (bytes after this field)
    u8   kind: 0 = request, 1 = response-ok, 2 = response-error
    u64  request id (responses echo the request's id)
    u32  header length; ``header`` bytes of UTF-8 JSON
    u32  blob count; per blob: u32 length + raw bytes
         (length 0xFFFFFFFF encodes ``None`` — a *missing* blob, distinct
         from an empty one, which is how batched KV fetches report holes)

The JSON header carries the method name and small structured arguments;
bulk payloads (delta blobs, eventlists) travel as raw blob attachments so
nothing re-encodes megabytes through JSON.  Deadlines are per call: the
client arms ``settimeout`` with the remaining budget before every socket
op and also ships the deadline in the header so servers can shed work
that can no longer meet it.

Transport errors are typed and classified for the fault layer
(:func:`repro.runtime.fault.retry` accepts a predicate):

* :class:`RpcConnectionError` / :class:`RpcTimeout` — ``retryable=True``;
  dial failures, resets, mid-frame EOF, deadline expiry.  Another attempt
  (same server or a replica) can succeed.
* :class:`RpcProtocolError` — ``retryable=False``; framing corruption or
  a response id mismatch.  Retrying a codec bug just re-fails.
* :class:`RemoteCallError` — the handler itself raised.  Carries the
  remote exception type, message, and the full remote traceback string
  (``remote_traceback``), so a failure inside a shard process surfaces in
  the coordinator's logs with the *server-side* frames, not just a local
  re-raise site.  Retryable only when the server classified the handler's
  exception as transient (IOError/TimeoutError by default).
"""
from __future__ import annotations

import itertools
import json
import socket
import struct
import threading
import time
from typing import Any, Callable, Iterable

MAGIC_NONE = 0xFFFFFFFF          # blob-length sentinel for None
MAX_FRAME = 1 << 30              # 1 GiB sanity cap: larger is corruption

KIND_REQUEST = 0
KIND_RESPONSE = 1
KIND_ERROR = 2

_RETRYABLE_REMOTE = (IOError, TimeoutError)


# --------------------------------------------------------------------- errors
class TransportError(Exception):
    """Base for everything the RPC layer raises; ``retryable`` tells the
    fault layer whether another attempt (same server or a replica) makes
    sense."""

    retryable = False


class RpcConnectionError(TransportError, ConnectionError):
    retryable = True


class RpcTimeout(TransportError, TimeoutError):
    retryable = True


class RpcProtocolError(TransportError):
    retryable = False


class RemoteCallError(TransportError):
    """The remote handler raised.  ``remote_traceback`` is the server-side
    traceback string; it is part of ``str(e)`` so any local re-raise
    (e.g. :func:`fault.retry`, which re-raises the last attempt's
    exception object) still shows where the worker actually failed."""

    def __init__(self, method: str, remote_type: str, message: str,
                 remote_traceback: str = "", retryable: bool = False):
        self.method = method
        self.remote_type = remote_type
        self.remote_message = message
        self.remote_traceback = remote_traceback
        self.retryable = bool(retryable)
        text = f"remote {remote_type} in {method!r}: {message}"
        if remote_traceback:
            text += f"\n--- remote traceback ---\n{remote_traceback.rstrip()}"
        super().__init__(text)


# -------------------------------------------------------------------- framing
def pack_frame(kind: int, req_id: int, header: dict,
               blobs: Iterable[bytes | None] = ()) -> bytes:
    head = json.dumps(header, separators=(",", ":")).encode()
    parts = [struct.pack("<BQI", kind, req_id, len(head)), head]
    blobs = list(blobs)
    parts.append(struct.pack("<I", len(blobs)))
    for b in blobs:
        if b is None:
            parts.append(struct.pack("<I", MAGIC_NONE))
        else:
            parts.append(struct.pack("<I", len(b)))
            parts.append(bytes(b))
    body = b"".join(parts)
    return struct.pack("<I", len(body)) + body


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        try:
            chunk = sock.recv(min(n - len(buf), 1 << 20))
        except socket.timeout as e:
            raise RpcTimeout("deadline expired mid-frame") from e
        except OSError as e:
            raise RpcConnectionError(str(e)) from e
        if not chunk:
            raise RpcConnectionError(
                f"connection closed mid-frame ({len(buf)}/{n} bytes)")
        buf += chunk
    return bytes(buf)


def read_frame(sock: socket.socket) -> tuple[int, int, dict,
                                             list[bytes | None]]:
    """Read one frame; raises the typed transport errors above."""
    (length,) = struct.unpack("<I", _recv_exact(sock, 4))
    if length < 13 or length > MAX_FRAME:
        raise RpcProtocolError(f"bad frame length {length}")
    body = _recv_exact(sock, length)
    kind, req_id, hlen = struct.unpack_from("<BQI", body, 0)
    off = 13
    if kind not in (KIND_REQUEST, KIND_RESPONSE, KIND_ERROR):
        raise RpcProtocolError(f"bad frame kind {kind}")
    if off + hlen > len(body):
        raise RpcProtocolError("header overruns frame")
    try:
        header = json.loads(body[off:off + hlen].decode())
    except ValueError as e:
        raise RpcProtocolError(f"unparseable header: {e}") from e
    off += hlen
    if off + 4 > len(body):
        raise RpcProtocolError("truncated blob count")
    (nblobs,) = struct.unpack_from("<I", body, off)
    off += 4
    blobs: list[bytes | None] = []
    for _ in range(nblobs):
        if off + 4 > len(body):
            raise RpcProtocolError("truncated blob length")
        (blen,) = struct.unpack_from("<I", body, off)
        off += 4
        if blen == MAGIC_NONE:
            blobs.append(None)
            continue
        if off + blen > len(body):
            raise RpcProtocolError("blob overruns frame")
        blobs.append(body[off:off + blen])
        off += blen
    return kind, req_id, header, blobs


# --------------------------------------------------------------------- client
class RpcClient:
    """Pooled client for one ``(host, port)`` endpoint.

    Connections are pooled per client (LIFO, capped at ``pool_size``):
    a call pops an idle socket or dials a new one, and returns it to the
    pool only after a clean response — any transport error discards the
    socket so a poisoned stream can never serve the next call.  Thread
    safe; concurrent calls simply use distinct pooled connections.
    """

    def __init__(self, host: str, port: int, *, pool_size: int = 4,
                 connect_timeout: float = 5.0,
                 default_deadline_s: float | None = 30.0) -> None:
        self.host = host
        self.port = int(port)
        self.pool_size = int(pool_size)
        self.connect_timeout = float(connect_timeout)
        self.default_deadline_s = default_deadline_s
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self._idle: list[socket.socket] = []
        self._closed = False
        self.calls = 0
        self.dials = 0

    # -- connection pool ----------------------------------------------------
    def _checkout(self) -> socket.socket:
        with self._lock:
            if self._closed:
                raise RpcConnectionError("client closed")
            if self._idle:
                return self._idle.pop()
        try:
            sock = socket.create_connection(
                (self.host, self.port), timeout=self.connect_timeout)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError as e:
            raise RpcConnectionError(
                f"connect {self.host}:{self.port}: {e}") from e
        with self._lock:
            self.dials += 1
        return sock

    def _checkin(self, sock: socket.socket) -> None:
        with self._lock:
            if not self._closed and len(self._idle) < self.pool_size:
                self._idle.append(sock)
                return
        sock.close()

    def close(self) -> None:
        with self._lock:
            self._closed = True
            idle, self._idle = self._idle, []
        for s in idle:
            s.close()

    # -- calls ----------------------------------------------------------------
    def call(self, method: str, args: dict | None = None,
             blobs: Iterable[bytes | None] = (),
             deadline_s: float | None = None) -> tuple[Any,
                                                       list[bytes | None]]:
        """Issue one request; returns ``(result, blobs)``."""
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        deadline = (time.monotonic() + deadline_s
                    if deadline_s is not None else None)
        req_id = next(self._ids)
        header = {"m": method, "a": args or {}}
        if deadline_s is not None:
            header["dl_s"] = round(float(deadline_s), 6)
        frame = pack_frame(KIND_REQUEST, req_id, header, blobs)
        sock = self._checkout()
        try:
            self._arm(sock, deadline)
            try:
                sock.sendall(frame)
            except socket.timeout as e:
                raise RpcTimeout(f"{method}: send deadline expired") from e
            except OSError as e:
                raise RpcConnectionError(f"{method}: {e}") from e
            self._arm(sock, deadline)
            kind, rid, rhead, rblobs = read_frame(sock)
            # validate BEFORE pooling: an id/kind anomaly means the
            # stream is desynchronized — checking it in would hand the
            # stray frame to whichever call borrows the socket next
            if rid != req_id:
                raise RpcProtocolError(
                    f"{method}: response id {rid} != request id {req_id}")
            if kind not in (KIND_RESPONSE, KIND_ERROR):
                raise RpcProtocolError(
                    f"{method}: unexpected frame kind {kind}")
        except BaseException:
            sock.close()
            raise
        self._checkin(sock)
        with self._lock:
            self.calls += 1
        if kind == KIND_ERROR:
            raise RemoteCallError(
                method, rhead.get("type", "Exception"),
                rhead.get("msg", ""), rhead.get("tb", ""),
                retryable=bool(rhead.get("retryable", False)))
        return rhead.get("r"), rblobs

    @staticmethod
    def _arm(sock: socket.socket, deadline: float | None) -> None:
        if deadline is None:
            sock.settimeout(None)
            return
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            raise RpcTimeout("deadline expired before socket op")
        sock.settimeout(remaining)


# --------------------------------------------------------------------- server
class RpcServer:
    """Threaded frame server dispatching ``handlers[method](args, blobs)``.

    Handlers return ``(result, blobs)`` (or just ``result``); a handler
    exception becomes an error frame carrying its type, message, full
    traceback string, and a retryable flag (True for IOError/TimeoutError
    plus anything in ``retryable_types``) — the connection stays usable.
    """

    def __init__(self, handlers: dict[str, Callable],
                 host: str = "127.0.0.1", port: int = 0,
                 retryable_types: tuple = ()) -> None:
        self.handlers = dict(handlers)
        self.retryable_types = _RETRYABLE_REMOTE + tuple(retryable_types)
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self.host, self.port = self._sock.getsockname()
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._conns: set[socket.socket] = set()
        self._lock = threading.Lock()
        self.requests = 0
        self.errors = 0

    def start(self) -> "RpcServer":
        t = threading.Thread(target=self._accept_loop,
                             name=f"rpc-accept:{self.port}", daemon=True)
        t.start()
        self._threads.append(t)
        return self

    def _accept_loop(self) -> None:
        try:
            self._sock.settimeout(0.2)
        except OSError:
            return                          # closed before the loop started

        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._lock:
                self._conns.add(conn)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name=f"rpc-conn:{self.port}", daemon=True)
            t.start()

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                try:
                    kind, req_id, header, blobs = read_frame(conn)
                except TransportError:
                    return                      # peer gone or stream poisoned
                if kind != KIND_REQUEST:
                    return
                conn.sendall(self._dispatch(req_id, header, blobs))
        except OSError:
            pass
        finally:
            with self._lock:
                self._conns.discard(conn)
            conn.close()

    def _dispatch(self, req_id: int, header: dict,
                  blobs: list[bytes | None]) -> bytes:
        import traceback as _tb
        method = header.get("m", "")
        with self._lock:
            self.requests += 1
        fn = self.handlers.get(method)
        try:
            if fn is None:
                raise KeyError(f"no such RPC method: {method!r}")
            out = fn(header.get("a", {}), blobs)
            result, out_blobs = out if isinstance(out, tuple) else (out, ())
            return pack_frame(KIND_RESPONSE, req_id, {"r": result}, out_blobs)
        except Exception as e:  # noqa: BLE001 — every handler error → frame
            with self._lock:
                self.errors += 1
            return pack_frame(KIND_ERROR, req_id, {
                "type": type(e).__name__,
                "msg": str(e),
                "tb": _tb.format_exc(),
                "retryable": isinstance(e, self.retryable_types),
            })

    def close(self) -> None:
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        with self._lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            c.close()
        for t in self._threads:
            t.join(timeout=2.0)

    def __enter__(self) -> "RpcServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()
