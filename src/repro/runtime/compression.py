"""Gradient compression for the data-parallel all-reduce.

``bf16``  — cast the fp32 grads to bf16 before the DP reduction (halves
collective bytes; the reduction itself accumulates in fp32 on TPU).
``int8``  — per-tensor symmetric int8 with a fp32 scale (4× fewer bytes);
stochastic rounding bounds bias, and because XLA all-reduces whatever
dtype flows through the graph, quantizing *before* the pjit boundary
shrinks the wire format.

These are graph-level transforms: under pjit/GSPMD the all-reduce happens
wherever the sharded grads are consumed, so compressing the values that
cross that boundary is exactly compressing the collective.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quant_int8(g: jnp.ndarray, key) -> tuple[jnp.ndarray, jnp.ndarray]:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    x = gf / scale
    # stochastic rounding
    noise = jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(x + noise), -127, 127).astype(jnp.int8)
    return q, scale


def compress_tree(grads, kind: str = "bf16", key=None):
    if kind == "bf16":
        return {"kind": "bf16",
                "data": jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)}
    if kind == "int8":
        leaves, treedef = jax.tree.flatten(grads)
        key = key if key is not None else jax.random.PRNGKey(0)
        keys = jax.random.split(key, len(leaves))
        qs = [_quant_int8(g, k) for g, k in zip(leaves, keys)]
        return {"kind": "int8", "treedef": treedef,
                "q": [q for q, _ in qs], "scale": [s for _, s in qs]}
    raise ValueError(f"unknown compression {kind!r}")


def decompress_tree(packed, like):
    if packed["kind"] == "bf16":
        return jax.tree.map(lambda g, l: g.astype(jnp.float32),
                            packed["data"], like)
    if packed["kind"] == "int8":
        leaves = [q.astype(jnp.float32) * s
                  for q, s in zip(packed["q"], packed["scale"])]
        return jax.tree.unflatten(packed["treedef"], leaves)
    raise ValueError(packed["kind"])
