"""Node-ID-space partitioning (paper §4.2, §4.6).

``partition_id = h_p(slot)``.  Two partitioners:

* ``word_cyclic`` — ``(slot >> 5) % P``: whole 32-bit bitmap *words* are
  assigned round-robin to partitions.  This is the TPU adaptation: every
  partition's membership bits pack into word-aligned shards (so a
  ``shard_map`` over partitions needs zero re-layout), while round-robin
  keeps load balanced for append-ordered slot ids.
* ``mod_hash``   — splitmix-style hash of the slot, the paper-faithful
  arbitrary hash (balanced, but not word-aligned; host engine only).

Both are stable pure functions of (slot, P) so storage written by one
deployment can be read by another with the same (name, P).
"""
from __future__ import annotations

from typing import Callable

import numpy as np

Partitioner = Callable[[np.ndarray, int], np.ndarray]


def word_cyclic(slots: np.ndarray, P: int) -> np.ndarray:
    s = np.asarray(slots, np.int64)
    return ((s >> 5) % P).astype(np.int32)


def mod_hash(slots: np.ndarray, P: int) -> np.ndarray:
    x = np.asarray(slots, np.uint64) + np.uint64(0x9E3779B97F4A7C15)
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return (x % np.uint64(P)).astype(np.int32)


_PARTITIONERS: dict[str, Partitioner] = {
    "word_cyclic": word_cyclic,
    "mod_hash": mod_hash,
}


def get_partitioner(name: str) -> Partitioner:
    if name not in _PARTITIONERS:
        raise KeyError(f"unknown partitioner {name!r}; have {sorted(_PARTITIONERS)}")
    return _PARTITIONERS[name]


def partition_word_slices(num_words: int, P: int) -> list[np.ndarray]:
    """Word indices owned by each partition under ``word_cyclic``."""
    return [np.arange(p, num_words, P) for p in range(P)]
