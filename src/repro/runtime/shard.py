"""Sharded multi-worker retrieval (paper §4.6: "single-site or parallel
processing").

One query, ``W`` shard servers: the graph's slot space is partitioned by
node-ID hash (``runtime/partition.py`` — the same registered partitioners
that route :class:`~repro.storage.kv.PartitionedKV` and split every
persisted delta into per-partition sub-payloads), partitions are assigned
to servers with rendezvous hashing (:class:`~repro.runtime.replica
.ReplicaManager` — killing a server moves only its partitions, each to
its next-ranked replica), and one plan IR is scattered into per-shard IRs
(:func:`~repro.api.compiler.scatter_plans` /
:func:`~repro.core.planir.scatter_ir`).

Each shard task executes the *same* step DAG, but its Fetch nodes pull
only the sub-payloads of the partitions it owns.  The partitioner
contract — events for slot ``s`` are stored only under partition
``h_p(s)`` — makes the shard's result exact on its owned slots; the
gather step stitches the owned slots of every shard into one state,
bit-identical to unsharded execution (``tests/test_sharded.py``
differences both against the replay oracle).

**Transports.**  Scheduling is transport-agnostic; what moves bytes is a
pluggable :class:`ShardTransport`:

* :class:`InThreadTransport` (default) — the legacy host pool: "servers"
  are names, fetches read the manager's own store.  Zero-copy, zero
  processes; differential-tested bit-identical against the oracle.
* :class:`ProcTransport` — real isolation: every server is a
  ``launch/shardd`` OS *process* answering batched fetch RPCs from a
  shard-local hot cache (origin read-through, epoch-invalidated); built
  by ``GraphManager.enable_sharding(transport="proc", replicas=R)`` /
  ``serve.py --shard-procs``.

Execution is scheduled through the fault layer: a
:class:`~repro.runtime.fault.StragglerMitigator` hands shard tasks to a
thread pool, hedges the oldest outstanding task onto idle workers when
the tail is short (first completion wins, per-task duplicate cap),
requeues a failed task to a survivor, and marks the failing server dead
so the next attempt/query routes around it.  Every duplicate or requeued
attempt routes each partition to a replica **distinct from the servers
already tried** whenever one exists (``ReplicaManager.route``) — racing
the same store only re-queues behind the same straggler.  The JAX
backend's shard-parallel path lives in :mod:`repro.runtime.jax_exec`;
this module is the host-side engine that serves ``serve.py --shards N``.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable

import numpy as np

from ..core.query import NO_ATTRS, AttrOptions
from .executor import HostExecutor
from .fault import (FetchTask, HeartbeatTracker, StragglerMitigator,
                    default_retryable, retry)
from .replica import ReplicaManager


class ShardExecutionError(RuntimeError):
    """A shard task failed on every attempt (primary, hedges, requeues)."""


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------


class ShardTransport:
    """How shard fetches move bytes; everything else (scatter, scheduling,
    hedging, gather) is transport-agnostic.

    * ``fetch(server, keys, min_epoch=..)`` → blob list (``None`` per
      missing key, the ``mget_optional`` protocol).  ``min_epoch`` is the
      coordinator's current epoch id: a caching server must not answer
      from hot bytes older than it.
    * ``health(server)`` → dict, raising on an unreachable server — the
      heartbeat RPC.  ``has_remote_health`` says whether that is a real
      liveness signal (process/remote transports) or a formality
      (in-thread servers cannot die separately from the coordinator).
    """

    name = "abstract"
    has_remote_health = False

    def servers(self) -> list[str]:
        raise NotImplementedError

    def fetch(self, server: str, keys: list, *, min_epoch: int = 0,
              deadline_s: float | None = None) -> list:
        raise NotImplementedError

    def health(self, server: str) -> dict:
        return {"ok": True}

    def stats(self) -> dict:
        return {}

    def close(self) -> None:
        pass


class InThreadTransport(ShardTransport):
    """Legacy transport: named logical servers, fetches served from the
    manager's own store on the calling thread.  Keeps the pre-process
    behavior bit-for-bit (same store object, same ``mget_optional``
    read path as ``DeltaGraph._mget``)."""

    name = "thread"

    def __init__(self, gm, servers: list[str]) -> None:
        self.gm = gm
        self._servers = list(servers)
        self.fetches = 0
        self._lock = threading.Lock()

    def servers(self) -> list[str]:
        return list(self._servers)

    def fetch(self, server: str, keys: list, *, min_epoch: int = 0,
              deadline_s: float | None = None) -> list:
        from ..storage.kv import mget_optional
        with self._lock:
            self.fetches += 1
        return mget_optional(self.gm.store, keys)

    def stats(self) -> dict:
        return {"fetches": self.fetches}


class ProcTransport(ShardTransport):
    """Process-isolated transport over :mod:`repro.launch.shardd`.

    Spawns (or reuses from the pool) ``n`` shardd processes, stands up an
    origin RPC server over the coordinator's store for their cache
    read-through, configures each with its candidate-partition subset
    (the rendezvous ranks a server can legitimately serve: the ``R``
    replicas plus one spare rank so single-failure failover needs no
    reconfigure), and subscribes to the manager's
    :class:`~repro.core.epoch.EpochRegistry` so every publish fans an
    ``announce`` out to the shard-local caches.

    Deeper failures (>1 server down) can route a partition beyond a
    survivor's configured ranks; its shardd rejects the fetch as a
    routing-config error (``UNOWNED_MSG``), and :meth:`fetch` reacts by
    widening that server's owned set (``set_owned`` RPC — cache kept)
    and retrying, so the origin's data stays reachable as long as any
    server is — ownership rejections never read as liveness failures.
    """

    name = "proc"
    has_remote_health = True

    def __init__(self, gm, n_procs: int = 2, *, replicas: int = 1,
                 hot_mb: float = 64.0) -> None:
        from ..launch.shardd import acquire_shard_procs, origin_server
        from .fault import rendezvous_rank
        self.gm = gm
        self.handles = acquire_shard_procs(max(1, int(n_procs)),
                                           hot_mb=hot_mb)
        self._names = [f"proc{i}" for i in range(len(self.handles))]
        self._by_name = dict(zip(self._names, self.handles))
        self.origin = origin_server(gm.store)
        self._epochs = getattr(gm, "epochs", None)
        epoch0 = self._epochs.current_id if self._epochs is not None else 0
        P = int(gm.dg.P)
        depth = min(len(self._names), max(1, int(replicas)) + 1)
        owned: dict[str, list[int]] = {n: [] for n in self._names}
        for p in range(P):
            for s in rendezvous_rank(p, self._names)[:depth]:
                owned[s].append(p)
        for name, h in self._by_name.items():
            h.client.call("configure", {
                "origin_host": self.origin.host,
                "origin_port": self.origin.port,
                "owned": owned[name],
                "hot_bytes": int(float(hot_mb) * 2**20),
                "epoch": epoch0,
            })
        self._owned = {n: set(ps) for n, ps in owned.items()}
        self._owned_lock = threading.Lock()
        self._sub = None
        if self._epochs is not None:
            self._sub = lambda eid, data: self.announce(eid)
            self._epochs.subscribe(self._sub)

    def servers(self) -> list[str]:
        return list(self._names)

    def fetch(self, server: str, keys: list, *, min_epoch: int = 0,
              deadline_s: float | None = None) -> list:
        from ..launch.shardd import UNOWNED_MSG, _encode_keys
        from .rpc import RemoteCallError

        def unowned(err: BaseException) -> bool:
            return (isinstance(err, RemoteCallError)
                    and err.remote_type == "ValueError"
                    and UNOWNED_MSG in err.remote_message)

        h = self._by_name[server]
        args = {"k": _encode_keys(keys), "min_epoch": int(min_epoch)}
        try:
            _, blobs = h.client.call("fetch", args, deadline_s=deadline_s)
        except RemoteCallError as e:
            if not unowned(e):
                raise
            # failover routed a partition beyond the server's configured
            # rendezvous ranks (>1 failure): a routing-config gap, not a
            # liveness failure — widen ownership (cache kept) and retry
            with self._owned_lock:
                owned = self._owned.setdefault(server, set())
                owned.update(k[0] for k in keys)
                widened = sorted(owned)
            try:
                h.client.call("set_owned", {"owned": widened},
                              deadline_s=5.0)
                _, blobs = h.client.call("fetch", args,
                                         deadline_s=deadline_s)
            except RemoteCallError as e2:
                if unowned(e2):     # still rejected: config bug, but the
                    e2.routing_error = True   # server is provably alive
                raise
        return blobs

    def health(self, server: str) -> dict:
        res, _ = self._by_name[server].client.call("health", deadline_s=1.0)
        return res

    def announce(self, epoch_id: int) -> None:
        """Fan the new epoch id out to every shard cache, best-effort: a
        dead replica misses the announcement but self-corrects through the
        fetch-time ``min_epoch`` gate once it (or its successor) serves
        again."""
        for h in self._by_name.values():
            try:
                h.client.call("announce", {"epoch": int(epoch_id)},
                              deadline_s=5.0)
            except Exception:
                pass

    def server_stats(self, server: str) -> dict:
        res, _ = self._by_name[server].client.call("stats", deadline_s=5.0)
        return res

    def inject_delay(self, server: str, ms: float, count: int = -1) -> None:
        self._by_name[server].client.call(
            "set_delay", {"ms": float(ms), "count": int(count)})

    def kill(self, server: str) -> int:
        """SIGKILL one shard process (chaos testing); returns its pid."""
        h = self._by_name[server]
        pid = h.pid
        h.kill()
        return pid

    def stats(self) -> dict:
        out: dict[str, Any] = {"procs": len(self._names)}
        for name in self._names:
            try:
                out[name] = self.server_stats(name)
            except Exception:
                out[name] = {"dead": True}
        return out

    def close(self) -> None:
        from ..launch.shardd import release_shard_procs
        if self._sub is not None and self._epochs is not None:
            self._epochs.unsubscribe(self._sub)
            self._sub = None
        release_shard_procs(list(self._by_name.values()))
        self._by_name = {}
        self.origin.close()


def make_transport(kind: str, gm, workers: list[str] | int, *,
                   replicas: int = 1, hot_mb: float = 64.0
                   ) -> ShardTransport:
    """``"thread"`` | ``"proc"`` — the ``REPRO_SHARD_TRANSPORT`` values."""
    kind = (kind or "thread").strip().lower()
    if kind in ("thread", "inproc", "local"):
        if isinstance(workers, int):
            workers = [f"shard{i}" for i in range(max(1, workers))]
        return InThreadTransport(gm, list(workers))
    if kind == "proc":
        n = workers if isinstance(workers, int) else len(workers)
        return ProcTransport(gm, n, replicas=replicas, hot_mb=hot_mb)
    raise ValueError(f"unknown shard transport {kind!r} (thread | proc)")


# ---------------------------------------------------------------------------
# retriever
# ---------------------------------------------------------------------------


class ShardedRetriever:
    """Scatter/execute/gather engine over a fleet of shard servers.

    * ``workers`` — worker count or explicit names (ignored when a
      ``transport`` instance is passed: its servers define the fleet).
    * ``transport`` — a :class:`ShardTransport` instance; default is the
      legacy :class:`InThreadTransport` over the manager's store.
    * ``replicas`` — candidate servers per partition (rendezvous-ranked);
      hedges and failover route to a *distinct* replica when one exists.
    * ``hedge_frac`` / ``max_hedges`` / ``hedge_delay_s`` — hedging
      policy: once remaining work is down to the outstanding tail, idle
      threads duplicate the oldest outstanding shard task (at most
      ``max_hedges`` duplicates per task, each issued only after the
      primary has been running ``hedge_delay_s``); first completion wins.
    * ``task_retries`` — how often a *failed* shard task is requeued
      before the query fails; the failing server is marked dead so later
      attempts and queries replan without it.
    * ``io_retries`` — bounded exponential backoff around each shard
      execution for transient faults (:func:`fault.retry` with the RPC
      layer's retryable/fatal classification).
    * ``health_interval_s`` — minimum spacing of the heartbeat-RPC probe
      that runs at query entry on transports with real liveness
      (``has_remote_health``); a SIGKILL'd process is excluded before any
      fetch is attempted.
    """

    def __init__(self, gm, workers: int | list[str] = 4, *,
                 transport: ShardTransport | str | None = None,
                 replicas: int = 1,
                 threads: int | None = None,
                 hedge_frac: float = 0.5, max_hedges: int = 1,
                 hedge_delay_s: float = 0.01, hedge_workers: int = 1,
                 task_retries: int = 1, io_retries: int = 2,
                 heartbeat_timeout: float = 10.0,
                 health_interval_s: float = 0.25,
                 use_prefetcher: bool = False,
                 poll_s: float = 0.002,
                 hot_mb: float = 64.0,
                 shard_hook: Callable[[str, tuple[int, ...]], None] | None
                 = None) -> None:
        self.gm = gm
        if isinstance(transport, str) or transport is None:
            transport = make_transport(transport or "thread", gm, workers,
                                       replicas=replicas, hot_mb=hot_mb)
        self.transport = transport
        self.workers = list(transport.servers())
        self.replicas = max(1, int(replicas))
        self.replica_mgr = ReplicaManager(self.workers, self.replicas)
        self.heartbeats = HeartbeatTracker(self.workers,
                                           timeout=heartbeat_timeout)
        self.hedge_frac = float(hedge_frac)
        self.max_hedges = int(max_hedges)
        self.hedge_delay_s = float(hedge_delay_s)
        self.hedge_workers = int(hedge_workers)
        self.task_retries = int(task_retries)
        self.io_retries = max(1, int(io_retries))
        self.health_interval_s = float(health_interval_s)
        self.use_prefetcher = bool(use_prefetcher)
        self.poll_s = float(poll_s)
        self.shard_hook = shard_hook
        n = len(self.workers) + self.hedge_workers
        self._pool = ThreadPoolExecutor(
            max_workers=threads if threads is not None else 4 * n,
            thread_name_prefix="shard")
        self._lock = threading.Lock()
        self._last_probe = 0.0
        self.hedges_total = 0
        self.requeues_total = 0
        self.failovers_total = 0
        self.last_stats: dict[str, Any] = {}

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        self._pool.shutdown(wait=True)
        self.transport.close()

    def __enter__(self) -> "ShardedRetriever":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------------- assignment
    def alive_workers(self) -> list[str]:
        alive = set(self.heartbeats.alive())
        out = [w for w in self.workers if w in alive]
        # a fully-dead fleet can't serve; fall back to every configured
        # worker rather than failing closed (their next success re-beats)
        return out or list(self.workers)

    def assignment(self, P: int) -> dict[str, tuple[int, ...]]:
        """Current ``server -> owned partitions`` map (primaries) over
        alive servers."""
        return self.replica_mgr.assignment(P, self.alive_workers())

    def probe_health(self, force: bool = False) -> None:
        """Heartbeat-RPC sweep: beat responders, expire the unreachable.
        Runs at query entry (rate-limited) on transports with real
        liveness, so a process SIGKILL'd at idle is excluded before the
        next query routes to it."""
        if not self.transport.has_remote_health:
            return
        now = time.monotonic()
        with self._lock:
            if not force and now - self._last_probe < self.health_interval_s:
                return
            self._last_probe = now
        for w in self.workers:
            try:
                self.transport.health(w)
                self.heartbeats.beat(w)
            except Exception:
                self.heartbeats.mark_dead(w)

    # ------------------------------------------------------------ execution
    def execute(self, dg, plan, options: AttrOptions = NO_ATTRS,
                pool=None) -> dict[Any, Any]:
        """Execute one plan IR sharded; returns states keyed by the plan's
        targets, bit-identical to ``dg.execute(plan, ...)``."""
        t_start = time.perf_counter()
        self.probe_health()
        parts_by_worker = self.assignment(dg.P)
        if len(parts_by_worker) <= 1 and not self.transport.has_remote_health:
            # one owner for every partition: in-thread sharded execution
            # degenerates to the plain host path (no scatter/gather
            # overhead); process transports still go through the routed
            # path so fetches hit the shard caches
            out = dg.execute(plan, options, pool=pool,
                             prefetch=self.gm.prefetcher
                             if self.use_prefetcher else None)
            self.last_stats = {"shards": 1, "hedges": 0, "requeues": 0,
                               "transport": self.transport.name}
            return out
        from ..api.compiler import scatter_plans
        shard_irs = scatter_plans([plan], parts_by_worker, dg.P)

        per_shard = self._run_scattered(dg, shard_irs, parts_by_worker,
                                        options, pool)
        out = self._gather(dg, per_shard, parts_by_worker)
        dg._record_workload(plan, options, t_start)
        return out

    def retrieve(self, times, options: AttrOptions = NO_ATTRS,
                 use_current: bool = True) -> dict[int, Any]:
        """Convenience: plan + execute one multipoint retrieval against the
        manager's current index."""
        dg = self.gm.dg
        times = [int(t) for t in dict.fromkeys(int(t) for t in times)]
        plan = dg.plan_multipoint(times, options, use_current)
        return self.execute(dg, plan, options, pool=self.gm.pool)

    # -- routed fetch --------------------------------------------------------
    def _routed_mget(self, route: dict[int, str], tried: frozenset,
                     min_epoch: int, keys: list) -> list:
        """Group a Fetch node's keys by each partition's chosen replica,
        one batched transport fetch per server, reassembled in key order.
        A failing fetch is tagged with the server that failed so the
        scheduler expires *that* replica, not the task's nominal owner."""
        alive = self.alive_workers()
        groups: dict[str, list[int]] = {}
        for i, k in enumerate(keys):
            s = route.get(k[0])
            if s is None:
                s = self.replica_mgr.route(k[0], alive, tried)
                route[k[0]] = s
            groups.setdefault(s, []).append(i)
        out: list = [None] * len(keys)
        for s, idxs in groups.items():
            try:
                blobs = self.transport.fetch(
                    s, [keys[i] for i in idxs], min_epoch=min_epoch)
            except Exception as e:
                e.failed_server = s
                raise
            for i, b in zip(idxs, blobs):
                out[i] = b
        return out

    # -- scheduling through the fault layer ---------------------------------
    def _run_scattered(self, dg, shard_irs: dict[str, Any],
                       parts_by_worker: dict[str, tuple[int, ...]],
                       options: AttrOptions, pool) -> dict[str, tuple]:
        prefetcher = self.gm.prefetcher if self.use_prefetcher else None
        epochs = getattr(self.gm, "epochs", None)
        min_epoch = epochs.current_id if epochs is not None else 0
        tasks = [FetchTask(partition=i, key=w,
                           size_est=max(1, len(parts_by_worker[w])))
                 for i, w in enumerate(shard_irs)]
        sm = StragglerMitigator(tasks, hedge_frac=self.hedge_frac,
                                max_duplicates=self.max_hedges)
        lock = threading.Lock()
        done_evt = threading.Event()
        started: dict[str, float] = {}
        fails: dict[str, int] = {}
        results: dict[str, Any] = {}
        errors: dict[str, BaseException] = {}
        # servers used by every issued attempt of a task: a duplicate or
        # requeued attempt must route to a server outside this set when a
        # replica exists (the hedging contract)
        used: dict[str, set[str]] = {}
        requeues = [0]
        failovers = [0]

        def run_one(worker: str, tried: frozenset):
            if self.shard_hook is not None:
                self.shard_hook(worker, parts_by_worker[worker])
            # plan the attempt's routing up front and record it into
            # ``used`` *before* fetching: a hedge issued while this
            # attempt is still in flight must already see its servers as
            # tried, or it would race the same replica
            route: dict[int, str] = self.replica_mgr.plan(
                parts_by_worker[worker], self.alive_workers(), tried)
            servers = set(route.values())
            with lock:
                used.setdefault(worker, set()).update(servers)
                if servers - {worker}:
                    failovers[0] += 1
            ex = HostExecutor(
                dg, prefetcher=prefetcher,
                mget=lambda keys: self._routed_mget(route, tried,
                                                    min_epoch, keys))
            try:
                res = ex.run(shard_irs[worker], options, pool)
            finally:
                with lock:
                    # lazily-routed keys (partitions outside the task's
                    # nominal set) may have widened the server set
                    used.setdefault(worker, set()).update(route.values())
            return res, set(route.values())

        def loop() -> None:
            while True:
                with lock:
                    if sm.finished():
                        done_evt.set()
                        return
                    task = sm.assign()
                    is_hedge = task is not None and task.key in started
                    if task is not None and not is_hedge:
                        started[task.key] = time.perf_counter()
                    tried = (frozenset(used.get(task.key, ()))
                             if task is not None else frozenset())
                if task is None:
                    time.sleep(self.poll_s)
                    continue
                if is_hedge and self.hedge_delay_s > 0:
                    wait = (started[task.key] + self.hedge_delay_s
                            - time.perf_counter())
                    if wait > 0:
                        time.sleep(wait)
                    with lock:
                        if task.key in sm.done:   # primary won meanwhile
                            continue
                        tried = frozenset(used.get(task.key, ()))
                # inner retries re-plan: each failed attempt adds the
                # server whose fetch failed to an attempt-local tried
                # set, so the next retry routes to a distinct replica
                # (when one exists) instead of hammering the same
                # unreachable server through the backoff schedule
                attempt_tried = set(tried)

                def attempt(key=task.key):
                    try:
                        return run_one(key, frozenset(attempt_tried))
                    except Exception as e:
                        failed = getattr(e, "failed_server", None)
                        if (failed is not None
                                and not getattr(e, "routing_error", False)):
                            attempt_tried.add(failed)
                            # and mark it dead right away so *other*
                            # tasks and lazily-routed keys also avoid
                            # the corpse; a transient blip is
                            # resurrected by the next health probe or
                            # by completing a later attempt
                            self.heartbeats.mark_dead(failed)
                        raise

                try:
                    res, served = retry(attempt,
                                        attempts=self.io_retries,
                                        retryable=default_retryable)
                except Exception as e:
                    failed = getattr(e, "failed_server", task.key)
                    with lock:
                        fails[task.key] = fails.get(task.key, 0) + 1
                        # the server whose fetch failed reads as dead
                        # until it completes something again: later
                        # attempts and the next query route around it —
                        # unless it rejected for ownership/routing
                        # reasons, which proves it alive
                        if not getattr(e, "routing_error", False):
                            self.heartbeats.mark_dead(failed)
                        if (fails[task.key] <= self.task_retries
                                and sm.fail(task.key)):
                            requeues[0] += 1
                            continue
                        errors.setdefault(task.key, e)
                        sm.complete(task.key)
                        if sm.finished():
                            done_evt.set()
                    continue
                with lock:
                    # beat the servers that actually served this attempt —
                    # the task's nominal owner may be a corpse the attempt
                    # routed around, and beating it would resurrect it
                    for s in served:
                        self.heartbeats.beat(s)
                    if sm.complete(task.key):
                        results[task.key] = res
                    if sm.finished():
                        done_evt.set()

        n_loops = len(tasks) + (self.hedge_workers if self.max_hedges else 0)
        for _ in range(n_loops):
            self._pool.submit(loop)
        # wait for the *task set*, not the threads: an abandoned attempt
        # whose hedge already won (first completion) keeps draining in the
        # persistent pool — joining it would hand the straggler's latency
        # right back to the query, defeating the hedge
        done_evt.wait()

        with self._lock:
            self.hedges_total += sm.duplicates
            self.requeues_total += requeues[0]
            self.failovers_total += failovers[0]
            self.last_stats = {"shards": len(tasks),
                               "hedges": sm.duplicates,
                               "requeues": requeues[0],
                               "failovers": failovers[0],
                               "transport": self.transport.name,
                               "replicas": self.replicas}
        if errors:
            worker, err = next(iter(errors.items()))
            detail = ""
            remote_tb = getattr(err, "remote_traceback", "")
            if remote_tb:
                detail = f"; remote traceback:\n{remote_tb.rstrip()}"
            raise ShardExecutionError(
                f"shard task for worker {worker!r} failed after "
                f"{fails.get(worker, 0)} attempt(s){detail}") from err
        return {w: (parts_by_worker[w], results[w]) for w in results}

    # ----------------------------------------------------------------- gather
    def _gather(self, dg, per_shard: dict[str, tuple],
                parts_by_worker: dict[str, tuple[int, ...]]) -> dict:
        """Union the per-shard states on their owned slots.

        Each shard's state is exact on the slots whose partition it owns
        and possibly stale elsewhere, and ownership tiles the slot space,
        so overwriting every shard's owned slots into one state
        reconstructs the unsharded result exactly."""
        items = list(per_shard.items())
        base_worker, (base_parts, base_states) = items[0]
        out = {}
        hp_cache: dict[int, np.ndarray] = {}

        def hp(size: int) -> np.ndarray:
            a = hp_cache.get(size)
            if a is None:
                a = dg._hp(np.arange(size, dtype=np.int64), dg.P)
                hp_cache[size] = a
            return a

        for tgt, st0 in base_states.items():
            combined = st0.copy()
            for worker, (parts, states) in items[1:]:
                st = states[tgt]
                pa = np.asarray(parts, np.int32)
                # sizes can differ only if a live ingest grew the universe
                # mid-execution; the overlap is the consistent region
                kn = min(combined.node_mask.size, st.node_mask.size)
                sel = np.isin(hp(kn), pa)
                combined.node_mask[:kn][sel] = st.node_mask[:kn][sel]
                if combined.node_attrs.size and st.node_attrs.size:
                    ka = min(kn, combined.node_attrs.shape[0],
                             st.node_attrs.shape[0])
                    combined.node_attrs[:ka][sel[:ka]] = \
                        st.node_attrs[:ka][sel[:ka]]
                ke = min(combined.edge_mask.size, st.edge_mask.size)
                sele = np.isin(hp(ke), pa)
                combined.edge_mask[:ke][sele] = st.edge_mask[:ke][sele]
                if combined.edge_attrs.size and st.edge_attrs.size:
                    ka = min(ke, combined.edge_attrs.shape[0],
                             st.edge_attrs.shape[0])
                    combined.edge_attrs[:ka][sele[:ka]] = \
                        st.edge_attrs[:ka][sele[:ka]]
            out[tgt] = combined
        return out
