"""Sharded multi-worker retrieval (paper §4.6: "single-site or parallel
processing").

One query, ``W`` shard workers: the graph's slot space is partitioned by
node-ID hash (``runtime/partition.py`` — the same registered partitioners
that route :class:`~repro.storage.kv.PartitionedKV` and split every
persisted delta into per-partition sub-payloads), partitions are assigned
to workers with consistent hashing (:func:`~repro.runtime.fault
.elastic_replan` — killing a worker moves only its partitions), and one
plan IR is scattered into per-shard IRs
(:func:`~repro.api.compiler.scatter_plans` /
:func:`~repro.core.planir.scatter_ir`).

Each shard executes the *same* step DAG, but its Fetch nodes pull only
the sub-payloads of the partitions it owns.  The partitioner contract —
events for slot ``s`` are stored only under partition ``h_p(s)`` — makes
the shard's result exact on its owned slots; the gather step stitches the
owned slots of every shard into one state, bit-identical to unsharded
execution (``tests/test_sharded.py`` differences both against the replay
oracle).

Execution is scheduled through the fault layer: a
:class:`~repro.runtime.fault.StragglerMitigator` hands shard tasks to a
pool of :class:`~repro.runtime.executor.HostExecutor` threads, hedges the
oldest outstanding task onto idle workers when the tail is short (first
completion wins, per-task duplicate cap), requeues a failed task to a
survivor, and marks the failing worker dead so the next query's
``elastic_replan`` routes around it.  The JAX backend's shard-parallel
path (``shard_map`` over the word_cyclic ``[P, Wp]`` layout, zero
collectives) lives in :mod:`repro.runtime.jax_exec`; this module is the
host-pool engine that serves ``serve.py --shards N``.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable

import numpy as np

from ..core.query import NO_ATTRS, AttrOptions
from .executor import HostExecutor
from .fault import (FetchTask, HeartbeatTracker, StragglerMitigator,
                    elastic_replan, retry)


class ShardExecutionError(RuntimeError):
    """A shard task failed on every attempt (primary, hedges, requeues)."""


class ShardedRetriever:
    """Scatter/execute/gather engine over a pool of host executors.

    Transport-agnostic like the rest of the fault layer: "workers" are
    named logical shard servers driven by local threads, so unit tests and
    benchmarks can inject latency or death deterministically through
    ``shard_hook`` — a real deployment would wire the same scheduling to
    its RPC layer.

    * ``workers`` — worker count or explicit names.
    * ``hedge_frac`` / ``max_hedges`` / ``hedge_delay_s`` — hedging
      policy: once remaining work is down to the outstanding tail, idle
      threads duplicate the oldest outstanding shard task (at most
      ``max_hedges`` duplicates per task, each issued only after the
      primary has been running ``hedge_delay_s``); first completion wins.
    * ``task_retries`` — how often a *failed* shard task is requeued to a
      survivor before the query fails; the failing worker is marked dead
      so the next query replans without it.
    * ``io_retries`` — bounded exponential backoff around each shard
      execution for transient store faults (:func:`fault.retry`).
    """

    def __init__(self, gm, workers: int | list[str] = 4, *,
                 threads: int | None = None,
                 hedge_frac: float = 0.5, max_hedges: int = 1,
                 hedge_delay_s: float = 0.01, hedge_workers: int = 1,
                 task_retries: int = 1, io_retries: int = 2,
                 heartbeat_timeout: float = 10.0,
                 use_prefetcher: bool = False,
                 poll_s: float = 0.002,
                 shard_hook: Callable[[str, tuple[int, ...]], None] | None
                 = None) -> None:
        if isinstance(workers, int):
            workers = [f"shard{i}" for i in range(max(1, workers))]
        self.gm = gm
        self.workers = list(workers)
        self.heartbeats = HeartbeatTracker(self.workers,
                                           timeout=heartbeat_timeout)
        self.hedge_frac = float(hedge_frac)
        self.max_hedges = int(max_hedges)
        self.hedge_delay_s = float(hedge_delay_s)
        self.hedge_workers = int(hedge_workers)
        self.task_retries = int(task_retries)
        self.io_retries = max(1, int(io_retries))
        self.use_prefetcher = bool(use_prefetcher)
        self.poll_s = float(poll_s)
        self.shard_hook = shard_hook
        n = len(self.workers) + self.hedge_workers
        self._pool = ThreadPoolExecutor(
            max_workers=threads if threads is not None else 4 * n,
            thread_name_prefix="shard")
        self._lock = threading.Lock()
        self.hedges_total = 0
        self.requeues_total = 0
        self.last_stats: dict[str, Any] = {}

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "ShardedRetriever":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------------- assignment
    def alive_workers(self) -> list[str]:
        alive = set(self.heartbeats.alive())
        out = [w for w in self.workers if w in alive]
        # a fully-dead fleet can't serve; fall back to every configured
        # worker rather than failing closed (their next success re-beats)
        return out or list(self.workers)

    def assignment(self, P: int) -> dict[str, tuple[int, ...]]:
        """Current ``worker -> owned partitions`` map over alive workers."""
        by_worker: dict[str, list[int]] = {}
        for p, w in elastic_replan(P, self.alive_workers()).items():
            by_worker.setdefault(w, []).append(p)
        return {w: tuple(sorted(ps)) for w, ps in by_worker.items()}

    # ------------------------------------------------------------ execution
    def execute(self, dg, plan, options: AttrOptions = NO_ATTRS,
                pool=None) -> dict[Any, Any]:
        """Execute one plan IR sharded; returns states keyed by the plan's
        targets, bit-identical to ``dg.execute(plan, ...)``."""
        t_start = time.perf_counter()
        parts_by_worker = self.assignment(dg.P)
        if len(parts_by_worker) <= 1:
            # one owner for every partition: sharded execution degenerates
            # to the plain host path (no scatter/gather overhead)
            out = dg.execute(plan, options, pool=pool,
                             prefetch=self.gm.prefetcher
                             if self.use_prefetcher else None)
            self.last_stats = {"shards": 1, "hedges": 0, "requeues": 0}
            return out
        from ..api.compiler import scatter_plans
        shard_irs = scatter_plans([plan], parts_by_worker, dg.P)

        per_shard = self._run_scattered(dg, shard_irs, parts_by_worker,
                                        options, pool)
        out = self._gather(dg, per_shard, parts_by_worker)
        dg._record_workload(plan, options, t_start)
        return out

    def retrieve(self, times, options: AttrOptions = NO_ATTRS,
                 use_current: bool = True) -> dict[int, Any]:
        """Convenience: plan + execute one multipoint retrieval against the
        manager's current index."""
        dg = self.gm.dg
        times = [int(t) for t in dict.fromkeys(int(t) for t in times)]
        plan = dg.plan_multipoint(times, options, use_current)
        return self.execute(dg, plan, options, pool=self.gm.pool)

    # -- scheduling through the fault layer ---------------------------------
    def _run_scattered(self, dg, shard_irs: dict[str, Any],
                       parts_by_worker: dict[str, tuple[int, ...]],
                       options: AttrOptions, pool) -> dict[str, tuple]:
        prefetcher = self.gm.prefetcher if self.use_prefetcher else None
        tasks = [FetchTask(partition=i, key=w,
                           size_est=max(1, len(parts_by_worker[w])))
                 for i, w in enumerate(shard_irs)]
        sm = StragglerMitigator(tasks, hedge_frac=self.hedge_frac,
                                max_duplicates=self.max_hedges)
        lock = threading.Lock()
        done_evt = threading.Event()
        started: dict[str, float] = {}
        fails: dict[str, int] = {}
        results: dict[str, Any] = {}
        errors: dict[str, BaseException] = {}
        requeues = [0]

        def run_one(worker: str):
            if self.shard_hook is not None:
                self.shard_hook(worker, parts_by_worker[worker])
            ex = HostExecutor(dg, prefetcher=prefetcher)
            return ex.run(shard_irs[worker], options, pool)

        def loop() -> None:
            while True:
                with lock:
                    if sm.finished():
                        done_evt.set()
                        return
                    task = sm.assign()
                    is_hedge = task is not None and task.key in started
                    if task is not None and not is_hedge:
                        started[task.key] = time.perf_counter()
                if task is None:
                    time.sleep(self.poll_s)
                    continue
                if is_hedge and self.hedge_delay_s > 0:
                    wait = (started[task.key] + self.hedge_delay_s
                            - time.perf_counter())
                    if wait > 0:
                        time.sleep(wait)
                    with lock:
                        if task.key in sm.done:   # primary won meanwhile
                            continue
                try:
                    res = retry(lambda: run_one(task.key),
                                attempts=self.io_retries,
                                retryable=(IOError, TimeoutError))
                except Exception as e:
                    with lock:
                        fails[task.key] = fails.get(task.key, 0) + 1
                        # a failed shard reads as dead until it completes
                        # something again: the next query replans around it
                        self.heartbeats.mark_dead(task.key)
                        if (fails[task.key] <= self.task_retries
                                and sm.fail(task.key)):
                            requeues[0] += 1
                            continue
                        errors.setdefault(task.key, e)
                        sm.complete(task.key)
                        if sm.finished():
                            done_evt.set()
                    continue
                with lock:
                    self.heartbeats.beat(task.key)
                    if sm.complete(task.key):
                        results[task.key] = res
                    if sm.finished():
                        done_evt.set()

        n_loops = len(tasks) + (self.hedge_workers if self.max_hedges else 0)
        for _ in range(n_loops):
            self._pool.submit(loop)
        # wait for the *task set*, not the threads: an abandoned attempt
        # whose hedge already won (first completion) keeps draining in the
        # persistent pool — joining it would hand the straggler's latency
        # right back to the query, defeating the hedge
        done_evt.wait()

        with self._lock:
            self.hedges_total += sm.duplicates
            self.requeues_total += requeues[0]
            self.last_stats = {"shards": len(tasks),
                               "hedges": sm.duplicates,
                               "requeues": requeues[0]}
        if errors:
            worker, err = next(iter(errors.items()))
            raise ShardExecutionError(
                f"shard task for worker {worker!r} failed after "
                f"{fails.get(worker, 0)} attempt(s)") from err
        return {w: (parts_by_worker[w], results[w]) for w in results}

    # ----------------------------------------------------------------- gather
    def _gather(self, dg, per_shard: dict[str, tuple],
                parts_by_worker: dict[str, tuple[int, ...]]) -> dict:
        """Union the per-shard states on their owned slots.

        Each shard's state is exact on the slots whose partition it owns
        and possibly stale elsewhere, and ownership tiles the slot space,
        so overwriting every shard's owned slots into one state
        reconstructs the unsharded result exactly."""
        items = list(per_shard.items())
        base_worker, (base_parts, base_states) = items[0]
        out = {}
        hp_cache: dict[int, np.ndarray] = {}

        def hp(size: int) -> np.ndarray:
            a = hp_cache.get(size)
            if a is None:
                a = dg._hp(np.arange(size, dtype=np.int64), dg.P)
                hp_cache[size] = a
            return a

        for tgt, st0 in base_states.items():
            combined = st0.copy()
            for worker, (parts, states) in items[1:]:
                st = states[tgt]
                pa = np.asarray(parts, np.int32)
                # sizes can differ only if a live ingest grew the universe
                # mid-execution; the overlap is the consistent region
                kn = min(combined.node_mask.size, st.node_mask.size)
                sel = np.isin(hp(kn), pa)
                combined.node_mask[:kn][sel] = st.node_mask[:kn][sel]
                if combined.node_attrs.size and st.node_attrs.size:
                    ka = min(kn, combined.node_attrs.shape[0],
                             st.node_attrs.shape[0])
                    combined.node_attrs[:ka][sel[:ka]] = \
                        st.node_attrs[:ka][sel[:ka]]
                ke = min(combined.edge_mask.size, st.edge_mask.size)
                sele = np.isin(hp(ke), pa)
                combined.edge_mask[:ke][sele] = st.edge_mask[:ke][sele]
                if combined.edge_attrs.size and st.edge_attrs.size:
                    ka = min(ke, combined.edge_attrs.shape[0],
                             st.edge_attrs.shape[0])
                    combined.edge_attrs[:ka][sele[:ka]] = \
                        st.edge_attrs[:ka][sele[:ka]]
            out[tgt] = combined
        return out
