"""Plan-IR execution engine: host backend, async KV prefetch, batching.

Three pieces, all consuming the unified :mod:`repro.core.planir` DAG:

* :class:`Prefetcher` — a thread pool that overlaps ``storage/kv.py`` gets
  with delta/bitmap application.  The executor submits every Fetch node's
  key list up front (the pool's queue preserves plan order, so the fetch
  for step *i+1* streams in while step *i* applies — double-buffering
  payload components along the plan's critical path), then blocks only
  when an apply actually needs its payload.

* :class:`HostExecutor` — the numpy/state backend (attribute-carrying
  retrievals, materialization).  Walks the DAG in topological order;
  Fork nodes alias their parent state (every apply copies-on-write, so
  sibling branches cannot corrupt each other).

* :class:`BatchScheduler` — merges concurrent ``get_snapshot`` /
  multipoint requests into **one** DAG via
  :func:`repro.core.planir.merge_irs`, executes it once, and splits the
  results back per request.  Common subpaths — the skeleton prefix two
  queries share — fetch and apply exactly once for the whole batch.

The JAX bitmap backend for the same IR lives in
:mod:`repro.runtime.jax_exec` (``execute_ir_jax``); host and device
execution are two backends of one plan representation.
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import TYPE_CHECKING, Any, Sequence

import numpy as np

from ..core.deltas import apply_delta
from ..core.events import MaterializedState, apply_events
from ..core.planir import (ApplyDelta, ApplyElist, ApplyRecent, Fetch, Fork,
                           Materialize, Noop, PlanIR, Source, merge_irs)
from ..core.query import NO_ATTRS, AttrOptions

if TYPE_CHECKING:  # pragma: no cover
    from ..core.deltagraph import DeltaGraph


# ---------------------------------------------------------------------------
# async KV prefetch
# ---------------------------------------------------------------------------


class Prefetcher:
    """Thread-pooled async multi-get (+ decode) over a KV store.

    ``submit(keys)`` returns a future resolving to the blob list (``None``
    for missing components, matching ``DeltaGraph._mget``); with a
    ``decode`` callable the worker thread also runs the codec-layer
    decompression/deserialization, so the future resolves straight to the
    decoded payload and the apply thread never touches raw blobs.  The
    store's stats counters are lock-protected (``storage.kv.KVStats``), so
    concurrent prefetch threads account bytes correctly.
    """

    def __init__(self, store, workers: int = 4) -> None:
        self.store = store
        self.workers = int(workers)
        self._pool: ThreadPoolExecutor | None = None
        self._lock = threading.Lock()

    def _ensure_pool(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.workers,
                    thread_name_prefix="kv-prefetch")
            return self._pool

    def submit(self, keys: list, decode=None) -> "Future":
        from ..storage.kv import mget_optional
        store = self.store

        def _work():
            self._install_nice()
            blobs = mget_optional(store, keys)
            return decode(blobs) if decode is not None else blobs

        return self._ensure_pool().submit(_work)

    def submit_fn(self, fn, *args) -> "Future":
        """Run an arbitrary callable on a prefetch worker (with the
        cooperative decode-yield installed).  The device pipeline uses this
        to build the *next* host-side plane chunk while the current chunk's
        kernels run."""
        def _work():
            self._install_nice()
            return fn(*args)

        return self._ensure_pool().submit(_work)

    @staticmethod
    def _install_nice() -> None:
        # Idempotent per worker thread: between-array decode yields keep
        # codec work from monopolizing the GIL against the apply thread.
        from ..storage import codec
        import time
        codec.set_decode_nice(lambda: time.sleep(0))

    def close(self, wait: bool = False) -> None:
        """``wait=True`` drains in-flight fetches first — required before
        closing the underlying store (a worker mid-get would otherwise
        read from closed file handles)."""
        with self._lock:
            if self._pool is not None:
                self._pool.shutdown(wait=wait)
                self._pool = None

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# host backend
# ---------------------------------------------------------------------------


class HostExecutor:
    """Execute a :class:`PlanIR` on the host (numpy states, full attribute
    support).  Semantically identical to the pre-IR ``DeltaGraph.execute``;
    additionally fetches each payload once per plan and can overlap fetches
    with application through a :class:`Prefetcher`."""

    def __init__(self, dg: "DeltaGraph", prefetcher: Prefetcher | None = None,
                 mget=None) -> None:
        self.dg = dg
        self.prefetcher = prefetcher
        # pluggable payload fetch (``keys -> list[bytes|None]``): the
        # sharded transports route each Fetch to the replica serving its
        # partitions; None keeps the direct store path (``dg._mget``)
        self.mget = mget if mget is not None else dg._mget
        self._routed = mget is not None

    # -- payload fetch plumbing --------------------------------------------
    def _fetch_keys(self, op: Fetch, options: AttrOptions):
        # a scattered (per-shard) plan restricts each Fetch to the
        # partitions the shard owns; unsharded plans carry parts=None
        if op.kind == "delta":
            keys, na, ea = self.dg._delta_keys(op.pid, options,
                                               parts=op.parts)
            return keys + na + ea, (len(keys), len(na))
        return self.dg._elist_keys(op.pid, options, parts=op.parts), None

    def _decode(self, op: Fetch, keys: list, meta, blobs: list):
        if op.kind == "delta":
            n_struct, n_na = meta
            return self.dg._decode_delta(blobs, n_struct, n_na)
        return self.dg._decode_elist(keys, blobs)

    # -- main walk ----------------------------------------------------------
    def run(self, ir: PlanIR, options: AttrOptions = NO_ATTRS,
            pool=None) -> dict[Any, MaterializedState]:
        dg = self.dg
        uni = dg.universe
        byid = {n.nid: n for n in ir.nodes}

        # fetches are issued a bounded window ahead of the apply cursor
        # (plan order == application order): enough in flight to overlap
        # every store get *and decode* with application, without ever
        # holding more than ~window payloads resident.  Decoded payloads
        # are dropped after their last consumer, so peak memory stays a
        # window deep — not the whole merged plan's KV traffic.
        pending: dict[int, tuple] = {}     # fetch nid -> (keys, meta)
        futures: dict[int, Any] = {}       # fetch nid -> in-flight future
        consumers: dict[int, int] = {}
        fetch_order: list[int] = []
        for n in ir.nodes:
            if isinstance(n.op, Fetch):
                pending[n.nid] = self._fetch_keys(n.op, options)
                fetch_order.append(n.nid)
            else:
                for d in n.deps:
                    if d in pending:
                        consumers[d] = consumers.get(d, 0) + 1

        window = (max(2 * self.prefetcher.workers, 4)
                  if self.prefetcher is not None else 0)
        next_submit = 0

        def top_up() -> None:
            nonlocal next_submit
            while (next_submit < len(fetch_order)
                   and len(futures) < window):
                nid = fetch_order[next_submit]
                next_submit += 1
                if nid in pending:      # not consumed out of order yet
                    keys, meta = pending[nid]
                    op = byid[nid].op
                    # decode runs inside the prefetch worker: the future
                    # resolves to arrays, not raw blobs
                    if self._routed:
                        # routed fetch: the worker thread calls the
                        # transport's mget, not the store directly
                        futures[nid] = self.prefetcher.submit_fn(
                            lambda op=op, keys=keys, meta=meta:
                                self._decode(op, keys, meta,
                                             self.mget(keys)))
                    else:
                        futures[nid] = self.prefetcher.submit(
                            keys,
                            decode=lambda blobs, op=op, keys=keys, meta=meta:
                                self._decode(op, keys, meta, blobs))

        if window:
            top_up()

        payloads: dict[int, Any] = {}

        def payload(nid: int):
            if nid not in payloads:
                keys, meta = pending.pop(nid)
                fut = futures.pop(nid, None)
                if fut is not None:
                    payloads[nid] = fut.result()   # decoded off-thread
                else:
                    payloads[nid] = self._decode(byid[nid].op, keys, meta,
                                                 self.mget(keys))
                if window:
                    top_up()
            out = payloads[nid]
            consumers[nid] -= 1
            if consumers[nid] <= 0:
                del payloads[nid]
            return out

        states: dict[int, MaterializedState] = {}
        out: dict[Any, MaterializedState] = {}
        for n in ir.nodes:
            op = n.op
            if isinstance(op, Fetch):
                continue
            if isinstance(op, Source):
                if op.kind == "empty":
                    st = MaterializedState.empty(uni)
                elif op.kind == "mat":
                    assert pool is not None, \
                        "materialized plan needs a GraphPool"
                    st = pool.get_state(op.gid,
                                        with_attrs=options.wants_attrs)
                else:  # current
                    base = dg._last_leaf_state.resized(uni).copy()
                    st = apply_events(base, dg.recent, forward=True)
            elif isinstance(op, Fork):
                st = states[n.deps[0]]          # alias; applies copy
            elif isinstance(op, Noop):
                st = states[self._state_dep(byid, n)].copy()
            elif isinstance(op, ApplyDelta):
                d = payload(self._fetch_dep(byid, n))
                st = apply_delta(
                    states[self._state_dep(byid, n)].resized(uni),
                    d, forward=op.forward)
            elif isinstance(op, ApplyElist):
                comps = payload(self._fetch_dep(byid, n))
                st = dg._apply_elist(
                    states[self._state_dep(byid, n)].resized(uni),
                    comps, op.forward, op.rng, options)
            elif isinstance(op, ApplyRecent):
                base = states[self._state_dep(byid, n)].resized(uni)
                ev = dg.recent
                if op.rng is not None:
                    lo, hi = op.rng
                    a = ev.search_time(lo, side="right")
                    b = ev.search_time(hi, side="right")
                    ev = ev[a:b]
                st = apply_events(base, ev, forward=op.forward)
            elif isinstance(op, Materialize):
                st = states[n.deps[0]].copy()
                st.node_mask &= ~uni.node_transient[: st.node_mask.size]
                st.edge_mask &= ~uni.edge_transient[: st.edge_mask.size]
                out[op.target] = st
                continue
            else:  # pragma: no cover
                raise ValueError(f"unknown IR op {op}")
            states[n.nid] = st
        return out

    @staticmethod
    def _state_dep(byid: dict, n) -> int:
        for d in n.deps:
            if not isinstance(byid[d].op, Fetch):
                return d
        raise ValueError(f"apply node {n.nid} has no state dependency")

    @staticmethod
    def _fetch_dep(byid: dict, n) -> int:
        for d in n.deps:
            if isinstance(byid[d].op, Fetch):
                return d
        raise ValueError(f"apply node {n.nid} has no fetch dependency")


# ---------------------------------------------------------------------------
# batch scheduling
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RetrievalRequest:
    """One logical query in a batch: a set of timepoints (singlepoint is a
    1-element set) under shared attr options."""
    times: Sequence[int]
    use_current: bool = True


class BatchScheduler:
    """Shared-prefix batch execution of concurrent retrieval requests.

    Plans each request, merges the plan DAGs (structural dedup — shared
    subpaths collapse), executes the merged DAG once on the host backend,
    and returns per-request result dicts.  The merged plan's weight is the
    true bytes-to-fetch for the whole batch; the sum of the individual
    plans' weights is what a query-at-a-time engine would have fetched.
    """

    def __init__(self, dg: "DeltaGraph", pool=None,
                 prefetcher: Prefetcher | None = None) -> None:
        self.dg = dg
        self.pool = pool
        self.prefetcher = prefetcher
        self.last_merged: PlanIR | None = None
        self.last_individual_weight = 0.0

    def run(self, requests: Sequence[RetrievalRequest],
            options: AttrOptions = NO_ATTRS
            ) -> list[dict[int, MaterializedState]]:
        irs = []
        for i, r in enumerate(requests):
            times = list(dict.fromkeys(int(t) for t in r.times))
            if not times:
                raise ValueError(f"request #{i} has no timepoints")
            irs.append(self.dg.plan_multipoint(times, options, r.use_current)
                       if len(times) > 1 else
                       self.dg.plan_singlepoint(times[0], options,
                                                r.use_current))
        self.last_individual_weight = sum(ir.total_weight for ir in irs)
        merged = merge_irs(irs)
        self.last_merged = merged
        all_states = self.dg.execute(merged, options, self.pool,
                                     prefetch=self.prefetcher)
        return [{int(t): all_states[int(t)] for t in r.times}
                for r in requests]
