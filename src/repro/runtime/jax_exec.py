"""TPU-native snapshot retrieval: DeltaGraph plans on packed bitmaps.

The host planner (Dijkstra / Steiner on the skeleton) stays as-is; this
module replaces the *apply* phase with JAX:

1. every plan step — delta edge (either direction) or partial eventlist —
   collapses to one ``(adds, dels)`` bitmap pair (exact because element ids
   are never reused, §3.1, so membership toggles at most add→del once);
2. a singlepoint plan is therefore a K-step chain, executed by the fused
   ``delta_apply`` kernel in **one pass** over the bitmap (K+2 instead of
   3K words of HBM traffic);
3. the distributed engine lays bitmap words out ``[P, Wp]`` per the
   ``word_cyclic`` partitioner and runs the same chain under ``shard_map``
   — per-partition deltas touch only their own words, so the lowered HLO
   contains **zero collectives** (the paper's "no network communication
   among machines during retrieval", made checkable: see
   ``tests/test_distributed.py``).
"""
from __future__ import annotations

import functools
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import compat
from ..core import bitmaps as bmod
from ..core.deltagraph import DeltaGraph, Plan
from ..core.events import (EV_DEL_EDGE, EV_DEL_NODE, EV_NEW_EDGE, EV_NEW_NODE)
from ..core.query import NO_ATTRS
from ..kernels import delta_apply_chain
from ..storage import columnar as col


# ---------------------------------------------------------------------------
# plan → (adds, dels) index pairs
# ---------------------------------------------------------------------------

def _elist_pair(comps, forward: bool, rng) -> tuple[np.ndarray, ...]:
    s = comps[col.ELIST_STRUCT]
    t = s["time"]
    m = np.ones(t.shape, bool) if rng is None else (t > rng[0]) & (t <= rng[1])
    et, sl = s["etype"][m], s["slot"][m]

    def pair(new_code, del_code):
        new_s = sl[et == new_code]
        del_s = sl[et == del_code]
        if forward:
            adds = np.setdiff1d(new_s, del_s)   # add-then-del nets to del
            dels = del_s
        else:
            adds = np.setdiff1d(del_s, new_s)   # un-delete revives
            dels = new_s
        return adds.astype(np.int32), dels.astype(np.int32)

    na, nd = pair(EV_NEW_NODE, EV_DEL_NODE)
    ea, ed = pair(EV_NEW_EDGE, EV_DEL_EDGE)
    return na, nd, ea, ed


def _recent_pair(dg: DeltaGraph, forward: bool, rng) -> tuple[np.ndarray, ...]:
    ev = dg.recent
    t = ev.time
    m = np.ones(t.shape, bool) if rng is None else (t > rng[0]) & (t <= rng[1])
    et, sl = ev.etype[m], ev.slot[m]

    def pair(new_code, del_code):
        new_s = sl[et == new_code]
        del_s = sl[et == del_code]
        if forward:
            return (np.setdiff1d(new_s, del_s).astype(np.int32),
                    del_s.astype(np.int32))
        return (np.setdiff1d(del_s, new_s).astype(np.int32),
                new_s.astype(np.int32))

    na, nd = pair(EV_NEW_NODE, EV_DEL_NODE)
    ea, ed = pair(EV_NEW_EDGE, EV_DEL_EDGE)
    return na, nd, ea, ed


def plan_to_chain(dg: DeltaGraph, plan: Plan, pool=None
                  ) -> tuple[tuple[np.ndarray, np.ndarray], list[tuple]]:
    """Lower a *singlepoint* plan into (base bitmaps, [(na,nd,ea,ed), ...])."""
    assert len(plan.targets) == 1, "use per-branch lowering for multipoint"
    steps = plan.steps
    src = steps[0]
    U_n, U_e = dg.universe.num_nodes, dg.universe.num_edges
    if src.action[0] == "empty":
        base_n = np.zeros(bmod.num_words(U_n), np.uint32)
        base_e = np.zeros(bmod.num_words(U_e), np.uint32)
    elif src.action[0] == "mat":
        base_n, base_e = pool._resolve_masks(src.action[1])
        base_n = np.asarray(base_n)
        base_e = np.asarray(base_e)
    elif src.action[0] == "current":
        st = dg._last_leaf_state
        base_n = bmod.np_pack(st.node_mask)
        base_e = bmod.np_pack(st.edge_mask)
        na, nd, ea, ed = _recent_pair(dg, True, None)
        chain0 = [(na, nd, ea, ed)]
    else:  # pragma: no cover
        raise ValueError(src.action)
    chain: list[tuple] = [] if src.action[0] != "current" else chain0
    for st in steps[1:]:
        kind = st.action[0]
        if kind == "delta":
            d = dg._fetch_delta(st.action[1], NO_ATTRS)
            if st.action[2]:
                chain.append((d.node_add, d.node_del, d.edge_add, d.edge_del))
            else:
                chain.append((d.node_del, d.node_add, d.edge_del, d.edge_add))
        elif kind == "elist":
            comps = dg._fetch_elist(st.action[1], NO_ATTRS)
            chain.append(_elist_pair(comps, st.action[2], st.action[3]))
        elif kind == "recent":
            chain.append(_recent_pair(dg, st.action[2], st.action[3]))
        elif kind == "noop":
            pass
        else:  # pragma: no cover
            raise ValueError(st.action)
    return (base_n, base_e), chain


# ---------------------------------------------------------------------------
# single-device execution (fused kernel)
# ---------------------------------------------------------------------------

def _stack_bitmaps(chain_idx: list[np.ndarray], U: int) -> jnp.ndarray:
    W = bmod.num_words(U)
    if not chain_idx:
        return jnp.zeros((0, W), jnp.uint32)
    rows = [np.asarray(bmod.np_from_indices(ix, U)) for ix in chain_idx]
    return jnp.asarray(np.stack(rows))


def execute_singlepoint_jax(dg: DeltaGraph, t: int, *, impl: str = "xla",
                            pool=None, use_current: bool = True
                            ) -> tuple[np.ndarray, np.ndarray]:
    """Returns (node_mask, edge_mask) bool arrays, computed on-device."""
    plan = dg.plan_singlepoint(t, NO_ATTRS, use_current)
    (base_n, base_e), chain = plan_to_chain(dg, plan, pool)
    U_n, U_e = dg.universe.num_nodes, dg.universe.num_edges
    n_adds = _stack_bitmaps([c[0] for c in chain], U_n)
    n_dels = _stack_bitmaps([c[1] for c in chain], U_n)
    e_adds = _stack_bitmaps([c[2] for c in chain], U_e)
    e_dels = _stack_bitmaps([c[3] for c in chain], U_e)
    out_n = delta_apply_chain(jnp.asarray(base_n), n_adds, n_dels, impl=impl)
    out_e = delta_apply_chain(jnp.asarray(base_e), e_adds, e_dels, impl=impl)
    nm = bmod.np_unpack(np.asarray(out_n), U_n)
    em = bmod.np_unpack(np.asarray(out_e), U_e)
    em &= ~dg.universe.edge_transient[:U_e]
    nm &= ~dg.universe.node_transient[:U_n]
    return nm, em


# ---------------------------------------------------------------------------
# distributed execution: shard_map over the node-ID partitions
# ---------------------------------------------------------------------------

def _to_sharded_layout(idx: np.ndarray, U: int, Pn: int) -> np.ndarray:
    """Slot → (partition row, local bit) under word_cyclic: word w lives at
    row ``w % P``, column ``w // P``; the local flat bit index is
    ``(w // P) * 32 + (slot & 31)``."""
    w = idx >> 5
    return (w % Pn).astype(np.int64), ((w // Pn) * 32 + (idx & 31)).astype(np.int64)


def _stack_sharded(chain_idx: list[np.ndarray], U: int, Pn: int) -> np.ndarray:
    Wp = -(-bmod.num_words(U) // Pn)
    K = len(chain_idx)
    out = np.zeros((K, Pn, Wp), np.uint32)
    for i, ix in enumerate(chain_idx):
        ix = np.asarray(ix, np.int64)
        if ix.size == 0:
            continue
        row, lbit = _to_sharded_layout(ix, U, Pn)
        np.bitwise_or.at(out[i], (row, lbit >> 5),
                         np.uint32(1) << (lbit & 31).astype(np.uint32))
    return out


def sharded_base(words: np.ndarray, Pn: int) -> np.ndarray:
    """Re-lay a packed bitmap [W] into the [P, Wp] word-cyclic layout."""
    W = words.size
    Wp = -(-W // Pn)
    out = np.zeros((Pn, Wp), np.uint32)
    w = np.arange(W)
    out[w % Pn, w // Pn] = words
    return out


def unshard(words_pw: np.ndarray, W: int) -> np.ndarray:
    Pn, Wp = words_pw.shape
    out = np.zeros(Pn * Wp, np.uint32)
    w = np.arange(W)
    out[:W] = words_pw[w % Pn, w // Pn]
    return out[:W]


def make_retrieval_fn(mesh: Mesh, axis: str = "data"):
    """Builds the shard_map'ed chain applier.  Each device owns one row of
    the [P, Wp] layout; the chain is applied locally — no collectives."""

    def _local(base, adds, dels):
        def step(m, ad):
            a, d = ad
            return (m & ~d) | a, None
        out, _ = jax.lax.scan(step, base, (adds, dels))
        return out

    shard = compat.shard_map(
        _local, mesh=mesh,
        in_specs=(P(axis, None), P(None, axis, None), P(None, axis, None)),
        out_specs=P(axis, None))
    return jax.jit(shard)


def execute_singlepoint_sharded(dg: DeltaGraph, t: int, mesh: Mesh, *,
                                axis: str = "data", pool=None,
                                use_current: bool = True
                                ) -> tuple[np.ndarray, np.ndarray]:
    """Distributed retrieval: requires ``dg.P == mesh.shape[axis]`` and the
    word_cyclic partitioner (storage partitions == compute partitions, the
    paper's aligned deployment)."""
    Pn = mesh.shape[axis]
    plan = dg.plan_singlepoint(t, NO_ATTRS, use_current)
    (base_n, base_e), chain = plan_to_chain(dg, plan, pool)
    U_n, U_e = dg.universe.num_nodes, dg.universe.num_edges
    fn = make_retrieval_fn(mesh, axis)
    outs = []
    for base, ix_a, ix_d, U in (
            (base_n, [c[0] for c in chain], [c[1] for c in chain], U_n),
            (base_e, [c[2] for c in chain], [c[3] for c in chain], U_e)):
        b = sharded_base(np.asarray(base), Pn)
        adds = _stack_sharded(ix_a, U, Pn)
        dels = _stack_sharded(ix_d, U, Pn)
        out = np.asarray(fn(jnp.asarray(b), jnp.asarray(adds), jnp.asarray(dels)))
        outs.append(bmod.np_unpack(unshard(out, bmod.num_words(U)), U))
    nm, em = outs
    em &= ~dg.universe.edge_transient[:U_e]
    nm &= ~dg.universe.node_transient[:U_n]
    return nm, em


def lowered_retrieval_hlo(mesh: Mesh, K: int, Wp: int, axis: str = "data") -> str:
    """Lowered HLO text of the sharded retrieval step (for the zero-
    collective assertion and the dry-run report)."""
    Pn = mesh.shape[axis]
    fn = make_retrieval_fn(mesh, axis)
    args = (jax.ShapeDtypeStruct((Pn, Wp), jnp.uint32),
            jax.ShapeDtypeStruct((K, Pn, Wp), jnp.uint32),
            jax.ShapeDtypeStruct((K, Pn, Wp), jnp.uint32))
    return fn.lower(*args).compile().as_text()
