"""TPU-native snapshot retrieval: DeltaGraph plans on packed bitmaps.

The host planner (Dijkstra / Steiner on the skeleton) stays as-is; this
module replaces the *apply* phase with JAX:

1. every plan step — delta edge (either direction) or partial eventlist —
   collapses to one ``(adds, dels)`` bitmap pair (exact because element ids
   are never reused, §3.1, so membership toggles at most add→del once);
2. a singlepoint plan is therefore a K-step chain, executed by the fused
   ``delta_apply`` kernel in **one pass** over the bitmap (K+2 instead of
   3K words of HBM traffic);
3. the distributed engine lays bitmap words out ``[P, Wp]`` per the
   ``word_cyclic`` partitioner and runs the same chain under ``shard_map``
   — per-partition deltas touch only their own words, so the lowered HLO
   contains **zero collectives** (the paper's "no network communication
   among machines during retrieval", made checkable: see
   ``tests/test_distributed.py``).
"""
from __future__ import annotations

import functools
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import compat
from .staging import DeviceStager, stream_chunk_k
from ..core import bitmaps as bmod
from ..core import planir
from ..core.deltagraph import DeltaGraph, Plan
from ..core.events import (EV_DEL_EDGE, EV_DEL_NODE, EV_NEW_EDGE, EV_NEW_NODE)
from ..core.query import NO_ATTRS
from ..kernels import (FusedOut, delta_apply_chain,
                       delta_apply_chain_batched,
                       delta_apply_chain_prefix_batched, delta_apply_fused,
                       segment_sum)
from ..storage import columnar as col


# ---------------------------------------------------------------------------
# plan → (adds, dels) index pairs
# ---------------------------------------------------------------------------

_fit_words = bmod.np_fit_words

def _elist_pair(comps, forward: bool, rng) -> tuple[np.ndarray, ...]:
    s = comps[col.ELIST_STRUCT]
    t = s["time"]
    m = np.ones(t.shape, bool) if rng is None else (t > rng[0]) & (t <= rng[1])
    et, sl = s["etype"][m], s["slot"][m]

    def pair(new_code, del_code):
        new_s = sl[et == new_code]
        del_s = sl[et == del_code]
        if forward:
            adds = np.setdiff1d(new_s, del_s)   # add-then-del nets to del
            dels = del_s
        else:
            adds = np.setdiff1d(del_s, new_s)   # un-delete revives
            dels = new_s
        return adds.astype(np.int32), dels.astype(np.int32)

    na, nd = pair(EV_NEW_NODE, EV_DEL_NODE)
    ea, ed = pair(EV_NEW_EDGE, EV_DEL_EDGE)
    return na, nd, ea, ed


def _recent_pair(dg: DeltaGraph, forward: bool, rng) -> tuple[np.ndarray, ...]:
    ev = dg.recent
    t = ev.time
    m = np.ones(t.shape, bool) if rng is None else (t > rng[0]) & (t <= rng[1])
    et, sl = ev.etype[m], ev.slot[m]

    def pair(new_code, del_code):
        new_s = sl[et == new_code]
        del_s = sl[et == del_code]
        if forward:
            return (np.setdiff1d(new_s, del_s).astype(np.int32),
                    del_s.astype(np.int32))
        return (np.setdiff1d(del_s, new_s).astype(np.int32),
                new_s.astype(np.int32))

    na, nd = pair(EV_NEW_NODE, EV_DEL_NODE)
    ea, ed = pair(EV_NEW_EDGE, EV_DEL_EDGE)
    return na, nd, ea, ed


def plan_to_chain(dg: DeltaGraph, plan: Plan, pool=None
                  ) -> tuple[tuple[np.ndarray, np.ndarray], list[tuple]]:
    """Lower a *singlepoint* plan into (base bitmaps, [(na,nd,ea,ed), ...])."""
    assert len(plan.targets) == 1, "use per-branch lowering for multipoint"
    steps = plan.steps
    src = steps[0]
    U_n, U_e = dg.universe.num_nodes, dg.universe.num_edges
    if src.action[0] == "empty":
        base_n = np.zeros(bmod.num_words(U_n), np.uint32)
        base_e = np.zeros(bmod.num_words(U_e), np.uint32)
    elif src.action[0] == "mat":
        base_n, base_e = pool._resolve_masks(src.action[1])
        base_n = _fit_words(base_n, bmod.num_words(U_n))
        base_e = _fit_words(base_e, bmod.num_words(U_e))
    elif src.action[0] == "current":
        st = dg._last_leaf_state.resized(dg.universe)
        base_n = bmod.np_pack(st.node_mask)
        base_e = bmod.np_pack(st.edge_mask)
        na, nd, ea, ed = _recent_pair(dg, True, None)
        chain0 = [(na, nd, ea, ed)]
    else:  # pragma: no cover
        raise ValueError(src.action)
    chain: list[tuple] = [] if src.action[0] != "current" else chain0
    for st in steps[1:]:
        kind = st.action[0]
        if kind == "delta":
            d = dg._fetch_delta(st.action[1], NO_ATTRS)
            if st.action[2]:
                chain.append((d.node_add, d.node_del, d.edge_add, d.edge_del))
            else:
                chain.append((d.node_del, d.node_add, d.edge_del, d.edge_add))
        elif kind == "elist":
            comps = dg._fetch_elist(st.action[1], NO_ATTRS)
            chain.append(_elist_pair(comps, st.action[2], st.action[3]))
        elif kind == "recent":
            chain.append(_recent_pair(dg, st.action[2], st.action[3]))
        elif kind == "noop":
            pass
        else:  # pragma: no cover
            raise ValueError(st.action)
    return (base_n, base_e), chain


# ---------------------------------------------------------------------------
# single-device execution (fused kernel)
# ---------------------------------------------------------------------------

def _stack_bitmaps(chain_idx: list[np.ndarray], U: int) -> jnp.ndarray:
    W = bmod.num_words(U)
    if not chain_idx:
        return jnp.zeros((0, W), jnp.uint32)
    rows = [np.asarray(bmod.np_from_indices(ix, U)) for ix in chain_idx]
    return jnp.asarray(np.stack(rows))


def execute_singlepoint_jax(dg: DeltaGraph, t: int, *, impl: str | None = None,
                            pool=None, use_current: bool = True
                            ) -> tuple[np.ndarray, np.ndarray]:
    """Returns (node_mask, edge_mask) bool arrays, computed on-device."""
    plan = dg.plan_singlepoint(t, NO_ATTRS, use_current)
    (base_n, base_e), chain = plan_to_chain(dg, plan, pool)
    U_n, U_e = dg.universe.num_nodes, dg.universe.num_edges
    n_adds = _stack_bitmaps([c[0] for c in chain], U_n)
    n_dels = _stack_bitmaps([c[1] for c in chain], U_n)
    e_adds = _stack_bitmaps([c[2] for c in chain], U_e)
    e_dels = _stack_bitmaps([c[3] for c in chain], U_e)
    out_n = delta_apply_chain(jnp.asarray(base_n), n_adds, n_dels, impl=impl)
    out_e = delta_apply_chain(jnp.asarray(base_e), e_adds, e_dels, impl=impl)
    nm = bmod.np_unpack(np.asarray(out_n), U_n)
    em = bmod.np_unpack(np.asarray(out_e), U_e)
    em &= ~dg.universe.edge_transient[:U_e]
    nm &= ~dg.universe.node_transient[:U_n]
    return nm, em


# ---------------------------------------------------------------------------
# fused retrieval + analytics (single pass over the landed bitmaps)
# ---------------------------------------------------------------------------


class SnapshotAnalytics:
    """Push-style analytics emitted by the fused delta-apply kernel: the
    node/edge :class:`FusedOut` partials from the same pass that landed the
    chain.  ``node.live_count()`` / ``edge.live_count()`` are the snapshot
    order and size; ``edge.live`` feeds :func:`degrees` (per-node degree via
    the segment_sum kernel); ``node.weighted_total()`` is the PageRank push
    mass when per-slot contributions were supplied."""

    def __init__(self, node: FusedOut, edge: FusedOut, dg: DeltaGraph):
        self.node = node
        self.edge = edge
        self._dg = dg

    def num_nodes(self) -> int:
        return int(self.node.live_count())

    def num_edges(self) -> int:
        return int(self.edge.live_count())

    def degrees(self, *, impl: str | None = None) -> np.ndarray:
        """Per-node degree (both endpoints of live edges) reduced from the
        fused kernel's unpacked edge indicator by the segment_sum kernel —
        no host round-trip between apply and reduction."""
        uni = self._dg.universe
        E, N = uni.num_edges, uni.num_nodes
        live = self.edge.live[:E][:, None]
        src = jnp.asarray(uni.edge_src[:E])
        dst = jnp.asarray(uni.edge_dst[:E])
        deg = (segment_sum(live, src, N, impl=impl)
               + segment_sum(live, dst, N, impl=impl))
        return np.asarray(deg).reshape(-1)


def _transient_step(dg: DeltaGraph, U_n: int, U_e: int):
    """Transient slots cleared as one more chain step (zero adds, packed
    transient dels) — fused analytics then see exactly the returned masks."""
    return (bmod.np_pack(dg.universe.node_transient[:U_n]),
            bmod.np_pack(dg.universe.edge_transient[:U_e]))


def execute_singlepoint_fused(dg: DeltaGraph, t: int, *,
                              node_weights=None, impl: str | None = None,
                              pool=None, use_current: bool = True
                              ) -> tuple[np.ndarray, np.ndarray,
                                         SnapshotAnalytics]:
    """Single-point retrieval with analytics fused into the apply pass.

    Same plan and chain lowering as :func:`execute_singlepoint_jax`, but
    executed by the fused kernel: while each bitmap block holds the landed
    chain state in registers it also emits popcount/degree partials and
    (optionally, via ``node_weights [num_nodes] f32``) a PageRank-style
    push accumulator — the separate analytics sweep over the mask is gone.
    Transient-slot clearing folds into the chain as a final delete step, so
    analytics and the returned bool masks agree bit-for-bit.
    """
    plan = dg.plan_singlepoint(t, NO_ATTRS, use_current)
    (base_n, base_e), chain = plan_to_chain(dg, plan, pool)
    U_n, U_e = dg.universe.num_nodes, dg.universe.num_edges
    W_n, W_e = bmod.num_words(U_n), bmod.num_words(U_e)
    tn, te = _transient_step(dg, U_n, U_e)
    n_adds = np.stack([bmod.np_from_indices(c[0], U_n) for c in chain]
                      + [np.zeros(W_n, np.uint32)])
    n_dels = np.stack([bmod.np_from_indices(c[1], U_n) for c in chain] + [tn])
    e_adds = np.stack([bmod.np_from_indices(c[2], U_e) for c in chain]
                      + [np.zeros(W_e, np.uint32)])
    e_dels = np.stack([bmod.np_from_indices(c[3], U_e) for c in chain] + [te])
    w = None
    if node_weights is not None:
        w = jnp.asarray(np.asarray(node_weights, np.float32).reshape(-1))
    fn = delta_apply_fused(jnp.asarray(base_n), jnp.asarray(n_adds),
                           jnp.asarray(n_dels), w, impl=impl)
    fe = delta_apply_fused(jnp.asarray(base_e), jnp.asarray(e_adds),
                           jnp.asarray(e_dels), impl=impl)
    nm = bmod.np_unpack(np.asarray(fn.mask), U_n)
    em = bmod.np_unpack(np.asarray(fe.mask), U_e)
    return nm, em, SnapshotAnalytics(fn, fe, dg)


# ---------------------------------------------------------------------------
# IR DAG execution: vmapped multi-snapshot apply
# ---------------------------------------------------------------------------

_EMPTY_PAIR = (np.zeros(0, np.int32),) * 4


def _node_pair(dg: DeltaGraph, op, get_payload) -> tuple[np.ndarray, ...]:
    """Lower one apply op to an ``(n_add, n_del, e_add, e_del)`` index
    quadruple; payloads come through ``get_payload`` (memoized per pid,
    possibly prefetched)."""
    if isinstance(op, planir.ApplyDelta):
        d = get_payload("delta", op.pid)
        if op.forward:
            return d.node_add, d.node_del, d.edge_add, d.edge_del
        return d.node_del, d.node_add, d.edge_del, d.edge_add
    if isinstance(op, planir.ApplyElist):
        return _elist_pair(get_payload("elist", op.pid), op.forward, op.rng)
    if isinstance(op, planir.ApplyRecent):
        return _recent_pair(dg, op.forward, op.rng)
    if isinstance(op, planir.Noop):
        return _EMPTY_PAIR
    raise ValueError(f"not an apply op: {op}")  # pragma: no cover


def _make_payload_resolver(dg: DeltaGraph, ir: Plan, prefetch):
    """Memoized payload access for the structure-only backend; with a
    Prefetcher, every Fetch node's (small, struct-component) key list is
    submitted up front — the worker threads fetch *and decode* the blobs,
    so store gets and codec decompression both overlap kernel execution
    and the host-fetch path consumes ready arrays."""
    futs: dict[tuple, Any] = {}
    if prefetch is not None:
        for n in ir.nodes:
            if not isinstance(n.op, planir.Fetch):
                continue
            fk = (n.op.kind, n.op.pid)
            if fk in futs:
                continue
            if n.op.kind == "delta":
                keys, na, ea = dg._delta_keys(n.op.pid, NO_ATTRS)
                allk, meta = keys + na + ea, (len(keys), len(na))
                decode = (lambda blobs, meta=meta:
                          dg._decode_delta(blobs, *meta))
            else:
                allk = dg._elist_keys(n.op.pid, NO_ATTRS)
                decode = (lambda blobs, allk=allk:
                          dg._decode_elist(allk, blobs))
            futs[fk] = prefetch.submit(allk, decode=decode)
    payloads: dict[tuple, Any] = {}

    def get_payload(kind: str, pid: int):
        fk = (kind, pid)
        if fk not in payloads:
            fut = futs.pop(fk, None)
            if fut is not None:
                payloads[fk] = fut.result()   # decoded in the worker
            else:
                payloads[fk] = (dg._fetch_delta(pid, NO_ATTRS)
                                if kind == "delta"
                                else dg._fetch_elist(pid, NO_ATTRS))
        return payloads[fk]

    return get_payload


def _np_apply_pair(bn: np.ndarray, be: np.ndarray, pair, U_n: int, U_e: int):
    na, nd, ea, ed = pair
    bn = (bn & ~bmod.np_from_indices(nd, U_n)) | bmod.np_from_indices(na, U_n)
    be = (be & ~bmod.np_from_indices(ed, U_e)) | bmod.np_from_indices(ea, U_e)
    return bn, be


def _apply_chains_streamed(bases_n, bases_e, chains, U_n: int, U_e: int, *,
                           impl, prefetch=None, stager: DeviceStager | None
                           = None) -> tuple[np.ndarray, np.ndarray]:
    """Land B index-quad chains over the node+edge planes, double-buffered.

    ``chains[i]`` is a list of ``(na, nd, ea, ed)`` slot-index quads.  When
    the common chain length exceeds the stream chunk
    (``REPRO_STREAM_CHUNK``, default 8) the ``[B, K, W]`` plane stacks are
    never materialized whole: the :class:`DeviceStager` builds (codec
    indices → packed planes) and ``device_put``s chunk *i+1* while chunk
    *i*'s kernels run.  The chain is a left fold of bitwise steps, so the
    chunked landing is bit-identical to the monolithic call."""
    W_n, W_e = bmod.num_words(U_n), bmod.num_words(U_e)
    B = len(chains)
    K = max(len(c) for c in chains)
    if K == 0:
        return np.asarray(bases_n), np.asarray(bases_e)

    def build(lo: int, hi: int):
        k = hi - lo
        an = np.zeros((B, k, W_n), np.uint32)
        dn = np.zeros((B, k, W_n), np.uint32)
        ae = np.zeros((B, k, W_e), np.uint32)
        de = np.zeros((B, k, W_e), np.uint32)
        for i, chain in enumerate(chains):
            for j in range(lo, min(hi, len(chain))):
                na, nd, ea, ed = chain[j]
                an[i, j - lo] = bmod.np_from_indices(na, U_n)
                dn[i, j - lo] = bmod.np_from_indices(nd, U_n)
                ae[i, j - lo] = bmod.np_from_indices(ea, U_e)
                de[i, j - lo] = bmod.np_from_indices(ed, U_e)
        return an, dn, ae, de

    ck = stream_chunk_k()
    if ck < 1 or K <= ck:
        an, dn, ae, de = build(0, K)
        out_n = delta_apply_chain_batched(
            jnp.asarray(bases_n), jnp.asarray(an), jnp.asarray(dn), impl=impl)
        out_e = delta_apply_chain_batched(
            jnp.asarray(bases_e), jnp.asarray(ae), jnp.asarray(de), impl=impl)
        return np.asarray(out_n), np.asarray(out_e)

    if stager is None:
        stager = DeviceStager(prefetcher=prefetch)
    nch = -(-K // ck)

    def apply_chunk(carry, dev):
        bn, be = carry
        an, dn, ae, de = dev
        return (delta_apply_chain_batched(bn, an, dn, impl=impl),
                delta_apply_chain_batched(be, ae, de, impl=impl))

    bn, be = stager.stream(
        nch, lambda i: build(i * ck, min((i + 1) * ck, K)), apply_chunk,
        (jnp.asarray(bases_n), jnp.asarray(bases_e)))
    return np.asarray(bn), np.asarray(be)


def execute_ir_jax(dg: DeltaGraph, ir: Plan, *, impl: str | None = None,
                   pool=None, prefetch=None,
                   stager: DeviceStager | None = None
                   ) -> dict[Any, tuple[np.ndarray, np.ndarray]]:
    """Execute a plan IR (structure-only) on the JAX bitmap backend.

    The DAG is decomposed into maximal linear **segments** between
    boundaries (sources, Fork nodes, targets); every wave batches all
    ready segments — sibling branches after a Fork in particular — into a
    single vmapped ``delta_apply_chain`` call over stacked bit-planes, so
    B branches cost one fused pass instead of B sequential chains.

    Returns ``{target: (node_mask, edge_mask)}`` bool arrays.
    """
    U_n, U_e = dg.universe.num_nodes, dg.universe.num_edges
    W_n, W_e = bmod.num_words(U_n), bmod.num_words(U_e)
    byid = {n.nid: n for n in ir.nodes}
    get_payload = _make_payload_resolver(dg, ir, prefetch)

    # state topology: apply children per state node; forks pass through
    children: dict[int, list[int]] = {}
    fork_child: dict[int, int] = {}
    for n in ir.nodes:
        if isinstance(n.op, planir.APPLY_OPS):
            for d in n.deps:
                if not isinstance(byid[d].op, planir.Fetch):
                    children.setdefault(d, []).append(n.nid)
        elif isinstance(n.op, planir.Fork):
            fork_child[n.deps[0]] = n.nid

    target_nids = set(ir.targets.values())

    def is_boundary(nid: int) -> bool:
        return (nid in target_nids or nid in fork_child
                or len(children.get(nid, ())) != 1)

    # source values (host-side: tiny — one packed bitmap each)
    vals: dict[int, tuple[np.ndarray, np.ndarray]] = {}
    frontier: list[int] = []
    for n in ir.nodes:
        op = n.op
        if isinstance(op, planir.Source):
            if op.kind == "empty":
                v = (np.zeros(W_n, np.uint32), np.zeros(W_e, np.uint32))
            elif op.kind == "mat":
                assert pool is not None, "materialized plan needs a GraphPool"
                pn, pe = pool._resolve_masks(op.gid)
                v = (_fit_words(pn, W_n), _fit_words(pe, W_e))
            else:  # current = last leaf + recent events
                st = dg._last_leaf_state.resized(dg.universe)
                v = _np_apply_pair(bmod.np_pack(st.node_mask),
                                   bmod.np_pack(st.edge_mask),
                                   _recent_pair(dg, True, None), U_n, U_e)
            vals[n.nid] = v
            frontier.append(n.nid)

    def expand(nid: int) -> None:
        """Fork nodes inherit their parent's value and join the frontier."""
        if nid in fork_child:
            f = fork_child[nid]
            vals[f] = vals[nid]
            frontier.append(f)

    for nid in list(vals):
        expand(nid)

    while frontier:
        # collect every ready segment in this wave
        segments: list[tuple[int, list[int]]] = []   # (parent, [apply nids])
        wave, frontier = frontier, []
        for pnid in wave:
            for c in children.get(pnid, ()):
                seg = [c]
                while not is_boundary(seg[-1]):
                    seg.append(children[seg[-1]][0])
                segments.append((pnid, seg))
        if not segments:
            break
        chains = [[_node_pair(dg, byid[s].op, get_payload) for s in seg]
                  for _, seg in segments]
        bases_n = np.stack([vals[p][0] for p, _ in segments])
        bases_e = np.stack([vals[p][1] for p, _ in segments])
        out_n, out_e = _apply_chains_streamed(
            bases_n, bases_e, chains, U_n, U_e, impl=impl,
            prefetch=prefetch, stager=stager)
        for i, (_, seg) in enumerate(segments):
            end = seg[-1]
            vals[end] = (out_n[i], out_e[i])
            frontier.append(end)
            expand(end)

    out: dict[Any, tuple[np.ndarray, np.ndarray]] = {}
    for tgt, nid in ir.targets.items():
        nm = bmod.np_unpack(vals[nid][0], U_n)
        em = bmod.np_unpack(vals[nid][1], U_e)
        nm &= ~dg.universe.node_transient[:U_n]
        em &= ~dg.universe.edge_transient[:U_e]
        out[tgt] = (nm, em)
    return out


def execute_multipoint_jax(dg: DeltaGraph, times, *, impl: str | None = None,
                           pool=None, use_current: bool = True,
                           land_in_pool: bool = False, prefetch=None):
    """Batched multipoint retrieval on the JAX backend: one Steiner plan,
    sibling branches vmapped, store gets optionally prefetched.  Returns
    ``{t: (node_mask, edge_mask)}``, or ``{t: pool gid}`` when
    ``land_in_pool`` — the masks are then overlaid into GraphPool bit
    pairs in a single batched insert."""
    ir = dg.plan_multipoint([int(t) for t in times], NO_ATTRS, use_current)
    masks = execute_ir_jax(dg, ir, impl=impl, pool=pool, prefetch=prefetch)
    if not land_in_pool:
        return masks
    assert pool is not None, "land_in_pool needs a GraphPool"
    order = list(masks)
    gids = pool.insert_snapshots_packed(
        [(bmod.np_pack(masks[t][0]), bmod.np_pack(masks[t][1]))
         for t in order])
    return dict(zip(order, gids))


# ---------------------------------------------------------------------------
# vmapped multi-interval temporal analytics
# ---------------------------------------------------------------------------

def evolve_intervals_jax(dg: DeltaGraph, intervals, *, impl: str | None = None,
                         pool=None, use_current: bool = True, prefetch=None,
                         stager: DeviceStager | None = None
                         ) -> list[dict[int, tuple[np.ndarray, np.ndarray]]]:
    """Per-timepoint (node_mask, edge_mask) for **B intervals at once**.

    The B interval *start* snapshots retrieve as one Steiner plan on the
    batched IR backend (:func:`execute_ir_jax` — sibling branches run as a
    single ``delta_apply_chain_batched`` call); the starts then become the
    base planes of a ``[B, K-1, W]`` stack of inter-snapshot delta bitmaps
    (net event slices via :mod:`repro.core.temporal`, each covering leaf
    eventlist fetched once per call) swept by the vmapped prefix chain —
    every prefix **is** one interval timepoint's membership bitmap, ready
    to feed the vmapped plane-masked analytics
    (:func:`repro.graph.algorithms.multi_snapshot_pagerank` etc.).

    Returns one ``{t: (node_mask, edge_mask)}`` dict per interval,
    bit-identical to the host engine (``tests/test_differential_exec.py``).
    """
    from ..core.temporal import IntervalSlicer
    ivs = [sorted(dict.fromkeys(int(t) for t in iv)) for iv in intervals]
    if not ivs or any(not iv for iv in ivs):
        raise ValueError("every interval needs at least one timepoint")
    U_n, U_e = dg.universe.num_nodes, dg.universe.num_edges
    W_n, W_e = bmod.num_words(U_n), bmod.num_words(U_e)

    # 1. batched retrieval of the B start snapshots (deduped by the plan)
    ir = dg.plan_multipoint([iv[0] for iv in ivs], NO_ATTRS, use_current)
    start_masks = execute_ir_jax(dg, ir, impl=impl, pool=pool,
                                 prefetch=prefetch)

    # 2. one slicer for the whole batch: overlapping intervals share leaf
    #    eventlist fetches, and quads are exactly the temporal engine's
    slicer = IntervalSlicer(dg, NO_ATTRS, prefetcher=prefetch)
    for iv in ivs:
        slicer.prefetch_interval(iv[0], iv[-1])
    quads = [[slicer.quad(lo, hi) for lo, hi in zip(iv, iv[1:])]
             for iv in ivs]

    # 3. vmapped prefix sweep (zero-padded rows are identity steps)
    B = len(ivs)
    Kmax = max(len(q) for q in quads)
    out: list[dict[int, tuple[np.ndarray, np.ndarray]]] = [
        {iv[0]: start_masks[iv[0]]} for iv in ivs]
    if Kmax == 0:
        return out
    bases_n = np.stack([bmod.np_pack(start_masks[iv[0]][0]) for iv in ivs])
    bases_e = np.stack([bmod.np_pack(start_masks[iv[0]][1]) for iv in ivs])

    def build(lo: int, hi: int):
        k = hi - lo
        an = np.zeros((B, k, W_n), np.uint32)
        dn = np.zeros((B, k, W_n), np.uint32)
        ae = np.zeros((B, k, W_e), np.uint32)
        de = np.zeros((B, k, W_e), np.uint32)
        for b, qs in enumerate(quads):
            for j in range(lo, min(hi, len(qs))):
                q = qs[j]
                an[b, j - lo] = bmod.np_from_indices(q.node_add, U_n)
                dn[b, j - lo] = bmod.np_from_indices(q.node_del, U_n)
                ae[b, j - lo] = bmod.np_from_indices(q.edge_add, U_e)
                de[b, j - lo] = bmod.np_from_indices(q.edge_del, U_e)
        return an, dn, ae, de

    ck = stream_chunk_k()
    if ck < 1 or Kmax <= ck:
        an, dn, ae, de = build(0, Kmax)
        pref_n = np.asarray(delta_apply_chain_prefix_batched(
            jnp.asarray(bases_n), jnp.asarray(an), jnp.asarray(dn)))
        pref_e = np.asarray(delta_apply_chain_prefix_batched(
            jnp.asarray(bases_e), jnp.asarray(ae), jnp.asarray(de)))
    else:
        # streamed prefix sweep: each chunk's last prefix seeds the next
        # chunk's base, so chunked prefixes concatenate bit-identically
        if stager is None:
            stager = DeviceStager(prefetcher=prefetch)
        nch = -(-Kmax // ck)
        parts: list[tuple] = []

        def apply_chunk(carry, dev):
            bn, be = carry
            an, dn, ae, de = dev
            pn = delta_apply_chain_prefix_batched(bn, an, dn)
            pe = delta_apply_chain_prefix_batched(be, ae, de)
            parts.append((pn, pe))
            return pn[:, -1], pe[:, -1]

        stager.stream(nch, lambda i: build(i * ck, min((i + 1) * ck, Kmax)),
                      apply_chunk,
                      (jnp.asarray(bases_n), jnp.asarray(bases_e)))
        pref_n = np.concatenate([np.asarray(p[0]) for p in parts], axis=1)
        pref_e = np.concatenate([np.asarray(p[1]) for p in parts], axis=1)
    for b, iv in enumerate(ivs):
        for j, t in enumerate(iv[1:]):
            nm = bmod.np_unpack(pref_n[b, j], U_n)
            em = bmod.np_unpack(pref_e[b, j], U_e)
            nm &= ~dg.universe.node_transient[:U_n]
            em &= ~dg.universe.edge_transient[:U_e]
            out[b][t] = (nm, em)
    return out


# ---------------------------------------------------------------------------
# distributed execution: shard_map over the node-ID partitions
# ---------------------------------------------------------------------------

def _to_sharded_layout(idx: np.ndarray, U: int, Pn: int) -> np.ndarray:
    """Slot → (partition row, local bit) under word_cyclic: word w lives at
    row ``w % P``, column ``w // P``; the local flat bit index is
    ``(w // P) * 32 + (slot & 31)``."""
    w = idx >> 5
    return (w % Pn).astype(np.int64), ((w // Pn) * 32 + (idx & 31)).astype(np.int64)


def _stack_sharded(chain_idx: list[np.ndarray], U: int, Pn: int) -> np.ndarray:
    Wp = -(-bmod.num_words(U) // Pn)
    K = len(chain_idx)
    out = np.zeros((K, Pn, Wp), np.uint32)
    for i, ix in enumerate(chain_idx):
        ix = np.asarray(ix, np.int64)
        if ix.size == 0:
            continue
        row, lbit = _to_sharded_layout(ix, U, Pn)
        np.bitwise_or.at(out[i], (row, lbit >> 5),
                         np.uint32(1) << (lbit & 31).astype(np.uint32))
    return out


def sharded_base(words: np.ndarray, Pn: int) -> np.ndarray:
    """Re-lay a packed bitmap [W] into the [P, Wp] word-cyclic layout."""
    W = words.size
    Wp = -(-W // Pn)
    out = np.zeros((Pn, Wp), np.uint32)
    w = np.arange(W)
    out[w % Pn, w // Pn] = words
    return out


def unshard(words_pw: np.ndarray, W: int) -> np.ndarray:
    Pn, Wp = words_pw.shape
    out = np.zeros(Pn * Wp, np.uint32)
    w = np.arange(W)
    out[:W] = words_pw[w % Pn, w // Pn]
    return out[:W]


def _scatter_row(out_kp: np.ndarray, ix: np.ndarray, Pn: int) -> None:
    """OR slot indices into one partition row [Wp] of the word_cyclic
    layout (the caller guarantees every slot belongs to that row)."""
    ix = np.asarray(ix, np.int64)
    if ix.size == 0:
        return
    lbit = ((ix >> 5) // Pn) * 32 + (ix & 31)
    np.bitwise_or.at(out_kp, lbit >> 5,
                     np.uint32(1) << (lbit & 31).astype(np.uint32))


_EMPTY_PAIR = (np.zeros(0, np.int32),) * 4


def plan_to_chain_sharded(dg: DeltaGraph, plan: Plan, Pn: int, pool=None
                          ) -> tuple[tuple[np.ndarray, np.ndarray],
                                     tuple[np.ndarray, ...]]:
    """Lower a *singlepoint* plan into base bitmaps plus per-partition
    ``[K, P, Wp]`` add/del stacks, fetching each storage partition's
    sub-payloads **separately** — the fetch pattern of the aligned
    deployment, where device ``p`` pulls only the partition-``p`` keys
    from the store and fills exactly its own layout row.

    Requires ``dg.P == Pn`` under the ``word_cyclic`` partitioner, so a
    delta/eventlist sub-payload's slots land entirely in row ``p``.
    In-memory steps (recent events, which are not yet partitioned into
    storage) carry slots from every partition and are scattered across
    rows like the dense path does."""
    assert len(plan.targets) == 1, "use per-branch lowering for multipoint"
    if dg.P != Pn or dg.partition_fn_name != "word_cyclic":
        raise ValueError(
            f"aligned sharded lowering needs dg.P == {Pn} storage "
            f"partitions under word_cyclic; have P={dg.P} "
            f"fn={dg.partition_fn_name}")
    steps = plan.steps
    src = steps[0]
    U_n, U_e = dg.universe.num_nodes, dg.universe.num_edges
    entries: list[tuple[str, Any]] = []
    if src.action[0] == "empty":
        base_n = np.zeros(bmod.num_words(U_n), np.uint32)
        base_e = np.zeros(bmod.num_words(U_e), np.uint32)
    elif src.action[0] == "mat":
        base_n, base_e = pool._resolve_masks(src.action[1])
        base_n = _fit_words(base_n, bmod.num_words(U_n))
        base_e = _fit_words(base_e, bmod.num_words(U_e))
    elif src.action[0] == "current":
        st = dg._last_leaf_state.resized(dg.universe)
        base_n = bmod.np_pack(st.node_mask)
        base_e = bmod.np_pack(st.edge_mask)
        entries.append(("full", _recent_pair(dg, True, None)))
    else:  # pragma: no cover
        raise ValueError(src.action)
    for st in steps[1:]:
        kind = st.action[0]
        if kind == "delta":
            per = []
            for p in range(Pn):
                d = dg._fetch_delta(st.action[1], NO_ATTRS, parts=(p,))
                if st.action[2]:
                    per.append((d.node_add, d.node_del,
                                d.edge_add, d.edge_del))
                else:
                    per.append((d.node_del, d.node_add,
                                d.edge_del, d.edge_add))
            entries.append(("parts", per))
        elif kind == "elist":
            per = []
            for p in range(Pn):
                comps = dg._fetch_elist(st.action[1], NO_ATTRS,
                                        parts=(p,))
                per.append(_elist_pair(comps, st.action[2], st.action[3])
                           if col.ELIST_STRUCT in comps else _EMPTY_PAIR)
            entries.append(("parts", per))
        elif kind == "recent":
            entries.append(("full", _recent_pair(dg, st.action[2],
                                                 st.action[3])))
        elif kind == "noop":
            pass
        else:  # pragma: no cover
            raise ValueError(st.action)
    K = len(entries)
    Wp_n = -(-bmod.num_words(U_n) // Pn)
    Wp_e = -(-bmod.num_words(U_e) // Pn)
    stacks = (np.zeros((K, Pn, Wp_n), np.uint32),
              np.zeros((K, Pn, Wp_n), np.uint32),
              np.zeros((K, Pn, Wp_e), np.uint32),
              np.zeros((K, Pn, Wp_e), np.uint32))
    for k, (tag, data) in enumerate(entries):
        if tag == "parts":
            for p, pair in enumerate(data):
                for st_arr, ix in zip(stacks, pair):
                    _scatter_row(st_arr[k, p], ix, Pn)
        else:  # full-state step: slots span partitions
            for st_arr, ix in zip(stacks, data):
                ix = np.asarray(ix, np.int64)
                if ix.size == 0:
                    continue
                U = U_n if st_arr is stacks[0] or st_arr is stacks[1] else U_e
                row, lbit = _to_sharded_layout(ix, U, Pn)
                np.bitwise_or.at(
                    st_arr[k], (row, lbit >> 5),
                    np.uint32(1) << (lbit & 31).astype(np.uint32))
    return (base_n, base_e), stacks


def make_retrieval_fn(mesh: Mesh, axis: str = "data"):
    """Builds the shard_map'ed chain applier.  Each device owns one row of
    the [P, Wp] layout; the chain is applied locally — no collectives."""

    def _local(base, adds, dels):
        def step(m, ad):
            a, d = ad
            return (m & ~d) | a, None
        out, _ = jax.lax.scan(step, base, (adds, dels))
        return out

    shard = compat.shard_map(
        _local, mesh=mesh,
        in_specs=(P(axis, None), P(None, axis, None), P(None, axis, None)),
        out_specs=P(axis, None))
    return jax.jit(shard)


def execute_singlepoint_sharded(dg: DeltaGraph, t: int, mesh: Mesh, *,
                                axis: str = "data", pool=None,
                                use_current: bool = True
                                ) -> tuple[np.ndarray, np.ndarray]:
    """Distributed retrieval: requires ``dg.P == mesh.shape[axis]`` and the
    word_cyclic partitioner (storage partitions == compute partitions, the
    paper's aligned deployment)."""
    Pn = mesh.shape[axis]
    plan = dg.plan_singlepoint(t, NO_ATTRS, use_current)
    U_n, U_e = dg.universe.num_nodes, dg.universe.num_edges
    fn = make_retrieval_fn(mesh, axis)
    aligned = dg.P == Pn and dg.partition_fn_name == "word_cyclic"
    if aligned:
        # aligned deployment: each partition's sub-payloads are fetched
        # separately and fill exactly their own layout row
        (base_n, base_e), (an, dn, ae, de) = plan_to_chain_sharded(
            dg, plan, Pn, pool)
        sides = ((base_n, an, dn, U_n), (base_e, ae, de, U_e))
    else:
        (base_n, base_e), chain = plan_to_chain(dg, plan, pool)
        sides = tuple(
            (base, _stack_sharded(ix_a, U, Pn), _stack_sharded(ix_d, U, Pn), U)
            for base, ix_a, ix_d, U in (
                (base_n, [c[0] for c in chain], [c[1] for c in chain], U_n),
                (base_e, [c[2] for c in chain], [c[3] for c in chain], U_e)))
    outs = []
    for base, adds, dels, U in sides:
        b = sharded_base(np.asarray(base), Pn)
        out = np.asarray(fn(jnp.asarray(b), jnp.asarray(adds), jnp.asarray(dels)))
        outs.append(bmod.np_unpack(unshard(out, bmod.num_words(U)), U))
    nm, em = outs
    em &= ~dg.universe.edge_transient[:U_e]
    nm &= ~dg.universe.node_transient[:U_n]
    return nm, em


def lowered_retrieval_hlo(mesh: Mesh, K: int, Wp: int, axis: str = "data") -> str:
    """Lowered HLO text of the sharded retrieval step (for the zero-
    collective assertion and the dry-run report)."""
    Pn = mesh.shape[axis]
    fn = make_retrieval_fn(mesh, axis)
    args = (jax.ShapeDtypeStruct((Pn, Wp), jnp.uint32),
            jax.ShapeDtypeStruct((K, Pn, Wp), jnp.uint32),
            jax.ShapeDtypeStruct((K, Pn, Wp), jnp.uint32))
    return fn.lower(*args).compile().as_text()
