"""Fault tolerance & straggler mitigation for 1000+-node deployments.

* :class:`HeartbeatTracker` — detects dead partitions/hosts from missed
  heartbeats (coordinator-side logic; transport is pluggable).
* :func:`elastic_replan` — when ``P`` storage partitions must be served by
  ``W < P`` (or ``> P``) surviving workers, reassigns partitions with
  consistent hashing so only the failed node's shard moves.
* :class:`StragglerMitigator` — the multipoint-retrieval scheduler:
  deficit-based work stealing over per-partition fetch queues, plus
  hedged ("backup") requests for the slowest percentile, the standard
  tail-latency defense.
* :func:`retry` — bounded exponential backoff for storage operations.

These are deliberately transport-agnostic (pure logic + callables) so unit
tests can drive them deterministically — the same structure a real
multi-host deployment would wire to its RPC layer.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable


def retry(fn: Callable, *, attempts: int = 4, base_delay: float = 0.01,
          retryable=(IOError, KeyError, TimeoutError),
          sleep: Callable = time.sleep):
    last = None
    for i in range(attempts):
        try:
            return fn()
        except retryable as e:  # noqa: PERF203
            last = e
            if i + 1 < attempts:
                sleep(base_delay * (2 ** i))
    raise last


class HeartbeatTracker:
    def __init__(self, workers: Iterable[str], timeout: float = 10.0,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.timeout = timeout
        self.clock = clock
        self.last_seen = {w: clock() for w in workers}

    def beat(self, worker: str) -> None:
        self.last_seen[worker] = self.clock()

    def dead(self) -> list[str]:
        now = self.clock()
        return [w for w, t in self.last_seen.items()
                if now - t > self.timeout]

    def alive(self) -> list[str]:
        now = self.clock()
        return [w for w, t in self.last_seen.items()
                if now - t <= self.timeout]


def elastic_replan(partitions: int, workers: list[str]) -> dict[int, str]:
    """Consistent-hash partition→worker assignment: when one worker dies,
    only its partitions move (stable for the survivors)."""
    import hashlib

    def h(s: str) -> int:
        return int(hashlib.md5(s.encode()).hexdigest()[:8], 16)

    ring = sorted((h(f"{w}#{v}"), w) for w in workers for v in range(8))
    out = {}
    for p in range(partitions):
        hp = h(f"part{p}")
        for hv, w in ring:
            if hv >= hp:
                out[p] = w
                break
        else:
            out[p] = ring[0][1]
    return out


@dataclasses.dataclass
class FetchTask:
    partition: int
    key: Any
    size_est: int


class StragglerMitigator:
    """Deficit-based scheduler over per-partition queues with hedging.

    ``assign(next_free_worker)`` hands out the task from the queue with the
    largest remaining byte deficit; when < ``hedge_frac`` of tasks remain,
    outstanding tasks are replicated to idle workers (first completion
    wins) — bounded duplicate work for a bounded tail.
    """

    def __init__(self, tasks: list[FetchTask], hedge_frac: float = 0.05):
        self.queues: dict[int, list[FetchTask]] = {}
        for t in tasks:
            self.queues.setdefault(t.partition, []).append(t)
        self.total = sum(t.size_est for t in tasks)
        self.outstanding: dict[Any, FetchTask] = {}
        self.done: set[Any] = set()
        self.hedge_threshold = max(1, int(len(tasks) * hedge_frac))
        self.duplicates = 0

    def remaining(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def assign(self) -> FetchTask | None:
        # largest-deficit queue first
        best = None
        for p, q in self.queues.items():
            if not q:
                continue
            deficit = sum(t.size_est for t in q)
            if best is None or deficit > best[0]:
                best = (deficit, p)
        if best is not None:
            task = self.queues[best[1]].pop(0)
            self.outstanding[task.key] = task
            return task
        # hedge: replicate an outstanding task for an idle worker
        if self.outstanding and len(self.outstanding) <= self.hedge_threshold:
            task = next(iter(self.outstanding.values()))
            self.duplicates += 1
            return task
        return None

    def complete(self, key: Any) -> bool:
        """Returns True if this completion is the first for the task."""
        self.outstanding.pop(key, None)
        if key in self.done:
            return False
        self.done.add(key)
        return True

    def finished(self) -> bool:
        return not self.outstanding and self.remaining() == 0
