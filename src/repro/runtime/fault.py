"""Fault tolerance & straggler mitigation for 1000+-node deployments.

* :class:`HeartbeatTracker` — detects dead partitions/hosts from missed
  heartbeats (coordinator-side logic; transport is pluggable).
* :func:`elastic_replan` — when ``P`` storage partitions must be served by
  ``W < P`` (or ``> P``) surviving workers, reassigns partitions with
  consistent hashing so only the failed node's shard moves.
* :class:`StragglerMitigator` — the multipoint-retrieval scheduler:
  deficit-based work stealing over per-partition fetch queues, plus
  hedged ("backup") requests for the slowest percentile, the standard
  tail-latency defense.
* :func:`retry` — bounded exponential backoff for storage operations.

These are deliberately transport-agnostic (pure logic + callables) so unit
tests can drive them deterministically — the same structure a real
multi-host deployment would wire to its RPC layer.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable


def default_retryable(e: BaseException) -> bool:
    """The standard transient-fault classification: local IO/timeout
    faults, plus any error that classifies *itself* via a ``retryable``
    attribute — the RPC layer's typed transport errors
    (:mod:`repro.runtime.rpc`) mark connection resets and deadline expiry
    retryable but framing corruption and remote logic errors fatal."""
    return (isinstance(e, (IOError, TimeoutError))
            or getattr(e, "retryable", False) is True)


def retry(fn: Callable, *, attempts: int = 4, base_delay: float = 0.01,
          retryable=(IOError, TimeoutError),
          sleep: Callable = time.sleep):
    """Bounded exponential backoff.  ``retryable`` is either an exception
    class tuple or a predicate ``(exc) -> bool`` (pass
    :func:`default_retryable` to honor the RPC layer's own
    retryable/fatal classification).  ``KeyError`` is deliberately *not*
    retryable by default: a missing blob is a routing/consistency bug, not
    a transient fault, and backing off on it turns every such bug into a
    multi-attempt stall."""
    if attempts < 1:
        raise ValueError(f"attempts must be >= 1, got {attempts}")
    if callable(retryable) and not isinstance(retryable, type):
        pred = retryable
    else:
        pred = lambda e: isinstance(e, retryable)  # noqa: E731
    for i in range(attempts):
        try:
            return fn()
        except Exception as e:  # noqa: PERF203
            if not pred(e):
                raise
            last = e
            if i + 1 < attempts:
                sleep(base_delay * (2 ** i))
    # re-raise the final attempt's exception with its original traceback
    # (the exception object carries __traceback__; `raise` appends here).
    # For RPC RemoteCallError the *remote* traceback string rides along in
    # the message, so the worker-side frames survive this local re-raise.
    raise last


class HeartbeatTracker:
    def __init__(self, workers: Iterable[str], timeout: float = 10.0,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.timeout = timeout
        self.clock = clock
        self.last_seen = {w: clock() for w in workers}

    def beat(self, worker: str) -> None:
        self.last_seen[worker] = self.clock()

    def mark_dead(self, worker: str) -> None:
        """Administratively expire a worker (fault injection, or a failed
        task observed out-of-band): it reads as dead from now on, until a
        fresh :meth:`beat`."""
        self.last_seen[worker] = float("-inf")

    def dead(self) -> list[str]:
        now = self.clock()
        return [w for w, t in self.last_seen.items()
                if now - t > self.timeout]

    def alive(self) -> list[str]:
        now = self.clock()
        return [w for w, t in self.last_seen.items()
                if now - t <= self.timeout]


def _hrw(s: str) -> int:
    import hashlib
    return int(hashlib.md5(s.encode()).hexdigest()[:8], 16)


def rendezvous_rank(partition: int, workers: list[str]) -> list[str]:
    """Workers ordered by descending rendezvous weight for ``partition``.
    ``rank[0]`` is :func:`elastic_replan`'s assignment; ``rank[1:]`` are
    the natural replica/failover candidates — removing any worker deletes
    its entry without reordering the rest, so replica sets move minimally
    on membership change (same hash, same guarantee)."""
    scored = sorted(((-_hrw(f"part{partition}@{w}"), i, w)
                     for i, w in enumerate(workers)))
    return [w for _, _, w in scored]


def elastic_replan(partitions: int, workers: list[str]) -> dict[int, str]:
    """Rendezvous (highest-random-weight) partition→worker assignment:
    partition ``p`` goes to the worker maximizing ``h(p, w)``.  When a
    worker dies only its partitions move — removing ``w`` cannot change
    any other partition's argmax — and each partition picks independently
    and uniformly, so the load is multinomial-balanced (the ring variant's
    arc-length skew made small fleets badly lopsided)."""
    return {p: rendezvous_rank(p, workers)[0] for p in range(partitions)}


@dataclasses.dataclass
class FetchTask:
    partition: int
    key: Any
    size_est: int


class StragglerMitigator:
    """Deficit-based scheduler over per-partition queues with hedging.

    ``assign(next_free_worker)`` hands out the task from the queue with the
    largest remaining byte deficit; when < ``hedge_frac`` of tasks remain,
    outstanding tasks are replicated to idle workers (first completion
    wins) — bounded duplicate work for a bounded tail.
    """

    def __init__(self, tasks: list[FetchTask], hedge_frac: float = 0.05,
                 max_duplicates: int = 1):
        self.queues: dict[int, list[FetchTask]] = {}
        for t in tasks:
            self.queues.setdefault(t.partition, []).append(t)
        self.total = sum(t.size_est for t in tasks)
        self.outstanding: dict[Any, FetchTask] = {}
        self.done: set[Any] = set()
        self.hedge_threshold = max(1, int(len(tasks) * hedge_frac))
        # per-task duplicate cap: N idle workers must not all pile onto
        # one outstanding key (unbounded duplicates defeat the point of
        # hedging — bounded extra work for a bounded tail)
        self.max_duplicates = max(0, int(max_duplicates))
        self.duplicates = 0
        self._assign_seq = 0
        self._seq: dict[Any, int] = {}      # key -> first-assignment order
        self._dups: dict[Any, int] = {}     # key -> duplicates handed out

    def remaining(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def assign(self) -> FetchTask | None:
        # largest-deficit queue first
        best = None
        for p, q in self.queues.items():
            if not q:
                continue
            deficit = sum(t.size_est for t in q)
            if best is None or deficit > best[0]:
                best = (deficit, p)
        if best is not None:
            task = self.queues[best[1]].pop(0)
            self.outstanding[task.key] = task
            self._seq[task.key] = self._assign_seq
            self._assign_seq += 1
            return task
        # hedge: replicate for an idle worker the *oldest-assigned*
        # outstanding task that still has duplicate budget — the task
        # most likely stuck on a straggler, each key at most
        # ``max_duplicates`` extra times
        if self.outstanding and len(self.outstanding) <= self.hedge_threshold:
            cands = [k for k in self.outstanding
                     if self._dups.get(k, 0) < self.max_duplicates]
            if cands:
                key = min(cands, key=lambda k: self._seq.get(k, 0))
                self._dups[key] = self._dups.get(key, 0) + 1
                self.duplicates += 1
                return self.outstanding[key]
        return None

    def complete(self, key: Any) -> bool:
        """Returns True if this completion is the first for the task."""
        self.outstanding.pop(key, None)
        self._seq.pop(key, None)
        self._dups.pop(key, None)
        if key in self.done:
            return False
        self.done.add(key)
        return True

    def fail(self, key: Any) -> bool:
        """A worker's attempt errored: drop its claim and requeue the task
        for another worker, unless some attempt already completed.  Returns
        True when the task was requeued."""
        task = self.outstanding.pop(key, None)
        self._seq.pop(key, None)
        self._dups.pop(key, None)
        if task is None or key in self.done:
            return False
        self.queues.setdefault(task.partition, []).append(task)
        return True

    def finished(self) -> bool:
        return not self.outstanding and self.remaining() == 0
