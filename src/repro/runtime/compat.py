"""Version compatibility shims for the pinned JAX (0.4.37).

Newer JAX exposes ``jax.shard_map``, ``jax.set_mesh`` and
``jax.sharding.AxisType``; the pinned release has none of the three.
Everything in the repo that touches those surfaces goes through this
module so the same code runs on 0.4.37 and on current JAX.
"""
from __future__ import annotations

import contextlib
import math
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh

# -- shard_map ---------------------------------------------------------------
if hasattr(jax, "shard_map"):                      # jax >= 0.6
    shard_map = jax.shard_map
else:                                              # pinned 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with ``axis_types=Auto`` where supported; the
    0.4.x signature has no ``axis_types`` and is Auto-only anyway."""
    axis_type = getattr(getattr(jax, "sharding", None), "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=(axis_type.Auto,) * len(axis_names))
    need = math.prod(axis_shapes)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(f"need {need} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:need]).reshape(tuple(axis_shapes)),
                tuple(axis_names))


def set_mesh(mesh: Mesh):
    """``jax.set_mesh`` context manager, or the classic ``with mesh:``
    scope on 0.4.x (NamedSharding-carrying code paths only need the
    latter)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return _mesh_scope(mesh)


@contextlib.contextmanager
def _mesh_scope(mesh: Mesh):
    with mesh:
        yield mesh
