"""Replica placement and routing for the sharded retrieval fleet.

The follow-up paper (*Storing and Analyzing Historical Graph Data at
Scale*, Khurana & Deshpande 2015) scales DeltaGraph by partitioning the
history across storage servers **with replication**: each partition has
``R`` candidate servers, so a hedged fetch can race a *different copy*
(racing the same store only re-queues behind the same straggler) and a
dead server's partitions fail over without touching anyone else's.

:class:`ReplicaManager` derives everything from one rendezvous ranking
(:func:`repro.runtime.fault.rendezvous_rank`, the same hash that has
driven ``elastic_replan`` since the sharding PR):

* ``replicas_of(p)`` — the first ``R`` alive servers in partition ``p``'s
  ranking.  Rank 0 is the *primary* (identical to ``elastic_replan``'s
  assignment when every server is alive, so enabling replication does not
  reshuffle an existing fleet's primaries).
* **Minimal reassignment** — rendezvous ranking is per-server
  independent: removing a dead server deletes its entry from each
  ranking without reordering the rest, so exactly the partitions it
  served move (each to its old rank-1 replica), and no other partition's
  replica set changes.
* ``route(p, tried=...)`` — failover/hedge routing: the first replica not
  yet tried by this task, falling back to the primary when every replica
  has been tried (the caller may then retry the same server — there is
  genuinely nobody else).
"""
from __future__ import annotations

from .fault import rendezvous_rank


class ReplicaManager:
    """Pure placement logic (no I/O): servers in, rankings out.

    ``alive`` is passed per call by the owner (``ShardedRetriever`` keeps
    liveness in its :class:`~repro.runtime.fault.HeartbeatTracker`), so
    the manager itself never goes stale.
    """

    def __init__(self, servers: list[str], replicas: int = 1) -> None:
        self.servers = list(servers)
        self.replicas = max(1, int(replicas))
        self._rank_memo: dict[tuple, dict[int, list[str]]] = {}

    def _ranks(self, P: int, alive: tuple[str, ...]) -> dict[int, list[str]]:
        memo = self._rank_memo.get(alive)
        if memo is None:
            memo = self._rank_memo[alive] = {}
            if len(self._rank_memo) > 64:     # membership churn is rare
                self._rank_memo.clear()
                self._rank_memo[alive] = memo
        for p in range(P):
            if p not in memo:
                memo[p] = rendezvous_rank(p, list(alive))
        return memo

    def replicas_of(self, p: int, alive: list[str]) -> list[str]:
        """The ``R`` alive candidate servers for partition ``p``, primary
        first."""
        rank = self._ranks(p + 1, tuple(alive))[p]
        return rank[:self.replicas]

    def primary(self, p: int, alive: list[str]) -> str:
        return self.replicas_of(p, alive)[0]

    def assignment(self, P: int, alive: list[str]) -> dict[str, tuple[int, ...]]:
        """``server -> owned partitions`` over primaries — the scatter map.
        With ``replicas == 1`` and a fully-alive fleet this is exactly the
        pre-replication ``elastic_replan`` grouping."""
        ranks = self._ranks(P, tuple(alive))
        by_server: dict[str, list[int]] = {}
        for p in range(P):
            by_server.setdefault(ranks[p][0], []).append(p)
        return {w: tuple(sorted(ps)) for w, ps in by_server.items()}

    def route(self, p: int, alive: list[str],
              tried: set[str] = frozenset()) -> str:
        """Pick the serving replica for one attempt: the highest-ranked
        replica this task has *not* yet tried, else the primary.  This is
        the hedging contract — a duplicate attempt must land on a distinct
        candidate server whenever one exists."""
        cands = self.replicas_of(p, alive)
        for s in cands:
            if s not in tried:
                return s
        return cands[0]

    def plan(self, parts: tuple[int, ...], alive: list[str],
             tried: set[str] = frozenset()) -> dict[int, str]:
        """Routing map ``partition -> server`` for one attempt over a
        task's owned partitions."""
        return {p: self.route(p, alive, tried) for p in parts}
