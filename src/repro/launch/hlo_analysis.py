"""Loop-aware roofline accounting from optimized HLO text.

``compiled.cost_analysis()`` visits each ``while`` body **once**, so a
61-layer scanned transformer reports 1/61 of its real FLOPs.  This module
re-derives the three roofline inputs directly from ``compiled.as_text()``:

* **flops**            — 2·prod(out)·K for every ``dot`` (K = contracted
  extent), with each computation's total multiplied by the product of
  enclosing ``while`` trip counts (parsed from the loop condition);
* **hbm bytes**        — operand+output bytes of every *top-level* op in
  each computation (fusion bodies are excluded: a fusion's traffic is its
  operands/outputs, which is exactly how XLA:TPU schedules HBM), again
  trip-count-multiplied;
* **collective bytes** — output bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute ops, trip-count-
  multiplied, reported per collective kind.

This is an analytical model of the compiled program, not a simulation —
exactly what the dry-run needs on a CPU container.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*?\))?\s*->.*{\s*$")
_CALL_ATTR_RE = re.compile(r"(?:condition|body|calls|to_apply|branch_computations)="
                           r"(%?[\w.\-]+|\{[^}]*\})")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def shape_bytes(text: str) -> int:
    """Sum bytes over every dtype[shape] occurrence in a type string."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instruction:
    name: str
    rhs: str
    out_type: str
    opcode: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list[Instruction]


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if line.rstrip().endswith("{") and ("->" in line or line.startswith("ENTRY")):
            m = _COMP_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1), [])
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        tm = re.match(r"((?:\([^)]*\))|(?:[\w\[\],{}\d]+))\s+([\w\-]+)", rhs)
        if not tm:
            continue
        out_type, opcode = tm.group(1), tm.group(2)
        cur.instructions.append(Instruction(name, rhs, out_type, opcode))
    return comps


def _trip_count(cond: Computation) -> int:
    """Scan-style loop conditions compare the induction var with a
    constant; take the largest integer constant found."""
    best = 1
    for ins in cond.instructions:
        for m in re.finditer(r"constant\((\d+)\)", ins.rhs):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: Instruction, shapes: dict[str, str]) -> float:
    out_elems = 1
    for d in _shape_dims(ins.out_type):
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rhs)
    ops = re.findall(r"%([\w.\-]+)", ins.rhs)
    if not m or not ops:
        return 2.0 * out_elems  # fallback
    lhs_type = shapes.get(ops[0], "")
    dims = _shape_dims(lhs_type)
    k = 1
    for ci in (int(x) for x in m.group(1).split(",") if x):
        if ci < len(dims):
            k *= dims[ci]
    return 2.0 * out_elems * k


def analyze(text: str) -> dict:
    """Returns {'flops', 'hbm_bytes', 'collective_bytes',
    'collectives': {kind: bytes}, 'per_comp': {...}}."""
    comps = parse_hlo(text)
    # global symbol table name -> out_type (names are unique in HLO dumps)
    shapes: dict[str, str] = {}
    for c in comps.values():
        for ins in c.instructions:
            shapes[ins.name] = ins.out_type

    # computations called as fusion bodies / reducers: exclude from direct
    # accounting (their traffic is the call site's operands/outputs)
    fused_bodies: set[str] = set()
    called_by: dict[str, list[tuple[str, int]]] = defaultdict(list)
    trip_of_body: dict[str, int] = {}
    for c in comps.values():
        for ins in c.instructions:
            attrs = dict()
            for m in re.finditer(r"(condition|body|calls|to_apply)=%?([\w.\-]+)",
                                 ins.rhs):
                attrs[m.group(1)] = m.group(2)
            if ins.opcode == "while":
                cond = attrs.get("condition")
                body = attrs.get("body")
                tc = _trip_count(comps[cond]) if cond in comps else 1
                if body in comps:
                    trip_of_body[body] = tc
                    called_by[body].append((c.name, tc))
                if cond in comps:
                    fused_bodies.add(cond)  # negligible; skip
            elif ins.opcode == "fusion":
                if "calls" in attrs:
                    fused_bodies.add(attrs["calls"])
            elif "to_apply" in attrs:  # reduce/scatter combiners
                fused_bodies.add(attrs["to_apply"])

    # multiplier per computation: product of trip counts on the call chain
    def multiplier(name: str, seen=None) -> float:
        seen = seen or set()
        if name in seen:
            return 1.0
        seen = seen | {name}
        if not called_by.get(name):
            return 1.0
        total = 0.0
        for caller, tc in called_by[name]:
            total += tc * multiplier(caller, seen)
        return max(total, 1.0)

    flops = 0.0
    hbm = 0.0
    coll: dict[str, float] = defaultdict(float)
    per_comp: dict[str, dict] = {}
    for c in comps.values():
        if c.name in fused_bodies:
            continue
        mult = multiplier(c.name)
        c_fl = 0.0
        c_hbm = 0.0
        for ins in c.instructions:
            if ins.opcode in ("dot", "convolution"):
                c_fl += _dot_flops(ins, shapes)
            out_b = shape_bytes(ins.out_type)
            if ins.opcode in ("fusion", "dot", "convolution", "copy",
                              "dynamic-update-slice", "dynamic-slice",
                              "gather", "scatter", "sort", "transpose",
                              "reshape", "broadcast", "reduce", "concatenate",
                              "slice", "convert", "select-and-scatter",
                              "pad", "iota", "rng-bit-generator") or \
                    ins.opcode.startswith("all-") or \
                    ins.opcode in ("reduce-scatter", "collective-permute"):
                in_b = 0
                for op in re.findall(r"%([\w.\-]+)", ins.rhs):
                    if op in shapes:
                        in_b += shape_bytes(shapes[op])
                c_hbm += out_b + in_b
            for kind in _COLLECTIVES:
                if ins.opcode == kind or ins.opcode == kind + "-start":
                    coll[kind] += out_b * mult
        flops += c_fl * mult
        hbm += c_hbm * mult
        per_comp[c.name] = {"mult": mult, "flops": c_fl, "hbm": c_hbm}

    return {"flops": flops, "hbm_bytes": hbm,
            "collective_bytes": sum(coll.values()),
            "collectives": dict(coll), "per_comp": per_comp}
