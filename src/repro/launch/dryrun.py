import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init) — this is the only entry point that fakes 512 devices.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs.registry import get_cell, list_cells  # noqa: E402
from ..runtime import compat  # noqa: E402
from . import hlo_analysis  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

# TPU v5e constants (roofline targets; the container itself is CPU-only)
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


def _shardings(mesh, pspec_tree):
    return jax.tree.map(
        lambda sp: NamedSharding(mesh, sp if isinstance(sp, P) else P()),
        pspec_tree, is_leaf=lambda x: isinstance(x, P) or x is None)


def input_specs(arch: str, shape: str, mesh, multi_pod: bool):
    """ShapeDtypeStruct stand-ins for every input of the cell's step
    function — weak-type-correct, shardable, no device allocation."""
    cell = get_cell(arch, shape, mesh, multi_pod)
    return cell.args


def run_cell(arch: str, shape: str, multi_pod: bool, *,
             want_hlo: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    cell = get_cell(arch, shape, mesh, multi_pod)
    rec: dict = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                 "chips": chips, "step_kind": cell.step_kind,
                 "model_flops": cell.flops_model,
                 "n_params": cell.n_params,
                 "n_params_active": cell.n_params_active}
    if cell.skip_reason:
        rec["status"] = "skipped"
        rec["skip_reason"] = cell.skip_reason
        return rec
    in_sh = _shardings(mesh, cell.pspecs)
    t0 = time.time()
    with compat.set_mesh(mesh):
        lowered = jax.jit(cell.fn, in_shardings=in_sh).lower(*cell.args)
        t1 = time.time()
        compiled = lowered.compile()
    t2 = time.time()
    rec["lower_s"] = round(t1 - t0, 2)
    rec["compile_s"] = round(t2 - t1, 2)

    ma = compiled.memory_analysis()
    if ma is not None:
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        }
        live = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        rec["memory"]["live_bytes_per_device"] = live
        rec["fits_16gb"] = bool(live <= 16 * 1024 ** 3)
    ca = compiled.cost_analysis() or {}
    rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                            if k in ("flops", "bytes accessed",
                                     "transcendentals")}
    if want_hlo:
        text = compiled.as_text()
        h = hlo_analysis.analyze(text)
        rec["hlo"] = {k: h[k] for k in ("flops", "hbm_bytes",
                                        "collective_bytes", "collectives")}
        # roofline terms (per device; HLO is the per-device SPMD program)
        rec["roofline"] = {
            "compute_s": h["flops"] / PEAK_FLOPS,
            "memory_s": h["hbm_bytes"] / HBM_BW,
            "collective_s": h["collective_bytes"] / ICI_BW,
        }
        dom = max(rec["roofline"], key=rec["roofline"].get)
        rec["roofline"]["bottleneck"] = dom
        total_hlo_flops = h["flops"] * chips
        rec["roofline"]["useful_flops_ratio"] = (
            cell.flops_model / total_hlo_flops if total_hlo_flops else 0.0)
        bound = max(rec["roofline"]["compute_s"], rec["roofline"]["memory_s"],
                    rec["roofline"]["collective_s"])
        ideal = cell.flops_model / (chips * PEAK_FLOPS)
        rec["roofline"]["roofline_fraction"] = (ideal / bound) if bound else 0.0
    rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    results: dict[str, dict] = {}
    if os.path.exists(args.out) and not args.no_resume:
        with open(args.out) as f:
            results = json.load(f)

    cells = list_cells()
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch, shape in cells:
        for multi_pod in meshes:
            key = f"{arch}|{shape}|{'multi' if multi_pod else 'single'}"
            if key in results and results[key].get("status") in ("ok", "skipped"):
                continue
            print(f"=== {key} ===", flush=True)
            try:
                rec = run_cell(arch, shape, multi_pod)
            except Exception as e:  # record the failure, keep sweeping
                rec = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                       "status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print(rec["error"], flush=True)
            results[key] = rec
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
            if rec.get("status") == "ok":
                r = rec.get("roofline", {})
                print(f"  compile={rec.get('compile_s')}s "
                      f"mem/dev={rec.get('memory', {}).get('live_bytes_per_device', 0)/2**30:.2f}GiB "
                      f"bottleneck={r.get('bottleneck')} "
                      f"roofline={r.get('roofline_fraction', 0):.3f}",
                      flush=True)

    ok = sum(1 for r in results.values() if r.get("status") == "ok")
    sk = sum(1 for r in results.values() if r.get("status") == "skipped")
    er = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"done: {ok} ok, {sk} skipped, {er} errors -> {args.out}")


if __name__ == "__main__":
    main()
