"""Concurrent NDJSON query server with SLOs (the serving front end).

``QueryServer`` is a socket server in front of one
:class:`~repro.core.manager.GraphManager`: a threaded accept loop, one
session per connection, newline-delimited JSON framing reusing the
:class:`~repro.api.document.GraphQuery` request /
:class:`~repro.api.service.QueryResult` envelope wire forms.  Every
parsed document is submitted to a shared
:class:`~repro.api.scheduler.BatchingScheduler`, which holds arrivals in
a small batching window and merges co-plannable documents **across
clients** into one Steiner plan; responses are demultiplexed back to
their sessions through per-request futures and written in each session's
request order.

Per-session machinery lives in :class:`SessionCore`, which is
transport-agnostic: the socket session drives it from a connection, and
``serve.py --mode query``'s stdin fallback drives the *same* code path
from a line iterator (:func:`run_session_lines`) — there is one parse /
control / lease / envelope implementation, not a parallel flush loop.

SLO surface (see :mod:`repro.api.scheduler` for admission/deadlines):

* **Leases** — a document with ``reply: "lease"`` overlays its retrieved
  snapshot(s) in the GraphPool and returns lease gids; the client reads
  them via follow-up queries or releases them with a control frame
  ``{"release": [gid, ...]}`` (or ``{"release": "all"}``).  Leases are
  per-session :class:`~repro.core.manager.HistGraph` handles and are
  auto-reclaimed when the session disconnects.

* **Backpressure** — each session has a lease byte budget tied to the
  pool/store budgets (advisor GraphPool budget, else the TieredKV hot
  tier, else a default).  A session over budget first *stops being read*
  for a bounded grace period (the socket's receive buffer fills — real
  transport backpressure), then sheds query documents with typed
  ``backpressure`` envelopes until leases are released; control frames
  keep flowing so releases always get through (no deadlock).
"""
from __future__ import annotations

import json
import queue
import socket
import threading
import time
from typing import TYPE_CHECKING, Any, Iterable, Iterator

from ..api.document import GraphQuery
from ..api.scheduler import BatchingScheduler
from ..core.errors import BackpressureError
from ..core.query import AttrOptions

if TYPE_CHECKING:  # pragma: no cover
    from ..api.service import QueryResult
    from ..core.manager import GraphManager, HistGraph


def _default_session_budget(gm: "GraphManager") -> int:
    """Session lease byte budget tied to the existing byte budgets: a
    slice of the advisor's GraphPool budget when enabled, else of the
    TieredKV hot tier, else 16 MiB."""
    if gm.advisor is not None:
        return max(int(gm.advisor.cfg.budget_bytes) // 4, 1 << 20)
    hot = getattr(gm.store, "hot_bytes", None)
    if hot:
        return max(int(hot) // 4, 1 << 20)
    return 16 << 20


class SessionCore:
    """Transport-agnostic per-client protocol state: line parsing,
    control frames, GraphPool lease accounting, backpressure checks, and
    envelope rendering.  One instance per client session (socket or
    stdin)."""

    def __init__(self, gm: "GraphManager", scheduler: BatchingScheduler,
                 *, lease_budget_bytes: int | None = None,
                 pool_lock: threading.RLock | None = None) -> None:
        self.gm = gm
        self.scheduler = scheduler
        self.lease_budget = (lease_budget_bytes
                             if lease_budget_bytes is not None
                             else _default_session_budget(gm))
        self.pool_lock = pool_lock or threading.RLock()
        self.leases: dict[int, "HistGraph"] = {}
        self.lease_bytes = 0
        self._lease_lock = threading.Lock()
        self.backpressure_sheds = 0

    # ------------------------------------------------------------ parsing
    def parse_line(self, line: str):
        """One wire line → ``None`` (blank), a ``("control", dict)``
        frame, a ``("doc", GraphQuery, raw_id)``, or an
        ``("err", QueryResult, raw_id)`` for malformed input."""
        from ..api.service import QueryService
        line = line.strip()
        if not line:
            return None
        raw_id = None
        try:
            d = json.loads(line)
        except json.JSONDecodeError as e:
            from ..core.errors import DocumentError
            err = DocumentError(f"invalid JSON: {e.msg}", position=e.pos)
            return ("err", QueryService._error_result(None, err), None)
        if isinstance(d, dict):
            if "release" in d:
                return ("control", d)
            rid = d.get("id")
            if isinstance(rid, (str, int)) and not isinstance(rid, bool):
                raw_id = rid
        try:
            doc = GraphQuery.from_dict(d)
        except Exception as e:
            return ("err", QueryService._error_result(None, e), raw_id)
        return ("doc", doc, raw_id)

    # ------------------------------------------------------- backpressure
    def over_budget(self) -> bool:
        return self.lease_bytes > self.lease_budget

    def shed_backpressure(self, doc: GraphQuery) -> "QueryResult":
        self.backpressure_sheds += 1
        return self.scheduler.service._error_result(doc, BackpressureError(
            f"session holds {self.lease_bytes} lease bytes over its "
            f"{self.lease_budget}-byte budget; release leases first"))

    # ------------------------------------------------------------- leases
    def _lease_states(self, res: "QueryResult") -> list[tuple[Any, Any]]:
        if res.kind == "multipoint":
            return [(int(t), st) for t, st in res.value.items()]
        t = res.query.t if res.kind == "snapshot" else None
        return [(t, res.value)]

    def attach_leases(self, res: "QueryResult") -> dict:
        """Overlay a lease-reply result in the GraphPool and annotate the
        envelope with the granted gids (``result.lease``)."""
        from ..core.manager import HistGraph
        env = res.to_dict()
        opts = res.query.attrs
        if not isinstance(opts, AttrOptions):
            opts = self.gm.query.compiler.parse_attrs(opts or "")
        pairs = self._lease_states(res)
        with self.pool_lock:
            pool = self.gm.pool
            gids = pool.insert_snapshots([st for _, st in pairs])
            grants = {}
            added = 0
            for (t, _), gid in zip(pairs, gids):
                hg = HistGraph(self.gm, gid, t, opts)
                with self._lease_lock:
                    self.leases[gid] = hg
                added += (pool.entry_attr_bytes(gid)
                          + (pool.Wn + pool.We) * 4 * 2)
                grants[str(gid)] = {"t": t}
        with self._lease_lock:
            self.lease_bytes += added
        env["result"]["lease"] = grants
        return env

    def handle_control(self, d: dict) -> dict:
        """``{"release": [gid, ...] | "all"}`` → close the named leases
        (idempotent; unknown gids reported, not fatal)."""
        want = d.get("release")
        with self._lease_lock:
            held = list(self.leases)
        gids = held if want == "all" else [
            g for g in (want if isinstance(want, list) else [want])
            if isinstance(g, int) and not isinstance(g, bool)]
        released, unknown = [], []
        for gid in gids:
            with self._lease_lock:
                hg = self.leases.pop(gid, None)
            if hg is None:
                unknown.append(gid)
                continue
            with self.pool_lock:
                bytes_held = (self.gm.pool.entry_attr_bytes(gid)
                              + (self.gm.pool.Wn + self.gm.pool.We) * 4 * 2)
                hg.close()
            with self._lease_lock:
                self.lease_bytes = max(0, self.lease_bytes - bytes_held)
            released.append(gid)
        env = {"v": 1, "ok": True, "kind": "release",
               "released": released, "held": len(self.leases)}
        if unknown:
            env["unknown"] = unknown
        rid = d.get("id")
        if isinstance(rid, (str, int)) and not isinstance(rid, bool):
            env["id"] = rid
        return env

    def release_all(self) -> None:
        """Auto-reclaim on disconnect: every lease back to the pool."""
        with self._lease_lock:
            leases = list(self.leases.values())
            self.leases.clear()
            self.lease_bytes = 0
        for hg in leases:
            with self.pool_lock:
                hg.close()

    # ---------------------------------------------------------- rendering
    def render(self, res: "QueryResult", raw_id=None) -> dict:
        """QueryResult → wire dict, with lease post-processing and id
        echo salvaged from the raw line when the document never parsed."""
        if (res.ok and res.query is not None
                and res.query.reply == "lease"):
            env = self.attach_leases(res)
        else:
            env = res.to_dict()
        if raw_id is not None and "id" not in env:
            env["id"] = raw_id
        return env


def run_session_lines(core: SessionCore, lines: Iterable[str],
                      batch: int = 8) -> Iterator[str]:
    """The stdin code path: drive one :class:`SessionCore` from a line
    iterator, co-batching each chunk of ``batch`` documents as one
    scheduler wave (the same grouping the socket dispatcher applies to a
    batching window), and yield one JSON envelope per input line in
    input order."""

    def flush(chunk: list) -> Iterator[str]:
        docs = []
        for i, item in enumerate(chunk):
            if item[0] == "doc":
                if core.over_budget():
                    chunk[i] = ("err", core.shed_backpressure(item[1]),
                                item[2])
                else:
                    docs.append(item[1])
        results = iter(core.scheduler.run_wave(docs))
        for item in chunk:
            if item[0] == "control":
                yield json.dumps(core.handle_control(item[1]),
                                 sort_keys=True)
            elif item[0] == "err":
                yield json.dumps(core.render(item[1], item[2]),
                                 sort_keys=True)
            else:
                yield json.dumps(core.render(next(results), item[2]),
                                 sort_keys=True)

    chunk: list = []
    for line in lines:
        item = core.parse_line(line)
        if item is None:
            continue
        chunk.append(item)
        if len(chunk) >= batch:
            yield from flush(chunk)
            chunk = []
    if chunk:
        yield from flush(chunk)


# ---------------------------------------------------------------------------
# the socket server
# ---------------------------------------------------------------------------


class _Session(threading.Thread):
    """One connection: a reader thread (this) parsing lines into
    scheduler submissions, and a writer thread demultiplexing resolved
    futures back in request order."""

    _SENTINEL = object()

    def __init__(self, server: "QueryServer", conn: socket.socket,
                 addr, sid: int) -> None:
        super().__init__(name=f"query-session-{sid}", daemon=True)
        self.server = server
        self.conn = conn
        self.addr = addr
        self.sid = sid
        self.core = SessionCore(
            server.gm, server.scheduler,
            lease_budget_bytes=server.session_lease_bytes,
            pool_lock=server.pool_lock)
        self._out: "queue.Queue" = queue.Queue()
        self._writer = threading.Thread(
            target=self._write_loop, name=f"query-session-{sid}-w",
            daemon=True)
        self._closed = threading.Event()

    # --------------------------------------------------------------- reader
    def run(self) -> None:
        self._writer.start()
        try:
            rfile = self.conn.makefile("r", encoding="utf-8",
                                       newline="\n")
            for line in rfile:
                self._pause_while_over_budget()
                item = self.core.parse_line(line)
                if item is None:
                    continue
                if item[0] == "control":
                    # handled on the writer thread so a release that
                    # follows a lease grant in the request stream sees
                    # that lease attached (strict per-session ordering)
                    self._out.put(("control", item[1]))
                elif item[0] == "err":
                    self._out.put(("ready",
                                   self.core.render(item[1], item[2])))
                else:
                    _, doc, raw_id = item
                    if self.core.over_budget():
                        self._out.put(("ready", self.core.render(
                            self.core.shed_backpressure(doc), raw_id)))
                        continue
                    fut = self.server.scheduler.submit(doc)
                    self._out.put(("future", fut, raw_id))
        except (OSError, ValueError):
            pass          # connection reset / server shutdown
        finally:
            self._out.put(self._SENTINEL)

    def _pause_while_over_budget(self) -> None:
        """Transport-level backpressure: while this session is over its
        lease budget, stop reading its socket for up to
        ``backpressure_grace_s`` (bounded, so control frames that release
        leases are always read eventually)."""
        deadline = time.monotonic() + self.server.backpressure_grace_s
        while (self.core.over_budget()
               and time.monotonic() < deadline
               and not self._closed.is_set()):
            time.sleep(0.005)

    # --------------------------------------------------------------- writer
    def _write_loop(self) -> None:
        while True:
            entry = self._out.get()
            if entry is self._SENTINEL:
                break
            try:
                if entry[0] == "ready":
                    env = entry[1]
                elif entry[0] == "control":
                    env = self.core.handle_control(entry[1])
                else:
                    _, fut, raw_id = entry
                    env = self.core.render(fut.result(timeout=120),
                                           raw_id)
                data = (json.dumps(env, sort_keys=True) + "\n").encode()
                self.conn.sendall(data)
            except (OSError, ValueError):
                break     # client went away mid-write
            except Exception:
                break     # future timeout under shutdown
        self._teardown()

    def _teardown(self) -> None:
        if self._closed.is_set():
            return
        self._closed.set()
        self.core.release_all()       # leases auto-reclaimed on disconnect
        try:
            self.conn.close()
        except OSError:
            pass
        self.server._forget(self)

    def close(self) -> None:
        self._closed.set()
        try:
            self.conn.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.conn.close()
        except OSError:
            pass


class QueryServer:
    """Socket front end: threaded accept loop, one :class:`_Session` per
    connection, one shared :class:`BatchingScheduler` (see module
    docstring).  ``port=0`` binds an ephemeral port (read it back from
    :attr:`port` after :meth:`start`)."""

    def __init__(self, gm: "GraphManager", host: str = "127.0.0.1",
                 port: int = 0, *, window_ms: float = 2.0,
                 workers: int = 4, admit_horizon_ms: float = 250.0,
                 session_lease_mb: float | None = None,
                 backpressure_grace_s: float = 0.05,
                 backlog: int = 128) -> None:
        self.gm = gm
        self.scheduler = BatchingScheduler(
            gm.query, window_ms=window_ms, workers=workers,
            admit_horizon_ms=admit_horizon_ms)
        self.pool_lock = threading.RLock()
        self.session_lease_bytes = (int(session_lease_mb * 2**20)
                                    if session_lease_mb is not None
                                    else _default_session_budget(gm))
        self.backpressure_grace_s = float(backpressure_grace_s)
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, int(port)))
        self._listener.listen(backlog)
        # accept() with a short timeout so close() can stop the loop —
        # closing a socket does not reliably wake a blocked accept()
        self._listener.settimeout(0.2)
        self.host, self.port = self._listener.getsockname()[:2]
        self._sessions: dict[int, _Session] = {}
        self._sessions_lock = threading.Lock()
        self._next_sid = 0
        self._accept_thread: threading.Thread | None = None
        self._closing = threading.Event()
        self.sessions_total = 0

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "QueryServer":
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="query-server-accept",
            daemon=True)
        self._accept_thread.start()
        return self

    def _accept_loop(self) -> None:
        while not self._closing.is_set():
            try:
                conn, addr = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break                   # listener closed
            conn.settimeout(None)       # sessions block on reads
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._sessions_lock:
                sid = self._next_sid
                self._next_sid += 1
                sess = _Session(self, conn, addr, sid)
                self._sessions[sid] = sess
                self.sessions_total += 1
            sess.start()

    def _forget(self, sess: _Session) -> None:
        with self._sessions_lock:
            self._sessions.pop(sess.sid, None)

    def close(self) -> None:
        """Stop accepting, disconnect every session (auto-reclaiming
        their leases), drain the scheduler, join all threads."""
        if self._closing.is_set():
            return
        self._closing.set()
        try:
            self._listener.close()
        except OSError:
            pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=10)
            self._accept_thread = None
        with self._sessions_lock:
            sessions = list(self._sessions.values())
        for s in sessions:
            s.close()
        for s in sessions:
            s.join(timeout=10)
            s._writer.join(timeout=10)
            s._teardown()               # idempotent; covers join timeouts
        self.scheduler.close()

    def __enter__(self) -> "QueryServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        with self._sessions_lock:
            live = len(self._sessions)
            sheds = sum(s.core.backpressure_sheds
                        for s in self._sessions.values())
            lease_bytes = sum(s.core.lease_bytes
                              for s in self._sessions.values())
        return {"sessions_live": live,
                "sessions_total": self.sessions_total,
                "backpressure_sheds_live": sheds,
                "lease_bytes_live": lease_bytes,
                "scheduler": self.scheduler.snapshot_stats()}
