"""Serving launchers.

* ``--mode query`` — the declarative wire protocol: newline-delimited
  JSON :class:`~repro.api.document.GraphQuery` documents in (stdin or
  ``--input``), JSON :class:`~repro.api.service.QueryResult` envelopes
  out, with co-batched documents merged into one Steiner plan
  (``--doc-batch``) — the documented ``--port 0`` stdin fallback of the
  socket server, sharing its SessionCore code path;
* ``--mode server`` — the concurrent socket front end
  (launch/server.py): NDJSON sessions over TCP, cross-client co-batching
  inside a ``--window-ms`` window, deadline admission control
  (``--admit-ms``), GraphPool leases with per-session byte budgets and
  backpressure (``--session-mb``);
* ``--mode snapshots`` — historical-snapshot traffic against a
  GraphManager with the workload-aware materialization advisor + snapshot
  cache enabled (the paper's retrieval service, core/materialize.py);
* ``--mode evolve`` — interval-analytics traffic: evolutionary queries
  (PageRank / components / density over dense timepoint intervals)
  served by the incremental temporal engine (core/temporal.py) vs the
  per-snapshot recompute loop;
* ``--mode ingest`` — live mixed read/write serving: a writer thread
  streams events through the threaded
  :class:`~repro.core.ingest.IngestPipeline` (group commit + red/green
  rollovers) while reader threads issue snapshot/interval documents
  against epoch-pinned consistent views; reports sustained events/s,
  query latency under write pressure, and freshness lag, ending with one
  machine-parseable ``INGEST_SUMMARY`` line (the CI smoke contract);
* ``--mode model`` (default) — batched autoregressive decode for LM archs
  (reduced config on CPU; the production mesh decode path is exercised by
  dryrun.py) and batched CTR scoring for DIN.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Iterable, Iterator

import numpy as np

import jax
import jax.numpy as jnp

from ..configs.registry import family_of, get_arch, reduced_config
from ..models import common as mc


def serve_snapshots(n_events: int, budget_mb: float, queries: int,
                    zipf: float, seed: int = 0, batch: int = 1,
                    codec: str = "v2", kv: str = "mem",
                    kv_dir: str | None = None,
                    hot_mb: float = 8.0) -> None:
    """Drive a recency-skewed snapshot workload and report cold vs advised
    latency plus cache hit rate — the quickstart for the advisor.

    ``batch > 1`` groups concurrent queries into ``get_snapshots`` calls:
    one merged multipoint plan per group (shared prefixes fetch and apply
    once) executed with async KV prefetch — the serving configuration for
    a query *stream* rather than a query at a time.

    ``codec`` picks the payload wire format (``v2`` compressed+checksummed
    or legacy ``raw``); ``kv`` picks the store tier (``mem`` | ``logfile``
    | ``tiered`` = ``hot_mb`` in-memory blob cache over a log file under
    ``kv_dir``) — the storage-config quickstart in the README."""
    import os as _os

    from ..core import GraphManager
    from ..data.generators import churn_network
    from ..storage import codec as codec_mod
    from ..storage.kv import TieredKV, make_store

    codec_mod.set_default_codec(codec)
    uni, ev = churn_network(n_initial_edges=max(n_events // 12, 50),
                            n_events=n_events, seed=seed)
    tmax = int(ev.time[-1])
    rng = np.random.default_rng(seed)
    # zipf-ish recency skew over a modest set of distinct timepoints, the
    # shape real snapshot traffic has (hot recent dashboards + long tail)
    distinct = np.sort(rng.integers(0, tmax + 1, 256))
    ranks = rng.zipf(zipf, queries) if zipf > 1 else rng.integers(
        1, distinct.size, queries)
    ts = distinct[distinct.size - 1 - np.minimum(ranks, distinct.size - 1)]

    # explicitly-passed stores are not owned by the manager — close them
    # here so disk-backed tiers flush their log tail + index durably
    made_stores = []

    def _store(tag: str):
        if kv == "mem":
            s = make_store("mem")
        else:
            d = _os.path.join(kv_dir, tag) if kv_dir else None
            s = make_store(kv, directory=d, hot_bytes=int(hot_mb * 2**20))
        made_stores.append(s)
        return s

    with GraphManager(uni, ev, store=_store("cold"),
                      L=max(n_events // 40, 64), k=2,
                      diff_fn="intersection", cache_bytes=0) as cold:
        t0 = time.perf_counter()
        for t in ts:
            cold.dg.get_snapshot(int(t), pool=cold.pool)
        cold_s = time.perf_counter() - t0

    gm = GraphManager(uni, ev, store=_store("advised"),
                      L=max(n_events // 40, 64), k=2,
                      diff_fn="intersection")
    advice = gm.enable_advisor(budget_bytes=int(budget_mb * 2**20),
                               replan_every=max(queries // 8, 32))
    t0 = time.perf_counter()
    if batch > 1:
        for i in range(0, len(ts), batch):
            gm.get_snapshots([int(t) for t in ts[i:i + batch]])
    else:
        for t in ts:
            gm.get_snapshot(int(t))
    adv_s = time.perf_counter() - t0

    q = len(ts)
    print(f"cold    : {cold_s / q * 1e6:8.1f} us/q  ({q / cold_s:8.0f} q/s)")
    print(f"advised : {adv_s / q * 1e6:8.1f} us/q  ({q / adv_s:8.0f} q/s)  "
          f"speedup x{cold_s / adv_s:.2f}")
    print(f"pins={len(gm.advisor.pinned)} "
          f"pool={gm.pool.memory_bytes() / 2**20:.2f} MiB "
          f"(budget {budget_mb} MiB)  "
          f"cache hits={gm.cache.hits}/{gm.cache.hits + gm.cache.misses} "
          f"({gm.cache.nbytes() / 2**20:.2f} MiB)")
    if advice is not None:
        print(f"warm-start expected saving: {advice.expected_saved_bytes:.0f}"
              f" / {advice.expected_cold_bytes:.0f} plan-cost units")
    sk = gm.dg.skeleton_stats()
    print(f"store   : codec={codec} kv={kv} "
          f"stored={sk['stored_total_bytes'] / 2**20:.2f} MiB "
          f"logical={sk['total_bytes'] / 2**20:.2f} MiB "
          f"(x{sk['compression_ratio']:.2f})")
    st = gm.store.stats
    if isinstance(gm.store, TieredKV):
        print(f"tier    : hot {gm.store.hot_bytes_used() / 2**20:.2f}"
              f"/{gm.store.hot_bytes / 2**20:.2f} MiB  "
              f"hits={st.hot_hits} misses={st.hot_misses} "
              f"evictions={gm.store.evictions} "
              f"cold gets={gm.store.cold.stats.gets}")
    print(f"kv      : {st.gets} gets, {st.bytes_read / 2**20:.2f} MiB read")
    gm.close()
    for s in made_stores:
        s.close()


def run_query_documents(gm, lines: Iterable[str], batch: int = 8,
                        scheduler=None) -> Iterator[str]:
    """The stdin wire loop: parse each NDJSON line into a GraphQuery,
    execute groups of up to ``batch`` documents as one scheduler wave
    (co-plannable documents share one merged Steiner plan), and yield one
    JSON envelope per input line, in input order.  A malformed line
    yields an error envelope; it never poisons its batch.

    This is the same :class:`~repro.launch.server.SessionCore` code path
    the socket server (``--mode server``) drives per connection — one
    parse / control / lease / envelope implementation for both
    transports.  Pass ``scheduler`` to share a live server's scheduler;
    by default a private synchronous one is created and closed here."""
    from ..api.scheduler import BatchingScheduler
    from .server import SessionCore, run_session_lines

    sched = scheduler or BatchingScheduler(gm.query, window_ms=0.0,
                                           workers=1)
    core = SessionCore(gm, sched)
    try:
        yield from run_session_lines(core, lines, batch=batch)
    finally:
        core.release_all()
        if scheduler is None:
            sched.close()


def _build_query_gm(n_events: int, seed: int, codec: str, kv: str,
                    kv_dir: str | None, hot_mb: float, budget_mb: float,
                    shards: int, shard_procs: int = 0, replicas: int = 1):
    """Shared GraphManager construction for the query / server front
    ends: synthetic churn history, optional disk-backed store tier,
    advisor budget and shard workers.  ``shard_procs > 0`` serves
    retrievals through that many ``launch/shardd`` OS processes (the
    replicated RPC transport) instead of the in-thread pool; partitions
    then default to ``4 × shard_procs`` for balance unless ``--shards``
    pins a count."""
    import os as _os

    from ..core import GraphManager
    from ..data.generators import churn_network
    from ..storage import codec as codec_mod
    from ..storage.kv import make_store

    codec_mod.set_default_codec(codec)
    uni, ev = churn_network(n_initial_edges=max(n_events // 12, 50),
                            n_events=n_events, seed=seed)
    store = None
    if kv != "mem":
        d = _os.path.join(kv_dir, "query") if kv_dir else None
        store = make_store(kv, directory=d, hot_bytes=int(hot_mb * 2**20))
    P = shards if shards > 1 else (4 * shard_procs if shard_procs > 0 else 1)
    part_kw = {}
    if P > 1:
        part_kw = dict(num_partitions=P, partition_fn="mod_hash")
    gm = GraphManager(uni, ev, store=store,
                      L=max(n_events // 40, 64), k=2,
                      diff_fn="intersection", **part_kw)
    if budget_mb > 0:
        gm.enable_advisor(budget_bytes=int(budget_mb * 2**20))
    if shard_procs > 0:
        gm.enable_sharding(shard_procs, transport="proc",
                           replicas=replicas, hot_mb=hot_mb)
    elif shards > 1:
        gm.enable_sharding(shards)
    return gm, store, ev


def serve_query(n_events: int, batch: int, input_path: str | None,
                seed: int = 0, codec: str = "v2", kv: str = "mem",
                kv_dir: str | None = None, hot_mb: float = 8.0,
                budget_mb: float = 0.0, shards: int = 1,
                shard_procs: int = 0, replicas: int = 1) -> None:
    """Real request serving over stdin (the documented ``--port 0``
    fallback): NDJSON GraphQuery documents in, JSON QueryResult envelopes
    out (stdout stays pure NDJSON; the summary goes to stderr).
    ``--advisor-mb > 0`` also enables the materialization advisor under
    that GraphPool budget.  ``--shards N > 1`` stores the history in N
    mod_hash partitions and serves retrievals through N shard workers
    (scatter/gather with hedged fetches).  ``--shard-procs N`` upgrades
    the workers to N real shardd OS processes behind the RPC transport,
    each partition served by ``--replicas R`` rendezvous-ranked
    replicas."""
    gm, store, ev = _build_query_gm(n_events, seed, codec, kv, kv_dir,
                                    hot_mb, budget_mb, shards,
                                    shard_procs, replicas)
    print(f"ready: {n_events} events, tmax={int(ev.time[-1])}, "
          f"doc-batch={batch}"
          + (f", shards={shards}" if shards > 1 else "")
          + (f", shard-procs={shard_procs} replicas={replicas}"
             if shard_procs > 0 else ""),
          file=sys.stderr, flush=True)

    lines = (open(input_path) if input_path and input_path != "-"
             else sys.stdin)
    served = ok = 0
    t0 = time.perf_counter()
    try:
        for envelope in run_query_documents(gm, lines, batch=batch):
            print(envelope, flush=True)
            served += 1
            ok += '"ok": true' in envelope
    finally:
        if lines is not sys.stdin:
            lines.close()
        wall = time.perf_counter() - t0
        st = gm.store.stats
        shard_note = ""
        if gm.sharded is not None:
            shard_note = (f"  shards: {len(gm.sharded.workers)} "
                          f"{gm.sharded.transport.name} workers, "
                          f"{gm.sharded.hedges_total} hedges, "
                          f"{gm.sharded.requeues_total} requeues, "
                          f"{gm.sharded.failovers_total} failovers")
        print(f"served {served} documents ({ok} ok) in {wall:.2f}s "
              f"({served / max(wall, 1e-9):.0f} docs/s)  "
              f"kv: {st.gets} gets, {st.bytes_read / 2**20:.2f} MiB"
              + shard_note,
              file=sys.stderr, flush=True)
        gm.close()
        if store is not None:
            store.close()


def serve_server(n_events: int, port: int, seed: int = 0,
                 codec: str = "v2", kv: str = "mem",
                 kv_dir: str | None = None, hot_mb: float = 8.0,
                 budget_mb: float = 0.0, shards: int = 1,
                 window_ms: float = 2.0, workers: int = 4,
                 admit_ms: float = 250.0, session_mb: float | None = None,
                 serve_s: float = 0.0, shard_procs: int = 0,
                 replicas: int = 1) -> None:
    """The concurrent socket front end (``--mode server``): one
    :class:`~repro.launch.server.QueryServer` accepting NDJSON sessions,
    co-batching co-plannable documents across clients inside a
    ``--window-ms`` batching window, with deadline admission control and
    lease-budget backpressure (see launch/server.py).  Prints one
    ``SERVER_READY host=... port=...`` line to stdout once bound (the
    subprocess-harness contract), serves until SIGINT or ``--serve-s``
    elapses, then prints ``SERVER_STATS <json>``."""
    import json as _json

    from .server import QueryServer

    gm, store, ev = _build_query_gm(n_events, seed, codec, kv, kv_dir,
                                    hot_mb, budget_mb, shards,
                                    shard_procs, replicas)
    srv = QueryServer(gm, port=port, window_ms=window_ms, workers=workers,
                      admit_horizon_ms=admit_ms,
                      session_lease_mb=session_mb)
    srv.start()
    print(f"ready: {n_events} events, tmax={int(ev.time[-1])}, "
          f"window={window_ms}ms workers={workers}",
          file=sys.stderr, flush=True)
    print(f"SERVER_READY host={srv.host} port={srv.port}", flush=True)
    try:
        if serve_s > 0:
            time.sleep(serve_s)
        else:
            while True:
                time.sleep(1.0)
    except KeyboardInterrupt:
        pass
    finally:
        stats = srv.stats()
        srv.close()
        print("SERVER_STATS " + _json.dumps(stats, sort_keys=True),
              flush=True)
        gm.close()
        if store is not None:
            store.close()


def serve_ingest(n_events: int, duration_s: float, readers: int,
                 group: int, seed: int = 0, codec: str = "v2",
                 kv: str = "mem", kv_dir: str | None = None,
                 hot_mb: float = 8.0) -> None:
    """Mixed ingest + query serving: one writer streams the live tail of
    a synthetic history through the threaded ingest pipeline, paced to
    fill ``duration_s``, while ``readers`` threads issue ``Q.at`` /
    ``Q.between`` documents against epoch-pinned views.  Reports
    sustained events/s, freshness lag (append → visible), per-query
    latency under write pressure, and rollover/epoch counters; the last
    stdout line is ``INGEST_SUMMARY <json>`` for CI to parse."""
    import json
    import os as _os
    import threading
    from collections import deque

    from ..api.document import Q
    from ..core import GraphManager
    from ..core.ingest import IngestPipeline
    from ..data.generators import churn_network
    from ..storage import codec as codec_mod
    from ..storage.kv import make_store

    codec_mod.set_default_codec(codec)
    uni, ev = churn_network(n_initial_edges=max(n_events // 12, 50),
                            n_events=n_events, seed=seed)
    n_build = max(n_events // 5, 200)
    store = None
    if kv != "mem":
        d = _os.path.join(kv_dir, "ingest") if kv_dir else None
        store = make_store(kv, directory=d, hot_bytes=int(hot_mb * 2**20))
    gm = GraphManager(uni, ev[:n_build], store=store,
                      L=max(n_events // 40, 64), k=2,
                      diff_fn="intersection")
    pipe = IngestPipeline(gm, group_events=group, threaded=True)
    gm._ingest = pipe
    svc = gm.query
    print(f"ready: {n_build} built, {n_events - n_build} live events, "
          f"{readers} readers, {duration_s:.0f}s", file=sys.stderr,
          flush=True)

    stop = threading.Event()
    docs_served = [0] * max(readers, 1)
    doc_fail = [0] * max(readers, 1)
    lat: deque[float] = deque(maxlen=65536)

    def reader(idx: int) -> None:
        rng = np.random.default_rng(1000 + idx)
        while not stop.is_set():
            hi = max(int(gm.epochs.current_data.max_time), 1)
            docs = [Q.at(int(t)).attrs("+node:all").build()
                    for t in rng.integers(0, hi + 1, size=3)]
            a, b = sorted(int(t) for t in rng.integers(0, hi + 1, size=2))
            docs.append(Q.between(a, b + 1).build())
            t0 = time.perf_counter()
            for r in svc.run_batch(docs, on_error="envelope"):
                docs_served[idx] += 1
                doc_fail[idx] += not r.ok
            lat.append((time.perf_counter() - t0) / len(docs))

    threads = [threading.Thread(target=reader, args=(i,), daemon=True)
               for i in range(readers)]
    for th in threads:
        th.start()

    chunks = []
    i = n_build
    rng = np.random.default_rng(seed)
    while i < n_events:
        j = min(n_events, i + int(rng.integers(group // 2, group * 2)))
        chunks.append((i, j))
        i = j
    pace = duration_s / max(len(chunks), 1)
    t_start = time.perf_counter()
    for n, (i, j) in enumerate(chunks):
        pipe.submit(ev[i:j])
        sleep = t_start + (n + 1) * pace - time.perf_counter()
        if sleep > 0:
            time.sleep(sleep)
    pipe.drain(timeout=max(duration_s, 60.0))
    wall = time.perf_counter() - t_start
    stop.set()
    for th in threads:
        th.join(timeout=10)

    ps = pipe.stats()
    lats = sorted(lat)
    summary = {
        "events_per_s": round(ps["committed_events"] / max(wall, 1e-9), 1),
        "committed_events": ps["committed_events"],
        "groups": ps["groups_committed"],
        "rollovers": ps["rollovers"],
        "freshness_lag_p99_ms": (round(ps["freshness_lag_p99_ms"], 3)
                                 if ps["freshness_lag_p99_ms"] else None),
        "docs_served": sum(docs_served),
        "docs_failed": sum(doc_fail),
        "query_p50_ms": (round(1e3 * lats[len(lats) // 2], 3)
                         if lats else None),
        "query_p99_ms": (round(1e3 * lats[int(len(lats) * 0.99)], 3)
                         if lats else None),
        "epochs": ps["epochs"]["current_id"],
        "wall_s": round(wall, 2),
    }
    print(f"ingested {summary['committed_events']} events in {wall:.1f}s "
          f"({summary['events_per_s']:.0f} ev/s, "
          f"{summary['rollovers']} rollovers)  "
          f"queries: {summary['docs_served']} docs "
          f"({summary['docs_failed']} failed) "
          f"p99={summary['query_p99_ms']} ms  "
          f"freshness p99={summary['freshness_lag_p99_ms']} ms",
          file=sys.stderr, flush=True)
    print("INGEST_SUMMARY " + json.dumps(summary, sort_keys=True),
          flush=True)
    gm.close()
    if store is not None:
        store.close()


def serve_evolve(n_events: int, intervals: int, points: int, op: str,
                 seed: int = 0, window_frac: float = 0.05) -> None:
    """Drive an evolutionary-query workload — ``intervals`` dense
    ``points``-timepoint windows — through the incremental temporal
    engine and the per-snapshot recompute loop, and report the speedup
    (the serving configuration for evolution dashboards)."""
    from ..core import GraphManager
    from ..data.generators import churn_network, dense_intervals

    uni, ev = churn_network(n_initial_edges=max(n_events // 12, 50),
                            n_events=n_events, seed=seed)
    tmax = int(ev.time[-1])
    ivs = dense_intervals(tmax, intervals, points,
                          window_frac=window_frac, seed=seed)

    gm = GraphManager(uni, ev, L=max(n_events // 40, 64), k=2,
                      diff_fn="intersection", cache_bytes=0)
    # warm the jit compile cache for both engines so one-time compilation
    # is not charged to whichever engine happens to run first
    for engine_warm in (False, True):
        gm.evolve(ivs[0][:3], op, incremental=engine_warm)
    results = {}
    for engine in ("recompute", "incremental"):
        t0 = time.perf_counter()
        iters = 0
        for iv in ivs:
            res = gm.evolve(iv, op, incremental=(engine == "incremental"))
            if res.stats.get("solver_iters"):
                iters += sum(res.stats["solver_iters"])
        results[engine] = (time.perf_counter() - t0, iters)
    q = intervals * points
    for engine, (wall, iters) in results.items():
        print(f"{engine:12s}: {wall / q * 1e6:8.1f} us/point "
              f"({q / wall:8.0f} points/s, solver iters {iters})")
    print(f"speedup x{results['recompute'][0] / results['incremental'][0]:.2f}"
          f"  ({intervals} intervals x {points} points, op={op})")
    gm.close()


def serve_lm(arch: str, batch: int, prompt_len: int, gen: int) -> None:
    from ..models.transformer import model as tm
    cfg = reduced_config(arch)
    params = mc.init_params(tm.param_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                         jnp.int32)
    prefill = jax.jit(lambda p, t: tm.prefill_step(p, t, cfg))
    decode = jax.jit(lambda p, c, t, l: tm.decode_step(p, c, t, l, cfg))

    t0 = time.time()
    last, caches = prefill(params, tokens)
    # move prefill caches into a max-length decode cache
    cache = tm.init_cache(cfg, batch, prompt_len + gen)
    new_cache = []
    for (ck, cv), (pk, pv) in zip(cache, caches):
        if cfg.mla is not None:
            pk_ = jnp.moveaxis(pk, 0, 0)
            new_cache.append((ck.at[:, :, :prompt_len].set(pk),
                              cv.at[:, :, :prompt_len].set(pv)))
        else:
            new_cache.append((ck.at[:, :, :, :prompt_len].set(pk),
                              cv.at[:, :, :, :prompt_len].set(pv)))
    cache = new_cache
    prefill_s = time.time() - t0

    out = []
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for i in range(gen):
        logits, cache = decode(params, cache, tok, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    dt = time.time() - t0
    print(f"prefill {batch}x{prompt_len}: {prefill_s*1000:.0f} ms; "
          f"decode {gen} steps: {dt/gen*1000:.1f} ms/step "
          f"({batch*gen/dt:.0f} tok/s)")
    print("sample:", np.concatenate(out, 1)[0][:16].tolist())


def serve_din(batch: int) -> None:
    from ..models.recsys.din import din_forward, din_param_defs
    cfg = reduced_config("din")
    params = mc.init_params(din_param_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    S = cfg.seq_len
    b = {"hist_goods": jnp.asarray(rng.integers(0, cfg.n_goods, (batch, S)), jnp.int32),
         "hist_cates": jnp.asarray(rng.integers(0, cfg.n_cates, (batch, S)), jnp.int32),
         "hist_mask": jnp.asarray(rng.random((batch, S)) < 0.8),
         "target_goods": jnp.asarray(rng.integers(0, cfg.n_goods, batch), jnp.int32),
         "target_cates": jnp.asarray(rng.integers(0, cfg.n_cates, batch), jnp.int32)}
    fwd = jax.jit(lambda p, b_: din_forward(p, b_, cfg))
    fwd(params, b)  # compile
    t0 = time.time()
    for _ in range(20):
        fwd(params, b).block_until_ready()
    dt = (time.time() - t0) / 20
    print(f"din batch={batch}: {dt*1000:.2f} ms/batch "
          f"({batch/dt:.0f} scores/s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("model", "snapshots", "evolve",
                                       "query", "ingest", "server"),
                    default="model")
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--events", type=int, default=20_000,
                    help="snapshots mode: history size")
    ap.add_argument("--budget-mb", type=float, default=16.0,
                    help="snapshots mode: GraphPool memory budget")
    ap.add_argument("--queries", type=int, default=2_000)
    ap.add_argument("--zipf", type=float, default=1.3,
                    help="snapshots mode: recency skew (<=1 → uniform)")
    ap.add_argument("--multipoint-batch", type=int, default=1,
                    help="snapshots mode: merge this many concurrent "
                         "queries into one batched get_snapshots plan")
    ap.add_argument("--codec", choices=("v2", "raw"), default="v2",
                    help="payload codec: v2 (compressed+checksummed) or "
                         "legacy raw")
    ap.add_argument("--kv", choices=("mem", "logfile", "tiered"),
                    default="mem",
                    help="snapshots mode: store tier (tiered = hot blob "
                         "cache over a log file)")
    ap.add_argument("--kv-dir", default=None,
                    help="directory for logfile/tiered stores "
                         "(default: fresh temp dir)")
    ap.add_argument("--hot-mb", type=float, default=8.0,
                    help="tiered store: hot-tier byte budget")
    ap.add_argument("--input", default=None,
                    help="query mode: NDJSON document file ('-' = stdin, "
                         "the default)")
    ap.add_argument("--doc-batch", type=int, default=8,
                    help="query mode: merge up to this many concurrent "
                         "documents into one co-batched Steiner plan")
    ap.add_argument("--advisor-mb", type=float, default=0.0,
                    help="query mode: enable the materialization advisor "
                         "under this GraphPool budget (0 = off)")
    ap.add_argument("--shards", type=int, default=1,
                    help="query mode: partition the history into this many "
                         "mod_hash shards and serve retrievals through a "
                         "shard-worker pool (1 = unsharded)")
    ap.add_argument("--shard-procs", type=int, default=0,
                    help="query/server mode: serve retrievals through this "
                         "many shardd OS processes behind the RPC "
                         "transport (0 = in-thread workers; implies "
                         "4*N partitions unless --shards is set)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="query/server mode: replicas per partition for "
                         "the proc transport — hedges and failover route "
                         "to a distinct replica")
    ap.add_argument("--port", type=int, default=0,
                    help="server mode: TCP port to bind (0 in query mode "
                         "= the documented stdin fallback; 0 in server "
                         "mode = an ephemeral OS-assigned port, read it "
                         "from the SERVER_READY line)")
    ap.add_argument("--window-ms", type=float, default=2.0,
                    help="server mode: co-batching window — arrivals "
                         "within it merge into one cross-client plan")
    ap.add_argument("--server-workers", type=int, default=4,
                    help="server mode: scheduler execution threads")
    ap.add_argument("--admit-ms", type=float, default=250.0,
                    help="server mode: admission horizon — shed new work "
                         "when the queue drain estimate exceeds this")
    ap.add_argument("--session-mb", type=float, default=None,
                    help="server mode: per-session lease byte budget "
                         "(default: derived from pool/store budgets)")
    ap.add_argument("--serve-s", type=float, default=0.0,
                    help="server mode: serve for this many seconds then "
                         "exit (0 = until SIGINT)")
    ap.add_argument("--duration", type=float, default=30.0,
                    help="ingest mode: seconds to pace the live event "
                         "stream over")
    ap.add_argument("--readers", type=int, default=2,
                    help="ingest mode: concurrent query reader threads")
    ap.add_argument("--group", type=int, default=256,
                    help="ingest mode: commit-group event target")
    ap.add_argument("--intervals", type=int, default=8,
                    help="evolve mode: number of evolutionary queries")
    ap.add_argument("--points", type=int, default=32,
                    help="evolve mode: timepoints per interval")
    ap.add_argument("--op", default="pagerank",
                    choices=("pagerank", "components", "degree", "density",
                             "masks"),
                    help="evolve mode: incremental operator")
    args = ap.parse_args()
    if args.mode == "server" or (args.mode == "query" and args.port > 0):
        serve_server(args.events, args.port, codec=args.codec,
                     kv=args.kv, kv_dir=args.kv_dir, hot_mb=args.hot_mb,
                     budget_mb=args.advisor_mb, shards=args.shards,
                     window_ms=args.window_ms, workers=args.server_workers,
                     admit_ms=args.admit_ms, session_mb=args.session_mb,
                     serve_s=args.serve_s, shard_procs=args.shard_procs,
                     replicas=args.replicas)
    elif args.mode == "query":
        serve_query(args.events, args.doc_batch, args.input,
                    codec=args.codec, kv=args.kv, kv_dir=args.kv_dir,
                    hot_mb=args.hot_mb, budget_mb=args.advisor_mb,
                    shards=args.shards, shard_procs=args.shard_procs,
                    replicas=args.replicas)
    elif args.mode == "snapshots":
        serve_snapshots(args.events, args.budget_mb, args.queries, args.zipf,
                        batch=args.multipoint_batch, codec=args.codec,
                        kv=args.kv, kv_dir=args.kv_dir, hot_mb=args.hot_mb)
    elif args.mode == "ingest":
        serve_ingest(args.events, args.duration, args.readers, args.group,
                     codec=args.codec, kv=args.kv, kv_dir=args.kv_dir,
                     hot_mb=args.hot_mb)
    elif args.mode == "evolve":
        serve_evolve(args.events, args.intervals, args.points, args.op)
    elif family_of(args.arch) == "recsys":
        serve_din(args.batch)
    else:
        serve_lm(args.arch, args.batch, args.prompt, args.gen)


if __name__ == "__main__":
    main()
