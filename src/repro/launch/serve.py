"""Serving launcher: batched autoregressive decode for LM archs (reduced
config on CPU; the production mesh decode path is exercised by dryrun.py)
and batched CTR scoring for DIN."""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from ..configs.registry import family_of, get_arch, reduced_config
from ..models import common as mc


def serve_lm(arch: str, batch: int, prompt_len: int, gen: int) -> None:
    from ..models.transformer import model as tm
    cfg = reduced_config(arch)
    params = mc.init_params(tm.param_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                         jnp.int32)
    prefill = jax.jit(lambda p, t: tm.prefill_step(p, t, cfg))
    decode = jax.jit(lambda p, c, t, l: tm.decode_step(p, c, t, l, cfg))

    t0 = time.time()
    last, caches = prefill(params, tokens)
    # move prefill caches into a max-length decode cache
    cache = tm.init_cache(cfg, batch, prompt_len + gen)
    new_cache = []
    for (ck, cv), (pk, pv) in zip(cache, caches):
        if cfg.mla is not None:
            pk_ = jnp.moveaxis(pk, 0, 0)
            new_cache.append((ck.at[:, :, :prompt_len].set(pk),
                              cv.at[:, :, :prompt_len].set(pv)))
        else:
            new_cache.append((ck.at[:, :, :, :prompt_len].set(pk),
                              cv.at[:, :, :, :prompt_len].set(pv)))
    cache = new_cache
    prefill_s = time.time() - t0

    out = []
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for i in range(gen):
        logits, cache = decode(params, cache, tok, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    dt = time.time() - t0
    print(f"prefill {batch}x{prompt_len}: {prefill_s*1000:.0f} ms; "
          f"decode {gen} steps: {dt/gen*1000:.1f} ms/step "
          f"({batch*gen/dt:.0f} tok/s)")
    print("sample:", np.concatenate(out, 1)[0][:16].tolist())


def serve_din(batch: int) -> None:
    from ..models.recsys.din import din_forward, din_param_defs
    cfg = reduced_config("din")
    params = mc.init_params(din_param_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    S = cfg.seq_len
    b = {"hist_goods": jnp.asarray(rng.integers(0, cfg.n_goods, (batch, S)), jnp.int32),
         "hist_cates": jnp.asarray(rng.integers(0, cfg.n_cates, (batch, S)), jnp.int32),
         "hist_mask": jnp.asarray(rng.random((batch, S)) < 0.8),
         "target_goods": jnp.asarray(rng.integers(0, cfg.n_goods, batch), jnp.int32),
         "target_cates": jnp.asarray(rng.integers(0, cfg.n_cates, batch), jnp.int32)}
    fwd = jax.jit(lambda p, b_: din_forward(p, b_, cfg))
    fwd(params, b)  # compile
    t0 = time.time()
    for _ in range(20):
        fwd(params, b).block_until_ready()
    dt = (time.time() - t0) / 20
    print(f"din batch={batch}: {dt*1000:.2f} ms/batch "
          f"({batch/dt:.0f} scores/s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    if family_of(args.arch) == "recsys":
        serve_din(args.batch)
    else:
        serve_lm(args.arch, args.batch, args.prompt, args.gen)


if __name__ == "__main__":
    main()
