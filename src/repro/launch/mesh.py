"""Production mesh construction (assignment-mandated shapes).

A function, not a module constant, so importing this module never touches
jax device state.  Single pod: 16×16 = 256 chips (``data`` × ``model``);
multi-pod: 2×16×16 = 512 chips with the leading ``pod`` axis as the
cross-pod data-parallel dimension (DCN-ish axis on real hardware).
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices for mesh {shape}; have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (launch/dryrun.py does this)")
    arr = np.asarray(devices[:need]).reshape(shape)
    return Mesh(arr, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(f"need {need} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:need]).reshape(shape), axes)


def retrieval_mesh(partitions: int, axis: str = "data") -> Mesh:
    """1-D mesh for sharded snapshot retrieval: one device per storage
    partition, so the ``word_cyclic`` layout row owned by partition ``p``
    lives on device ``p`` and the delta-apply chain runs collective-free
    (see :func:`repro.runtime.jax_exec.execute_singlepoint_sharded`)."""
    return make_mesh((partitions,), (axis,))
