"""Training launcher: ``--arch <id> --shape <shape>`` runs a real train
loop (reduced config on CPU; full config on a real TPU mesh), with
checkpoint/resume, LR schedule, gradient compression, and deterministic
data cursors — the fault-tolerant path a cluster job would use.

Dry-run lowering of full configs lives in ``dryrun.py``; this driver
executes real steps.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from ..configs.registry import family_of, get_arch, reduced_config
from ..models import common as mc
from ..storage.checkpoint import restore_checkpoint, save_checkpoint
from ..storage.kv import LogFileKV
from ..training.optim import OPTIMIZERS, warmup_cosine
from ..training.trainer import make_train_step


def synth_batch(arch: str, cfg, rng: np.random.Generator, batch: int,
                seq: int):
    fam = family_of(arch)
    if fam == "lm":
        return {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)}
    if fam == "recsys":
        S = cfg.seq_len
        return {"hist_goods": jnp.asarray(rng.integers(0, cfg.n_goods, (batch, S)), jnp.int32),
                "hist_cates": jnp.asarray(rng.integers(0, cfg.n_cates, (batch, S)), jnp.int32),
                "hist_mask": jnp.asarray(rng.random((batch, S)) < 0.8),
                "target_goods": jnp.asarray(rng.integers(0, cfg.n_goods, batch), jnp.int32),
                "target_cates": jnp.asarray(rng.integers(0, cfg.n_cates, batch), jnp.int32),
                "labels": jnp.asarray(rng.integers(0, 2, batch), jnp.int32)}
    # gnn: random graph batch
    N, E = 256, 1024
    src = rng.integers(0, N, E // 2).astype(np.int32)
    dst = rng.integers(0, N, E // 2).astype(np.int32)
    ei = np.stack([np.concatenate([src, dst]), np.concatenate([dst, src])])
    b = {"edge_index": jnp.asarray(ei)}
    if cfg.kind in ("gcn", "gin"):
        b.update(x=jnp.asarray(rng.standard_normal((N, cfg.d_in)), jnp.float32),
                 labels=jnp.asarray(rng.integers(0, cfg.n_classes, N), jnp.int32),
                 label_mask=jnp.ones(N, jnp.float32))
    elif cfg.kind == "meshgraphnet":
        b.update(x=jnp.asarray(rng.standard_normal((N, cfg.d_node_in)), jnp.float32),
                 edge_attr=jnp.asarray(rng.standard_normal((E, cfg.d_edge_in)), jnp.float32),
                 target=jnp.asarray(rng.standard_normal((N, cfg.d_out)), jnp.float32))
    else:
        T = 4 * E
        b.update(z=jnp.asarray(rng.integers(1, 10, N), jnp.int32),
                 pos=jnp.asarray(rng.standard_normal((N, 3)), jnp.float32),
                 triplet_kj=jnp.asarray(rng.integers(0, E, T), jnp.int32),
                 triplet_ji=jnp.asarray(rng.integers(0, E, T), jnp.int32),
                 graph_ids=jnp.zeros(N, jnp.int32),
                 target=jnp.asarray(rng.standard_normal((1, cfg.d_out)), jnp.float32))
    return b


def make_loss(arch: str, cfg):
    fam = family_of(arch)
    if fam == "lm":
        from ..models.transformer import model as tm
        return lambda p, b: tm.loss_fn(p, b, cfg), tm.param_defs(cfg)
    if fam == "recsys":
        from ..models.recsys.din import din_loss, din_param_defs
        return lambda p, b: din_loss(p, b, cfg), din_param_defs(cfg)
    from ..models.gnn import gnn_loss, gnn_param_defs
    return lambda p, b: gnn_loss(p, b, cfg), gnn_param_defs(cfg)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (production) config — TPU cluster only")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--grad-compression", default=None,
                    choices=[None, "bf16", "int8"])
    args = ap.parse_args()

    if args.full_config:
        cfg, opt_name = get_arch(args.arch)
    else:
        cfg = reduced_config(args.arch)
        _, opt_name = get_arch(args.arch)
    print(f"arch={args.arch} family={family_of(args.arch)} opt={opt_name}")

    loss_fn, defs = make_loss(args.arch, cfg)
    params = mc.init_params(defs, jax.random.PRNGKey(0))
    n_params = sum(np.prod(x.shape) for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f}M")

    opt = OPTIMIZERS[opt_name](lr=args.lr,
                               schedule=warmup_cosine(args.lr, 20, args.steps))
    opt_state = opt[0](params)
    step_fn = jax.jit(make_train_step(loss_fn, opt,
                                      grad_compression=args.grad_compression))

    store = None
    start = 0
    if args.ckpt_dir:
        store = LogFileKV(args.ckpt_dir)
        try:
            (params, opt_state), extra, start = restore_checkpoint(
                store, like=(params, opt_state))
            print(f"resumed @ step {start}")
        except (FileNotFoundError, KeyError):
            pass

    rng = np.random.default_rng(0)
    t0 = time.time()
    m = {}
    for step in range(start, args.steps):
        batch = synth_batch(args.arch, cfg, rng, args.batch, args.seq)
        params, opt_state, m = step_fn(params, opt_state, batch)
        if (step + 1) % 20 == 0:
            dt = (time.time() - t0) / (step - start + 1)
            print(f"step {step+1:5d}  loss {float(m['loss']):.4f}  "
                  f"{dt*1000:.0f} ms/step")
        if store and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(store, step + 1, (params, opt_state),
                            extra={"data_cursor": step + 1})
    print(f"final loss {float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
