"""Per-shard worker daemon: ``python -m repro.launch.shardd``.

One shardd process is a **partition-restricted caching fetch server** —
the storage-server half of the paper's distributed DeltaGraph.  The
coordinator (``ShardedRetriever`` with the process transport) computes
plan IRs and delta-apply locally; what crosses the wire is the storage
protocol only: batched ``fetch`` RPCs (key lists → blobs, ``None`` for
holes) answered from a shard-local :class:`~repro.storage.kv.TieredKV`
hot cache whose **cold tier is an RPC client back to the coordinator's
origin store** (:class:`RemoteKV`).  The origin stays authoritative, so a
SIGKILL'd shardd loses nothing but its cache, and a replica serving the
same partitions warms independently.

Cache freshness is epoch-driven, matching the ingest pipeline's
invariants: committed group writes *overwrite* the open leaf's eventlist
keys in place, so a cross-process cache goes stale the moment an epoch
publishes.  Two guards make that safe:

* ``announce`` RPC — the coordinator's :class:`EpochRegistry` publish
  hook fans the new epoch id out to every shardd, which drops its hot
  tier (``invalidations`` counter).
* ``min_epoch`` fetch gate — every fetch carries the coordinator's
  current epoch id; a shardd that has not yet heard the announcement
  (publish → announce is asynchronous) sees ``min_epoch > epoch``,
  invalidates immediately and adopts the newer id.  A query can therefore
  never read hot bytes older than the epoch it pinned.

Also served: ``health`` (the heartbeat RPC — liveness, pid, epoch),
``stats``, ``configure`` (point at an origin / reset between owners, so a
pooled fleet is reusable across tests), ``set_delay`` (fault injection
for degraded-replica benchmarks), ``flush_cache``, ``ping``.

The bottom half of this module is the coordinator-side process
management: :func:`spawn_shard_procs` / :class:`ShardProc` handles, an
:func:`origin_server` factory, and a process pool reused across
transports (spawning pays a full interpreter + jax import, ~0.5 s; a
``configure`` RPC is microseconds).
"""
from __future__ import annotations

import argparse
import atexit
import os
import subprocess
import sys
import threading
import time

from ..runtime.rpc import RpcClient, RpcServer
from ..storage.kv import KVStore, TieredKV

READY_PREFIX = "SHARDD_READY"

# h_fetch's unowned-partition rejection message prefix — the transport
# matches it (through RemoteCallError.remote_message) to tell a
# routing-config gap apart from a liveness failure, widen the server's
# owned set via ``set_owned`` and retry instead of blacklisting it
UNOWNED_MSG = "fetch for unowned partition(s)"


def _decode_keys(raw: list) -> list[tuple]:
    return [(int(p), int(d), str(c)) for p, d, c in raw]


def _encode_keys(keys: list) -> list:
    return [[int(p), int(d), str(c)] for p, d, c in keys]


class RemoteKV(KVStore):
    """KVStore client over the RPC layer: ``mget`` is one round trip.

    Used as a :class:`TieredKV` cold tier inside shardd (reads through to
    the coordinator's origin server) — so every hot-tier miss batch costs
    exactly one RPC, and the tiered cache's byte budget and versioned
    admission apply unchanged to remote blobs.
    """

    def __init__(self, host: str, port: int, *,
                 deadline_s: float | None = 30.0) -> None:
        super().__init__()
        self.client = RpcClient(host, int(port),
                                default_deadline_s=deadline_s)

    def mget(self, keys: list) -> list:
        if not keys:
            return []
        _, blobs = self.client.call("mget", {"k": _encode_keys(keys)})
        for b in blobs:
            if b is not None:
                self.stats.add_get(len(b))
        return blobs

    def get(self, key) -> bytes:
        (v,) = self.mget([key])
        if v is None:
            raise KeyError(key)
        return v

    def multi_get(self, keys: list) -> list[bytes]:
        out = self.mget(keys)
        for k, v in zip(keys, out):
            if v is None:
                raise KeyError(k)
        return out

    def __contains__(self, key) -> bool:
        try:
            self.get(key)
            return True
        except KeyError:
            return False

    def close(self) -> None:
        self.client.close()


class ShardServer:
    """The daemon's state machine; all handlers run on RPC threads."""

    def __init__(self, hot_mb: float = 64.0) -> None:
        self.hot_bytes = int(float(hot_mb) * 2**20)
        self.origin: RemoteKV | None = None
        self.cache: TieredKV | None = None
        self.owned: frozenset[int] | None = None
        self.epoch = -1
        self.t0 = time.monotonic()
        self._lock = threading.Lock()
        self._delay_s = 0.0
        self._delay_left = 0
        self.counters = {"fetches": 0, "keys": 0, "bytes_out": 0,
                         "invalidations": 0, "implied_invalidations": 0,
                         "configures": 0}

    # -- handlers -----------------------------------------------------------
    def h_configure(self, args: dict, blobs) -> dict:
        """(Re)point at an origin store and reset per-owner state — what
        makes a pooled shardd reusable across coordinators."""
        with self._lock:
            if self.origin is not None:
                self.origin.close()
            self.origin = RemoteKV(args.get("origin_host", "127.0.0.1"),
                                   int(args["origin_port"]))
            self.cache = TieredKV(self.origin,
                                  hot_bytes=int(args.get(
                                      "hot_bytes", self.hot_bytes)))
            owned = args.get("owned")
            self.owned = None if owned is None else frozenset(
                int(p) for p in owned)
            self.epoch = int(args.get("epoch", 0))
            self._delay_s = 0.0
            self._delay_left = 0
            self.counters["configures"] += 1
        return {"pid": os.getpid(), "epoch": self.epoch}

    def h_fetch(self, args: dict, blobs) -> tuple:
        with self._lock:
            cache, owned = self.cache, self.owned
            delay = 0.0
            if self._delay_left != 0 and self._delay_s > 0:
                delay = self._delay_s
                if self._delay_left > 0:
                    self._delay_left -= 1
        if cache is None:
            raise RuntimeError("shardd not configured (no origin)")
        if delay:
            time.sleep(delay)
        keys = _decode_keys(args.get("k", []))
        if owned is not None:
            bad = [k for k in keys if k[0] not in owned]
            if bad:
                # fatal by classification: a fetch for an unowned
                # partition is a routing-config gap, not a transient
                # fault — the transport reacts with set_owned + retry
                raise ValueError(
                    f"{UNOWNED_MSG} {sorted({k[0] for k in bad})}; "
                    f"this shard owns {sorted(owned)}")
        min_epoch = int(args.get("min_epoch", 0))
        with self._lock:
            if min_epoch > self.epoch:
                # the coordinator is ahead of our last announcement: any
                # hot byte may predate the publish — drop and adopt
                if self.cache is not None:
                    self.cache.invalidate_hot()
                self.epoch = min_epoch
                self.counters["implied_invalidations"] += 1
        out = cache.mget(keys)
        with self._lock:
            self.counters["fetches"] += 1
            self.counters["keys"] += len(keys)
            self.counters["bytes_out"] += sum(
                len(b) for b in out if b is not None)
        return None, out

    def h_set_owned(self, args: dict, blobs) -> dict:
        """Replace the owned partition set *without* touching the cache
        or origin — the coordinator's failover path when >1 server has
        died and routing must land a partition beyond the rendezvous
        ranks this server was originally configured with."""
        owned = args.get("owned")
        with self._lock:
            self.owned = None if owned is None else frozenset(
                int(p) for p in owned)
            out = None if self.owned is None else sorted(self.owned)
        return {"owned": out}

    def h_announce(self, args: dict, blobs) -> dict:
        epoch = int(args.get("epoch", 0))
        with self._lock:
            stale = epoch > self.epoch
            if stale:
                self.epoch = epoch
                if self.cache is not None:
                    self.cache.invalidate_hot()
                self.counters["invalidations"] += 1
        return {"epoch": self.epoch, "invalidated": stale}

    def h_health(self, args: dict, blobs) -> dict:
        return {"pid": os.getpid(), "epoch": self.epoch,
                "uptime_s": round(time.monotonic() - self.t0, 3),
                "configured": self.cache is not None}

    def h_stats(self, args: dict, blobs) -> dict:
        with self._lock:
            out = dict(self.counters)
            out["epoch"] = self.epoch
            if self.cache is not None:
                out["hot_hits"] = self.cache.stats.hot_hits
                out["hot_misses"] = self.cache.stats.hot_misses
                out["hot_bytes_used"] = self.cache.hot_bytes_used()
        return out

    def h_set_delay(self, args: dict, blobs) -> dict:
        """Fault injection: stall the next ``count`` fetches (-1 = all) by
        ``ms`` — the degraded-replica model for hedging benchmarks."""
        with self._lock:
            self._delay_s = float(args.get("ms", 0)) / 1e3
            self._delay_left = int(args.get("count", -1))
        return {"ok": True}

    def h_flush_cache(self, args: dict, blobs) -> dict:
        n = self.cache.invalidate_hot() if self.cache is not None else 0
        return {"dropped": n}

    def h_ping(self, args: dict, blobs) -> dict:
        return {"pong": True, "pid": os.getpid()}

    def handlers(self) -> dict:
        return {"configure": self.h_configure, "fetch": self.h_fetch,
                "set_owned": self.h_set_owned,
                "announce": self.h_announce, "health": self.h_health,
                "stats": self.h_stats, "set_delay": self.h_set_delay,
                "flush_cache": self.h_flush_cache, "ping": self.h_ping}


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(prog="shardd")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--hot-mb", type=float, default=64.0)
    args = ap.parse_args(argv)

    shard = ShardServer(hot_mb=args.hot_mb)
    server = RpcServer(shard.handlers(), port=args.port).start()
    print(f"{READY_PREFIX} port={server.port} pid={os.getpid()}",
          flush=True)
    try:
        # lifetime = parent's: block until stdin EOF (parent exited or
        # closed the pipe), so an abandoned coordinator never leaks us
        sys.stdin.buffer.read()
    except KeyboardInterrupt:
        pass
    finally:
        server.close()


# ---------------------------------------------------------------------------
# coordinator-side process management
# ---------------------------------------------------------------------------

class ShardProc:
    """Handle on one spawned shardd: its OS process + an RPC client."""

    def __init__(self, proc: subprocess.Popen, port: int) -> None:
        self.proc = proc
        self.port = int(port)
        self.pid = proc.pid
        self.client = RpcClient("127.0.0.1", self.port)

    def alive(self) -> bool:
        if self.proc.poll() is not None:
            return False
        try:
            self.client.call("ping", deadline_s=2.0)
            return True
        except Exception:
            return False

    def kill(self) -> None:
        """SIGKILL — the chaos-test path; no cleanup runs in the child."""
        self.client.close()
        try:
            self.proc.kill()
        except OSError:
            pass
        self.proc.wait(timeout=10)

    def terminate(self) -> None:
        self.client.close()
        if self.proc.poll() is None:
            try:
                self.proc.stdin.close()     # EOF → clean exit
            except OSError:
                pass
            try:
                self.proc.wait(timeout=5)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait(timeout=10)
        # reap pipes so a long-lived coordinator doesn't leak fds
        for f in (self.proc.stdout, self.proc.stdin):
            try:
                if f is not None:
                    f.close()
            except OSError:
                pass


def spawn_shard_procs(n: int, *, hot_mb: float = 64.0,
                      ready_timeout_s: float = 60.0) -> list[ShardProc]:
    """Spawn ``n`` shardd processes and wait for their ready lines.

    Children are full interpreters (``sys.executable -m
    repro.launch.shardd``) — real isolation, SIGKILL-able — with
    ``PYTHONPATH`` extended so the child resolves the same ``repro``
    tree as the parent.
    """
    import repro
    # repro is a namespace package: resolve its source root via __path__
    src_dir = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_dir + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    procs = []
    for _ in range(n):
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.launch.shardd",
             "--hot-mb", str(hot_mb)],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE, env=env))
    handles = []
    try:
        for proc in procs:
            deadline = time.monotonic() + ready_timeout_s
            line = ""
            while time.monotonic() < deadline:
                raw = proc.stdout.readline()
                if not raw:
                    raise RuntimeError(
                        f"shardd pid {proc.pid} exited before ready "
                        f"(rc={proc.poll()})")
                line = raw.decode(errors="replace").strip()
                if line.startswith(READY_PREFIX):
                    break
            if not line.startswith(READY_PREFIX):
                raise TimeoutError(f"shardd pid {proc.pid} never readied")
            fields = dict(f.split("=", 1) for f in line.split()[1:])
            handles.append(ShardProc(proc, int(fields["port"])))
    except BaseException:
        for h in handles:
            h.terminate()
        for proc in procs[len(handles):]:
            proc.kill()
            proc.wait(timeout=10)
        raise
    return handles


def origin_server(store: KVStore) -> RpcServer:
    """The coordinator-side authoritative endpoint shardd reads through
    to: one ``mget`` method over the manager's own store.  Runs on
    threads inside the coordinator process (the store API is
    thread-safe; the prefetcher already drives it concurrently)."""
    def h_mget(args: dict, blobs) -> tuple:
        keys = _decode_keys(args.get("k", []))
        return None, store.mget(keys)

    return RpcServer({"mget": h_mget,
                      "ping": lambda a, b: {"pong": True}}).start()


# -- pooled fleet (spawn once per process, reconfigure per owner) -----------
_POOL: list[ShardProc] = []
_POOL_LOCK = threading.Lock()


def _pooling_enabled() -> bool:
    return os.environ.get("REPRO_SHARDD_POOL", "1") != "0"


def acquire_shard_procs(n: int, *, hot_mb: float = 64.0) -> list[ShardProc]:
    out: list[ShardProc] = []
    if _pooling_enabled():
        with _POOL_LOCK:
            while _POOL and len(out) < n:
                out.append(_POOL.pop())
        # one alive() (an RPC ping) per handle: evaluating it twice can
        # double-count a handle whose state flips between calls — or
        # drop it entirely, leaking the Popen and its pipes
        live: list[ShardProc] = []
        for h in out:
            if h.alive():
                live.append(h)
            else:
                h.terminate()
        out = live
    if len(out) < n:
        out.extend(spawn_shard_procs(n - len(out), hot_mb=hot_mb))
    return out


def release_shard_procs(handles: list[ShardProc]) -> None:
    live = []
    for h in handles:
        if h.proc.poll() is None and _pooling_enabled():
            live.append(h)
        else:
            h.terminate()
    with _POOL_LOCK:
        _POOL.extend(live)


@atexit.register
def _drain_pool() -> None:  # pragma: no cover - process teardown
    with _POOL_LOCK:
        handles, _POOL[:] = list(_POOL), []
    for h in handles:
        try:
            h.terminate()
        except Exception:
            pass


if __name__ == "__main__":
    main()
