"""Generic train-step builder: value_and_grad → optimizer, with optional
gradient accumulation and gradient compression for the DP all-reduce.

The returned step is pure (params, opt_state, batch) → (params, opt_state,
metrics) so it can be jitted with explicit in/out shardings by the
launcher, lowered for the dry-run, and donated for real runs.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..runtime.compression import compress_tree, decompress_tree


def make_train_step(loss_fn: Callable, optimizer: tuple[Callable, Callable],
                    *, accum_steps: int = 1,
                    grad_compression: str | None = None) -> Callable:
    """``loss_fn(params, batch) -> (loss, metrics)``;
    ``optimizer = (init_fn, update_fn)``."""
    _, update_fn = optimizer

    def step(params, opt_state, batch):
        if accum_steps == 1:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                acc, = carry
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, acc, g),), m
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), batch)
            (grads,), metrics = jax.lax.scan(micro, (zeros,), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        if grad_compression is not None:
            # quantize → (implicit DP all-reduce on use) → dequantize, with
            # error feedback folded into the next step via stochastic round
            packed = compress_tree(grads, kind=grad_compression)
            grads = decompress_tree(packed, like=grads)
        new_params, new_state = update_fn(grads, opt_state, params)
        return new_params, new_state, metrics

    return step


def make_eval_step(loss_fn: Callable) -> Callable:
    def step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics
    return step
