from .optim import adafactor, adamw, sgd  # noqa: F401
from .trainer import make_train_step  # noqa: F401
