"""Optimizers as pure pytree transforms (no optax dependency).

* :func:`adamw`     — bf16/f32 params with fp32 master + moments; the
  default for ≤34B models.
* :func:`adafactor` — factored second moment, no master copy; the only
  arithmetically feasible choice for the 480B/671B MoEs on 16 GB v5e
  (see DESIGN.md §5): state is ~2 fp32 vectors per matrix instead of
  2 fp32 matrices + master.
* :func:`sgd`       — momentum SGD (GNN/recsys configs).

Each returns ``(init_fn, update_fn)``; ``update_fn(grads, state, params)
→ (new_params, new_state)``.  Gradient clipping and the LR schedule are
closed over.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def _clip(grads, max_norm):
    if max_norm is None:
        return grads
    g = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        grads)


def warmup_cosine(base_lr: float, warmup: int = 100, total: int = 10_000,
                  min_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * w * cos
    return lr


def adamw(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.01,
          clip_norm=1.0, schedule: Callable | None = None):
    lr_fn = schedule or (lambda s: jnp.asarray(lr, jnp.float32))

    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(f32, params),
                "v": jax.tree.map(f32, params),
                "master": jax.tree.map(lambda p: p.astype(jnp.float32), params)}

    def update(grads, state, params):
        grads = _clip(grads, clip_norm)
        step = state["step"] + 1
        lr_t = lr_fn(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, master):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            new_master = master - lr_t * (u + weight_decay * master)
            return m2, v2, new_master

        out = jax.tree.map(upd, grads, state["m"], state["v"], state["master"])
        m = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        master = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype), master, params)
        return new_params, {"step": step, "m": m, "v": v, "master": master}

    return init, update


def adafactor(lr=1e-2, decay=0.8, eps=1e-30, clip_norm=1.0,
              schedule: Callable | None = None):
    """Factored second moment (Shazeer & Stern, arXiv:1804.04235), no
    first moment, no master copy — O(n+m) state per n×m matrix."""
    lr_fn = schedule or (lambda s: jnp.asarray(lr, jnp.float32))

    def init(params):
        def fac(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "stats": jax.tree.map(fac, params)}

    def update(grads, state, params):
        grads = _clip(grads, clip_norm)
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1) ** -decay
        lr_t = lr_fn(step)

        def upd(g, st, p):
            g = g.astype(jnp.float32)
            if p.ndim >= 2:
                vr = beta * st["vr"] + (1 - beta) * (g * g).mean(-1)
                vc = beta * st["vc"] + (1 - beta) * (g * g).mean(-2)
                rfac = jax.lax.rsqrt(vr / jnp.maximum(
                    vr.mean(-1, keepdims=True), eps) + eps)
                cfac = jax.lax.rsqrt(vc + eps)
                u = g * rfac[..., None] * cfac[..., None, :]
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g * g
                u = g * jax.lax.rsqrt(v + eps)
                new_st = {"v": v}
            # update clipping (RMS ≤ 1) per the paper
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), new_st

        is_st = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
        out = jax.tree.map(upd, grads, state["stats"], params, is_leaf=None)
        # out is a tree of (param, stats) tuples
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        stats = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step, "stats": stats}

    return init, update


def sgd(lr=1e-2, momentum=0.9, clip_norm=None,
        schedule: Callable | None = None):
    lr_fn = schedule or (lambda s: jnp.asarray(lr, jnp.float32))

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                    params)}

    def update(grads, state, params):
        grads = _clip(grads, clip_norm)
        step = state["step"] + 1
        lr_t = lr_fn(step)

        def upd(g, m, p):
            m2 = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * m2).astype(p.dtype), m2

        out = jax.tree.map(upd, grads, state["mom"], params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        mom = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step, "mom": mom}

    return init, update


OPTIMIZERS = {"adamw": adamw, "adafactor": adafactor, "sgd": sgd}
