"""QueryCompiler: one lowering from GraphQuery documents onto the engine.

Every document kind compiles to a :class:`CompiledQuery` with two halves:

* ``point_times`` / ``point_group`` — the snapshot timepoints the document
  needs retrieved, if any, keyed by the execution parameters that make two
  documents co-plannable.  The :class:`~repro.api.service.QueryService`
  unions the timepoints of every co-batched document in a group and
  retrieves them through **one** merged Steiner plan (exactly what
  ``GraphManager.get_snapshots`` does for a plain time batch) — so a batch
  of mixed snapshot / multipoint / expr documents shares prefix fetches
  and applies across documents.
* ``finish(service, states)`` — turns retrieved states (or, for
  interval/evolve kinds, a direct engine call) into the document's result
  payload.

Compilation is where *semantic* validation happens, with the typed error
taxonomy (:mod:`repro.core.errors`): attribute names are resolved against
the universe, TimeExpressions are parsed, named evolve operators are
checked against the registry — so a malformed wire document fails before
any KV traffic, with a structured error.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any

import numpy as np

from ..core.errors import DocumentError
from ..core.query import AttrOptions, TimeExpression, parse_attr_options
from .document import GraphQuery

if TYPE_CHECKING:  # pragma: no cover
    from ..core.events import GraphUniverse, MaterializedState
    from .service import QueryService


def expr_state(tex: TimeExpression, states: dict[int, "MaterializedState"],
               ) -> "MaterializedState":
    """Evaluate a Boolean TimeExpression over retrieved per-time states
    (paper §3.2.1): the element set satisfying the expression; attributes
    come from the latest queried time point at which the element exists."""
    from ..core.events import MaterializedState
    ordered = [states[t] for t in tex.times]
    nmask = tex.evaluate([s.node_mask for s in ordered])
    emask = tex.evaluate([s.edge_mask for s in ordered])
    na = np.full_like(ordered[0].node_attrs, np.nan)
    ea = np.full_like(ordered[0].edge_attrs, np.nan)
    for s in ordered:  # later time points override
        take = s.node_mask & nmask
        na[take] = s.node_attrs[take]
        take_e = s.edge_mask & emask
        ea[take_e] = s.edge_attrs[take_e]
    return MaterializedState(nmask, emask, na, ea)


@dataclasses.dataclass
class CompiledQuery:
    """A validated, universe-resolved document ready to execute."""

    doc: GraphQuery
    options: AttrOptions
    tex: TimeExpression | None = None

    @property
    def kind(self) -> str:
        return self.doc.kind

    @property
    def point_times(self) -> tuple[int, ...]:
        """Snapshot timepoints this document needs (empty for kinds the
        engine retrieves internally)."""
        d = self.doc
        if d.kind == "snapshot":
            return (d.t,)
        if d.kind in ("multipoint", "expr"):
            return d.times
        return ()

    @property
    def point_group(self) -> tuple | None:
        """Co-batching key: documents with the same group key can share
        one merged Steiner plan."""
        if not self.point_times:
            return None
        return (self.options.node_cols, self.options.edge_cols,
                self.doc.use_current, self.doc.no_cache)

    def finish(self, service: "QueryService",
               states: dict[int, "MaterializedState"] | None,
               dg=None) -> Any:
        """Produce the result payload from retrieved ``states`` (point
        kinds) or by calling the engine directly (interval / evolve).
        ``dg`` is the epoch-pinned index version the whole document must
        resolve against (defaults to the manager's current one)."""
        d = self.doc
        if d.kind == "snapshot":
            return states[d.t]
        if d.kind == "multipoint":
            return {t: states[t] for t in d.times}
        if d.kind == "expr":
            return expr_state(self.tex, states)
        gm = service.gm
        if dg is None:
            dg = gm.dg
        if d.kind == "interval":
            return dg.get_interval(d.ts, d.te)
        # evolve: the temporal engine plans/retrieves its first snapshot
        # itself (through the service shims, so cache/advisor apply)
        return service.temporal_engine().evolve(
            list(d.times), d.op, attr_options=self.options,
            use_current=d.use_current, incremental=d.incremental,
            dg=dg, **d.op_kwargs)


class QueryCompiler:
    """Compiles documents against one universe (attribute tables)."""

    def __init__(self, universe: "GraphUniverse") -> None:
        self.universe = universe
        # spec-string -> AttrOptions memo: the legacy shims route every
        # retrieval through here, so repeated specs (the common case on a
        # serving hot path) must not re-run the regex parse per query.
        # Keyed on the attribute-table sizes too: live updates can add
        # columns, and a memoized ``+node:all`` must re-resolve then.
        self._opt_memo: dict[tuple, AttrOptions] = {}

    def parse_attrs(self, spec: str) -> AttrOptions:
        key = (spec, self.universe.num_node_attrs,
               self.universe.num_edge_attrs)
        opts = self._opt_memo.get(key)
        if opts is None:
            opts = parse_attr_options(spec, self.universe)
            if len(self._opt_memo) < 4096:   # bound pathological streams
                self._opt_memo[key] = opts
        return opts

    def compile(self, doc: GraphQuery) -> CompiledQuery:
        doc.validate()
        if isinstance(doc.attrs, AttrOptions):
            options = doc.attrs
        elif isinstance(doc.attrs, str):
            options = self.parse_attrs(doc.attrs)
        else:
            raise DocumentError(f"'attrs' must be a spec string or "
                                f"AttrOptions, got {type(doc.attrs).__name__}",
                                position="attrs")
        tex = None
        if doc.kind == "expr":
            tex = doc.time_expression()
        if doc.kind == "evolve" and isinstance(doc.op, str):
            from ..core.temporal import resolve_op
            resolve_op(doc.op, {})   # registry check -> UnknownOperatorError
        return CompiledQuery(doc, options, tex)


# ---------------------------------------------------------------------------
# cross-shard planning
# ---------------------------------------------------------------------------


def scatter_plans(irs, parts_by_shard: dict[Any, tuple[int, ...]],
                  total_parts: int) -> dict[Any, Any]:
    """Scatter one or more compiled plan IRs across shards.

    Each plan is scattered (:func:`repro.core.planir.scatter_ir`) so a
    shard's Fetch nodes pull only the storage partitions it owns; a shard
    handed several plans (a co-batched document group) gets them merged
    back into one DAG with :func:`repro.core.planir.merge_irs`, so shared
    prefixes still fetch and apply once *per shard*.  Returns
    ``{shard: PlanIR}``; the per-shard slot results are unioned by the
    sharded retriever's gather step."""
    from ..core.planir import merge_irs, scatter_ir

    per_shard: dict[Any, list] = {s: [] for s in parts_by_shard}
    for ir in irs:
        for s, sir in scatter_ir(ir, parts_by_shard, total_parts).items():
            per_shard[s].append(sir)
    return {s: (merge_irs(plans) if len(plans) > 1 else plans[0])
            for s, plans in per_shard.items()}
