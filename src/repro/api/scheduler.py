"""BatchingScheduler: cross-client co-batching with SLO admission control.

The socket server (:mod:`repro.launch.server`) accepts one NDJSON session
per connection; every parsed :class:`~repro.api.document.GraphQuery`
lands here.  The scheduler holds arriving documents in a small *batching
window* (``window_ms``, ~2ms), groups co-plannable documents **across
clients** by the same compatibility key ``run_batch`` uses for a
single-client chunk (attr columns / ``use_current`` / ``no_cache``), and
dispatches each group as **one** merged Steiner plan on a worker pool —
so the multi-query optimization that gives batched multipoint retrieval
its win (BENCH_retrieval.json) is realized over *concurrent clients*,
not just documents that happen to share a stdin chunk.  Responses are
demultiplexed back through per-request futures, so each session writes
its own envelopes in its own request order.

SLO machinery, layered in dispatch order:

* **Admission control** (at ``submit``): when queued work — queue depth x
  estimated plan cost, converted to seconds through an EWMA of the
  observed cost-units-per-second execution rate — exceeds the configured
  drain horizon (``admit_horizon_ms``), the request is shed immediately
  with a typed ``overloaded`` envelope.  Shedding keeps the p99 of
  *admitted* requests bounded as offered load passes capacity
  (the shed-vs-meltdown gate in BENCH_server.json).

* **Deadline control** (at dispatch): a request carrying ``deadline_ms``
  is checked against the planner's decode-aware cost model *before*
  execution — the group's timepoints are planned (pure index work, no KV
  traffic) and a request whose estimated execution time already exceeds
  its remaining budget is rejected with a ``deadline`` envelope instead
  of executed and discarded.  Requests that expired while queued are
  rejected the same way.  Deadline-rejected requests consume **no** KV
  gets (gated in BENCH_server.json).

* **Backpressure** is session-level (lease bytes against the GraphPool
  budget) and lives in :mod:`repro.launch.server`.

``window_ms=0`` disables cross-client merging: every request dispatches
as its own single-document group (the honest baseline the co-batching
gate compares against).  ``run_wave(docs)`` is the synchronous entry the
stdin fallback uses: one chunk of lines = one arrival wave, grouped and
executed inline — the stdin loop and the socket server share this one
code path.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import TYPE_CHECKING, Any, Sequence

from ..core.errors import DeadlineError, OverloadedError
from .document import GraphQuery

if TYPE_CHECKING:  # pragma: no cover
    from .compiler import CompiledQuery
    from .service import QueryResult, QueryService


class _Request:
    """One in-flight document: compiled form + resolution future."""

    __slots__ = ("doc", "compiled", "future", "arrival", "cost_est")

    def __init__(self, doc: GraphQuery, compiled: "CompiledQuery | None",
                 arrival: float) -> None:
        self.doc = doc
        self.compiled = compiled
        self.future: Future = Future()
        self.arrival = arrival          # perf_counter at enqueue
        self.cost_est: float | None = None


class _Ewma:
    """Thread-safe exponential moving average with a sane prior."""

    def __init__(self, prior: float, alpha: float = 0.2) -> None:
        self.value = float(prior)
        self.alpha = float(alpha)
        self._lock = threading.Lock()

    def update(self, x: float) -> None:
        with self._lock:
            self.value += self.alpha * (float(x) - self.value)


class BatchingScheduler:
    """Co-batching dispatch queue in front of one
    :class:`~repro.api.service.QueryService` (see module docstring).

    * ``window_ms`` — batching window: how long arrivals accumulate
      before a dispatch wave (0 = no cross-client merging).
    * ``workers`` — executor pool size for dispatched groups.
    * ``admit_horizon_ms`` — admission control: shed when the queue's
      estimated drain time exceeds this.  ``<= 0`` disables shedding.
    * ``max_queue`` — hard queue-depth backstop regardless of cost.
    """

    def __init__(self, service: "QueryService", *, window_ms: float = 2.0,
                 workers: int = 4, admit_horizon_ms: float = 250.0,
                 max_queue: int = 4096) -> None:
        self.service = service
        self.window_ms = float(window_ms)
        self.admit_horizon_ms = float(admit_horizon_ms)
        self.max_queue = int(max_queue)
        self._queue: deque[_Request] = deque()
        self._queued_cost = 0.0
        # cost dispatched to the worker pool but not yet executed —
        # admission must see the pool's backlog too, or everything past
        # the window looks like an empty queue and the drain-horizon
        # bound silently stops holding
        self._inflight_cost = 0.0
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._pool = ThreadPoolExecutor(
            max_workers=max(1, int(workers)),
            thread_name_prefix="query-sched")
        self._dispatcher: threading.Thread | None = None
        # cost-units-per-second execution rate (decode-aware plan-cost
        # units, core/planir EdgeInfo.weight) and per-point cost priors;
        # both learned online from executed groups
        self.cost_rate = _Ewma(5e6)
        self.point_cost = _Ewma(1e3)
        self.solo_s = _Ewma(5e-3)       # non-point docs (interval/evolve)
        self.stats_lock = threading.Lock()
        self.counters = {"submitted": 0, "executed": 0, "groups": 0,
                         "co_batched_docs": 0, "shed_overload": 0,
                         "shed_deadline": 0, "max_group": 0}

    # ------------------------------------------------------------ lifecycle
    def _ensure_dispatcher(self) -> None:
        if self._dispatcher is None or not self._dispatcher.is_alive():
            with self._lock:
                if self._dispatcher is None or \
                        not self._dispatcher.is_alive():
                    self._dispatcher = threading.Thread(
                        target=self._dispatch_loop,
                        name="query-sched-dispatch", daemon=True)
                    self._dispatcher.start()

    def close(self) -> None:
        """Stop the dispatcher, fail queued requests with ``overloaded``
        envelopes, and join the worker pool (idempotent)."""
        self._stop.set()
        self._wake.set()
        d = self._dispatcher
        if d is not None:
            d.join(timeout=10)
            self._dispatcher = None
        with self._lock:
            drained = list(self._queue)
            self._queue.clear()
            self._queued_cost = 0.0
        for req in drained:
            self._resolve_error(req, OverloadedError(
                "server shutting down"))
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "BatchingScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------ admission
    def _estimate_cost(self, cq: "CompiledQuery | None") -> float:
        """Queue-time cost estimate in plan-cost units (cheap: EWMA'd
        per-point prior, no planning on the submit path)."""
        if cq is None:
            return 0.0
        n = len(cq.point_times)
        if n == 0:   # interval/evolve: convert the time prior to units
            return self.solo_s.value * self.cost_rate.value
        return n * self.point_cost.value

    def submit(self, doc: GraphQuery,
               compiled: "CompiledQuery | None" = None) -> Future:
        """Enqueue one document; returns a Future resolving to a
        :class:`~repro.api.service.QueryResult` (never raises — compile
        failures, sheds and deadline misses resolve to error envelopes).
        """
        arrival = time.perf_counter()
        with self.stats_lock:
            self.counters["submitted"] += 1
        if self._stop.is_set():
            req = _Request(doc, None, arrival)
            self._resolve_error(req, OverloadedError("scheduler closed"))
            return req.future
        if compiled is None:
            try:
                compiled = self.service.compiler.compile(doc)
            except Exception as e:
                req = _Request(doc, None, arrival)
                self._resolve_error(req, e)
                return req.future
        req = _Request(doc, compiled, arrival)
        req.cost_est = self._estimate_cost(compiled)
        with self._lock:
            over = (len(self._queue) >= self.max_queue
                    or (self.admit_horizon_ms > 0
                        and self._queued_cost + self._inflight_cost
                        + req.cost_est
                        > self.cost_rate.value
                        * self.admit_horizon_ms / 1e3))
            if not over:
                self._queue.append(req)
                self._queued_cost += req.cost_est
        if over:
            with self.stats_lock:
                self.counters["shed_overload"] += 1
            self._resolve_error(req, OverloadedError(
                f"admission control: queued work exceeds the "
                f"{self.admit_horizon_ms:.0f}ms drain horizon"))
            return req.future
        self._wake.set()
        self._ensure_dispatcher()
        return req.future

    # ------------------------------------------------------------- dispatch
    def _dispatch_loop(self) -> None:
        while not self._stop.is_set():
            if not self._wake.wait(timeout=0.2):
                continue
            self._wake.clear()
            if self.window_ms > 0:
                # the batching window: let concurrent arrivals accumulate
                time.sleep(self.window_ms / 1e3)
            with self._lock:
                wave = list(self._queue)
                self._queue.clear()
                self._queued_cost = 0.0
            if wave:
                self._dispatch_wave(wave)

    def _dispatch_wave(self, wave: list[_Request]) -> None:
        """Group a wave by co-batching key and hand each group to the
        worker pool.  ``window_ms=0`` ⇒ every request is its own group."""
        units: list[list[_Request]] = []
        if self.window_ms <= 0:
            units = [[r] for r in wave]
        else:
            groups: dict[tuple, list[_Request]] = {}
            solo: list[list[_Request]] = []
            for r in wave:
                key = r.compiled.point_group
                if key is None:
                    solo.append([r])
                else:
                    groups.setdefault(key, []).append(r)
            units = list(groups.values()) + solo
        for unit in units:
            cost = sum(r.cost_est or 0.0 for r in unit)
            with self._lock:
                self._inflight_cost += cost
            self._pool.submit(self._run_unit, unit, cost)

    # ------------------------------------------------------------ execution
    def _plan_cost(self, cq: "CompiledQuery") -> float:
        """The planner's decode-aware cost of this document's own
        retrieval (``α·stored + β·logical`` units) — pure index work
        against the pinned epoch, no KV traffic."""
        gm = self.service.gm
        with gm.epochs.acquire() as pin:
            ir = pin.data.dg.plan_multipoint(
                list(cq.point_times), cq.options, cq.doc.use_current)
            return float(ir.total_weight)

    def _check_deadline(self, req: _Request, now: float) -> bool:
        """True if the request may execute; False ⇒ resolved with a
        ``deadline`` error envelope (no KV gets were performed)."""
        d = req.doc.deadline_ms
        if d is None:
            return True
        remaining = d / 1e3 - (now - req.arrival)
        if remaining <= 0:
            self._reject_deadline(req, f"deadline_ms={d:g} expired in "
                                       f"queue")
            return False
        if req.compiled is not None and req.compiled.point_times:
            cost = self._plan_cost(req.compiled)
            est = cost / max(self.cost_rate.value, 1e-9)
            if est > remaining:
                self._reject_deadline(
                    req, f"plan cost {cost:.0f} units "
                         f"(~{est * 1e3:.1f}ms at the current rate) "
                         f"exceeds remaining budget "
                         f"{remaining * 1e3:.1f}ms of deadline_ms={d:g}")
                return False
        return True

    def _reject_deadline(self, req: _Request, msg: str) -> None:
        with self.stats_lock:
            self.counters["shed_deadline"] += 1
        self._resolve_error(req, DeadlineError(msg))

    def _run_unit(self, unit: list[_Request],
                  inflight_cost: float = 0.0) -> None:
        try:
            self._run_unit_inner(unit)
        finally:
            if inflight_cost:
                with self._lock:
                    self._inflight_cost = max(
                        0.0, self._inflight_cost - inflight_cost)

    def _run_unit_inner(self, unit: list[_Request]) -> None:
        try:
            now = time.perf_counter()
            live = [r for r in unit if self._check_deadline(r, now)]
            if not live:
                return
            t0 = time.perf_counter()
            results = self._execute(live)
            wall = time.perf_counter() - t0
            self._learn(live, results, wall)
            for req, res in zip(live, results):
                if not req.future.done():
                    req.future.set_result(res)
            with self.stats_lock:
                self.counters["executed"] += len(live)
                self.counters["groups"] += 1
                if len(live) > 1:
                    self.counters["co_batched_docs"] += len(live)
                self.counters["max_group"] = max(
                    self.counters["max_group"], len(live))
        except Exception as e:  # pragma: no cover - defensive backstop
            for req in unit:
                self._resolve_error(req, e)

    def _execute(self, live: list[_Request]) -> "list[QueryResult]":
        svc = self.service
        groupable = [r for r in live if r.compiled.point_group is not None]
        if len(groupable) == len(live) and len(live) > 1:
            return svc.run_group([r.compiled for r in live],
                                 on_error="envelope")
        out = []
        for r in live:
            try:
                out.append(svc._execute(r.compiled))
            except Exception as e:
                out.append(svc._error_result(r.doc, e))
        return out

    def _learn(self, live: list[_Request],
               results: "list[QueryResult]", wall: float) -> None:
        """Update the cost model from an executed unit."""
        cost = 0.0
        points = 0
        for req, res in zip(live, results):
            if res.ok:
                cost += float(res.stats.get("plan_cost", 0.0) or 0.0)
                points += len(req.compiled.point_times)
        if wall <= 0:
            return
        if cost > 0:
            self.cost_rate.update(cost / wall)
            if points:
                self.point_cost.update(cost / points)
        elif points == 0 and live:
            self.solo_s.update(wall / len(live))

    # ------------------------------------------------------- synchronous path
    def run_wave(self, items: Sequence[Any]) -> "list[QueryResult]":
        """Synchronously execute one arrival wave — the stdin fallback's
        chunk loop.  ``items`` are :class:`GraphQuery` documents or
        already-made :class:`QueryResult` error envelopes (malformed
        lines); results come back in input order.  Grouping matches the
        async dispatcher's (and ``run_batch``'s) co-batching key."""
        from .service import QueryResult
        results: list[Any] = [None] * len(items)
        reqs: list[tuple[int, _Request]] = []
        arrival = time.perf_counter()
        for i, item in enumerate(items):
            if isinstance(item, QueryResult):
                results[i] = item
                continue
            try:
                cq = self.service.compiler.compile(item)
            except Exception as e:
                results[i] = self.service._error_result(item, e)
                continue
            reqs.append((i, _Request(item, cq, arrival)))
        groups: dict[tuple, list[tuple[int, _Request]]] = {}
        solos: list[tuple[int, _Request]] = []
        for i, r in reqs:
            key = r.compiled.point_group
            if key is None:
                solos.append((i, r))
            else:
                groups.setdefault(key, []).append((i, r))
        now = time.perf_counter()
        for unit in list(groups.values()) + [[s] for s in solos]:
            live = [(i, r) for i, r in unit
                    if self._check_deadline(r, now)]
            for i, r in unit:
                if r.future.done():     # deadline-rejected above
                    results[i] = r.future.result()
            if not live:
                continue
            t0 = time.perf_counter()
            res = self._execute([r for _, r in live])
            self._learn([r for _, r in live], res,
                        time.perf_counter() - t0)
            for (i, _), rr in zip(live, res):
                results[i] = rr
        return results

    # ---------------------------------------------------------------- stats
    def snapshot_stats(self) -> dict:
        with self.stats_lock:
            out = dict(self.counters)
        out["cost_rate_units_per_s"] = self.cost_rate.value
        out["point_cost_units"] = self.point_cost.value
        with self._lock:
            out["queue_depth"] = len(self._queue)
            out["inflight_cost"] = self._inflight_cost
        return out

    # ---------------------------------------------------------------- errors
    def _resolve_error(self, req: _Request, e: Exception) -> None:
        if not req.future.done():
            req.future.set_result(
                self.service._error_result(req.doc, e))
