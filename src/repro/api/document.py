"""The declarative GraphQuery document (schema v1) and its fluent builder.

A :class:`GraphQuery` is the *serializable* form of every retrieval and
analytics request the system answers — the wire protocol a client puts on
a socket, a queue, or a file.  One document, one ``kind``:

======================  ====================================================
kind                    fields
======================  ====================================================
``snapshot``            ``t``  — the paper's ``GetHistGraph(t)``
``multipoint``          ``times`` — batched retrieval (one Steiner plan)
``expr``                ``expr`` (infix TimeExpression) + ``times``
``interval``            ``ts``, ``te`` — elements added during ``[ts, te)``
``evolve``              ``times`` + ``op`` (+ ``op_kwargs``,
                        ``incremental``) — temporal analytics
======================  ====================================================

Common fields: ``attrs`` (an attr_options spec string, Table 1),
``use_current`` (may the planner route through the live current graph),
``no_cache`` (consistency hint: bypass the snapshot cache), ``reply``
(``"summary"``, ``"full"``, or — under the socket server — ``"lease"``:
overlay the result in the GraphPool and return lease gids instead of
slot lists), ``id`` (opaque client correlation token, echoed verbatim in
the result envelope — the cross-wiring oracle under concurrent serving),
``deadline_ms`` (SLO budget from arrival; the scheduler rejects the
request with a typed ``deadline`` error envelope once the planner's cost
estimate says it cannot be met — see ``api/scheduler.py``), ``v``
(schema version, currently 1).

``GraphQuery.from_dict`` / :meth:`GraphQuery.to_dict` round-trip the JSON
form losslessly (property-tested in ``tests/test_api.py``); malformed
documents raise :class:`~repro.core.errors.DocumentError` with the
offending field name as ``position``.

Programmatic construction goes through :class:`Q`::

    Q.at(1966).attrs("+node:papers").build()
    Q.at(1963, 1969, 1973).build()                      # multipoint
    Q.expr("t0 & ~t1", [1969, 1973]).build()
    Q.between(1970, 1973).build()                       # interval
    Q.between(ts, te).compute("pagerank").build()       # evolve
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Sequence

from ..core.errors import DocumentError
from ..core.query import AttrOptions, TimeExpression

SCHEMA_VERSION = 1

KINDS = ("snapshot", "multipoint", "expr", "interval", "evolve")

# fields meaningful per kind (beyond the common ones); anything else set to
# a non-default value makes the document invalid — strictness keeps the
# wire form canonical and the JSON round-trip exact
_KIND_FIELDS = {
    "snapshot": ("t",),
    "multipoint": ("times",),
    "expr": ("expr", "times"),
    "interval": ("ts", "te"),
    "evolve": ("times", "op", "op_kwargs", "incremental"),
}
_COMMON_FIELDS = ("attrs", "use_current", "no_cache", "reply", "id",
                  "deadline_ms")
_ALL_FIELDS = ("kind", "v", "t", "times", "ts", "te", "expr", "op",
               "op_kwargs", "incremental") + _COMMON_FIELDS


def _as_int(v: Any, field: str) -> int:
    if isinstance(v, bool) or not isinstance(v, (int, float)) or int(v) != v:
        raise DocumentError(f"field {field!r} must be an integer, "
                            f"got {v!r}", position=field)
    return int(v)


@dataclasses.dataclass(frozen=True)
class GraphQuery:
    """One serializable query document (see module docstring).

    ``attrs`` is normally an attr_options spec *string*; legacy
    programmatic callers may pass a pre-parsed
    :class:`~repro.core.query.AttrOptions` (and ``op`` an
    :class:`~repro.core.temporal.EvolveOp` instance or callable) — such
    documents execute normally but refuse to serialize."""

    kind: str
    t: int | None = None
    times: tuple[int, ...] | None = None
    ts: int | None = None
    te: int | None = None
    expr: str | None = None
    op: Any = None
    op_kwargs: dict = dataclasses.field(default_factory=dict)
    attrs: Any = ""
    use_current: bool = True
    no_cache: bool = False
    reply: str = "summary"
    v: int = SCHEMA_VERSION
    incremental: bool = True
    id: Any = None
    deadline_ms: float | None = None

    def __post_init__(self) -> None:
        # normalize so that equality and the JSON round-trip are canonical
        if self.times is not None:
            seq = (self.times if isinstance(self.times, (list, tuple))
                   else [self.times])
            norm = [_as_int(x, "times") for x in seq]
            if self.kind != "expr":   # expr indices (t0, t1, ...) are
                norm = list(dict.fromkeys(norm))  # positional — keep dups
            object.__setattr__(self, "times", tuple(norm))
        for f in ("t", "ts", "te"):
            val = getattr(self, f)
            if val is not None:
                object.__setattr__(self, f, _as_int(val, f))

    # -- validation ---------------------------------------------------------
    def validate(self) -> "GraphQuery":
        """Structural validation (kind, required/forbidden fields, basic
        types).  Semantic validation — attribute names against a universe,
        TimeExpression syntax, operator registry — happens in the
        compiler.  Returns ``self`` so call sites can chain."""
        if self.v != SCHEMA_VERSION:
            raise DocumentError(f"unsupported document version {self.v!r} "
                                f"(this build speaks v{SCHEMA_VERSION})",
                                position="v")
        if self.kind not in KINDS:
            raise DocumentError(f"unknown query kind {self.kind!r}; "
                                f"choose from {list(KINDS)}", position="kind")
        allowed = set(_KIND_FIELDS[self.kind])
        for f in ("t", "times", "ts", "te", "expr", "op"):
            if f not in allowed and getattr(self, f) is not None:
                raise DocumentError(
                    f"field {f!r} does not apply to kind {self.kind!r}",
                    position=f)
        if "op_kwargs" not in allowed and self.op_kwargs:
            raise DocumentError("field 'op_kwargs' only applies to evolve "
                                "documents", position="op_kwargs")
        if self.kind == "snapshot" and self.t is None:
            raise DocumentError("snapshot document needs 't'", position="t")
        if self.kind in ("multipoint", "expr", "evolve") and not self.times:
            raise DocumentError(f"{self.kind} document needs a non-empty "
                                f"'times' list", position="times")
        if self.kind == "expr":
            if not isinstance(self.expr, str) or not self.expr.strip():
                raise DocumentError("expr document needs a TimeExpression "
                                    "infix string in 'expr'", position="expr")
        if self.kind == "interval":
            if self.ts is None or self.te is None:
                raise DocumentError("interval document needs 'ts' and 'te'",
                                    position="ts" if self.ts is None else "te")
        if self.kind == "evolve" and not isinstance(self.op_kwargs, dict):
            raise DocumentError("'op_kwargs' must be an object",
                                position="op_kwargs")
        if self.kind != "evolve" and self.incremental is not True:
            raise DocumentError("field 'incremental' only applies to "
                                "evolve documents", position="incremental")
        if self.reply not in ("summary", "full", "lease"):
            raise DocumentError(f"'reply' must be 'summary', 'full' or "
                                f"'lease', got {self.reply!r}",
                                position="reply")
        if self.reply == "lease" and self.kind not in ("snapshot",
                                                       "multipoint", "expr"):
            raise DocumentError(f"reply='lease' only applies to state-"
                                f"returning kinds, not {self.kind!r}",
                                position="reply")
        for f in ("use_current", "no_cache", "incremental"):
            if not isinstance(getattr(self, f), bool):
                raise DocumentError(f"field {f!r} must be a boolean",
                                    position=f)
        if self.id is not None and not isinstance(self.id, (str, int)):
            raise DocumentError("'id' must be a string or integer",
                                position="id")
        if isinstance(self.id, bool):
            raise DocumentError("'id' must be a string or integer",
                                position="id")
        if self.deadline_ms is not None:
            d = self.deadline_ms
            if isinstance(d, bool) or not isinstance(d, (int, float)) \
                    or not d > 0:
                raise DocumentError("'deadline_ms' must be a positive "
                                    "number", position="deadline_ms")
        return self

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        """Canonical wire dict: ``v`` + ``kind`` + the kind's fields, with
        common fields included only when they differ from the default.
        Documents carrying non-serializable programmatic payloads
        (AttrOptions / EvolveOp instances) raise
        :class:`~repro.core.errors.DocumentError`."""
        self.validate()
        if not isinstance(self.attrs, str):
            raise DocumentError(
                "document holds a pre-parsed AttrOptions; only attr-spec "
                "strings serialize — build with the spec string instead",
                position="attrs")
        out: dict[str, Any] = {"v": self.v, "kind": self.kind}
        for f in _KIND_FIELDS[self.kind]:
            val = getattr(self, f)
            if f == "op":
                if val is None:
                    continue
                if not isinstance(val, str):
                    raise DocumentError(
                        "only named operators serialize; EvolveOp instances "
                        "and callables are programmatic-only", position="op")
            if f == "op_kwargs" and not val:
                continue
            if f == "times":
                val = list(val)
            out[f] = val
        defaults = {"attrs": "", "use_current": True, "no_cache": False,
                    "reply": "summary", "id": None, "deadline_ms": None}
        for f, dflt in defaults.items():
            if getattr(self, f) != dflt:
                out[f] = getattr(self, f)
        return out

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_dict(cls, d: Any) -> "GraphQuery":
        if not isinstance(d, dict):
            raise DocumentError(f"query document must be a JSON object, "
                                f"got {type(d).__name__}")
        unknown = set(d) - set(_ALL_FIELDS)
        if unknown:
            raise DocumentError(f"unknown document field(s) "
                                f"{sorted(unknown)}",
                                position=sorted(unknown)[0])
        if "kind" not in d:
            raise DocumentError("document needs a 'kind'", position="kind")
        kw = dict(d)
        kind = kw.pop("kind")
        if not isinstance(kind, str):
            raise DocumentError("'kind' must be a string", position="kind")
        if "op_kwargs" in kw and kw["op_kwargs"] is None:
            kw.pop("op_kwargs")
        if kind == "evolve" and kw.get("op") is None:
            kw["op"] = "masks"     # the engine's default operator
        if "attrs" in kw and not isinstance(kw["attrs"], str):
            raise DocumentError("'attrs' must be an attr_options spec "
                                "string on the wire", position="attrs")
        try:
            doc = cls(kind=kind, **kw)
        except TypeError as e:  # pragma: no cover - guarded by unknown check
            raise DocumentError(str(e)) from e
        return doc.validate()

    @classmethod
    def from_json(cls, text: str) -> "GraphQuery":
        try:
            d = json.loads(text)
        except json.JSONDecodeError as e:
            raise DocumentError(f"invalid JSON: {e.msg}",
                                position=e.pos) from e
        return cls.from_dict(d)

    # -- helpers ------------------------------------------------------------
    def time_expression(self) -> TimeExpression:
        """Parse ``expr`` against ``times`` (expr documents only)."""
        return TimeExpression.parse(self.expr, list(self.times))


# ---------------------------------------------------------------------------
# fluent builder
# ---------------------------------------------------------------------------


class _Builder:
    """Accumulates fields; :meth:`build` produces a validated document."""

    def __init__(self, **fields: Any) -> None:
        self._f = fields

    def _set(self, **kw: Any) -> "_Builder":
        self._f.update(kw)
        return self

    def attrs(self, spec: str | AttrOptions) -> "_Builder":
        """Attribute selection — a Table-1 spec string like
        ``"+node:all-node:salary"`` (or a pre-parsed AttrOptions for
        programmatic, non-wire use)."""
        return self._set(attrs=spec)

    def use_current(self, flag: bool = True) -> "_Builder":
        return self._set(use_current=bool(flag))

    def fresh(self) -> "_Builder":
        """Consistency hint: bypass the snapshot cache for this query."""
        return self._set(no_cache=True)

    def full(self) -> "_Builder":
        """Request the full (slot-list) result payload on the wire."""
        return self._set(reply="full")

    def lease(self) -> "_Builder":
        """Request a GraphPool lease instead of a payload: the server
        overlays the retrieved snapshot(s) and returns lease gids the
        session holds (and must ``release``) — see ``launch/server.py``."""
        return self._set(reply="lease")

    def tag(self, id: str | int) -> "_Builder":
        """Attach a client correlation ``id``, echoed in the envelope."""
        return self._set(id=id)

    def deadline(self, ms: float) -> "_Builder":
        """SLO budget in milliseconds from arrival; the serving scheduler
        sheds the request with a ``deadline`` error envelope rather than
        executing it late (``api/scheduler.py``)."""
        return self._set(deadline_ms=float(ms))

    def compute(self, op: Any, *, incremental: bool = True,
                **op_kwargs: Any) -> "_Builder":
        """Turn the query into an evolve (temporal-analytics) document
        running ``op`` over its timepoints.  On a ``between(ts, te)``
        builder the window is sampled at up to 32 evenly spaced integer
        timepoints unless :meth:`step` / :meth:`points` chose otherwise."""
        f = self._f
        if f.get("kind") == "snapshot":
            f["times"] = (f.pop("t"),)
        if f.get("kind") == "interval":
            ts, te = f.pop("ts"), f.pop("te")
            step = f.pop("_step", None)
            npts = f.pop("_points", None)
            if step is not None:
                times = tuple(range(ts, te + 1, max(int(step), 1)))
            else:
                n = min(te - ts + 1, int(npts) if npts else 32)
                n = max(n, 1)
                times = tuple(dict.fromkeys(
                    ts + round(i * (te - ts) / max(n - 1, 1))
                    for i in range(n)))
            f["times"] = times
        return self._set(kind="evolve", op=op, op_kwargs=dict(op_kwargs),
                         incremental=bool(incremental))

    def step(self, dt: int) -> "_Builder":
        """Sample a ``between`` window every ``dt`` time units (only
        meaningful before :meth:`compute`)."""
        return self._set(_step=int(dt))

    def points(self, n: int) -> "_Builder":
        """Sample a ``between`` window at ``n`` evenly spaced timepoints
        (only meaningful before :meth:`compute`)."""
        return self._set(_points=int(n))

    def build(self) -> GraphQuery:
        f = {k: v for k, v in self._f.items() if not k.startswith("_")}
        return GraphQuery(**f).validate()


class Q:
    """Entry points of the fluent builder (see module docstring)."""

    @staticmethod
    def at(*times: int | Sequence[int]) -> _Builder:
        """``Q.at(t)`` → snapshot; ``Q.at(t1, t2, ...)`` or
        ``Q.at([t1, t2])`` → multipoint."""
        flat: list[int] = []
        for t in times:
            if isinstance(t, (list, tuple)):
                flat.extend(int(x) for x in t)
            else:
                flat.append(int(t))
        if not flat:
            raise DocumentError("Q.at() needs at least one timepoint",
                                position="times")
        if len(flat) == 1:
            return _Builder(kind="snapshot", t=flat[0])
        return _Builder(kind="multipoint", times=tuple(flat))

    @staticmethod
    def between(ts: int, te: int) -> _Builder:
        """``[ts, te)`` interval query; chain :meth:`_Builder.compute` to
        make it an evolve document over the window instead."""
        return _Builder(kind="interval", ts=int(ts), te=int(te))

    @staticmethod
    def expr(text: str, times: Sequence[int]) -> _Builder:
        """Boolean TimeExpression over ``times``, e.g.
        ``Q.expr("t0 & ~t1", [1969, 1973])``."""
        return _Builder(kind="expr", expr=str(text),
                        times=tuple(int(t) for t in times))

    @staticmethod
    def evolve(times: Sequence[int], op: Any = "masks",
               **op_kwargs: Any) -> _Builder:
        """Evolve document over explicit timepoints."""
        return _Builder(kind="evolve", times=tuple(int(t) for t in times),
                        op=op, op_kwargs=dict(op_kwargs))
