"""Declarative query API: serializable GraphQuery documents, one compiler
onto the retrieval-plan IR, and the request-serving execution service.

Public surface:

* :class:`~repro.api.document.GraphQuery` — the versioned,
  JSON-serializable query document (the wire protocol);
* :class:`~repro.api.document.Q` — the fluent builder
  (``Q.at(t).attrs("+node:all").build()``);
* :class:`~repro.api.compiler.QueryCompiler` — lowers every document kind
  onto the plan IR / batched executor / temporal engine;
* :class:`~repro.api.service.QueryService` — executes documents (and
  merges co-batched point documents into one Steiner plan), producing
  :class:`~repro.api.service.QueryResult` envelopes with execution stats;
* the typed error taxonomy re-exported from :mod:`repro.core.errors`.

Reach the service through ``GraphManager.query``; every legacy
``GraphManager`` entry point is a thin shim over it.
"""
from ..core.errors import (AttrOptionsError, DocumentError,  # noqa: F401
                           ExecutionError, QueryError, TimeExpressionError,
                           UnknownAttributeError, UnknownOperatorError)
from .compiler import CompiledQuery, QueryCompiler  # noqa: F401
from .document import SCHEMA_VERSION, GraphQuery, Q  # noqa: F401
from .service import QueryResult, QueryService  # noqa: F401
