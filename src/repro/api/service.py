"""QueryService: execute GraphQuery documents with a uniform result
envelope.

``run(doc)`` compiles and executes one document; ``run_batch(docs)``
additionally *merges* co-batched point documents (snapshot / multipoint /
expr sharing attr options + consistency hints) into **one** Steiner plan,
the multi-query optimization ``GraphManager.get_snapshots`` applies to a
plain time batch — here applied across whole documents arriving on the
wire.

Every execution returns a :class:`QueryResult` carrying the payload plus
execution stats: KV gets/bytes (store-counter deltas — exact single-
threaded, best-effort attribution under concurrent serving), planner cost
(decode-aware ``α·stored + β·logical`` units), snapshot-cache hits, and
wall time.  ``to_dict()``/``to_json()`` render the JSON wire envelope::

    {"v": 1, "ok": true, "kind": "multipoint",
     "result": {"points": [{"t": 50, "nodes": 132, "edges": 410,
                            "node_crc": 2186839876, ...}]},
     "stats": {"wall_s": 0.003, "kv_gets": 12, "kv_bytes": 18944,
               "plan_cost": 25310.0, "cache_hits": 0, "merged_docs": 2}}

Errors become ``{"ok": false, "error": {"kind": ..., "message": ...,
"position": ...}}`` envelopes via the typed taxonomy
(:mod:`repro.core.errors`).

The retrieval core (:meth:`QueryService.retrieve_points`) is the single
implementation of cached + advised + batched snapshot retrieval; the
legacy ``GraphManager.get_snapshot(s)`` entry points are thin shims over
it, so results stay bit-identical across the old and new surfaces
(``tests/test_query_service.py``).
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import TYPE_CHECKING, Any, Sequence

import numpy as np

from ..core.errors import ExecutionError, QueryError
from ..core.materialize import SnapshotCache
from ..core.query import AttrOptions
from .compiler import CompiledQuery, QueryCompiler
from .document import GraphQuery

if TYPE_CHECKING:  # pragma: no cover
    from ..core.events import MaterializedState
    from ..core.manager import GraphManager
    from ..core.temporal import EvolveResult, TemporalEngine


# ---------------------------------------------------------------------------
# result envelope
# ---------------------------------------------------------------------------


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


def _state_payload(st: "MaterializedState", full: bool,
                   with_attrs: bool = False) -> dict:
    """Wire form of a MaterializedState: counts + CRCs (summary) or live
    slot lists (full) — full bitmaps don't belong in a JSON envelope.
    ``attr_crc`` is computed only when the document fetched attributes
    (hashing all-NaN padding would cost more than the whole retrieval)."""
    out = {"nodes": int(st.node_mask.sum()),
           "edges": int(st.edge_mask.sum()),
           "node_crc": _crc(np.packbits(st.node_mask)),
           "edge_crc": _crc(np.packbits(st.edge_mask))}
    if with_attrs:
        out["attr_crc"] = _crc(st.node_attrs) ^ _crc(st.edge_attrs)
    if full:
        out["node_slots"] = np.nonzero(st.node_mask)[0].tolist()
        out["edge_slots"] = np.nonzero(st.edge_mask)[0].tolist()
    return out


def _jsonable(v: Any, full: bool) -> Any:
    """Best-effort JSON projection of an operator value: arrays summarize
    to size+CRC unless ``full``."""
    if isinstance(v, np.ndarray):
        if full:
            return v.tolist()
        return {"size": int(v.size), "dtype": str(v.dtype), "crc": _crc(v)}
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, dict):
        return {str(k): _jsonable(x, full) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x, full) for x in v]
    return v


@dataclasses.dataclass
class QueryResult:
    """Uniform result envelope: payload + execution stats (+ error)."""

    kind: str | None
    ok: bool
    value: Any
    stats: dict
    error: QueryError | None = None
    query: GraphQuery | None = None

    def _payload(self, full: bool) -> Any:
        v = self.value
        q = self.query
        wa = bool(q is not None and (q.attrs.wants_attrs
                                     if isinstance(q.attrs, AttrOptions)
                                     else q.attrs))
        if self.kind == "snapshot":
            return dict(t=q.t if q else None, **_state_payload(v, full, wa))
        if self.kind == "multipoint":
            return {"points": [dict(t=int(t), **_state_payload(st, full, wa))
                               for t, st in v.items()]}
        if self.kind == "expr":
            return dict(expr=q.expr if q else None,
                        times=list(q.times) if q else None,
                        **_state_payload(v, full, wa))
        if self.kind == "interval":
            return {k: np.asarray(a).tolist() for k, a in v.items()}
        if self.kind == "evolve":
            return {"times": [int(t) for t in v.times],
                    "incremental": bool(v.stats.get("incremental", True)),
                    "values": [_jsonable(x, full) for x in v.values],
                    "engine_stats": _jsonable(v.stats, False)}
        return _jsonable(v, full)

    def to_dict(self) -> dict:
        if not self.ok:
            out = {"v": 1, "ok": False, "kind": self.kind,
                   "error": self.error.to_dict()}
        else:
            full = bool(self.query is not None
                        and self.query.reply == "full")
            out = {"v": 1, "ok": True, "kind": self.kind,
                   "result": self._payload(full),
                   "stats": _jsonable(self.stats, False)}
        # correlation id echo (cross-wiring oracle under concurrent
        # serving): every envelope names the request it answers
        if self.query is not None and self.query.id is not None:
            out["id"] = self.query.id
        return out

    def to_json(self) -> str:
        import json
        return json.dumps(self.to_dict(), sort_keys=True)


# ---------------------------------------------------------------------------
# the service
# ---------------------------------------------------------------------------


class _StatClock:
    """Wall + KV-counter delta around one execution (best-effort under
    concurrency: store counters are process-global)."""

    def __init__(self, store) -> None:
        self._store = store
        self.g0 = store.stats.gets
        self.b0 = store.stats.bytes_read
        self.t0 = time.perf_counter()

    def done(self) -> dict:
        return {"wall_s": time.perf_counter() - self.t0,
                "kv_gets": self._store.stats.gets - self.g0,
                "kv_bytes": self._store.stats.bytes_read - self.b0}


class QueryService:
    """Runs GraphQuery documents against one :class:`GraphManager`."""

    def __init__(self, gm: "GraphManager") -> None:
        self.gm = gm
        self.compiler = QueryCompiler(gm.universe)

    # -- engines ------------------------------------------------------------
    def temporal_engine(self) -> "TemporalEngine":
        if self.gm._temporal is None:
            from ..core.temporal import TemporalEngine
            self.gm._temporal = TemporalEngine(self.gm)
        return self.gm._temporal

    # -- the single snapshot-retrieval implementation ------------------------
    def retrieve_points(self, times: Sequence[int], options: AttrOptions,
                        use_current: bool = True, no_cache: bool = False,
                        pin=None,
                        ) -> tuple[dict[int, "MaterializedState"], dict]:
        """Cached + advised + batched retrieval of ``times``: cache hits
        split off, misses become one merged Steiner plan executed with
        async KV prefetch.  Returns ``(states, stats)``; results are
        bit-identical to a cold ``DeltaGraph.get_snapshot`` per point.

        The whole call resolves against one epoch-pinned index version
        (``core/epoch.py``): the caller's ``pin`` if given (so a document
        retrieves and finishes on the same version), else one acquired
        here.  Cache keys carry an epoch tag — results at times below the
        ingest watermark are stable across epochs, results at/past it
        (plans crossing CURRENT / the unfolded tail) only hit within the
        epoch that produced them."""
        gm = self.gm
        times = [int(t) for t in dict.fromkeys(int(t) for t in times)]
        own_pin = pin is None
        if own_pin:
            pin = gm.epochs.acquire()
        try:
            dg = pin.data.dg
            watermark = pin.data.max_time

            def key_for(t: int) -> tuple:
                tag = (SnapshotCache.STABLE if t < watermark else pin.id)
                return SnapshotCache.key(t, options, use_current, tag)

            out: dict[int, "MaterializedState"] = {}
            stats = {"cache_hits": 0, "plan_cost": 0.0, "payload_fetches": 0,
                     "plan_steps": 0, "epoch": pin.id,
                     "epoch_events": pin.data.n_events}
            misses: list[int] = []
            for t in times:
                if gm.cache is not None and not no_cache:
                    hit = gm.cache.get(key_for(t))
                    if hit is not None:
                        gm.workload.record_cache_hit()
                        stats["cache_hits"] += 1
                        # live ingest may have grown the slot universe
                        # since the entry was cached
                        out[t] = hit.resized(gm.universe)
                        continue
                misses.append(t)
            if misses:
                plan = dg.plan_multipoint(misses, options, use_current)
                if gm.sharded is not None:
                    # sharded multi-worker path (runtime/shard.py): scatter
                    # the merged plan across the shard-executor pool and
                    # gather the per-shard slot results — bit-identical to
                    # the unsharded execution below
                    states = gm.sharded.execute(dg, plan, options,
                                                pool=gm.pool)
                    stats.update({f"shard_{k}": v for k, v in
                                  gm.sharded.last_stats.items()})
                else:
                    # prefetch for batch-shaped queries (even when cache
                    # hits leave a single miss) — legacy ``get_snapshots``
                    # parity; a lone singlepoint query stays synchronous
                    # (``get_snapshot`` parity: thread-queue latency beats
                    # overlap on fast stores)
                    pf = gm.prefetcher if len(times) > 1 else None
                    states = dg.execute(plan, options, pool=gm.pool,
                                        prefetch=pf)
                # per-target deps: only the pins on a target's own branch
                # invalidate its entry, not every pin the batch touched
                deps = plan.per_target_source_nids()
                for t in misses:
                    out[t] = states[t]
                    if gm.cache is not None:
                        gm.cache.put(key_for(t), states[t], deps=deps.get(t))
                cs = plan.cost_summary()
                stats["plan_cost"] += cs["plan_cost"]
                stats["payload_fetches"] += cs["payload_fetches"]
                stats["plan_steps"] += cs["plan_steps"]
                if gm.advisor is not None:
                    with gm._advisor_lock:
                        if gm.advisor is not None:
                            gm.advisor.on_query(n=len(misses))
            return out, stats
        finally:
            if own_pin:
                pin.release()

    # -- execution ----------------------------------------------------------
    def _execute(self, cq: CompiledQuery) -> QueryResult:
        clock = _StatClock(self.gm.store)
        pts = cq.point_times
        # one pin for the whole document: retrieval and finish() (interval /
        # evolve engine calls included) resolve against one index version
        with self.gm.epochs.acquire() as pin:
            if pts:
                states, rstats = self.retrieve_points(
                    pts, cq.options, cq.doc.use_current, cq.doc.no_cache,
                    pin=pin)
                value = cq.finish(self, states, dg=pin.data.dg)
            else:
                rstats = {"epoch": pin.id, "epoch_events": pin.data.n_events}
                value = cq.finish(self, None, dg=pin.data.dg)
        stats = {**clock.done(), **rstats, "targets": len(pts)}
        return QueryResult(cq.kind, True, value, stats, query=cq.doc)

    def run(self, doc: GraphQuery) -> QueryResult:
        """Compile + execute one document.  Raises typed
        :class:`~repro.core.errors.QueryError` subclasses on bad
        documents; execution exceptions propagate unchanged (the legacy
        shims depend on that).  Use :meth:`run_safe` /
        ``run_batch(on_error="envelope")`` for wire serving."""
        return self._execute(self.compiler.compile(doc))

    def run_safe(self, doc: GraphQuery) -> QueryResult:
        """Like :meth:`run` but never raises: any failure becomes an
        error envelope (non-QueryError exceptions wrapped as
        :class:`~repro.core.errors.ExecutionError`)."""
        try:
            return self.run(doc)
        except Exception as e:
            return self._error_result(doc, e)

    @staticmethod
    def _error_result(doc: Any, e: Exception) -> QueryResult:
        err = e if isinstance(e, QueryError) else ExecutionError(
            f"{type(e).__name__}: {e}")
        if not isinstance(e, QueryError):
            err.__cause__ = e
        kind = getattr(doc, "kind", None)
        q = doc if isinstance(doc, GraphQuery) else None
        return QueryResult(kind, False, None, {}, error=err, query=q)

    def run_group(self, compiled: Sequence[CompiledQuery], *,
                  on_error: str = "envelope") -> list[QueryResult]:
        """Execute co-plannable compiled documents (same
        :attr:`CompiledQuery.point_group`) as **one** merged retrieval:
        their timepoints union into one Steiner plan, then each document
        finishes from the shared states.  Response ordering is pinned to
        input order.  Failure isolation: a retrieval failure fails every
        member (the plan was shared), but a ``finish`` failure — one
        poisoned document — yields an error envelope for that document
        *only*, without dropping its groupmates' results.  Group stats are
        shared (``merged_docs`` / union ``targets``); each envelope also
        carries its own ``doc_targets`` attribution."""
        times = list(dict.fromkeys(
            t for cq in compiled for t in cq.point_times))
        try:
            clock = _StatClock(self.gm.store)
            cq0 = compiled[0]
            with self.gm.epochs.acquire() as pin:
                states, rstats = self.retrieve_points(
                    times, cq0.options, cq0.doc.use_current,
                    cq0.doc.no_cache, pin=pin)
                stats = {**clock.done(), **rstats,
                         "targets": len(times),
                         "merged_docs": len(compiled)}
                results: list[QueryResult] = []
                for cq in compiled:
                    try:
                        value = cq.finish(self, states, dg=pin.data.dg)
                    except Exception as e:
                        if on_error == "raise":
                            raise
                        results.append(self._error_result(cq.doc, e))
                        continue
                    results.append(QueryResult(
                        cq.kind, True, value,
                        dict(stats,
                             doc_targets=len(cq.point_times)),
                        query=cq.doc))
                return results
        except Exception as e:
            if on_error == "raise":
                raise
            return [self._error_result(cq.doc, e) for cq in compiled]

    def run_batch(self, docs: Sequence[GraphQuery], *,
                  on_error: str = "raise") -> list[QueryResult]:
        """Execute a batch of documents, merging co-plannable point
        documents (same attr options / ``use_current`` / ``no_cache``)
        into one Steiner plan per group.  Results come back in input
        order; grouped documents share the group's stats (tagged with
        ``merged_docs``).  ``on_error="envelope"`` turns per-document
        failures into error envelopes instead of raising (a bad document
        never poisons the rest of the batch)."""
        if on_error not in ("raise", "envelope"):
            raise ValueError(f"on_error must be 'raise' or 'envelope', "
                             f"got {on_error!r}")
        results: list[QueryResult | None] = [None] * len(docs)
        compiled: dict[int, CompiledQuery] = {}
        for i, doc in enumerate(docs):
            try:
                compiled[i] = self.compiler.compile(doc)
            except Exception as e:
                if on_error == "raise":
                    raise
                results[i] = self._error_result(doc, e)
        groups: dict[tuple, list[int]] = {}
        solo: list[int] = []
        for i, cq in compiled.items():
            key = cq.point_group
            if key is None:
                solo.append(i)
            else:
                groups.setdefault(key, []).append(i)
        for idxs in groups.values():
            group_res = self.run_group([compiled[i] for i in idxs],
                                       on_error=on_error)
            for i, res in zip(idxs, group_res):
                results[i] = res
        for i in solo:
            try:
                results[i] = self._execute(compiled[i])
            except Exception as e:
                if on_error == "raise":
                    raise
                results[i] = self._error_result(docs[i], e)
        return results  # type: ignore[return-value]
