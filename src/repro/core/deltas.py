"""Delta algebra (paper §4.2): columnar, bidirectional deltas.

A delta ``Δ(target, source)`` holds what must change to turn *source* into
*target*.  It is stored **columnar** (paper's key optimization): the
``struct`` component (node/edge membership changes) is separate from the
``nodeattr`` / ``edgeattr`` components, so structure-only retrievals never
fetch attribute bytes.  Deltas are bidirectional — attribute triplets carry
both the target value and the source value — which is what lets the planner
traverse skeleton edges in either direction (leaf eventlists are likewise
bidirectional, §3.1).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .events import (EV_DEL_EDGE, EV_DEL_NODE, EV_NEW_EDGE, EV_NEW_NODE,
                     EV_UPD_EDGE_ATTR, EV_UPD_NODE_ATTR, EventList,
                     MaterializedState)


@dataclasses.dataclass
class AttrDelta:
    """Sparse attribute changes: set ``attrs[slot, col] = new`` going
    forward, ``= old`` going backward.  Rows are ordered by application
    order (later rows win)."""

    slot: np.ndarray  # int32[M]
    col: np.ndarray   # int16[M]
    new: np.ndarray   # float32[M]
    old: np.ndarray   # float32[M]

    @staticmethod
    def empty() -> "AttrDelta":
        return AttrDelta(np.zeros(0, np.int32), np.zeros(0, np.int16),
                         np.zeros(0, np.float32), np.zeros(0, np.float32))

    def __len__(self) -> int:
        return int(self.slot.shape[0])

    def nbytes(self) -> int:
        return self.slot.nbytes + self.col.nbytes + self.new.nbytes + self.old.nbytes

    def select_cols(self, cols: np.ndarray | None) -> "AttrDelta":
        if cols is None:
            return self
        m = np.isin(self.col, cols.astype(self.col.dtype))
        return AttrDelta(self.slot[m], self.col[m], self.new[m], self.old[m])

    def nbytes_cols(self, cols: np.ndarray | None) -> int:
        if cols is None:
            return self.nbytes()
        m = np.isin(self.col, cols.astype(self.col.dtype))
        per_row = 4 + 2 + 4 + 4
        return int(m.sum()) * per_row


@dataclasses.dataclass
class Delta:
    """Columnar delta.  ``node_add``/... are sorted unique int32 slot arrays."""

    node_add: np.ndarray
    node_del: np.ndarray
    edge_add: np.ndarray
    edge_del: np.ndarray
    node_attr: AttrDelta
    edge_attr: AttrDelta

    @staticmethod
    def empty() -> "Delta":
        z = np.zeros(0, np.int32)
        return Delta(z, z, z, z, AttrDelta.empty(), AttrDelta.empty())

    # -- size accounting (skeleton edge weights, §4.3) ------------------------
    def struct_nbytes(self) -> int:
        return (self.node_add.nbytes + self.node_del.nbytes
                + self.edge_add.nbytes + self.edge_del.nbytes)

    def nbytes(self) -> int:
        return self.struct_nbytes() + self.node_attr.nbytes() + self.edge_attr.nbytes()

    def struct_count(self) -> int:
        return (self.node_add.size + self.node_del.size
                + self.edge_add.size + self.edge_del.size)

    def invert(self) -> "Delta":
        return Delta(self.node_del, self.node_add, self.edge_del, self.edge_add,
                     AttrDelta(self.node_attr.slot[::-1], self.node_attr.col[::-1],
                               self.node_attr.old[::-1], self.node_attr.new[::-1]),
                     AttrDelta(self.edge_attr.slot[::-1], self.edge_attr.col[::-1],
                               self.edge_attr.old[::-1], self.edge_attr.new[::-1]))


def state_diff(target: MaterializedState, source: MaterializedState) -> Delta:
    """Δ(target, source): elements of ``source`` to delete (source−target)
    and to add (target−source), plus attribute corrections.

    Attribute rows are *symmetric canonical*: a row ``(slot, col, new, old)``
    is emitted wherever the canonical values (the matrix value for live
    slots, NaN for dead slots) differ between the two sides.  This makes
    every delta edge traversable in both directions even across liveness
    changes (dying slots carry their old values — the WAL-undo analogue),
    which the Steiner planner relies on.
    """
    node_add = np.nonzero(target.node_mask & ~source.node_mask)[0].astype(np.int32)
    node_del = np.nonzero(source.node_mask & ~target.node_mask)[0].astype(np.int32)
    edge_add = np.nonzero(target.edge_mask & ~source.edge_mask)[0].astype(np.int32)
    edge_del = np.nonzero(source.edge_mask & ~target.edge_mask)[0].astype(np.int32)

    def attr_diff(tm, sm, ta, sa) -> AttrDelta:
        if ta.size == 0:
            return AttrDelta.empty()
        tac = np.where(tm[:, None], ta, np.nan)
        sac = np.where(sm[:, None], sa, np.nan)
        diff = ~((tac == sac) | (np.isnan(tac) & np.isnan(sac)))
        slot, col = np.nonzero(diff)
        return AttrDelta(slot.astype(np.int32), col.astype(np.int16),
                         tac[slot, col].astype(np.float32),
                         sac[slot, col].astype(np.float32))

    return Delta(node_add, node_del, edge_add, edge_del,
                 attr_diff(target.node_mask, source.node_mask,
                           target.node_attrs, source.node_attrs),
                 attr_diff(target.edge_mask, source.edge_mask,
                           target.edge_attrs, source.edge_attrs))


def apply_delta(state: MaterializedState, delta: Delta,
                forward: bool = True) -> MaterializedState:
    """Apply Δ (or its inverse) to a materialized state.

    Slots *added* by the delta get their attribute rows reset to NaN first
    ("revival resets attributes"), then the delta's attribute rows are
    applied — together with symmetric canonical rows this keeps every
    reconstructed state's attribute matrix exactly canonical (dead slot ⇒
    NaN), independent of the path taken through the skeleton.
    """
    d = delta if forward else delta.invert()
    out = state.copy()
    out.node_mask[d.node_del] = False
    out.node_mask[d.node_add] = True
    out.edge_mask[d.edge_del] = False
    out.edge_mask[d.edge_add] = True
    if out.node_attrs.size:
        out.node_attrs[d.node_add] = np.nan
        out.node_attrs[d.node_del] = np.nan
    if out.edge_attrs.size:
        out.edge_attrs[d.edge_add] = np.nan
        out.edge_attrs[d.edge_del] = np.nan
    if len(d.node_attr):
        out.node_attrs[d.node_attr.slot, d.node_attr.col] = d.node_attr.new
    if len(d.edge_attr):
        out.edge_attrs[d.edge_attr.slot, d.edge_attr.col] = d.edge_attr.new
    return out


def eventlist_to_delta(ev: EventList) -> Delta:
    """Collapse an eventlist into an equivalent delta (applied forward to the
    state at the start of the list).  Membership: net effect of alternating
    add/del toggles; attributes: last write wins, first old-value is the
    source value."""
    et, sl = ev.etype, ev.slot

    def net(add_code, del_code, n_slots_hint=None):
        cnt: dict[int, int] = {}
        first: dict[int, int] = {}
        for i in np.nonzero((et == add_code) | (et == del_code))[0]:
            s = int(sl[i])
            cnt[s] = cnt.get(s, 0) + (1 if et[i] == add_code else -1)
            first.setdefault(s, 1 if et[i] == add_code else -1)
        adds = sorted(s for s, c in cnt.items() if c > 0)
        dels = sorted(s for s, c in cnt.items() if c < 0)
        return (np.asarray(adds, np.int32), np.asarray(dels, np.int32))

    node_add, node_del = net(EV_NEW_NODE, EV_DEL_NODE)
    edge_add, edge_del = net(EV_NEW_EDGE, EV_DEL_EDGE)

    def attr(code) -> AttrDelta:
        idx = np.nonzero(et == code)[0]
        if idx.size == 0:
            return AttrDelta.empty()
        lastv: dict[tuple[int, int], float] = {}
        firstold: dict[tuple[int, int], float] = {}
        for i in idx:
            k = (int(sl[i]), int(ev.attr_col[i]))
            lastv[k] = float(ev.value[i])
            firstold.setdefault(k, float(ev.old_value[i]))
        keys = sorted(lastv)
        return AttrDelta(np.asarray([k[0] for k in keys], np.int32),
                         np.asarray([k[1] for k in keys], np.int16),
                         np.asarray([lastv[k] for k in keys], np.float32),
                         np.asarray([firstold[k] for k in keys], np.float32))

    return Delta(node_add, node_del, edge_add, edge_del,
                 attr(EV_UPD_NODE_ATTR), attr(EV_UPD_EDGE_ATTR))
