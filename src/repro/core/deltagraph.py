"""DeltaGraph: the hierarchical index over a historical graph trace (§4).

Construction is a single pass over the eventlist, bottom-up like a
bulk-loaded B+-tree (§4.6): leaves are implicit snapshots every ``L``
events; every ``k`` nodes of a level get a parent whose (virtual) graph is
``f(children)`` for a pluggable differential function ``f`` (§5.2); only the
*deltas* along edges are persisted — columnar, partitioned by the node-ID
space, into a get/put KV store under ``⟨partition, delta_id, component⟩``
keys (§4.2).

The in-memory **skeleton** holds topology + byte statistics only.  Planning:

* singlepoint  → multi-source Dijkstra (super-root + every materialized
  node + the current graph are distance-0 sources) over the skeleton plus
  per-query virtual nodes (§4.3);
* multipoint   → metric-closure MST 2-approximate Steiner tree, unfolded
  onto the skeleton and pruned (§4.4); shared prefixes execute once
  (multi-query optimization).

Incremental maintenance (§6 "updates to the current graph"): new events
accumulate in a *recent* eventlist; at ``L`` events it becomes a new leaf
and the ragged right spine ("cap") is torn down and rebuilt.
"""
from __future__ import annotations

import copy
import dataclasses
import heapq
import json
import time
from typing import Any, Callable, Sequence

import numpy as np

from ..storage import columnar as col
from ..storage.kv import KVStore
from . import diff_functions
from .deltas import AttrDelta, Delta, apply_delta, state_diff
from .events import (EV_DEL_EDGE, EV_DEL_NODE, EV_NEW_EDGE, EV_NEW_NODE,
                     EventList, GraphUniverse, MaterializedState, apply_events)
from .planir import PlanBuilder, PlanIR
from .query import NO_ATTRS, AttrOptions

# every planner emits the unified retrieval-plan IR (core/planir.py);
# ``Plan`` is kept as the public name for the emitted DAG
Plan = PlanIR

SUPERROOT = 0

# Decode-aware plan cost model: traversing an edge costs
# ``α·stored_bytes + β·logical_bytes`` — fetching a payload moves its
# *stored* (compressed, at-rest) bytes over the store, while decoding it
# back into arrays costs roughly its *logical* (decoded) bytes.  In-memory
# event replay (the recent eventlist / CURRENT crossings) has no fetch
# half, so it is priced at β·logical only.  With the raw codec
# stored == logical and the model degrades to the paper's bytes-fetched.
COST_ALPHA_STORED = 1.0
COST_BETA_DECODE = 0.15

# ---------------------------------------------------------------------------
# skeleton
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class NodeInfo:
    nid: int
    kind: str                      # 'superroot' | 'interior' | 'leaf'
    level: int                     # leaves = 1 (paper numbers from bottom)
    leaf_index: int = -1
    pos: int = -1                  # event-prefix length defining a leaf
    time: int = 0                  # boundary time (leaves)
    hierarchy: int = 0             # which diff-function hierarchy (fig 3b)
    materialized_as: int | None = None  # GraphPool graph id
    mat_node_cols: tuple | None = None  # attr columns stored at materialization
    mat_edge_cols: tuple | None = None


@dataclasses.dataclass
class EdgeInfo:
    eid: int
    src: int                       # apply `forward` = src -> dst
    dst: int
    kind: str                      # 'delta' | 'elist'
    payload_id: int
    w_struct: int = 0              # stored (at-rest, compressed) bytes
    w_nodeattr: np.ndarray | None = None   # int64[A_n] stored bytes per column
    w_edgeattr: np.ndarray | None = None
    n_events: int = 0              # elist edges: struct event count
    is_cap: bool = False           # part of the tear-down-able right spine
    w_struct_logical: int = 0      # decoded (raw array) bytes
    w_nodeattr_logical: np.ndarray | None = None
    w_edgeattr_logical: np.ndarray | None = None

    def weight(self, options: AttrOptions, frac: float = 1.0,
               backward: bool = False) -> float:
        """Cost to fetch+decode+apply this edge under the given options:
        ``α·stored + β·logical`` bytes (``COST_ALPHA_STORED`` /
        ``COST_BETA_DECODE``) — the planner prices compressed payloads by
        what they actually move over the store *and* what they cost to
        decode back into arrays.

        Backward traversal of *eventlist* edges cannot restore attributes of
        elements whose attribute events lie before the traversed window
        (deleted-element revival), so it is priced at +inf for attribute-
        carrying queries; structure-only backward traversal is exact.
        """
        if options.wants_attrs and self.kind == "elist" and backward:
            return float("inf")
        stored = float(self.w_struct)
        logical = float(self.w_struct_logical)
        if options.wants_node and self.w_nodeattr is not None and self.w_nodeattr.size:
            cols = [c for c in options.node_cols if c < self.w_nodeattr.size]
            stored += float(self.w_nodeattr[cols].sum())
            if (self.w_nodeattr_logical is not None
                    and self.w_nodeattr_logical.size):
                logical += float(self.w_nodeattr_logical[cols].sum())
        if options.wants_edge and self.w_edgeattr is not None and self.w_edgeattr.size:
            cols = [c for c in options.edge_cols if c < self.w_edgeattr.size]
            stored += float(self.w_edgeattr[cols].sum())
            if (self.w_edgeattr_logical is not None
                    and self.w_edgeattr_logical.size):
                logical += float(self.w_edgeattr_logical[cols].sum())
        return (COST_ALPHA_STORED * stored + COST_BETA_DECODE * logical) * frac


class DeltaGraph:
    """Build once (or incrementally maintain) and query forever."""

    def __init__(self, universe: GraphUniverse, store: KVStore, *,
                 L: int = 1000, k: int = 2,
                 diff_fn: str | Sequence[str] = "balanced",
                 diff_params: dict | Sequence[dict] | None = None,
                 num_partitions: int = 1,
                 partition_fn: str = "word_cyclic") -> None:
        if k < 2:
            raise ValueError("arity k must be >= 2")
        self.universe = universe
        self.store = store
        self.L = int(L)
        self.k = int(k)
        fns = [diff_fn] if isinstance(diff_fn, str) else list(diff_fn)
        prm = diff_params
        if prm is None:
            prm = [{}] * len(fns)
        elif isinstance(prm, dict):
            prm = [prm]
        self.diff_names = fns
        self.diff_params = list(prm)
        self.diff_fns = [diff_functions.get(n, **p) for n, p in zip(fns, prm)]
        self.P = int(num_partitions)
        self.partition_fn_name = partition_fn
        from ..runtime.partition import get_partitioner
        self._hp = get_partitioner(partition_fn)

        # skeleton ----------------------------------------------------------
        self.nodes: dict[int, NodeInfo] = {
            SUPERROOT: NodeInfo(SUPERROOT, "superroot", level=10**6)}
        self.edges: dict[int, EdgeInfo] = {}
        self.adj: dict[int, list[int]] = {SUPERROOT: []}
        self._next_nid = 1
        self._next_eid = 0
        self._next_payload = 0
        self.leaf_nids: list[int] = []
        self.leaf_pos: list[int] = []      # event-prefix length per leaf
        self.leaf_time: list[int] = []     # boundary time per leaf
        # bulk-load frontier: per hierarchy, per level, list of (nid, state)
        self._frontier: list[list[list[tuple[int, MaterializedState]]]] = [
            [] for _ in fns]
        self._cap_nodes: list[int] = []
        self._cap_edges: list[int] = []
        self._last_leaf_state: MaterializedState | None = None
        # recent (unindexed) events, §6
        self.recent = EventList.empty()
        self._total_events = 0
        # red/green rebuilds (core/ingest.py): when set, payload deletion
        # is *deferred* — keys append here instead of hitting the store, so
        # readers pinned to an older epoch keep their cap deltas until the
        # epoch registry drains them
        self.reclaim_sink: list | None = None
        # cooperative-yield hook for background folds (core/ingest.py):
        # called between fold sub-steps so a rollover running on a worker
        # thread hands the GIL to query threads instead of holding it for
        # the whole multi-ms fold
        self.nice = None
        # online query-traffic histogram (materialize.WorkloadStats),
        # attached by GraphManager; every execute() records into it
        self.workload = None

    def _nice(self) -> None:
        n = self.nice
        if n is not None:
            n()

    # ------------------------------------------------------------------ build
    def build(self, events: EventList) -> "DeltaGraph":
        """Single-pass bottom-up construction (§4.6)."""
        state = MaterializedState.empty(self.universe)
        self._emit_leaf(state, pos=0,
                        time=int(events.time[0]) - 1 if len(events) else 0)
        n_full = len(events) // self.L
        for i in range(n_full):
            chunk = events[i * self.L:(i + 1) * self.L]
            state = apply_events(state, chunk, forward=True)
            self._store_eventlist(self.leaf_nids[-1], chunk)
            self._emit_leaf(state, pos=(i + 1) * self.L,
                            time=int(chunk.time[-1]))
        self.recent = events[n_full * self.L:]
        self._total_events = len(events)
        self._cap()
        return self

    def _emit_leaf(self, state: MaterializedState, pos: int, time: int) -> None:
        nid = self._new_node("leaf", level=1, leaf_index=len(self.leaf_nids),
                             pos=pos, time=time)
        self.leaf_nids.append(nid)
        self.leaf_pos.append(pos)
        self.leaf_time.append(time)
        self._last_leaf_state = state.copy()
        for h in range(len(self.diff_fns)):
            self._push_frontier(h, 0, nid, state.copy(), cap=False)

    def _push_frontier(self, h: int, depth: int, nid: int,
                       state: MaterializedState, cap: bool) -> None:
        levels = self._frontier[h]
        while len(levels) <= depth:
            levels.append([])
        levels[depth].append((nid, state))
        if len(levels[depth]) == self.k:
            self._make_parent(h, depth, levels[depth], cap=cap)
            levels[depth] = []

    def _make_parent(self, h: int, depth: int,
                     children: list[tuple[int, MaterializedState]],
                     cap: bool) -> int:
        # children may predate live universe growth (§6) — resize first
        children = [(nid, st.resized(self.universe)) for nid, st in children]
        states = [s for _, s in children]
        pstate = self.diff_fns[h](states)
        pnid = self._new_node("interior", level=depth + 2, hierarchy=h)
        if cap:
            self._cap_nodes.append(pnid)
        for cnid, cstate in children:
            d = state_diff(cstate, pstate)
            self._add_delta_edge(pnid, cnid, d, cap=cap)
        self._push_frontier(h, depth + 1, pnid, pstate, cap=cap)
        return pnid

    def _cap(self) -> None:
        """Close the ragged right spine up to a root per hierarchy and hang
        the root off the super-root.  Cap nodes/edges are torn down by
        :meth:`_uncap` when appends arrive (§6).  Pending frontier nodes are
        flattened top-level-first (chronological order) and grouped ≤ k."""
        for h in range(len(self.diff_fns)):
            cur: list[tuple[int, MaterializedState]] = []
            for lv in reversed(self._frontier[h]):
                cur.extend(lv)
            if not cur:
                continue
            cur = [(nid, st.resized(self.universe)) for nid, st in cur]
            depth = 1
            while len(cur) > 1:
                nxt: list[tuple[int, MaterializedState]] = []
                for j in range(0, len(cur), self.k):
                    sub = cur[j:j + self.k]
                    if len(sub) == 1:
                        nxt.extend(sub)
                        continue
                    states = [s for _, s in sub]
                    pstate = self.diff_fns[h](states)
                    pnid = self._new_node("interior", level=depth + 1,
                                          hierarchy=h)
                    self._cap_nodes.append(pnid)
                    for cnid, cstate in sub:
                        d = state_diff(cstate, pstate)
                        self._add_delta_edge(pnid, cnid, d, cap=True)
                    nxt.append((pnid, pstate))
                cur = nxt
                depth += 1
            root_nid, root_state = cur[0]
            d = state_diff(root_state, MaterializedState.empty(self.universe))
            self._add_delta_edge(SUPERROOT, root_nid, d, cap=True)

    def _uncap(self) -> None:
        for eid in self._cap_edges:
            e = self.edges.pop(eid)
            self.adj[e.src].remove(eid)
            self.adj[e.dst].remove(eid)
            self._delete_payload(e.payload_id, col.DELTA_COMPONENTS, attrs=True)
        for nid in self._cap_nodes:
            self.nodes.pop(nid, None)
            self.adj.pop(nid, None)
        self._cap_edges = []
        self._cap_nodes = []

    # --------------------------------------------------------- §6 maintenance
    def append_events(self, ev: EventList) -> None:
        """Record new events into the recent eventlist; fold full leaves into
        the index as they fill (§6)."""
        self.recent = EventList.concat([self.recent, ev])
        self._total_events += len(ev)
        # live updates may have grown the slot universe (§6)
        self._last_leaf_state = self._last_leaf_state.resized(self.universe)
        while len(self.recent) >= self.L:
            chunk = self.recent[: self.L]
            self.recent = self.recent[self.L:]
            self._uncap()
            self._nice()
            state = apply_events(self._last_leaf_state, chunk, forward=True)
            self._store_eventlist(self.leaf_nids[-1], chunk)
            self._nice()
            self._emit_leaf(state, pos=self.leaf_pos[-1] + self.L,
                            time=int(chunk.time[-1]))
            self._cap()
            self._nice()

    # ----------------------------------------------------- red/green epochs
    def clone_for_commit(self, ev: EventList) -> "DeltaGraph":
        """Cheap per-group epoch clone: shares the whole skeleton with this
        graph and differs only in the ``recent`` tail.  The clone must never
        be structurally mutated (``append_events``) — rollovers go through
        :meth:`fork`."""
        dg = copy.copy(self)
        if len(ev):
            dg.recent = EventList.concat([self.recent, ev])
            dg._total_events = self._total_events + len(ev)
        if dg._last_leaf_state is not None:
            dg._last_leaf_state = dg._last_leaf_state.resized(self.universe)
        return dg

    def fork(self) -> "DeltaGraph":
        """Structural copy-on-write fork for shadow (green) rebuilds: own
        skeleton containers so ``append_events`` on the fork never mutates
        what readers pinned to this (red) version see.  Node/edge records
        and frontier states are shared — folds only add new entries and pop
        cap entries from the fork's own dicts."""
        dg = copy.copy(self)
        dg.nodes = dict(self.nodes)
        dg.edges = dict(self.edges)
        dg.adj = {nid: list(eids) for nid, eids in self.adj.items()}
        dg.leaf_nids = list(self.leaf_nids)
        dg.leaf_pos = list(self.leaf_pos)
        dg.leaf_time = list(self.leaf_time)
        dg._frontier = [[list(lv) for lv in h] for h in self._frontier]
        dg._cap_nodes = list(self._cap_nodes)
        dg._cap_edges = list(self._cap_edges)
        dg.reclaim_sink = None
        return dg

    def restore_append_state(self) -> None:
        """Rebuild the in-memory append machinery (`_last_leaf_state` and the
        bulk-load frontier) that :meth:`save_skeleton` does not persist, by
        retrieving the relevant node states through the index itself — after
        this a loaded skeleton accepts :meth:`append_events` again (crash
        recovery, ``core/ingest.py``)."""
        opts = AttrOptions(tuple(range(self.universe.num_node_attrs)),
                           tuple(range(self.universe.num_edge_attrs)))
        cap = set(self._cap_nodes)
        # pending frontier membership: any non-cap leaf/interior node with no
        # non-cap delta parent still awaits a parent at depth = level - 1
        pending: list[list[list[int]]] = []
        want: set[int] = {self.leaf_nids[-1]}
        for h in range(len(self.diff_fns)):
            levels: list[list[int]] = []
            for nid, info in self.nodes.items():
                if info.kind == "superroot" or nid in cap:
                    continue
                if info.kind == "interior" and info.hierarchy != h:
                    continue
                has_parent = any(
                    e.kind == "delta" and e.dst == nid and not e.is_cap
                    and self.nodes[e.src].kind == "interior"
                    and self.nodes[e.src].hierarchy == h
                    for e in (self.edges[eid] for eid in self.adj[nid]))
                if has_parent:
                    continue
                depth = info.level - 1
                while len(levels) <= depth:
                    levels.append([])
                levels[depth].append(nid)
                want.add(nid)
            # nid order is creation (chronological) order within a level
            for lv in levels:
                lv.sort()
            pending.append(levels)
        plans = {nid: self.plan_node(nid, opts) for nid in sorted(want)}
        states = {}
        for nid, plan in plans.items():
            states[nid] = self.execute(plan, opts)[("node", nid)]
        self._last_leaf_state = states[self.leaf_nids[-1]].copy()
        self._frontier = [
            [[(nid, states[nid]) for nid in lv] for lv in levels]
            for levels in pending]

    # ------------------------------------------------------------ persistence
    def _new_node(self, kind: str, level: int, **kw) -> int:
        nid = self._next_nid
        self._next_nid += 1
        self.nodes[nid] = NodeInfo(nid, kind, level=level, **kw)
        self.adj[nid] = []
        return nid

    def _add_edge(self, info: EdgeInfo) -> int:
        self.edges[info.eid] = info
        self.adj.setdefault(info.src, []).append(info.eid)
        self.adj.setdefault(info.dst, []).append(info.eid)
        return info.eid

    def _add_delta_edge(self, src: int, dst: int, d: Delta, cap: bool) -> int:
        pid = self._next_payload
        self._next_payload += 1
        wn, we, wnl, wel, struct_stored = self._store_delta(pid, d)
        eid = self._next_eid
        self._next_eid += 1
        self._add_edge(EdgeInfo(eid, src, dst, "delta", pid,
                                w_struct=struct_stored,
                                w_nodeattr=wn, w_edgeattr=we, is_cap=cap,
                                w_struct_logical=d.struct_nbytes(),
                                w_nodeattr_logical=wnl,
                                w_edgeattr_logical=wel))
        if cap:
            self._cap_edges.append(eid)
        return eid

    def _split_attr(self, a: AttrDelta, by_node: bool) -> list[np.ndarray]:
        part = self._hp(a.slot, self.P)
        return [np.nonzero(part == p)[0] for p in range(self.P)]

    def _store_delta(self, pid: int, d: Delta):
        """Encode + persist one delta's components; returns the per-column
        stored (at-rest blob) and logical (decoded array) byte tallies the
        planner's decode-aware cost model weighs."""
        A_n = self.universe.num_node_attrs
        A_e = self.universe.num_edge_attrs
        wn = np.zeros(A_n, np.int64)
        we = np.zeros(A_e, np.int64)
        wn_lg = np.zeros(A_n, np.int64)
        we_lg = np.zeros(A_e, np.int64)
        struct_stored = 0
        for p in range(self.P):
            sub = self._partition_delta(d, p)
            self._nice()
            b = col.encode_delta_struct(sub)
            struct_stored += len(b)
            self.store.put((p, pid, col.STRUCT), b)
            self._nice()
            for c in range(A_n):
                m = sub.node_attr.col == c
                ad = AttrDelta(sub.node_attr.slot[m], sub.node_attr.col[m],
                               sub.node_attr.new[m], sub.node_attr.old[m])
                b = col.encode_attr(ad)
                wn[c] += len(b)
                wn_lg[c] += ad.nbytes()
                self.store.put((p, pid, f"{col.NODEATTR}.{c}"), b)
                self._nice()
            for c in range(A_e):
                m = sub.edge_attr.col == c
                ad = AttrDelta(sub.edge_attr.slot[m], sub.edge_attr.col[m],
                               sub.edge_attr.new[m], sub.edge_attr.old[m])
                b = col.encode_attr(ad)
                we[c] += len(b)
                we_lg[c] += ad.nbytes()
                self.store.put((p, pid, f"{col.EDGEATTR}.{c}"), b)
                self._nice()
        return wn, we, wn_lg, we_lg, struct_stored

    def _partition_delta(self, d: Delta, p: int) -> Delta:
        if self.P == 1:
            return d
        hp = self._hp
        def f(a):
            return a[hp(a, self.P) == p]
        def fa(a: AttrDelta):
            m = hp(a.slot, self.P) == p
            return AttrDelta(a.slot[m], a.col[m], a.new[m], a.old[m])
        return Delta(f(d.node_add), f(d.node_del), f(d.edge_add), f(d.edge_del),
                     fa(d.node_attr), fa(d.edge_attr))

    def _store_eventlist(self, left_leaf_nid: int, ev: EventList) -> None:
        """Store the leaf-eventlist between leaf i and the upcoming leaf
        i+1, and add the bidirectional leaf edge."""
        pid = self._next_payload
        self._next_payload += 1
        A_n = self.universe.num_node_attrs
        A_e = self.universe.num_edge_attrs
        wn = np.zeros(A_n, np.int64)
        we = np.zeros(A_e, np.int64)
        wn_lg = np.zeros(A_n, np.int64)
        we_lg = np.zeros(A_e, np.int64)
        n_struct = 0
        w_struct = 0
        w_struct_lg = 0
        hp = self._hp
        part_all = hp(ev.slot, self.P)
        for p in range(self.P):
            sub = ev[part_all == p] if self.P > 1 else ev
            # component *arrays* (pre-encode) — attr components re-key per
            # column without decoding a just-encoded blob
            comps = col.eventlist_components(sub)
            self._nice()
            b_struct = col.pack_arrays(comps[col.ELIST_STRUCT])
            self.store.put((p, pid, col.ELIST_STRUCT), b_struct)
            self._nice()
            self.store.put((p, pid, col.ELIST_TRANSIENT),
                           col.pack_arrays(comps[col.ELIST_TRANSIENT]))
            self._nice()
            n_struct += comps[col.ELIST_STRUCT]["slot"].size
            w_struct += len(b_struct)
            w_struct_lg += col.logical_nbytes(comps[col.ELIST_STRUCT])
            for base, ws, ws_lg, A in ((col.ELIST_NODEATTR, wn, wn_lg, A_n),
                                       (col.ELIST_EDGEATTR, we, we_lg, A_e)):
                arrays = comps[base]
                for c in range(A):
                    m = arrays["col"] == c
                    sub_arrays = {k: v[m] for k, v in arrays.items()}
                    b = col.pack_arrays(sub_arrays)
                    ws[c] += len(b)
                    ws_lg[c] += col.logical_nbytes(sub_arrays)
                    self.store.put((p, pid, f"{base}.{c}"), b)
                    self._nice()
        eid = self._next_eid
        self._next_eid += 1
        # dst is the leaf about to be emitted (nid of next node)
        self._add_edge(EdgeInfo(eid, left_leaf_nid, self._next_nid, "elist",
                                pid, w_struct=w_struct, w_nodeattr=wn,
                                w_edgeattr=we, n_events=len(ev),
                                w_struct_logical=w_struct_lg,
                                w_nodeattr_logical=wn_lg,
                                w_edgeattr_logical=we_lg))

    def _delete_payload(self, pid: int, comps, attrs: bool) -> None:
        keys = []
        for p in range(self.P):
            for c in comps:
                keys.append((p, pid, c))
            if attrs:
                for c in range(self.universe.num_node_attrs):
                    keys.append((p, pid, f"{col.NODEATTR}.{c}"))
                for c in range(self.universe.num_edge_attrs):
                    keys.append((p, pid, f"{col.EDGEATTR}.{c}"))
        if self.reclaim_sink is not None:
            self.reclaim_sink.extend(keys)
        else:
            for key in keys:
                self.store.delete(key)

    # ----------------------------------------------------------------- stats
    @staticmethod
    def _edge_total_bytes(e: EdgeInfo, stored: bool) -> int:
        if stored:
            w = e.w_struct
            wn, we = e.w_nodeattr, e.w_edgeattr
        else:
            w = e.w_struct_logical
            wn, we = e.w_nodeattr_logical, e.w_edgeattr_logical
        if wn is not None:
            w += int(wn.sum())
        if we is not None:
            w += int(we.sum())
        return int(w)

    def skeleton_stats(self) -> dict:
        """Index-size report.  ``*_bytes`` fields are *logical* (decoded
        array) bytes — what the §5 analytical models predict; the
        ``stored_*`` mirrors report at-rest bytes after the payload codec,
        and ``compression_ratio`` is their quotient (per level and
        overall).  With the raw codec the two coincide up to blob-header
        overhead."""
        per_level: dict[int, int] = {}
        per_level_nocap: dict[int, int] = {}
        struct_nocap: dict[int, int] = {}
        stored_level: dict[int, int] = {}
        for e in self.edges.values():
            if e.kind == "delta":
                lvl = self.nodes[e.src].level if e.src != SUPERROOT else -1
                w = self._edge_total_bytes(e, stored=False)
                per_level[lvl] = per_level.get(lvl, 0) + w
                stored_level[lvl] = (stored_level.get(lvl, 0)
                                     + self._edge_total_bytes(e, stored=True))
                if not e.is_cap:
                    per_level_nocap[lvl] = per_level_nocap.get(lvl, 0) + w
                    struct_nocap[lvl] = (struct_nocap.get(lvl, 0)
                                         + e.w_struct_logical)
        total_delta = sum(per_level.values())
        stored_delta = sum(stored_level.values())
        elists = [e for e in self.edges.values() if e.kind == "elist"]
        total_elist = sum(self._edge_total_bytes(e, stored=False)
                          for e in elists)
        stored_elist = sum(self._edge_total_bytes(e, stored=True)
                           for e in elists)
        total = total_delta + total_elist
        stored_total = stored_delta + stored_elist
        return {"num_nodes": len(self.nodes), "num_edges": len(self.edges),
                "num_leaves": len(self.leaf_nids),
                "delta_bytes_per_level": per_level,
                "delta_bytes_per_level_nocap": per_level_nocap,
                "struct_bytes_per_level_nocap": struct_nocap,
                "delta_bytes": total_delta, "eventlist_bytes": total_elist,
                "total_bytes": total,
                "stored_delta_bytes_per_level": stored_level,
                "stored_delta_bytes": stored_delta,
                "stored_eventlist_bytes": stored_elist,
                "stored_total_bytes": stored_total,
                "compression_ratio_per_level": {
                    lvl: per_level[lvl] / max(stored_level.get(lvl, 0), 1)
                    for lvl in per_level},
                "compression_ratio": total / max(stored_total, 1)}

    # ------------------------------------------------------------- planning
    def _leaf_for_time(self, t: int) -> int:
        """Largest leaf index i with boundary time <= t (leaf 0 has -inf)."""
        i = int(np.searchsorted(np.asarray(self.leaf_time[1:]), t, side="right"))
        return min(i, len(self.leaf_nids) - 1)

    def _first_leaf_covering(self, ts: int) -> int:
        """First eventlist index whose rows can include ``time >= ts`` —
        the *inclusive-start* counterpart of :meth:`_leaf_for_time` (which
        is exclusive at its bound).  Expressed directly with a
        ``side="left"`` search instead of ``_leaf_for_time(ts - 1)``
        arithmetic; for integer timestamps the two coincide
        (#{j : leaf_time[j] < ts} either way) — pinned by
        ``tests/test_boundary_slices.py``."""
        i = int(np.searchsorted(np.asarray(self.leaf_time[1:]), ts, side="left"))
        return min(i, len(self.leaf_nids) - 1)

    def elists_covering(self, lo: int, hi: int) -> list[int]:
        """Leaf-eventlist indices holding rows with ``lo < time <= hi``
        (the interval-slice convention used everywhere in planning).
        Chunk ``i``'s rows satisfy ``leaf_time[i] <= time <= leaf_time[i+1]``
        — times are chronologically sorted and boundary timestamps may
        repeat across the cut — so the covering range is
        ``[_leaf_for_time(lo), _leaf_for_time(hi)]`` clipped to real
        eventlists; rows past the last leaf live in ``self.recent``."""
        if hi <= lo or len(self.leaf_nids) < 2:
            return []
        i0 = self._leaf_for_time(lo)
        i1 = min(self._leaf_for_time(hi), len(self.leaf_nids) - 2)
        return list(range(i0, i1 + 1))

    def _recent_cost(self, frac: float = 1.0) -> float:
        """Applying a slice of the in-memory recent eventlist has no fetch
        half — β·logical bytes, same units as :meth:`EdgeInfo.weight`."""
        return COST_BETA_DECODE * self.recent.nbytes() * frac

    def _virtual_edges(self, t: int, options: AttrOptions):
        """Edges connecting the virtual node S_t to the skeleton (§4.3).

        Partial-eventlist actions are ``(kind, payload, forward, (lo, hi))``
        — apply the rows with ``lo < time <= hi``; the explicit range makes
        the action invertible (flip ``forward``) so virtual nodes can be
        traversed *through* by multipoint plans.
        """
        NEG, POS = -(1 << 62), (1 << 62)
        li = self._leaf_for_time(t)
        out = []
        if li + 1 < len(self.leaf_nids):
            eid = self._leaf_elist_eid(li)
            e = self.edges[eid]
            t0, t1 = self.leaf_time[li], self.leaf_time[li + 1]
            frac = 0.5 if t1 <= t0 else min(max((t - t0) / (t1 - t0), 0.0), 1.0)
            out.append((self.leaf_nids[li], ("elist", e.payload_id, True, (NEG, t)),
                        e.weight(options, frac=frac)))
            out.append((self.leaf_nids[li + 1],
                        ("elist", e.payload_id, False, (t, POS)),
                        e.weight(options, frac=1.0 - frac, backward=True)))
        else:
            # t falls in the recent (unindexed) region past the last leaf
            n = len(self.recent)
            if n:
                cut = self.recent.search_time(t, side="right")
                frac = cut / n
                out.append((self.leaf_nids[li],
                            ("recent", None, True, (NEG, t)),
                            self._recent_cost(frac)))
                wb = (float("inf") if options.wants_attrs
                      else self._recent_cost(1 - frac))
                out.append(("CURRENT", ("recent", None, False, (t, POS)), wb))
            else:
                out.append((self.leaf_nids[li], ("noop", None, True, None), 0.0))
        return out

    def _chain_edges(self, times: list[int], options: AttrOptions,
                     virtuals: dict[Any, list]) -> None:
        """Direct S_ta -> S_tb partial edges for consecutive query times that
        share a leaf-eventlist (fig 4b: one eventlist serving several
        targets), appended into ``virtuals`` in place."""
        order = sorted(set(times))
        for ta, tb in zip(order, order[1:]):
            la, lb = self._leaf_for_time(ta), self._leaf_for_time(tb)
            if la != lb:
                continue
            if la + 1 < len(self.leaf_nids):
                e = self.edges[self._leaf_elist_eid(la)]
                t0, t1 = self.leaf_time[la], self.leaf_time[la + 1]
                frac = 0.5 if t1 <= t0 else min((tb - ta) / (t1 - t0), 1.0)
                virtuals[("t", tb)].append(
                    (("t", ta), ("elist", e.payload_id, True, (ta, tb)),
                     e.weight(options, frac=frac)))
            elif len(self.recent):
                n = len(self.recent)
                frac = (self.recent.search_time(tb) - self.recent.search_time(ta)) / n
                virtuals[("t", tb)].append(
                    (("t", ta), ("recent", None, True, (ta, tb)),
                     self._recent_cost(frac)))

    def _leaf_elist_eid(self, leaf_index: int) -> int:
        a, b = self.leaf_nids[leaf_index], self.leaf_nids[leaf_index + 1]
        for eid in self.adj[a]:
            e = self.edges[eid]
            if e.kind == "elist" and {e.src, e.dst} == {a, b}:
                return eid
        raise KeyError(f"no eventlist edge between leaves {leaf_index}, {leaf_index+1}")

    def _sources(self, use_current: bool,
                 options: AttrOptions = NO_ATTRS) -> list[tuple[Any, tuple]]:
        src: list[tuple[Any, tuple]] = [(SUPERROOT, ("empty",))]
        for nid, info in self.nodes.items():
            if info.materialized_as is None:
                continue
            # a materialized node is a usable source only if it holds every
            # attribute column the query needs
            if (set(options.node_cols) <= set(info.mat_node_cols or ())
                    and set(options.edge_cols) <= set(info.mat_edge_cols or ())):
                src.append((nid, ("mat", info.materialized_as)))
        if use_current and self._last_leaf_state is not None:
            src.append(("CURRENT", ("current",)))
        return src

    def _dijkstra(self, starts: dict[Any, float], options: AttrOptions,
                  virtuals: dict[Any, list[tuple[Any, tuple, float]]],
                  use_current: bool):
        """Shortest paths over skeleton ∪ virtual nodes.

        ``virtuals`` maps virtual node key -> [(skeleton nid, action, w)].
        Returns (dist, prev) with prev[v] = (u, action, w).
        """
        # adjacency including virtual edges (bidirectional where legal)
        vadj: dict[Any, list[tuple[Any, tuple, float]]] = {}
        for v, conns in virtuals.items():
            for u, action, w in conns:
                vadj.setdefault(u, []).append((v, action, w))
                # virtual nodes can be traversed *through* (multipoint
                # chains); the inverse flips direction over the same range
                if action[0] in ("elist", "recent"):
                    inv_fwd = not action[2]
                    if not inv_fwd and options.wants_attrs:
                        continue  # backward event replay can't restore attrs
                    inv = (action[0], action[1], inv_fwd, action[3])
                    vadj.setdefault(v, []).append((u, inv, w))
        if use_current and self.leaf_nids and not options.wants_attrs:
            # CURRENT = last leaf + recent events; crossing it backward
            # restores the last leaf (structure-only, §6)
            w = self._recent_cost()
            vadj.setdefault("CURRENT", []).append(
                (self.leaf_nids[-1], ("recent", None, False, None), w))
            vadj.setdefault(self.leaf_nids[-1], []).append(
                ("CURRENT", ("recent", None, True, None), w))

        dist: dict[Any, float] = dict(starts)
        prev: dict[Any, tuple] = {}
        pq = [(d, repr(n), n) for n, d in starts.items()]
        heapq.heapify(pq)
        seen: set = set()
        while pq:
            d, _, u = heapq.heappop(pq)
            if u in seen:
                continue
            seen.add(u)
            for eid in self.adj.get(u, []):
                e = self.edges[eid]
                v = e.dst if e.src == u else e.src
                fwd = e.src == u
                w = e.weight(options, backward=(e.kind == "elist" and not fwd))
                if w == float("inf"):
                    continue
                nd = d + w
                if nd < dist.get(v, float("inf")):
                    dist[v] = nd
                    prev[v] = (u, (e.kind, e.payload_id, fwd, None), w)
                    heapq.heappush(pq, (nd, repr(v), v))
            for (v, action, w) in vadj.get(u, []):
                if w == float("inf"):
                    continue
                nd = d + w
                if nd < dist.get(v, float("inf")):
                    dist[v] = nd
                    prev[v] = (u, action, w)
                    heapq.heappush(pq, (nd, repr(v), v))
        return dist, prev

    @staticmethod
    def _emit_chain(b: PlanBuilder, prev: dict, src_action: dict,
                    target: Any) -> None:
        """Unwind a Dijkstra predecessor map from ``target`` back to a
        source (or an already-emitted state) into the builder."""
        chain = []
        u = target
        while u in prev and not b.has_state(u):
            p, action, w = prev[u]
            chain.append((u, p, action, w))
            u = p
        if not b.has_state(u):
            b.source(u, src_action[u])
        for key, parent, action, w in reversed(chain):
            b.apply(key, parent, action, w)

    def plan_singlepoint(self, t: int, options: AttrOptions = NO_ATTRS,
                         use_current: bool = True) -> Plan:
        virtuals = {("t", t): self._virtual_edges(t, options)}
        sources = self._sources(use_current, options)
        starts = {n: 0.0 for n, _ in sources}
        dist, prev = self._dijkstra(starts, options, virtuals, use_current)
        target = ("t", t)
        if target not in dist:
            raise RuntimeError(f"no retrieval path for t={t}")
        b = PlanBuilder()
        self._emit_chain(b, prev, dict(sources), target)
        b.target(t, target)
        return b.build()

    def plan_node(self, nid: int, options: AttrOptions = NO_ATTRS) -> Plan:
        """Plan retrieval of a *skeleton* node's (virtual) graph — used for
        memory materialization (§4.5)."""
        sources = self._sources(False, options)
        starts = {n: 0.0 for n, _ in sources}
        dist, prev = self._dijkstra(starts, options, {}, False)
        b = PlanBuilder()
        self._emit_chain(b, prev, dict(sources), nid)
        b.target(("node", nid), nid)
        return b.build()

    def plan_multipoint(self, times: Sequence[int],
                        options: AttrOptions = NO_ATTRS,
                        use_current: bool = True) -> Plan:
        """Metric-closure MST 2-approx Steiner tree (§4.4)."""
        times = list(dict.fromkeys(times))  # dedup, keep order
        if len(times) == 1:
            return self.plan_singlepoint(times[0], options, use_current)
        virtuals: dict[Any, list] = {}
        for t in times:
            virtuals[("t", t)] = self._virtual_edges(t, options)
        self._chain_edges(times, options, virtuals)
        sources = self._sources(use_current, options)
        terminals = [("t", t) for t in times]

        # Dijkstra from the collapsed source set, then from each terminal.
        runs: dict[Any, tuple[dict, dict]] = {}
        runs["SRC"] = self._dijkstra({n: 0.0 for n, _ in sources}, options,
                                     virtuals, use_current)
        for tm in terminals:
            runs[tm] = self._dijkstra({tm: 0.0}, options, virtuals, use_current)

        # Prim over {SRC} ∪ terminals in the metric closure
        in_tree = {"SRC"}
        tree_paths: list[tuple[Any, Any]] = []  # (metric edge: from, to)
        rem = set(terminals)
        while rem:
            best = None
            for a in in_tree:
                da = runs[a][0]
                for b in rem:
                    d = da.get(b, float("inf"))
                    if best is None or d < best[0]:
                        best = (d, a, b)
            if best is None or best[0] == float("inf"):
                raise RuntimeError("unreachable multipoint target")
            _, a, b = best
            in_tree.add(b)
            rem.discard(b)
            tree_paths.append((a, b))

        # unfold: union of the chosen shortest paths as a directed step DAG
        src_action = dict(sources)
        builder = PlanBuilder()

        def add_path(run_key: Any, target: Any):
            _, prev = runs[run_key]
            chain = []
            u = target
            while u in prev and not builder.has_state(u):
                p, action, w = prev[u]
                chain.append((u, p, action, w))
                u = p
            if not builder.has_state(u):
                if run_key == "SRC":
                    builder.source(u, src_action[u])
                else:
                    # path hangs off an already-computed state
                    assert u == run_key, u
            for key, parent, action, w in reversed(chain):
                builder.apply(key, parent, action, w)

        for a, b in tree_paths:
            add_path(a, b)

        for t in times:
            builder.target(t, ("t", t))
        return builder.build()

    # ------------------------------------------------------------- execution
    def _mget(self, keys: list) -> list:
        from ..storage.kv import mget_optional
        return mget_optional(self.store, keys)

    def _delta_keys(self, pid: int, options: AttrOptions,
                    parts: tuple[int, ...] | None = None
                    ) -> tuple[list, list, list]:
        """Component keys for one delta payload.  ``parts`` restricts to a
        subset of the storage partitions (sharded execution fetches only
        the partitions a shard owns); ``None`` = all of them."""
        ps = range(self.P) if parts is None else parts
        keys = [(p, pid, col.STRUCT) for p in ps]
        na_keys = [(p, pid, f"{col.NODEATTR}.{c}")
                   for p in ps for c in options.node_cols]
        ea_keys = [(p, pid, f"{col.EDGEATTR}.{c}")
                   for p in ps for c in options.edge_cols]
        return keys, na_keys, ea_keys

    def _fetch_delta(self, pid: int, options: AttrOptions,
                     parts: tuple[int, ...] | None = None) -> Delta:
        keys, na_keys, ea_keys = self._delta_keys(pid, options, parts)
        blobs = self._mget(keys + na_keys + ea_keys)
        return self._decode_delta(blobs, len(keys), len(na_keys))

    def _decode_delta(self, blobs: list, n_struct: int, n_na: int) -> Delta:
        structs = [col.decode_delta_struct(b) for b in blobs[:n_struct]]
        na_blobs = blobs[n_struct: n_struct + n_na]
        ea_blobs = blobs[n_struct + n_na:]
        nas = [col.decode_attr(b) for b in na_blobs if b is not None]
        eas = [col.decode_attr(b) for b in ea_blobs if b is not None]

        def cat(field):
            return np.concatenate([s[field] for s in structs]) if structs else np.zeros(0, np.int32)

        def cat_attr(parts: list[AttrDelta]) -> AttrDelta:
            if not parts:
                return AttrDelta.empty()
            return AttrDelta(np.concatenate([a.slot for a in parts]),
                             np.concatenate([a.col for a in parts]),
                             np.concatenate([a.new for a in parts]),
                             np.concatenate([a.old for a in parts]))

        return Delta(cat("node_add"), cat("node_del"), cat("edge_add"),
                     cat("edge_del"), cat_attr(nas), cat_attr(eas))

    def _elist_keys(self, pid: int, options: AttrOptions,
                    transient: bool = False,
                    parts: tuple[int, ...] | None = None) -> list:
        comps = [col.ELIST_STRUCT]
        comps += [f"{col.ELIST_NODEATTR}.{c}" for c in options.node_cols]
        comps += [f"{col.ELIST_EDGEATTR}.{c}" for c in options.edge_cols]
        if transient:
            comps.append(col.ELIST_TRANSIENT)
        ps = range(self.P) if parts is None else parts
        return [(p, pid, c) for p in ps for c in comps]

    def _fetch_elist(self, pid: int, options: AttrOptions,
                     transient: bool = False,
                     parts: tuple[int, ...] | None = None
                     ) -> dict[str, dict[str, np.ndarray]]:
        keys = self._elist_keys(pid, options, transient, parts)
        return self._decode_elist(keys, self._mget(keys))

    @staticmethod
    def _decode_elist(keys: list, blobs: list
                      ) -> dict[str, dict[str, np.ndarray]]:
        out: dict[str, list[dict[str, np.ndarray]]] = {}
        for (pkey, blob) in zip(keys, blobs):
            if blob is not None:
                out.setdefault(pkey[2], []).append(col.unpack_arrays(blob))
        merged = {}
        for comp, parts in out.items():
            merged[comp] = {k: np.concatenate([p[k] for p in parts])
                            for k in parts[0]}
        return merged

    def _apply_elist(self, state: MaterializedState,
                     comps: dict[str, dict[str, np.ndarray]],
                     forward: bool, rng: tuple[int, int] | None,
                     options: AttrOptions) -> MaterializedState:
        """Apply a (possibly partial) leaf-eventlist from its columnar
        components.  ``rng = (lo, hi)`` selects rows with lo < time <= hi;
        the same row set is applied forward or backward."""
        out = state.copy()
        s = comps[col.ELIST_STRUCT]

        def sel(times: np.ndarray) -> np.ndarray:
            if rng is None:
                return np.ones(times.shape, bool)
            lo, hi = rng
            return (times > lo) & (times <= hi)

        m = sel(s["time"])
        et, slot = s["etype"][m], s["slot"][m]
        add_n, del_n = (EV_NEW_NODE, EV_DEL_NODE) if forward else (EV_DEL_NODE, EV_NEW_NODE)
        add_e, del_e = (EV_NEW_EDGE, EV_DEL_EDGE) if forward else (EV_DEL_EDGE, EV_NEW_EDGE)
        ncnt = out.node_mask.astype(np.int32)
        np.add.at(ncnt, slot[et == add_n], 1)
        np.add.at(ncnt, slot[et == del_n], -1)
        out.node_mask = ncnt > 0
        ecnt = out.edge_mask.astype(np.int32)
        np.add.at(ecnt, slot[et == add_e], 1)
        np.add.at(ecnt, slot[et == del_e], -1)
        out.edge_mask = ecnt > 0

        for base, attrs, cols in ((col.ELIST_NODEATTR, out.node_attrs, options.node_cols),
                                  (col.ELIST_EDGEATTR, out.edge_attrs, options.edge_cols)):
            for c in cols:
                comp = comps.get(f"{base}.{c}")
                if comp is None:
                    continue
                m = sel(comp["time"])
                pos, sl = comp["pos"][m], comp["slot"][m]
                val = (comp["new"] if forward else comp["old"])[m]
                order = np.argsort(pos, kind="stable")
                if not forward:
                    order = order[::-1]
                attrs[sl[order], c] = val[order]
        return out

    def execute(self, plan: Plan, options: AttrOptions = NO_ATTRS,
                pool=None, prefetch=None) -> dict[Any, MaterializedState]:
        """Run a plan IR on the host backend; returns states keyed by the
        plan's query targets.  ``prefetch`` takes a
        :class:`repro.runtime.executor.Prefetcher` to overlap KV gets with
        delta/eventlist application."""
        from ..runtime.executor import HostExecutor
        t_start = time.perf_counter()
        out = HostExecutor(self, prefetcher=prefetch).run(plan, options, pool)
        self._record_workload(plan, options, t_start)
        return out

    def _record_workload(self, plan: Plan, options: AttrOptions,
                         t_start: float) -> None:
        """Feed one executed plan into the workload stats (advisor input).
        Shared by :meth:`execute` and the sharded retriever, which runs the
        scattered plan through its own executor pool."""
        if self.workload is not None:
            # time-point targets only (node-materialization plans carry
            # ("node", nid) targets and are not workload — recording their
            # routes would let the advisor reinforce its own pins)
            tts = [t for t in plan.targets
                   if isinstance(t, (int, np.integer))]
            if tts:
                # per-IR-node hit counts feed the advisor candidate ranking
                self.workload.record_nodes(
                    [k for k in plan.state_keys()
                     if isinstance(k, (int, np.integer)) and k in self.nodes])
                wall = (time.perf_counter() - t_start) / len(tts)
                share = plan.total_weight / len(tts)
                for t in tts:
                    self.workload.record(self._leaf_for_time(int(t)), share,
                                         options, wall)

    # --------------------------------------------------------------- queries
    def get_snapshot(self, t: int, options: AttrOptions = NO_ATTRS,
                     pool=None, use_current: bool = True,
                     prefetch=None) -> MaterializedState:
        plan = self.plan_singlepoint(t, options, use_current)
        return self.execute(plan, options, pool, prefetch=prefetch)[t]

    def get_snapshots(self, times: Sequence[int],
                      options: AttrOptions = NO_ATTRS, pool=None,
                      use_current: bool = True,
                      prefetch=None) -> dict[int, MaterializedState]:
        """Batched multipoint retrieval: one Steiner plan, shared prefixes
        fetch and apply once (§4.4 multi-query optimization)."""
        plan = self.plan_multipoint(times, options, use_current)
        return self.execute(plan, options, pool, prefetch=prefetch)

    def get_interval(self, ts: int, te: int) -> dict[str, np.ndarray]:
        """GetHistGraphInterval: elements *added* during [ts, te), plus the
        transient events in that window (§3.2.1)."""
        node_add, edge_add, tr_slot, tr_time = [], [], [], []
        li = self._first_leaf_covering(ts)
        for i in range(li, len(self.leaf_nids) - 1):
            if self.leaf_time[i] >= te:
                break
            e = self.edges[self._leaf_elist_eid(i)]
            comps = self._fetch_elist(e.payload_id, NO_ATTRS, transient=True)
            s = comps[col.ELIST_STRUCT]
            m = (s["time"] >= ts) & (s["time"] < te)
            node_add.append(s["slot"][m & (s["etype"] == EV_NEW_NODE)])
            edge_add.append(s["slot"][m & (s["etype"] == EV_NEW_EDGE)])
            tr = comps[col.ELIST_TRANSIENT]
            mt = (tr["time"] >= ts) & (tr["time"] < te)
            tr_slot.append(tr["slot"][mt])
            tr_time.append(tr["time"][mt])
        rec = self.recent
        if len(rec):
            m = (rec.time >= ts) & (rec.time < te)
            node_add.append(rec.slot[m & (rec.etype == EV_NEW_NODE)])
            edge_add.append(rec.slot[m & (rec.etype == EV_NEW_EDGE)])
            from .events import EV_TRANS_EDGE, EV_TRANS_NODE
            mt = m & np.isin(rec.etype, (EV_TRANS_EDGE, EV_TRANS_NODE))
            tr_slot.append(rec.slot[mt])
            tr_time.append(rec.time[mt])

        def cat(parts, dtype):
            return (np.unique(np.concatenate(parts)).astype(dtype)
                    if parts else np.zeros(0, dtype))

        return {"node_added": cat(node_add, np.int32),
                "edge_added": cat(edge_add, np.int32),
                "transient_slot": (np.concatenate(tr_slot) if tr_slot
                                   else np.zeros(0, np.int32)),
                "transient_time": (np.concatenate(tr_time) if tr_time
                                   else np.zeros(0, np.int64))}

    # -------------------------------------------------------- materialization
    def materialize(self, nid: int, pool, options: AttrOptions | None = None) -> int:
        """Fetch a skeleton node's graph into the GraphPool and add the
        zero-weight shortcut (§4.5).  Returns the pool graph id."""
        options = options if options is not None else NO_ATTRS
        plan = self.plan_node(nid, options)
        st = self.execute(plan, options, pool)[("node", nid)]
        gid = pool.insert_materialized(st)
        info = self.nodes[nid]
        info.materialized_as = gid
        info.mat_node_cols = tuple(options.node_cols)
        info.mat_edge_cols = tuple(options.edge_cols)
        return gid

    def unmaterialize(self, nid: int, pool) -> None:
        info = self.nodes[nid]
        if info.materialized_as is not None:
            pool.release(info.materialized_as)
            info.materialized_as = None

    def root_nids(self) -> list[int]:
        return [self.edges[eid].dst for eid in self.adj[SUPERROOT]]

    def save_skeleton(self) -> None:
        """Persist the skeleton so the index can be reopened later
        (``loadDeltaGraphIndex``)."""
        payload = {
            "L": self.L, "k": self.k, "P": self.P,
            "diff_names": self.diff_names, "diff_params": self.diff_params,
            "partition_fn": self.partition_fn_name,
            "next": [self._next_nid, self._next_eid, self._next_payload],
            "leaf_nids": self.leaf_nids, "leaf_pos": self.leaf_pos,
            "leaf_time": self.leaf_time,
            "cap_nodes": self._cap_nodes, "cap_edges": self._cap_edges,
            "total_events": self._total_events,
            "nodes": [dataclasses.asdict(n) for n in self.nodes.values()],
        }
        self._nice()
        payload["edges"] = [{**dataclasses.asdict(e),
                             "w_nodeattr": None, "w_edgeattr": None,
                             "w_nodeattr_logical": None,
                             "w_edgeattr_logical": None}
                            for e in self.edges.values()]
        self._nice()
        arrays = {}
        for e in self.edges.values():
            if e.w_nodeattr is not None:
                arrays[f"wn{e.eid}"] = e.w_nodeattr
            if e.w_edgeattr is not None:
                arrays[f"we{e.eid}"] = e.w_edgeattr
            if e.w_nodeattr_logical is not None:
                arrays[f"wnl{e.eid}"] = e.w_nodeattr_logical
            if e.w_edgeattr_logical is not None:
                arrays[f"wel{e.eid}"] = e.w_edgeattr_logical
        arrays["json"] = np.frombuffer(json.dumps(payload).encode(), np.uint8)
        self._nice()
        self.store.put((0, -1, "skeleton"), col.pack_arrays(arrays))

    @staticmethod
    def load_skeleton(universe: GraphUniverse, store: KVStore) -> "DeltaGraph":
        arrays = col.unpack_arrays(store.get((0, -1, "skeleton")))
        payload = json.loads(bytes(arrays["json"]).decode())
        dg = DeltaGraph(universe, store, L=payload["L"], k=payload["k"],
                        diff_fn=payload["diff_names"],
                        diff_params=payload["diff_params"],
                        num_partitions=payload["P"],
                        partition_fn=payload["partition_fn"])
        dg._next_nid, dg._next_eid, dg._next_payload = payload["next"]
        dg.leaf_nids = payload["leaf_nids"]
        dg.leaf_pos = payload["leaf_pos"]
        dg.leaf_time = payload["leaf_time"]
        dg._cap_nodes = payload["cap_nodes"]
        dg._cap_edges = payload["cap_edges"]
        dg._total_events = payload["total_events"]
        dg.nodes = {}
        dg.adj = {}
        for nd in payload["nodes"]:
            info = NodeInfo(**nd)
            dg.nodes[info.nid] = info
            dg.adj[info.nid] = []
        dg.edges = {}
        for ed in payload["edges"]:
            # skeletons saved before the codec layer lack the logical-byte
            # fields — EdgeInfo defaults keep them loadable (decode cost
            # simply prices as zero until a rebuild)
            e = EdgeInfo(**ed)
            e.w_nodeattr = arrays.get(f"wn{e.eid}")
            e.w_edgeattr = arrays.get(f"we{e.eid}")
            e.w_nodeattr_logical = arrays.get(f"wnl{e.eid}")
            e.w_edgeattr_logical = arrays.get(f"wel{e.eid}")
            dg._add_edge(e)
        return dg
