"""Unified retrieval-plan IR.

Every retrieval — singlepoint, multipoint, node materialization, host or
JAX backend — is one **DAG of typed steps**:

* :class:`Fetch`        — pull a payload's columnar components from the KV
  store (one node per ``(kind, pid)``, so a payload shared by several apply
  steps — e.g. one leaf-eventlist serving chained targets — is fetched
  exactly once, and the async prefetcher can overlap it with application);
* :class:`Source`       — a distance-0 plan source: the empty graph, a
  materialized GraphPool graph, or the current graph;
* :class:`ApplyDelta`   — apply a persisted delta (either direction);
* :class:`ApplyElist`   — apply a (possibly partial) leaf-eventlist;
* :class:`ApplyRecent`  — apply a slice of the in-memory recent eventlist;
* :class:`Noop`         — pass a state through unchanged;
* :class:`Fork`         — a state consumed by ≥ 2 branches; executors use
  it as the batching point (the JAX backend runs sibling branches as one
  vmapped ``delta_apply_chain`` call);
* :class:`Materialize`  — emit a state as a query result.

The IR stays **backend-neutral**: it references payload ids, pool graph
ids and time ranges, never raw bytes or arrays.  ``PlanIR.steps`` exposes
the state-producing nodes in topological order with the legacy
``(key, parent, action, weight)`` surface, so existing callers (tests,
benchmarks, the sharded lowering) keep working unchanged.

:func:`merge_irs` is the shared-prefix batch optimizer: concurrent plans
are merged into one DAG by structural signature — two nodes collapse when
their op and their (recursively merged) dependencies coincide — so common
subpaths fetch and apply exactly once for the whole batch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

# ---------------------------------------------------------------------------
# typed steps
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Fetch:
    """Fetch a payload's components from the KV store.

    ``parts`` restricts the fetch to a subset of the index's storage
    partitions (``None`` = all): the sharded scatter (:func:`scatter_ir`)
    rewrites every Fetch so each shard pulls only the sub-payloads whose
    slots it owns."""
    kind: str                       # 'delta' | 'elist'
    pid: int
    parts: tuple[int, ...] | None = None


@dataclasses.dataclass(frozen=True)
class Source:
    """A distance-0 source state."""
    kind: str                       # 'empty' | 'mat' | 'current'
    gid: int | None = None          # GraphPool graph id for 'mat'


@dataclasses.dataclass(frozen=True)
class ApplyDelta:
    pid: int
    forward: bool


@dataclasses.dataclass(frozen=True)
class ApplyElist:
    pid: int
    forward: bool
    rng: tuple[int, int] | None     # apply rows with lo < time <= hi


@dataclasses.dataclass(frozen=True)
class ApplyRecent:
    forward: bool
    rng: tuple[int, int] | None


@dataclasses.dataclass(frozen=True)
class Noop:
    pass


@dataclasses.dataclass(frozen=True)
class Fork:
    fanout: int


@dataclasses.dataclass(frozen=True)
class Materialize:
    target: Any                     # query target (t, or ("node", nid))


APPLY_OPS = (ApplyDelta, ApplyElist, ApplyRecent, Noop)
STATE_OPS = (Source, Fork) + APPLY_OPS


# ---------------------------------------------------------------------------
# DAG nodes
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class IRNode:
    nid: int
    op: Any
    deps: tuple[int, ...] = ()      # DAG dependencies (node ids)
    key: Any = None                 # state key produced (state ops only)
    parent_key: Any = None          # state key consumed (legacy surface)
    weight: float = 0.0

    # -- legacy PlanStep surface -------------------------------------------
    @property
    def parent(self) -> Any:
        return self.parent_key

    @property
    def action(self) -> tuple:
        op = self.op
        if isinstance(op, Source):
            if op.kind == "mat":
                return ("mat", op.gid)
            return (op.kind,)
        if isinstance(op, ApplyDelta):
            return ("delta", op.pid, op.forward, None)
        if isinstance(op, ApplyElist):
            return ("elist", op.pid, op.forward, op.rng)
        if isinstance(op, ApplyRecent):
            return ("recent", None, op.forward, op.rng)
        if isinstance(op, Noop):
            return ("noop", None, True, None)
        if isinstance(op, Fork):
            return ("fork", op.fanout)
        if isinstance(op, Fetch):
            if op.parts is not None:
                return ("fetch", op.kind, op.pid, op.parts)
            return ("fetch", op.kind, op.pid)
        if isinstance(op, Materialize):
            return ("materialize", op.target)
        raise ValueError(op)  # pragma: no cover


@dataclasses.dataclass
class PlanIR:
    """A retrieval plan: typed-step DAG in topological order."""

    nodes: list[IRNode]
    targets: dict[Any, int]         # query target -> producing node id
    total_weight: float
    payload_fetches: int = 0

    # -- legacy Plan surface -----------------------------------------------
    @property
    def steps(self) -> list[IRNode]:
        """State-producing nodes (sans Fork) in topo order — the legacy
        linear-plan view used by tests, benchmarks and the chain lowering."""
        return [n for n in self.nodes
                if isinstance(n.op, STATE_OPS) and not isinstance(n.op, Fork)]

    def source_nids(self) -> set:
        """Skeleton keys of materialized sources this plan routes through
        (cache-dependency tracking: evicting one invalidates the entry)."""
        return {n.key for n in self.nodes
                if isinstance(n.op, Source) and n.op.kind == "mat"}

    def per_target_source_nids(self) -> dict[Any, set]:
        """Materialized-source skeleton nids on each *target's* backward
        slice of the DAG — exact per-entry cache dependencies for batched
        plans (a target whose branch never touched a pin must not be
        invalidated when that pin is evicted)."""
        memo: dict[int, set] = {}
        for n in self.nodes:            # topo order: deps precede node
            s: set = set()
            if isinstance(n.op, Source) and n.op.kind == "mat":
                s.add(n.key)
            for d in n.deps:
                s |= memo[d]
            memo[n.nid] = s
        return {tgt: memo[nid] for tgt, nid in self.targets.items()}

    def state_keys(self) -> list:
        return [n.key for n in self.nodes
                if isinstance(n.op, STATE_OPS) and not isinstance(n.op, Fork)]

    def cost_summary(self) -> dict:
        """Planner-side execution stats for the :class:`QueryResult`
        envelope: total decode-aware cost, distinct payload fetches, and
        state-producing step count."""
        return {"plan_cost": float(self.total_weight),
                "payload_fetches": int(self.payload_fetches),
                "plan_steps": len(self.steps),
                "targets": len(self.targets)}


# ---------------------------------------------------------------------------
# builder
# ---------------------------------------------------------------------------


def _action_to_op(action: tuple):
    kind = action[0]
    if kind in ("empty", "current"):
        return Source(kind)
    if kind == "mat":
        return Source("mat", int(action[1]))
    if kind == "delta":
        return ApplyDelta(int(action[1]), bool(action[2]))
    if kind == "elist":
        rng = tuple(action[3]) if action[3] is not None else None
        return ApplyElist(int(action[1]), bool(action[2]), rng)
    if kind == "recent":
        rng = tuple(action[3]) if action[3] is not None else None
        return ApplyRecent(bool(action[2]), rng)
    if kind == "noop":
        return Noop()
    raise ValueError(f"unknown action {action}")


class PlanBuilder:
    """Accumulates planner output (source + apply chains keyed by state)
    into a :class:`PlanIR`; inserts Fetch and Fork nodes automatically."""

    def __init__(self) -> None:
        self._nodes: list[IRNode] = []
        self._by_key: dict[Any, int] = {}       # state key -> node id
        self._fetches: dict[tuple, int] = {}    # (kind, pid) -> node id
        self._targets: dict[Any, Any] = {}      # target -> state key
        self._next = 0

    def _add(self, node: IRNode) -> int:
        self._nodes.append(node)
        return node.nid

    def _new(self, op, deps=(), key=None, parent_key=None, weight=0.0) -> int:
        nid = self._next
        self._next += 1
        return self._add(IRNode(nid, op, tuple(deps), key, parent_key, weight))

    def has_state(self, key: Any) -> bool:
        return key in self._by_key

    def source(self, key: Any, action: tuple) -> int:
        if key in self._by_key:
            return self._by_key[key]
        nid = self._new(_action_to_op(action), key=key)
        self._by_key[key] = nid
        return nid

    def _fetch(self, kind: str, pid: int) -> int:
        fk = (kind, pid)
        if fk not in self._fetches:
            self._fetches[fk] = self._new(Fetch(kind, pid))
        return self._fetches[fk]

    def apply(self, key: Any, parent_key: Any, action: tuple,
              weight: float = 0.0) -> int:
        if key in self._by_key:
            return self._by_key[key]
        op = _action_to_op(action)
        deps = [self._by_key[parent_key]]
        if isinstance(op, ApplyDelta):
            deps.append(self._fetch("delta", op.pid))
        elif isinstance(op, ApplyElist):
            deps.append(self._fetch("elist", op.pid))
        nid = self._new(op, deps, key=key, parent_key=parent_key,
                        weight=float(weight))
        self._by_key[key] = nid
        return nid

    def target(self, tgt: Any, key: Any) -> None:
        self._targets[tgt] = key

    def build(self) -> PlanIR:
        nodes = list(self._nodes)
        targets = {}
        for tgt, key in self._targets.items():
            dep = self._by_key[key]
            nid = self._next
            self._next += 1
            nodes.append(IRNode(nid, Materialize(tgt), (dep,), key=key,
                                parent_key=key))
            targets[tgt] = dep
        ir = PlanIR(nodes, targets,
                    total_weight=sum(n.weight for n in nodes),
                    payload_fetches=len(self._fetches))
        return _insert_forks(ir)


# ---------------------------------------------------------------------------
# fork insertion / merging
# ---------------------------------------------------------------------------


def _strip_forks(ir: PlanIR) -> PlanIR:
    """Remove Fork pass-through nodes, re-pointing consumers at the fork's
    state parent (inverse of :func:`_insert_forks`)."""
    fwd: dict[int, int] = {}
    for n in ir.nodes:
        if isinstance(n.op, Fork):
            fwd[n.nid] = n.deps[0]

    def chase(nid: int) -> int:
        while nid in fwd:
            nid = fwd[nid]
        return nid

    nodes = []
    for n in ir.nodes:
        if isinstance(n.op, Fork):
            continue
        if any(d in fwd for d in n.deps):
            n = dataclasses.replace(n, deps=tuple(chase(d) for d in n.deps))
        nodes.append(n)
    targets = {t: chase(nid) for t, nid in ir.targets.items()}
    return PlanIR(nodes, targets, ir.total_weight, ir.payload_fetches)


def _insert_forks(ir: PlanIR) -> PlanIR:
    """Insert a Fork after every state node consumed by ≥ 2 apply steps."""
    consumers: dict[int, list[int]] = {}
    byid = {n.nid: n for n in ir.nodes}
    for n in ir.nodes:
        if isinstance(n.op, APPLY_OPS):
            for d in n.deps:
                if isinstance(byid[d].op, STATE_OPS):
                    consumers.setdefault(d, []).append(n.nid)
    fork_after = {nid: len(c) for nid, c in consumers.items() if len(c) >= 2}
    if not fork_after:
        return ir
    next_id = max(n.nid for n in ir.nodes) + 1
    fork_of: dict[int, int] = {}
    nodes: list[IRNode] = []
    for n in ir.nodes:
        if any(d in fork_of for d in n.deps) and isinstance(n.op, APPLY_OPS):
            n = dataclasses.replace(
                n, deps=tuple(fork_of.get(d, d) if isinstance(byid[d].op, STATE_OPS)
                              else d for d in n.deps))
        nodes.append(n)
        if n.nid in fork_after:
            f = IRNode(next_id, Fork(fork_after[n.nid]), (n.nid,),
                       key=n.key, parent_key=n.key)
            next_id += 1
            fork_of[n.nid] = f.nid
            nodes.append(f)
    return PlanIR(nodes, dict(ir.targets), ir.total_weight,
                  ir.payload_fetches)


def merge_irs(irs: Sequence[PlanIR]) -> PlanIR:
    """Merge concurrent plans into one batched DAG.

    Nodes are deduplicated by structural signature — ``(op, merged dep
    ids)`` — so any prefix two plans share (same source, same payload
    applies in the same order) becomes a single subpath that fetches and
    applies once.  Fork nodes are recomputed over the merged consumer
    counts."""
    if len(irs) == 1:
        return irs[0]
    sig_to_nid: dict[tuple, int] = {}
    nodes: list[IRNode] = []
    targets: dict[Any, int] = {}
    next_id = 0
    total = 0.0
    for ir in irs:
        flat = _strip_forks(ir)
        old2new: dict[int, int] = {}
        for n in flat.nodes:
            if isinstance(n.op, Materialize):
                targets[n.op.target] = old2new[n.deps[0]]
                continue
            sig = (n.op, tuple(old2new[d] for d in n.deps))
            nid = sig_to_nid.get(sig)
            if nid is None:
                nid = next_id
                next_id += 1
                sig_to_nid[sig] = nid
                nodes.append(dataclasses.replace(
                    n, nid=nid, deps=tuple(old2new[d] for d in n.deps)))
                total += n.weight
            old2new[n.nid] = nid
    byid = {n.nid: n for n in nodes}
    for tgt, dep in targets.items():
        n = IRNode(next_id, Materialize(tgt), (dep,), key=byid[dep].key,
                   parent_key=byid[dep].key)
        next_id += 1
        nodes.append(n)
    fetches = sum(1 for n in nodes if isinstance(n.op, Fetch))
    return _insert_forks(PlanIR(nodes, targets, total, fetches))


# ---------------------------------------------------------------------------
# cross-shard scatter
# ---------------------------------------------------------------------------


def scatter_ir(ir: PlanIR, parts_by_shard: dict[Any, tuple[int, ...]],
               total_parts: int) -> dict[Any, PlanIR]:
    """Scatter one plan into per-shard plan IRs.

    The DAG topology is shared — every shard applies the same step
    sequence — but each shard's Fetch nodes are restricted to the storage
    partitions it owns, so a shard pulls (and decodes) only the
    sub-payloads whose slots it is responsible for.  Apply weights are
    scaled by the shard's partition fraction: the sum of the per-shard
    costs equals the unsharded plan's cost.

    Correctness of the later gather rests on the partitioner contract:
    events for slot ``s`` are stored only under partition ``h_p(s)``, so a
    shard executing the restricted plan computes exactly the unsharded
    result on the slots it owns (other slots may be stale and are dropped
    at gather time)."""
    out: dict[Any, PlanIR] = {}
    for shard, parts in parts_by_shard.items():
        parts = tuple(sorted(int(p) for p in parts))
        frac = len(parts) / max(int(total_parts), 1)
        nodes = []
        for n in ir.nodes:
            if isinstance(n.op, Fetch):
                nodes.append(dataclasses.replace(
                    n, op=Fetch(n.op.kind, n.op.pid, parts)))
            elif n.weight:
                nodes.append(dataclasses.replace(n, weight=n.weight * frac))
            else:
                nodes.append(n)
        out[shard] = PlanIR(nodes, dict(ir.targets),
                            ir.total_weight * frac, ir.payload_fetches)
    return out
