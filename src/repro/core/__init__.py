"""Core of the paper's contribution: DeltaGraph + GraphPool.

Public surface:

* :class:`~repro.core.events.GraphHistoryBuilder` — ingest activity
* :class:`~repro.core.deltagraph.DeltaGraph` — the hierarchical index
* :class:`~repro.core.graphpool.GraphPool` — overlaid in-memory snapshots
* :class:`~repro.core.manager.GraphManager` — the paper's API façade
* :class:`~repro.core.materialize.MaterializationAdvisor` — workload-aware
  memory materialization + the snapshot LRU cache
* :class:`~repro.core.temporal.TemporalEngine` — incremental evolutionary
  queries over snapshot intervals (``GraphManager.evolve``)
"""
from .deltagraph import DeltaGraph  # noqa: F401
from .errors import (AttrOptionsError, DocumentError, ExecutionError,  # noqa: F401
                     QueryError, TimeExpressionError, UnknownAttributeError,
                     UnknownOperatorError)
from .events import (EventList, GraphHistoryBuilder, GraphUniverse,  # noqa: F401
                     MaterializedState, apply_events, replay)
from .graphpool import GraphPool  # noqa: F401
from .manager import GraphManager, HistGraph  # noqa: F401
from .materialize import (Advice, AdvisorConfig, MaterializationAdvisor,  # noqa: F401
                          SnapshotCache, WorkloadStats)
from .query import AttrOptions, TimeExpression, parse_attr_options  # noqa: F401
from .temporal import (EvolveOp, EvolveResult, PregelFold,  # noqa: F401
                       SnapshotBatchLoader, StepDelta, TemporalEngine)
