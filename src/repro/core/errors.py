"""Typed query-error taxonomy.

Every error a query document (or a legacy entry point) can produce on the
way from wire bytes to an executed plan is a :class:`QueryError` carrying
a stable machine-readable ``code``, a human message, and — for parse
errors — the offending position.  Subclasses *also* derive from the
built-in exception the pre-taxonomy code raised (``ValueError`` /
``KeyError``), so existing callers catching the bare built-ins keep
working while wire-facing code can map any failure to a structured
envelope via :meth:`QueryError.to_dict`.
"""
from __future__ import annotations

from typing import Any


class QueryError(Exception):
    """Base of the taxonomy: ``code`` (stable kind slug), ``message``,
    and optional ``position`` (character offset or field name)."""

    code = "query-error"

    def __init__(self, message: str, *, position: Any = None) -> None:
        super().__init__(message)
        self.message = str(message)
        self.position = position

    def __str__(self) -> str:  # KeyError would repr()-quote the message
        return self.message

    def to_dict(self) -> dict:
        """The wire form embedded in error envelopes."""
        return {"kind": self.code, "message": self.message,
                "position": self.position}


class AttrOptionsError(QueryError, ValueError):
    """Malformed ``attr_options`` syntax (paper Table 1 sub-options)."""

    code = "attr-options"


class UnknownAttributeError(QueryError, KeyError):
    """``attr_options`` names an attribute the universe doesn't have."""

    code = "unknown-attribute"


class TimeExpressionError(QueryError, ValueError):
    """Malformed ``TimeExpression`` infix text (or time index overflow)."""

    code = "time-expression"


class DocumentError(QueryError, ValueError):
    """A :class:`~repro.api.document.GraphQuery` document is structurally
    invalid: unknown kind/field, missing required field, bad type, or an
    unsupported schema version.  ``position`` is the field name."""

    code = "document"


class UnknownOperatorError(QueryError, ValueError):
    """An evolve document names an operator the temporal engine doesn't
    register."""

    code = "unknown-operator"


class ExecutionError(QueryError, RuntimeError):
    """A validated document failed during plan execution; wraps the
    underlying exception (``__cause__``) for the wire envelope."""

    code = "execution"


class DeadlineError(QueryError, TimeoutError):
    """A request carrying ``deadline_ms`` cannot meet it: either it
    expired while queued, or the planner's decode-aware cost estimate for
    its retrieval already exceeds the remaining budget.  Raised *before*
    execution — a deadline-rejected request performs no KV gets."""

    code = "deadline"


class OverloadedError(QueryError, RuntimeError):
    """Admission control shed this request: queued work (queue depth x
    estimated plan cost) exceeds the scheduler's drain-horizon capacity.
    Clients should back off and retry."""

    code = "overloaded"


class BackpressureError(QueryError, RuntimeError):
    """The session holds too many in-flight pooled snapshots (``lease``
    replies) against its GraphPool byte budget; release leases (or
    disconnect) before issuing more queries."""

    code = "backpressure"
