"""Streaming ingest pipeline with group commit and red/green epochs (§6).

The paper's system "maintain[s] the current state for ongoing updates"
while serving historical snapshot queries.  This module is that write
path at production rate:

* **Group commit** — live events batch into commit groups; each group is
  appended to a write-ahead log in the KV store (``⟨0, -2, wal.<start>⟩``
  keys, columnar-packed) and made durable with **one** durability barrier
  (:meth:`KVStore.sync`) per group, not one per event.  A group is *acked*
  only after its WAL record is synced — a crash before the sync loses
  only unacked events.

* **Epoch publish per group** — visibility is a cheap
  :meth:`DeltaGraph.clone_for_commit` (same skeleton, extended ``recent``)
  published atomically through the manager's
  :class:`~repro.core.epoch.EpochRegistry`; readers pinned to an older
  epoch keep their exact ``recent`` tail.

* **Red/green rollover** — once ``recent`` reaches ``L`` events the
  full-leaf prefix is folded on a **shadow fork** of the skeleton
  (:meth:`DeltaGraph.fork`), optionally on a background worker thread,
  while readers keep querying the red version.  The green→red switch is
  one atomic epoch publish; superseded cap-delta payloads and pool pins
  are reclaimed only after every reader of the red epoch drains
  (deferred reclamation), and fully folded WAL groups are truncated once
  the new skeleton is durable.

Crash windows (exercised exhaustively by ``tests/test_ingest_faults.py``
via the :data:`CRASH_POINTS` checkpoints): pre-sync loses only unacked
events; post-sync/pre-publish recovers them from the WAL; a crash
anywhere inside the swap recovers either the old skeleton + full WAL or
the new skeleton + truncated WAL — never a half-built one, because the
skeleton record and the WAL truncation are ordered behind the data sync.
"""
from __future__ import annotations

import queue
import sys
import threading
import time
from collections import deque

import numpy as np

from ..storage import codec
from ..storage import columnar as col
from .deltagraph import DeltaGraph
from .epoch import EpochData
from .events import EventList, GraphUniverse

__all__ = ["IngestPipeline", "CRASH_POINTS", "recover_index"]

# WAL keys live beside the skeleton in the payload key space:
# ⟨partition 0, delta_id -2, "wal.<zero-padded global start position>"⟩.
WAL_DELTA_ID = -2
_WAL_PREFIX = "wal."

#: Named checkpoints the fault-injection harness can crash at
#: (tests/faultlib.py installs a hook raising at one of these).
CRASH_POINTS = (
    "commit:pre-append",     # before the WAL record is written at all
    "commit:pre-sync",       # WAL appended but not yet durable
    "commit:post-sync",      # durable, not yet visible (pre-publish)
    "commit:pre-publish",    # pool updated, epoch not yet published
    "rollover:pre-fold",     # before the green fork starts folding
    "rollover:pre-save",     # folded, new skeleton not yet written
    "rollover:post-save",    # skeleton durable, WAL not yet truncated
    "rollover:pre-publish",  # mid-swap: everything durable, red still live
)


def wal_key(start: int) -> tuple:
    return (0, WAL_DELTA_ID, f"{_WAL_PREFIX}{start:020d}")


def encode_wal_group(ev: EventList, start: int) -> bytes:
    # raw codec, always: WAL records live only until the next rollover
    # truncates them, so compression buys nothing — but the encode sits on
    # the group-commit path where every CPU cycle is commit latency (the
    # v2 varint path is ~100x slower per group).  decode_blob sniffs the
    # format, so recovery reads either encoding.
    return codec.encode_blob({
        "time": ev.time, "etype": ev.etype, "slot": ev.slot,
        "attr_col": ev.attr_col, "value": ev.value,
        "old_value": ev.old_value,
        "meta": np.asarray([start], np.int64)}, codec="raw")


def decode_wal_group(blob: bytes) -> tuple[EventList, int]:
    a = col.unpack_arrays(blob)
    ev = EventList(a["time"], a["etype"], a["slot"], a["attr_col"],
                   a["value"], a["old_value"])
    return ev, int(a["meta"][0])


def _wal_keys(store) -> list[tuple]:
    return [k for k in store.keys()
            if k[0] == 0 and k[1] == WAL_DELTA_ID
            and str(k[2]).startswith(_WAL_PREFIX)]


def recover_index(universe: GraphUniverse, store) -> DeltaGraph:
    """Reopen the index after a crash: load the last durable skeleton,
    rebuild the append machinery, and replay the WAL tail past the folded
    prefix.  Returns a DeltaGraph ready for both queries and appends —
    its ``recent`` holds every group-committed event not yet folded."""
    dg = DeltaGraph.load_skeleton(universe, store)
    for info in dg.nodes.values():
        # pool pins do not survive a restart
        info.materialized_as = None
        info.mat_node_cols = info.mat_edge_cols = None
    dg.restore_append_state()
    folded = dg.leaf_pos[-1]
    groups = []
    for key in _wal_keys(store):
        ev, start = decode_wal_group(store.get(key))
        groups.append((start, ev))
    groups.sort(key=lambda g: g[0])
    parts, pos = [], folded
    for start, ev in groups:
        end = start + len(ev)
        if end <= pos:          # fully folded group the truncation missed
            continue
        if start < pos:         # group straddling the folded boundary
            ev = ev[pos - start:]
            start = pos
        if start != pos:
            raise RuntimeError(
                f"WAL gap: have events up to {pos}, next group at {start}")
        parts.append(ev)
        pos = end
    dg.recent = EventList.concat(parts) if parts else EventList.empty()
    dg._total_events = pos
    return dg


class IngestPipeline:
    """Production-rate write path for one :class:`GraphManager`.

    Synchronous mode (default — what ``GraphManager.update`` shims onto)
    commits each ``append()`` as one group and folds rollovers inline.
    Threaded mode (``threaded=True``) runs a writer thread that coalesces
    ``submit()``-ed events into commit groups (up to ``group_events``
    events or ``group_window_s`` seconds) and folds rollovers on a
    background worker while commits continue.
    """

    def __init__(self, gm, *, group_events: int = 256,
                 group_window_s: float = 0.005, wal: bool = True,
                 auto_rollover: bool = True, threaded: bool = False) -> None:
        self.gm = gm
        self.group_events = int(group_events)
        self.group_window_s = float(group_window_s)
        self.wal = bool(wal)
        self.auto_rollover = bool(auto_rollover)
        self.threaded = bool(threaded)
        # test hook: callable(checkpoint_name), may raise to simulate a
        # crash at that point (tests/faultlib.py)
        self.crash_hook = None

        # serializes commit + publish (writer thread vs rollover worker)
        self._state_lock = threading.Lock()
        self._rollover_lock = threading.Lock()   # one fold at a time
        self._cv = threading.Condition()
        self.submitted_events = 0
        self.committed_events = 0
        self.groups_committed = 0
        self.rollovers = 0
        self.wal_bytes = 0
        #: per-group freshness lag seconds (enqueue → epoch publish)
        self.freshness_lags: deque[float] = deque(maxlen=4096)
        self._error: BaseException | None = None
        self._baseline_done = False

        self._q: queue.Queue = queue.Queue()
        self._stop = False
        self._writer: threading.Thread | None = None
        self._roll_worker: threading.Thread | None = None
        self._roll_wanted = threading.Event()
        self._roll_inflight = False
        self._old_switch: float | None = None
        if self.threaded:
            # background writer/rebuild threads share the interpreter with
            # latency-sensitive readers; the default ~5 ms forced-switch
            # interval lets one CPU burst stall a whole query.  Tighten it
            # well below a typical sub-ms query while the pipeline is live
            # (restored in close()) so a contending reader interleaves at
            # fine grain instead of waiting out writer bursts.
            self._old_switch = sys.getswitchinterval()
            sys.setswitchinterval(0.0002)
            self._writer = threading.Thread(target=self._writer_loop,
                                            name="ingest-writer", daemon=True)
            self._writer.start()
            self._roll_worker = threading.Thread(target=self._roll_loop,
                                                 name="ingest-rebuild",
                                                 daemon=True)
            self._roll_worker.start()

    # ------------------------------------------------------------ helpers
    def _checkpoint(self, name: str) -> None:
        hook = self.crash_hook
        if hook is not None:
            hook(name)

    def _raise_if_failed(self) -> None:
        if self._error is not None:
            raise RuntimeError("ingest pipeline failed") from self._error

    def _ensure_baseline(self) -> None:
        """First use with WAL enabled: persist the build-time skeleton and
        WAL the build-time ``recent`` tail so recovery has a floor even if
        no rollover ever happens."""
        if self._baseline_done or not self.wal:
            return
        self._baseline_done = True
        gm = self.gm
        if (0, -1, "skeleton") in gm.store:
            return
        dg = gm.epochs.current_data.dg
        dg.save_skeleton()
        if len(dg.recent):
            start = dg._total_events - len(dg.recent)
            gm.store.put(wal_key(start), encode_wal_group(dg.recent, start))
        gm.store.sync()

    def _publish_locked(self, data: EpochData, reclaims=()) -> None:
        """Atomic epoch swap + re-point of everything that dereferences
        ``gm.dg`` directly (legacy callers, the advisor).  Caller holds
        ``_state_lock``."""
        gm = self.gm
        gm.epochs.publish(data, reclaims)
        gm.dg = data.dg
        with gm._advisor_lock:
            if gm.advisor is not None:
                gm.advisor.dg = data.dg

    def _yield_gil(self) -> None:
        """Hand the GIL to concurrent readers between commit steps.  The
        whole commit burst is ~1-2 ms of CPU; without explicit yields a
        reader mid-query waits out the burst (the interpreter only forces
        a switch every ~5 ms), which shows up directly in query p99 on
        few-core boxes.  ``sleep(0)`` is not enough: the releaser usually
        re-acquires the GIL before the waiter wakes, so we block for a
        real (but tiny) interval.  Readers never take ``_state_lock``, so
        yielding while holding it is safe."""
        if self.threaded:
            time.sleep(0.0005)

    # ------------------------------------------------------------- commit
    def _commit_group(self, ev: EventList, t_enqueue: float | None) -> None:
        if not len(ev):
            return
        gm = self.gm
        with self._state_lock:
            self._ensure_baseline()
            data = gm.epochs.current_data
            start = data.n_events
            self._checkpoint("commit:pre-append")
            if self.wal:
                key = wal_key(start)
                blob = encode_wal_group(ev, start)
                self._yield_gil()
                gm.store.put(key, blob)
                self._checkpoint("commit:pre-sync")
                gm.store.sync()                      # the durability point
                self.wal_bytes += len(blob)
            self._checkpoint("commit:post-sync")
            self._yield_gil()
            gm.pool.update_current(ev)
            self._yield_gil()
            new_dg = data.dg.clone_for_commit(ev)
            self._checkpoint("commit:pre-publish")
            new_data = EpochData(new_dg, start + len(ev),
                                 max(data.max_time, int(ev.time.max())))
            self._publish_locked(new_data)
            # scoped invalidation: only cached results a time-overlapping
            # append can change (see SnapshotCache.invalidate_from)
            if gm.cache is not None:
                gm.cache.invalidate_from(int(ev.time.min()))
                gm.cache.invalidate_epochs_before(gm.epochs.current_id)
        with self._cv:
            self.committed_events += len(ev)
            self.groups_committed += 1
            self._cv.notify_all()
        if t_enqueue is not None:
            self.freshness_lags.append(time.perf_counter() - t_enqueue)
        if self.auto_rollover and len(new_dg.recent) >= new_dg.L:
            if self.threaded:
                self._roll_wanted.set()
            else:
                self._rollover()

    # ----------------------------------------------------------- rollover
    def _rollover(self) -> None:
        """Fold every full leaf of ``recent`` on a green fork of the
        skeleton, then swap it in with one epoch publish."""
        gm = self.gm
        with self._rollover_lock:
            base = gm.epochs.current_data.dg
            if len(base.recent) < base.L:
                return
            self._checkpoint("rollover:pre-fold")
            green = base.fork()
            sink: list = []
            green.reclaim_sink = sink
            if self.threaded:
                # The fold runs on the rebuild worker but shares the GIL
                # with latency-sensitive readers, so between fold steps it
                # sleeps long enough that readers own the core while the
                # backlog is small (see _yield_gil for why sleep(0) won't
                # do).  Politeness is graduated: the sleep shrinks linearly
                # as the unfolded backlog approaches ~2 leaves and vanishes
                # past it, so fold throughput self-tunes to the offered
                # write rate instead of oscillating between a fixed nap
                # and a full-speed panic fold.
                reg = gm.epochs
                backlog_cap = 2 * base.L

                def _nice_sleep() -> None:
                    frac = len(reg.current_data.dg.recent) / backlog_cap
                    if frac < 1.0:
                        time.sleep(0.004 * (1.0 - frac))

                green.nice = _nice_sleep
                # also yield between individual array encodes — a single
                # pack_arrays() over leaf-sized arrays is otherwise the
                # longest GIL hold of the whole fold.  Cleared in the
                # finally below (per-thread hook, crash tests raise here).
                codec.set_encode_nice(_nice_sleep)
            try:
                self._rollover_body(green, sink)
            finally:
                codec.set_encode_nice(None)
                green.nice = None

    def _rollover_body(self, green, sink: list) -> None:
        gm = self.gm
        forked_len = len(green.recent)
        green.append_events(EventList.empty())   # folds full chunks
        n_folded = forked_len - len(green.recent)
        green.reclaim_sink = None
        self.rollovers += 1
        with self._state_lock:
            latest = gm.epochs.current_data
            # splice commits that landed while the fold ran: red's
            # recent is (forked recent + appended groups), the fold
            # consumed the first n_folded of it
            green.recent = latest.dg.recent[n_folded:]
            green._total_events = latest.dg._total_events
            green._last_leaf_state = \
                green._last_leaf_state.resized(green.universe)
            self._checkpoint("rollover:pre-save")
            if self.wal:
                # green.nice is still set: save_skeleton yields between
                # its phases too (it is the last multi-ms CPU stretch
                # before the swap)
                self._yield_gil()
                green.save_skeleton()
                self._yield_gil()
                gm.store.sync()                  # skeleton durable
            green.nice = None        # published dg carries no hook
            self._checkpoint("rollover:post-save")
            folded_pos = green.leaf_pos[-1]
            if self.wal:
                # truncate fully folded groups — recovery now starts
                # from the just-saved skeleton.  Groups are contiguous,
                # so a group ends where the next one starts; the last
                # group's end is unknown from its key alone, so it is
                # conservatively kept (recovery skips folded records).
                wkeys = sorted(_wal_keys(gm.store))
                starts = [int(str(k[2])[len(_WAL_PREFIX):])
                          for k in wkeys]
                for i, k in enumerate(wkeys[:-1]):
                    if starts[i + 1] <= folded_pos:
                        gm.store.delete(k)
            reclaims = []
            if sink:
                store = gm.store
                dead_keys = list(sink)
                reclaims.append(lambda: [store.delete(k)
                                         for k in dead_keys])
            # pins on cap nodes the fold tore down: unpin now (new
            # plans must not route through them), release the pool
            # graphs only once pinned readers drain
            with gm._advisor_lock:
                adv = gm.advisor
                stale_pins = {}
                if adv is not None:
                    for nid in [n for n in adv.pinned
                                if n not in green.nodes]:
                        stale_pins[nid] = adv.pinned.pop(nid)
                if stale_pins:
                    pool = gm.pool
                    gids = list(stale_pins.values())
                    reclaims.append(lambda: [pool.release(g)
                                             for g in gids])
                    if gm.cache is not None:
                        gm.cache.invalidate_deps(list(stale_pins))
            self._checkpoint("rollover:pre-publish")
            self._publish_locked(
                EpochData(green, latest.n_events, latest.max_time),
                reclaims)
            gm.pool.mark_flushed()
            if gm.cache is not None:
                gm.cache.invalidate_epochs_before(gm.epochs.current_id)

    # -------------------------------------------------------- public API
    def append(self, ev: EventList) -> None:
        """Synchronous ingest of one event batch as one commit group (the
        ``GraphManager.update`` shim).  Returns after the group is durable
        and visible; rollovers fold inline (sync mode) or are scheduled
        (threaded mode)."""
        self._raise_if_failed()
        if self.threaded:
            self.submit(ev)
            self.drain()
            return
        t0 = time.perf_counter()
        with self._cv:
            self.submitted_events += len(ev)
        self._commit_group(ev, t0)

    def submit(self, ev: EventList) -> None:
        """Enqueue events for the writer thread (threaded mode); returns
        immediately.  In sync mode this is :meth:`append`."""
        self._raise_if_failed()
        if not self.threaded:
            self.append(ev)
            return
        with self._cv:
            self.submitted_events += len(ev)
        self._q.put((ev, time.perf_counter()))

    def drain(self, timeout: float | None = 30.0) -> None:
        """Block until every submitted event is committed and no rollover
        is in flight."""
        deadline = (time.monotonic() + timeout) if timeout else None
        with self._cv:
            while True:
                self._raise_if_failed()
                if (self.committed_events >= self.submitted_events
                        and not self._roll_inflight
                        and not self._roll_wanted.is_set()):
                    return
                remaining = ((deadline - time.monotonic())
                             if deadline else None)
                if remaining is not None and remaining <= 0:
                    raise TimeoutError("ingest drain timed out")
                self._cv.wait(timeout=remaining)

    def close(self) -> None:
        """Stop worker threads (threaded mode).  Does not flush the store;
        the owning manager's ``close()`` does."""
        self._stop = True
        if self._writer is not None:
            self._q.put(None)
            self._writer.join(timeout=10)
            self._writer = None
        if self._roll_worker is not None:
            self._roll_wanted.set()
            self._roll_worker.join(timeout=10)
            self._roll_worker = None
        if self._old_switch is not None:
            sys.setswitchinterval(self._old_switch)
            self._old_switch = None

    def stats(self) -> dict:
        lags = list(self.freshness_lags)
        return {"submitted_events": self.submitted_events,
                "committed_events": self.committed_events,
                "groups_committed": self.groups_committed,
                "rollovers": self.rollovers,
                "wal_bytes": self.wal_bytes,
                "freshness_lag_mean_ms": (1e3 * float(np.mean(lags))
                                          if lags else None),
                "freshness_lag_p99_ms": (1e3 * float(np.quantile(lags, 0.99))
                                         if lags else None),
                "epochs": self.gm.epochs.stats()}

    # -------------------------------------------------------- worker loops
    def _writer_loop(self) -> None:
        while True:
            try:
                item = self._q.get(timeout=0.05)
            except queue.Empty:
                if self._stop:
                    return
                continue
            if item is None:
                return
            chunks = [item[0]]
            t_enq = item[1]
            n = len(item[0])
            deadline = time.perf_counter() + self.group_window_s
            while n < self.group_events:
                budget = deadline - time.perf_counter()
                if budget <= 0:
                    break
                try:
                    nxt = self._q.get(timeout=budget)
                except queue.Empty:
                    break
                if nxt is None:
                    self._stop = True
                    break
                chunks.append(nxt[0])
                n += len(nxt[0])
            group = (chunks[0] if len(chunks) == 1
                     else EventList.concat(chunks))
            try:
                self._commit_group(group, t_enq)
            except BaseException as e:   # noqa: BLE001 - surfaced via drain
                self._error = e
                with self._cv:
                    self._cv.notify_all()
                return
            if self._stop and self._q.empty():
                return

    def _roll_loop(self) -> None:
        while True:
            self._roll_wanted.wait()
            if self._stop:
                return
            with self._cv:
                self._roll_inflight = True
            self._roll_wanted.clear()
            try:
                while True:
                    dg = self.gm.epochs.current_data.dg
                    if len(dg.recent) < dg.L:
                        break
                    self._rollover()
            except BaseException as e:   # noqa: BLE001
                self._error = e
            finally:
                with self._cv:
                    self._roll_inflight = False
                    self._cv.notify_all()
