"""Incremental temporal analytics over snapshot intervals (paper §1/§4:
"support for temporal and evolutionary queries and analysis").

A per-snapshot analytics loop retrieves *every* timepoint through the
planner and re-runs each algorithm from scratch — O(points) full plans
and O(points) cold solves.  This engine exploits that consecutive
interval timepoints differ by a small slice of the eventlist:

1. only the **first** snapshot of the interval is retrieved through the
   plan IR (cache, advisor, prefetch — the whole PR-2 stack applies);
2. every subsequent timepoint advances the running state by the
   inter-snapshot event slice ``(t_prev, t_cur]`` pulled from the leaf
   eventlists already persisted in the KV store — each covering leaf
   payload is fetched **once per evolve call** (and prefetched
   asynchronously), however many timepoints it spans;
3. analytic state advances *incrementally*: degrees/density update in
   O(|delta|), PageRank warm-starts from the previous ranks with the
   delta-touched frontier reset, connected components re-union only
   affected components, and a generic fold warm-starts
   :func:`repro.graph.pregel.run_pregel_until` supersteps.

Incremental results match a per-snapshot recompute: masks are
bit-identical (same event algebra), fixpoint solvers agree within their
convergence tolerance (``tests/test_differential_exec.py``).

The batched-device counterpart (B intervals at once, vmapped prefix
bitmap chains) is :func:`repro.runtime.jax_exec.evolve_intervals_jax`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import numpy as np

from ..storage import columnar as col
from .events import (EV_DEL_EDGE, EV_DEL_NODE, EV_NEW_EDGE, EV_NEW_NODE,
                     MaterializedState, apply_events)
from .query import NO_ATTRS, AttrOptions, TimeExpression

# ---------------------------------------------------------------------------
# inter-snapshot event slices
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepDelta:
    """Net structural change over one inter-snapshot slice ``(lo, hi]``.

    ``*_add``/``*_del`` are **net** slot sets computed by ±1 count
    accumulation per slot — an element added *and* deleted inside the
    slice appears in neither (this is what makes the arrays safe for
    incremental operators: a net-zero toggle must not touch degrees)."""
    lo: int
    hi: int
    node_add: np.ndarray
    node_del: np.ndarray
    edge_add: np.ndarray
    edge_del: np.ndarray

    def touched_nodes(self, edge_src: np.ndarray,
                      edge_dst: np.ndarray) -> np.ndarray:
        """Every node whose neighborhood changed — the frontier reset set
        for warm-started solvers."""
        parts = [self.node_add, self.node_del]
        for e in (self.edge_add, self.edge_del):
            if e.size:
                parts.append(edge_src[e])
                parts.append(edge_dst[e])
        return (np.unique(np.concatenate(parts)).astype(np.int64)
                if parts else np.zeros(0, np.int64))

    @property
    def n_changes(self) -> int:
        return (self.node_add.size + self.node_del.size
                + self.edge_add.size + self.edge_del.size)


def _net_quad(etype: np.ndarray, slot: np.ndarray
              ) -> tuple[np.ndarray, ...]:
    """±1-count net membership change per slot (handles slots toggled
    multiple times inside one slice, unlike a plain set difference)."""
    out = []
    for add_code, del_code in ((EV_NEW_NODE, EV_DEL_NODE),
                               (EV_NEW_EDGE, EV_DEL_EDGE)):
        a = slot[etype == add_code]
        d = slot[etype == del_code]
        if a.size == 0 and d.size == 0:
            out.append(np.zeros(0, np.int32))
            out.append(np.zeros(0, np.int32))
            continue
        slots, inv = np.unique(np.concatenate([a, d]), return_inverse=True)
        net = np.zeros(slots.size, np.int64)
        np.add.at(net, inv[: a.size], 1)
        np.add.at(net, inv[a.size:], -1)
        out.append(slots[net > 0].astype(np.int32))
        out.append(slots[net < 0].astype(np.int32))
    return tuple(out)


class IntervalSlicer:
    """Streams ``(lo, hi]`` slices of the history to the engine.

    Fetches each covering leaf-eventlist payload at most once per slicer
    lifetime (an interval whose timepoints fall inside one leaf touches
    the KV store once, not once per point) and, when a
    :class:`~repro.runtime.executor.Prefetcher` is supplied, submits the
    whole interval's payload key lists up front so store gets overlap the
    per-point analytics."""

    def __init__(self, dg, options: AttrOptions = NO_ATTRS,
                 prefetcher=None) -> None:
        self.dg = dg
        self.options = options
        self.prefetcher = prefetcher
        self._comps: dict[int, dict] = {}      # leaf index -> decoded comps
        self._futs: dict[int, object] = {}     # leaf index -> decode future

    def prefetch_interval(self, lo: int, hi: int) -> None:
        if self.prefetcher is None:
            return
        for i in self.dg.elists_covering(lo, hi):
            if i in self._comps or i in self._futs:
                continue
            e = self.dg.edges[self.dg._leaf_elist_eid(i)]
            keys = self.dg._elist_keys(e.payload_id, self.options)
            # fetch *and* decode in the worker thread — the per-point
            # analytics loop consumes ready component arrays
            self._futs[i] = self.prefetcher.submit(
                keys, decode=lambda blobs, keys=keys:
                    self.dg._decode_elist(keys, blobs))

    def _leaf_comps(self, i: int) -> dict:
        comps = self._comps.get(i)
        if comps is None:
            fut = self._futs.pop(i, None)
            if fut is not None:
                comps = fut.result()
            else:
                e = self.dg.edges[self.dg._leaf_elist_eid(i)]
                comps = self.dg._fetch_elist(e.payload_id, self.options)
            self._comps[i] = comps
        return comps

    def quad(self, lo: int, hi: int) -> StepDelta:
        """Net structural delta of the slice ``(lo, hi]`` (no state
        advance — the device path applies it as bitmap planes instead)."""
        dg = self.dg
        ets, sls = [], []
        for i in dg.elists_covering(lo, hi):
            s = self._leaf_comps(i)[col.ELIST_STRUCT]
            m = (s["time"] > lo) & (s["time"] <= hi)
            ets.append(s["etype"][m])
            sls.append(s["slot"][m])
        rec = dg.recent
        if len(rec):
            a = rec.search_time(lo, side="right")
            b = rec.search_time(hi, side="right")
            if b > a:
                ets.append(rec.etype[a:b])
                sls.append(rec.slot[a:b])
        et = np.concatenate(ets) if ets else np.zeros(0, np.int8)
        sl = np.concatenate(sls) if sls else np.zeros(0, np.int32)
        na, nd, ea, ed = _net_quad(et, sl)
        return StepDelta(lo, hi, na, nd, ea, ed)

    def advance(self, state: MaterializedState, lo: int, hi: int
                ) -> tuple[MaterializedState, StepDelta]:
        """Advance ``state`` (a snapshot at ``lo``) to the snapshot at
        ``hi`` and return it with the slice's net structural delta.
        Each covering leaf's rows are filtered once, feeding both the
        state advance and the quad."""
        dg = self.dg
        ets, sls = [], []
        for i in dg.elists_covering(lo, hi):
            comps = self._leaf_comps(i)
            state = dg._apply_elist(state, comps, True, (lo, hi),
                                    self.options)
            s = comps[col.ELIST_STRUCT]
            m = (s["time"] > lo) & (s["time"] <= hi)
            ets.append(s["etype"][m])
            sls.append(s["slot"][m])
        rec = dg.recent
        if len(rec):
            a = rec.search_time(lo, side="right")
            b = rec.search_time(hi, side="right")
            if b > a:
                state = apply_events(state, rec[a:b], forward=True)
                ets.append(rec.etype[a:b])
                sls.append(rec.slot[a:b])
        et = np.concatenate(ets) if ets else np.zeros(0, np.int8)
        sl = np.concatenate(sls) if sls else np.zeros(0, np.int32)
        na, nd, ea, ed = _net_quad(et, sl)
        return state, StepDelta(lo, hi, na, nd, ea, ed)


# ---------------------------------------------------------------------------
# incremental operators
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EvolveContext:
    """Shared per-evolve state handed to operators."""
    universe: Any
    edge_src: np.ndarray
    edge_dst: np.ndarray
    kwargs: dict
    _jnp_edges: tuple | None = None

    def jnp_edges(self) -> tuple:
        if self._jnp_edges is None:
            import jax.numpy as jnp
            self._jnp_edges = (jnp.asarray(self.edge_src),
                               jnp.asarray(self.edge_dst))
        return self._jnp_edges


class EvolveOp:
    """Operator contract: ``init`` computes the value at the interval's
    first snapshot (cold); ``step`` advances it by one
    :class:`StepDelta`.  The invariant every operator must keep —
    enforced by the differential harness — is

        step(init(S_{t0}), delta_{t0→t1}, S_{t1}) == init(S_{t1})

    up to the operator's stated tolerance (exact for counting operators,
    convergence-tol for fixpoint solvers).  ``iters`` (when set) reports
    the last solve's iteration count, the quantity the warm start
    shrinks."""

    iters: int | None = None

    def init(self, ctx: EvolveContext, state: MaterializedState,
             t: int) -> Any:
        raise NotImplementedError

    def step(self, ctx: EvolveContext, state: MaterializedState,
             delta: StepDelta, t: int) -> Any:
        raise NotImplementedError


class MasksOp(EvolveOp):
    """The raw evolving snapshot: ``(node_mask, edge_mask)`` per point —
    the backend surface the differential harness compares bit-for-bit."""

    def init(self, ctx, state, t):
        return state.node_mask.copy(), state.edge_mask.copy()

    def step(self, ctx, state, delta, t):
        return state.node_mask.copy(), state.edge_mask.copy()


class DegreeOp(EvolveOp):
    """O(|delta|) degree maintenance (both endpoints of live edges)."""

    def __init__(self) -> None:
        self.deg: np.ndarray | None = None

    def init(self, ctx, state, t):
        deg = np.zeros(ctx.universe.num_nodes, np.int64)
        live = np.nonzero(state.edge_mask)[0]
        np.add.at(deg, ctx.edge_src[live], 1)
        np.add.at(deg, ctx.edge_dst[live], 1)
        self.deg = deg
        return deg.copy()

    def step(self, ctx, state, delta, t):
        from ..graph.algorithms import incremental_degrees
        self.deg = incremental_degrees(self.deg, delta.edge_add,
                                       delta.edge_del, ctx.edge_src,
                                       ctx.edge_dst)
        return self.deg.copy()


class DensityOp(EvolveOp):
    """Live element counts + graph density in O(|delta|)."""

    def __init__(self) -> None:
        self.n = 0
        self.e = 0

    @staticmethod
    def _pack(n: int, e: int) -> dict:
        return {"nodes": n, "edges": e,
                "density": (2.0 * e / (n * (n - 1))) if n > 1 else 0.0}

    def init(self, ctx, state, t):
        self.n = int(state.node_mask.sum())
        self.e = int(state.edge_mask.sum())
        return self._pack(self.n, self.e)

    def step(self, ctx, state, delta, t):
        self.n += delta.node_add.size - delta.node_del.size
        self.e += delta.edge_add.size - delta.edge_del.size
        return self._pack(self.n, self.e)


class PageRankOp(EvolveOp):
    """Warm-started masked PageRank: the previous point's ranks seed the
    solver with the delta-touched frontier reset to the uniform
    baseline, so iterations scale with how much the graph moved."""

    def __init__(self, damping: float = 0.85, tol: float = 1e-6,
                 max_iters: int = 200) -> None:
        # tol below ~1e-7 chases float32 segment-sum noise and saturates
        # max_iters on both the warm and cold paths
        self.damping = float(damping)
        self.tol = float(tol)
        self.max_iters = int(max_iters)
        self.pr: np.ndarray | None = None

    def _solve(self, ctx, state, pr0) -> np.ndarray:
        from ..graph.algorithms import pagerank_fixpoint
        from . import bitmaps as bm
        pr, iters = pagerank_fixpoint(
            ctx.edge_src, ctx.edge_dst, bm.np_pack(state.edge_mask),
            bm.np_pack(state.node_mask), pr0,
            num_nodes=ctx.universe.num_nodes, max_iters=self.max_iters,
            damping=self.damping, tol=self.tol)
        self.iters = iters
        self.pr = pr
        return self.pr.copy()

    def init(self, ctx, state, t):
        n_live = max(int(state.node_mask.sum()), 1)
        pr0 = state.node_mask.astype(np.float32) / n_live
        return self._solve(ctx, state, pr0)

    def step(self, ctx, state, delta, t):
        from ..graph.algorithms import pagerank_warm_start
        pr0 = pagerank_warm_start(
            self.pr, state.node_mask,
            delta.touched_nodes(ctx.edge_src, ctx.edge_dst))
        return self._solve(ctx, state, pr0)


class ComponentsOp(EvolveOp):
    """Incremental connected components: components untouched by the
    slice keep their converged labels; components that lost an element
    are reset and re-flooded; components merged by added edges are
    pre-unioned on the host so a merge costs O(1) HashMin sweeps."""

    def __init__(self, max_iters: int = 4096) -> None:
        self.max_iters = int(max_iters)
        self.labels: np.ndarray | None = None

    def _solve(self, ctx, state, labels0) -> np.ndarray:
        from ..graph.algorithms import connected_components_fixpoint
        from . import bitmaps as bm
        labels, iters = connected_components_fixpoint(
            ctx.edge_src, ctx.edge_dst, bm.np_pack(state.edge_mask),
            bm.np_pack(state.node_mask), labels0,
            num_nodes=ctx.universe.num_nodes, max_iters=self.max_iters)
        self.iters = iters
        self.labels = labels
        return self.labels.copy()

    def init(self, ctx, state, t):
        return self._solve(ctx, state,
                           np.arange(ctx.universe.num_nodes, dtype=np.int32))

    def step(self, ctx, state, delta, t):
        from ..graph.algorithms import cc_warm_labels
        labels0 = cc_warm_labels(self.labels, state.node_mask,
                                 (delta.node_add, delta.node_del),
                                 (delta.edge_add, delta.edge_del),
                                 ctx.edge_src, ctx.edge_dst)
        return self._solve(ctx, state, labels0)


class PregelFold(EvolveOp):
    """Generic fold over :func:`repro.graph.pregel.run_pregel_until`:
    the user's vertex program re-converges at every timepoint from the
    previous timepoint's state (``init_fn`` builds the cold state for the
    first snapshot; ``reseed_fn``, if given, may reset the touched
    frontier before each warm solve)."""

    def __init__(self, init_fn: Callable, msg_fn: Callable,
                 update_fn: Callable, *, max_supersteps: int = 64,
                 tol: float = 0.0, bidirectional: bool = True,
                 reseed_fn: Callable | None = None) -> None:
        self.init_fn = init_fn
        self.msg_fn = msg_fn
        self.update_fn = update_fn
        self.max_supersteps = int(max_supersteps)
        self.tol = float(tol)
        self.bidirectional = bool(bidirectional)
        self.reseed_fn = reseed_fn
        self.state = None

    def _solve(self, ctx, snap, state0):
        import jax.numpy as jnp
        from ..graph.pregel import run_pregel_until
        from . import bitmaps as bm
        es, ed = ctx.jnp_edges()
        out, steps = run_pregel_until(
            jnp.asarray(state0), es, ed,
            jnp.asarray(bm.np_pack(snap.edge_mask)),
            self.msg_fn, self.update_fn,
            max_supersteps=self.max_supersteps,
            num_nodes=ctx.universe.num_nodes, tol=self.tol,
            bidirectional=self.bidirectional)
        self.iters = int(steps)
        self.state = np.asarray(out)
        return self.state.copy()

    def init(self, ctx, state, t):
        return self._solve(ctx, state, self.init_fn(ctx, state, t))

    def step(self, ctx, state, delta, t):
        s0 = self.state
        if self.reseed_fn is not None:
            s0 = self.reseed_fn(ctx, state, delta, s0)
        return self._solve(ctx, state, s0)


_OPS: dict[str, Callable[..., EvolveOp]] = {
    "masks": MasksOp,
    "degree": DegreeOp,
    "density": DensityOp,
    "pagerank": PageRankOp,
    "components": ComponentsOp,
}


def resolve_op(op: str | EvolveOp | Callable, kwargs: dict) -> EvolveOp:
    if isinstance(op, str):
        if op not in _OPS:
            from .errors import UnknownOperatorError
            raise UnknownOperatorError(f"unknown evolve op {op!r}; "
                                       f"choose from {sorted(_OPS)}")
        return _OPS[op](**kwargs)
    # an instance or callable carries its own configuration — keyword
    # arguments would be silently dead, so reject them loudly
    if kwargs:
        raise TypeError(f"op_kwargs {sorted(kwargs)} only apply to named "
                        f"operators; configure {op!r} directly")
    if isinstance(op, EvolveOp):
        return op
    if callable(op):
        return _CallableFold(op)
    raise TypeError(f"op must be a name, EvolveOp or callable, got {op!r}")


class _CallableFold(EvolveOp):
    """Wraps a plain callable ``f(prev_value, state, delta, t)``; at the
    first snapshot it is called with ``prev_value=None, delta=None``."""

    def __init__(self, fn: Callable) -> None:
        self.fn = fn
        self.value = None

    def init(self, ctx, state, t):
        self.value = self.fn(None, state, None, t)
        return self.value

    def step(self, ctx, state, delta, t):
        self.value = self.fn(self.value, state, delta, t)
        return self.value


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EvolveResult:
    times: list[int]
    values: list[Any]
    stats: dict

    def __iter__(self):
        return iter(zip(self.times, self.values))


class TemporalEngine:
    """Evolutionary-query engine bound to a
    :class:`~repro.core.manager.GraphManager`."""

    def __init__(self, gm) -> None:
        self.gm = gm

    def evolve(self, times: Sequence[int] | TimeExpression,
               op: str | EvolveOp | Callable = "masks", *,
               attr_options: str | AttrOptions = "",
               use_current: bool = True, incremental: bool = True,
               dg=None, **op_kwargs) -> EvolveResult:
        gm = self.gm
        if isinstance(times, TimeExpression):
            times = list(times.times)
        times = sorted(dict.fromkeys(int(t) for t in times))
        if not times:
            raise ValueError("evolve needs at least one timepoint")
        opts = gm._parse_opts(attr_options)
        operator = resolve_op(op, op_kwargs)
        uni = gm.universe
        ctx = EvolveContext(uni, uni.edge_src, uni.edge_dst, dict(op_kwargs))

        t_start = time.perf_counter()
        if not incremental:
            return self._recompute(times, operator, ctx, opts, use_current,
                                   t_start)

        # dg is the epoch-pinned index version when the service threads one
        # through (api/compiler.py) — every slice and the first snapshot
        # then resolve against one consistent version under live ingest
        pinned = dg is not None
        dg = dg if pinned else gm.dg
        slicer = IntervalSlicer(dg, opts, prefetcher=gm.prefetcher)
        slicer.prefetch_interval(times[0], times[-1])
        if pinned:
            state = dg.get_snapshot(times[0], opts, pool=gm.pool,
                                    use_current=use_current)
        else:
            state = gm.get_snapshot(times[0], opts, use_current=use_current)
        state = state.resized(uni).copy()
        values = [operator.init(ctx, state, times[0])]
        iters = [operator.iters]
        changes = 0
        for lo, hi in zip(times, times[1:]):
            state, delta = slicer.advance(state, lo, hi)
            changes += delta.n_changes
            values.append(operator.step(ctx, state, delta, hi))
            iters.append(operator.iters)
        wall = time.perf_counter() - t_start
        gm.workload.record_interval(dg._leaf_for_time(times[0]),
                                    dg._leaf_for_time(times[-1]),
                                    len(times), wall_s=wall)
        stats = {"points": len(times), "incremental": True,
                 "elists_fetched": len(slicer._comps),
                 "net_changes": changes, "wall_s": wall,
                 "solver_iters": iters if iters[0] is not None else None}
        return EvolveResult(times, values, stats)

    def _recompute(self, times, operator, ctx, opts, use_current,
                   t_start) -> EvolveResult:
        """Per-snapshot recompute baseline: every timepoint is planned,
        retrieved and solved cold — the engine the incremental path is
        benchmarked against (``BENCH_temporal.json``)."""
        gm = self.gm
        values = []
        iters = []
        for t in times:
            state = gm.get_snapshot(t, opts, use_current=use_current)
            state = state.resized(gm.universe)
            values.append(operator.init(ctx, state, t))
            iters.append(operator.iters)
        wall = time.perf_counter() - t_start
        stats = {"points": len(times), "incremental": False,
                 "wall_s": wall,
                 "solver_iters": iters if iters[0] is not None else None}
        return EvolveResult(list(times), values, stats)


# ---------------------------------------------------------------------------
# snapshot batch streaming (training workloads)
# ---------------------------------------------------------------------------


class SnapshotBatchLoader:
    """Streams windows of interval snapshots as model-ready batches.

    Each batch covers ``batch_size`` consecutive timepoints of ``times``.
    The masks come from the batched device path
    (:func:`repro.runtime.jax_exec.evolve_intervals_jax`: one Steiner
    retrieval for the window start, then the double-buffered prefix-chain
    sweep), and per-node degree features come from the fused analytics
    kernel — the unpacked live-edge indicator it emits is reduced by the
    segment_sum kernel, so features never take a numpy scatter pass.

    Batch dict (all jnp, static shapes across batches — jit-stable):

    * ``x           [T, N, d_in] f32`` — degree features (random
      projection of degree + raw degree, matching the GNN example),
    * ``edge_index  [2, 2E] i32``     — every universe edge, both
      directions (liveness is carried by the mask, not by selection),
    * ``edge_mask   [T, 2E] f32``,
    * ``label_mask  [T, N]  f32``     — live nodes at each timepoint,
    * ``labels      [T, N]  i32``     — degree growth at
      ``t + label_horizon`` (only with a horizon),
    * ``num_edges   [T]     i32``     — fused popcount totals,
    * ``times       list[int]``.

    The last window is dropped if shorter than ``batch_size`` (static
    shapes); with ``label_horizon`` the horizon snapshots retrieve in the
    same batched device call as the window itself.
    """

    def __init__(self, gm, times: Sequence[int], *, batch_size: int = 4,
                 label_horizon: int | None = None, d_in: int = 16,
                 seed: int = 0, impl: str | None = None) -> None:
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.gm = gm
        self.times = sorted(dict.fromkeys(int(t) for t in times))
        self.batch_size = int(batch_size)
        self.label_horizon = (None if label_horizon is None
                              else int(label_horizon))
        self.d_in = int(d_in)
        self.impl = impl
        rng = np.random.default_rng(seed)
        self._proj = rng.standard_normal((1, self.d_in - 1)).astype(
            np.float32)
        uni = gm.universe
        E = uni.num_edges
        src, dst = uni.edge_src[:E], uni.edge_dst[:E]
        self._edge_index = np.stack(
            [np.concatenate([src, dst]), np.concatenate([dst, src])]
        ).astype(np.int32)

    def __len__(self) -> int:
        return len(self.times) // self.batch_size

    def _degrees(self, edge_masks: list[np.ndarray]):
        """Fused-kernel analytics over the window's edge planes: one K=0
        batched fused call lands popcounts + the live indicator, then the
        segment_sum kernel reduces per-node degrees on device."""
        import jax.numpy as jnp
        from .bitmaps import np_pack
        from ..kernels import delta_apply_fused_batched, segment_sum
        uni = self.gm.universe
        E, N = uni.num_edges, uni.num_nodes
        bases = np.stack([np_pack(em) for em in edge_masks])
        T, W = bases.shape
        fe = delta_apply_fused_batched(
            jnp.asarray(bases), jnp.zeros((T, 0, W), jnp.uint32),
            jnp.zeros((T, 0, W), jnp.uint32), impl=self.impl)
        src = jnp.asarray(uni.edge_src[:E])
        dst = jnp.asarray(uni.edge_dst[:E])
        deg = np.stack([
            np.asarray(segment_sum(fe.live[t, :E][:, None], src, N,
                                   impl=self.impl)
                       + segment_sum(fe.live[t, :E][:, None], dst, N,
                                     impl=self.impl)).reshape(-1)
            for t in range(T)])
        return deg.astype(np.float32), fe.live_count().astype(np.int32)

    def __iter__(self):
        import jax.numpy as jnp
        from ..runtime.jax_exec import evolve_intervals_jax
        gm, bs, hz = self.gm, self.batch_size, self.label_horizon
        for i in range(len(self)):
            window = self.times[i * bs:(i + 1) * bs]
            intervals = [window]
            if hz is not None:
                intervals.append(sorted({t + hz for t in window}))
            res = evolve_intervals_jax(gm.dg, intervals, impl=self.impl,
                                       pool=gm.pool,
                                       prefetch=gm.prefetcher)
            masks = res[0]
            node_masks = [masks[t][0] for t in window]
            deg, num_edges = self._degrees([masks[t][1] for t in window])
            x = np.concatenate(
                [deg[:, :, None] * self._proj[None] * 0.1,
                 deg[:, :, None]], axis=2)
            # edge liveness, both directions (edge_index order)
            live = np.stack([masks[t][1].astype(np.float32)
                             for t in window])
            em = np.concatenate([live, live], axis=1)
            batch = {
                "x": jnp.asarray(x),
                "edge_index": jnp.asarray(self._edge_index),
                "edge_mask": jnp.asarray(em),
                "label_mask": jnp.asarray(
                    np.stack(node_masks).astype(np.float32)),
                "num_edges": jnp.asarray(num_edges),
                "times": list(window),
            }
            if hz is not None:
                fmasks = res[1]
                fdeg, _ = self._degrees(
                    [fmasks[t + hz][1] for t in window])
                batch["labels"] = jnp.asarray(
                    (fdeg > deg).astype(np.int32))
            yield batch
