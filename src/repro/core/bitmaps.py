"""Packed-bitmap primitives (the paper's per-element ``BM`` strings, §6).

Membership sets over a dense slot universe are stored as packed ``uint32``
words, little-endian bit order: element ``i`` lives at bit ``i & 31`` of word
``i >> 5``.  Construction-time code paths use the numpy variants; the
query-time execution engine uses the jnp variants (jit-compatible) and, for
the hot fused path, the Pallas kernel in ``repro.kernels.delta_apply``.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

WORD_BITS = 32


def num_words(universe_size: int) -> int:
    return (int(universe_size) + WORD_BITS - 1) // WORD_BITS


# ---------------------------------------------------------------------------
# numpy variants (construction / host-side)
# ---------------------------------------------------------------------------

def np_pack(mask: np.ndarray) -> np.ndarray:
    """bool[U] -> uint32[W]."""
    mask = np.asarray(mask, dtype=bool)
    u8 = np.packbits(mask, bitorder="little")
    pad = (-u8.size) % 4
    if pad:
        u8 = np.concatenate([u8, np.zeros(pad, np.uint8)])
    return u8.view(np.uint32)


def np_unpack(words: np.ndarray, universe_size: int) -> np.ndarray:
    """uint32[W] -> bool[U]."""
    u8 = np.asarray(words, dtype=np.uint32).view(np.uint8)
    bits = np.unpackbits(u8, bitorder="little")
    return bits[:universe_size].astype(bool)


def np_from_indices(idx: np.ndarray, universe_size: int) -> np.ndarray:
    """Sorted-or-not unique indices -> packed uint32[W]."""
    words = np.zeros(num_words(universe_size), np.uint32)
    idx = np.asarray(idx, dtype=np.int64)
    if idx.size:
        np.bitwise_or.at(words, idx >> 5, (np.uint32(1) << (idx & 31).astype(np.uint32)))
    return words


def np_to_indices(words: np.ndarray, universe_size: int) -> np.ndarray:
    return np.nonzero(np_unpack(words, universe_size))[0].astype(np.int32)


def np_popcount(words: np.ndarray) -> int:
    return int(np.bitwise_count(np.asarray(words, np.uint32)).sum())


def np_fit_words(words: np.ndarray, W: int) -> np.ndarray:
    """Pad/trim packed words to width ``W`` (live updates grow slot
    universes past older states/planes, §6 — one shared invariant for the
    pool, the JAX executors, and anything else holding packed rows)."""
    words = np.asarray(words, np.uint32)
    if words.size < W:
        return np.concatenate([words, np.zeros(W - words.size, np.uint32)])
    return words[:W]


# ---------------------------------------------------------------------------
# jnp variants (query-time / jit)
# ---------------------------------------------------------------------------

def from_indices(idx: jnp.ndarray, universe_size: int) -> jnp.ndarray:
    """Unique element indices -> packed bitmap.  Valid because every
    (word, bit) pair is distinct, so scatter-add == scatter-or.  Negative
    indices (used as padding) are dropped."""
    W = num_words(universe_size)
    idx = idx.astype(jnp.int32)
    valid = idx >= 0
    word = jnp.where(valid, idx >> 5, 0)
    bit = jnp.where(valid, (jnp.uint32(1) << (idx & 31).astype(jnp.uint32)), jnp.uint32(0))
    return jnp.zeros(W, jnp.uint32).at[word].add(bit)


def unpack(words: jnp.ndarray, universe_size: int) -> jnp.ndarray:
    W = words.shape[0]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts[None, :]) & jnp.uint32(1)
    return bits.reshape(W * 32)[:universe_size].astype(bool)


def pack(mask: jnp.ndarray) -> jnp.ndarray:
    U = mask.shape[0]
    W = num_words(U)
    padded = jnp.zeros(W * 32, jnp.uint32).at[:U].set(mask.astype(jnp.uint32))
    lanes = padded.reshape(W, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (lanes << shifts[None, :]).sum(axis=1, dtype=jnp.uint32)


def popcount(words: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.population_count(words).sum(dtype=jnp.int64)


def apply_delta(base: jnp.ndarray, adds: jnp.ndarray, dels: jnp.ndarray) -> jnp.ndarray:
    """One delta step: (base & ~dels) | adds, all packed uint32[W]."""
    return (base & ~dels) | adds


def apply_delta_chain(base: jnp.ndarray, adds: jnp.ndarray, dels: jnp.ndarray) -> jnp.ndarray:
    """Sequentially apply K deltas stacked as [K, W] (pure-jnp reference for
    the fused Pallas kernel)."""
    def step(m, ad):
        a, d = ad
        return (m & ~d) | a, None
    out, _ = jax.lax.scan(step, base, (adds, dels))
    return out
