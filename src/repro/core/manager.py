"""GraphManager / HistoryManager / QueryManager composition (paper §3.2.2)
and the programmatic HistGraph API (§3.2.1).

* **HistoryManager** role — DeltaGraph construction, query planning, delta
  and eventlist reads → lives in :class:`repro.core.deltagraph.DeltaGraph`.
* **GraphManager** role — GraphPool maintenance, overlaying, bit
  assignment, post-query clean-up → here.
* **QueryManager** role — external-id ↔ slot translation → the universe's
  lookup tables, surfaced through :class:`HistGraph` accessors.
"""
from __future__ import annotations

import threading
from typing import Any, Sequence

import numpy as np

from ..graph.csr import CSR, build_csr
from ..storage.kv import KVStore, MemKV, store_from_env
from .analysis import estimate_rates
from .deltagraph import DeltaGraph
from .events import EventList, GraphUniverse, MaterializedState, replay
from .graphpool import GraphPool
from .materialize import (Advice, AdvisorConfig, MaterializationAdvisor,
                          SnapshotCache, WorkloadStats)
from .query import AttrOptions, TimeExpression, parse_attr_options


class HistGraph:
    """A retrieved historical snapshot, overlaid in the GraphPool."""

    def __init__(self, mgr: "GraphManager", gid: int, t: int | None,
                 options: AttrOptions) -> None:
        self._mgr = mgr
        self.gid = gid
        self.time = t
        self.options = options
        self._csr: CSR | None = None

    # -- structure ------------------------------------------------------
    @property
    def node_mask(self) -> np.ndarray:
        return self._mgr.pool.get_node_mask(self.gid)

    @property
    def edge_mask(self) -> np.ndarray:
        return self._mgr.pool.get_edge_mask(self.gid)

    def num_nodes(self) -> int:
        return int(self.node_mask.sum())

    def num_edges(self) -> int:
        return int(self.edge_mask.sum())

    def get_nodes(self) -> list[Any]:
        u = self._mgr.universe
        return [u.node_ids[s] for s in np.nonzero(self.node_mask)[0]]

    def csr(self) -> CSR:
        if self._csr is None:
            u = self._mgr.universe
            self._csr = build_csr(u.edge_src, u.edge_dst, u.num_nodes,
                                  self.edge_mask, u.edge_directed)
        return self._csr

    def get_neighbors(self, node_id: Any) -> list[Any]:
        u = self._mgr.universe
        s = u.node_slot(node_id)
        return [u.node_ids[v] for v in self.csr().neighbors(s)]

    def get_edge_obj(self, u_id: Any, v_id: Any) -> int | None:
        u = self._mgr.universe
        su, sv = u.node_slot(u_id), u.node_slot(v_id)
        c = self.csr()
        for v, e in zip(c.neighbors(su), c.edge_slots(su)):
            if v == sv:
                return int(e)
        return None

    # -- attributes ------------------------------------------------------
    def node_attr(self, node_id: Any, name: str) -> float:
        u = self._mgr.universe
        col = u.attr_col("node", name)
        entry = self._mgr.pool.table[self.gid]
        vec = entry.node_attr_cols.get(col)
        if vec is None:
            raise KeyError(f"attribute {name!r} was not fetched "
                           f"(options {self.options})")
        return float(vec[u.node_slot(node_id)])

    def edge_attr_by_slot(self, edge_slot: int, name: str) -> float:
        u = self._mgr.universe
        col = u.attr_col("edge", name)
        vec = self._mgr.pool.table[self.gid].edge_attr_cols.get(col)
        if vec is None:
            raise KeyError(f"attribute {name!r} was not fetched")
        return float(vec[edge_slot])

    def to_state(self, with_attrs: bool = True) -> MaterializedState:
        return self._mgr.pool.get_state(self.gid, with_attrs=with_attrs)

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        """Release this graph's GraphPool bits (idempotent); the pool
        cleaner reclaims the plane rows lazily."""
        if getattr(self, "_closed", False):
            return
        self._closed = True
        self._mgr.pool.release(self.gid)
        self._mgr.pool.cleaner()

    def __enter__(self) -> "HistGraph":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class GraphManager:
    """Top-level façade: owns the DeltaGraph index, the GraphPool, and the
    current graph; exposes the paper's retrieval calls."""

    def __init__(self, universe: GraphUniverse, events: EventList, *,
                 store: KVStore | None = None, L: int = 1000, k: int = 2,
                 diff_fn: str | Sequence[str] = "balanced",
                 diff_params: dict | Sequence[dict] | None = None,
                 num_partitions: int = 1,
                 partition_fn: str = "word_cyclic",
                 cache_bytes: int = 32 << 20,
                 cache_entries: int = 256,
                 prefetch_workers: int = 4) -> None:
        # default store honors REPRO_KV (mem | logfile | tiered) so every
        # entry point can run disk-resident without code changes; stores we
        # created are closed with the manager
        owns_store = store is None
        store = store if store is not None else (store_from_env() or MemKV())
        dg = DeltaGraph(universe, store, L=L, k=k, diff_fn=diff_fn,
                        diff_params=diff_params,
                        num_partitions=num_partitions,
                        partition_fn=partition_fn).build(events)
        current = replay(universe, events,
                         int(events.time[-1]) if len(events) else 0)
        self._wire(universe, dg, current, events, owns_store=owns_store,
                   cache_bytes=cache_bytes, cache_entries=cache_entries,
                   prefetch_workers=prefetch_workers)

    @classmethod
    def open(cls, universe: GraphUniverse, store: KVStore, *,
             cache_bytes: int = 32 << 20, cache_entries: int = 256,
             prefetch_workers: int = 4) -> "GraphManager":
        """Reopen a manager from a persisted skeleton + write-ahead log
        (crash recovery — ``core/ingest.py``): loads the last durable
        skeleton, replays the WAL tail past the folded prefix, and rebuilds
        the current graph.  Every group-committed event is present."""
        from .events import apply_events
        from .ingest import recover_index
        dg = recover_index(universe, store)
        current = apply_events(dg._last_leaf_state, dg.recent, forward=True)
        current.edge_mask &= ~universe.edge_transient[:current.edge_mask.size]
        current.node_mask &= ~universe.node_transient[:current.node_mask.size]
        gm = cls.__new__(cls)
        gm._wire(universe, dg, current, dg.recent, owns_store=False,
                 cache_bytes=cache_bytes, cache_entries=cache_entries,
                 prefetch_workers=prefetch_workers)
        return gm

    def _wire(self, universe: GraphUniverse, dg: DeltaGraph,
              current: MaterializedState, events: EventList, *,
              owns_store: bool, cache_bytes: int, cache_entries: int,
              prefetch_workers: int) -> None:
        """Common wiring shared by build (``__init__``) and recovery
        (:meth:`open`)."""
        from .epoch import EpochData, EpochRegistry
        from .epoch import NO_TIME
        self.universe = universe
        self._owns_store = owns_store
        self.store = dg.store
        self.dg = dg
        self.pool = GraphPool(universe)
        self.pool.set_current(current)
        # workload-aware materialization + caching (core/materialize.py)
        self.workload = WorkloadStats()
        self.dg.workload = self.workload
        self.rates = estimate_rates(events)
        self.cache = (SnapshotCache(cache_bytes, cache_entries)
                      if cache_bytes > 0 else None)
        self.advisor: MaterializationAdvisor | None = None
        # async KV prefetch for batched retrieval (runtime/executor.py);
        # threads spin up lazily on first batched query
        if prefetch_workers > 0:
            from ..runtime.executor import Prefetcher
            self.prefetcher = Prefetcher(self.store, workers=prefetch_workers)
        else:
            self.prefetcher = None
        self._temporal = None
        self._query_service = None
        # sharded multi-worker retrieval (runtime/shard.py); off by default,
        # enabled via enable_sharding() / serve.py --shards N
        self.sharded = None
        # concurrent retrievals are supported (cache and workload counters
        # are internally locked); advisor *replans* mutate the pool and the
        # skeleton's materialization marks, so they are serialized here —
        # see ARCHITECTURE.md "Concurrency" for what is and isn't safe
        self._advisor_lock = threading.Lock()
        # epoch-versioned index (§6 / core/epoch.py): readers pin the
        # current epoch at query entry; the ingest pipeline publishes a new
        # one per commit group and per rollover swap
        n_recent = len(dg.recent)
        max_t = (int(dg.recent.time[-1]) if n_recent
                 else (dg.leaf_time[-1] if dg.leaf_pos[-1] > 0 else NO_TIME))
        self.epochs = EpochRegistry(EpochData(dg, dg._total_events, max_t))
        self._ingest = None
        self._closed = False

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Shut down every worker this manager owns — the ingest pipeline,
        the shard-worker pool, the prefetch thread pool — and any store it
        created itself (flushes disk-backed tiers).  Idempotent: a second
        close is a no-op, and retrievals issued after close degrade to the
        synchronous unprefetched path instead of respawning threads."""
        if self._closed:
            return
        self._closed = True
        if self._ingest is not None:
            self._ingest.close()
            self._ingest = None
        if self.sharded is not None:
            self.sharded.close()
            self.sharded = None
        if self.prefetcher is not None:
            # drain in-flight fetches before the store's handles go away
            self.prefetcher.close(wait=self._owns_store)
            self.prefetcher = None
        if self._owns_store:
            self.store.close()

    @property
    def closed(self) -> bool:
        return self._closed

    def __enter__(self) -> "GraphManager":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- retrieval
    #
    # Every retrieval/analytics entry point below is a thin shim over the
    # declarative query service (repro/api): it builds the equivalent
    # GraphQuery document and runs it through ``self.query``.  The service
    # owns the single implementation of cached + advised + batched
    # retrieval, so the legacy surface and the wire protocol are
    # bit-identical by construction (tests/test_query_service.py).
    def _parse_opts(self, attr_options: str | AttrOptions) -> AttrOptions:
        return (attr_options if isinstance(attr_options, AttrOptions)
                else parse_attr_options(attr_options, self.universe))

    @property
    def query(self):
        """The :class:`~repro.api.service.QueryService` bound to this
        manager — the declarative entry point (``gm.query.run(doc)``)."""
        if self._query_service is None:
            from ..api.service import QueryService
            self._query_service = QueryService(self)
        return self._query_service

    def get_snapshot(self, t: int, attr_options: str | AttrOptions = "",
                     use_current: bool = True) -> MaterializedState:
        """Singlepoint retrieval through the snapshot cache (exact-timepoint
        LRU) with the advisor's online replan hook.  Results are always
        bit-identical to a cold ``DeltaGraph.get_snapshot``.
        ≡ ``Q.at(t).attrs(...).build()``."""
        from ..api.document import GraphQuery
        doc = GraphQuery(kind="snapshot", t=int(t), attrs=attr_options,
                         use_current=bool(use_current))
        return self.query.run(doc).value

    def get_snapshots(self, times: Sequence[int],
                      attr_options: str | AttrOptions = "",
                      use_current: bool = True
                      ) -> dict[int, MaterializedState]:
        """Batched multipoint retrieval (§4.4): cache hits are split off,
        the misses become **one** Steiner plan whose shared prefixes fetch
        and apply once, executed with async KV prefetch.
        ≡ ``Q.at(times).attrs(...).build()``."""
        from ..api.document import GraphQuery
        times = tuple(int(t) for t in times)
        if not times:     # wire documents reject this; the legacy
            return {}     # contract is an empty result
        doc = GraphQuery(kind="multipoint", times=times,
                         attrs=attr_options, use_current=bool(use_current))
        return self.query.run(doc).value

    def get_hist_graph(self, t: int, attr_options: str = "",
                       use_current: bool = True) -> HistGraph:
        opts = self._parse_opts(attr_options)
        st = self.get_snapshot(t, opts, use_current=use_current)
        gid = self.pool.insert_snapshot(st)
        return HistGraph(self, gid, t, opts)

    def get_hist_graphs(self, times: Sequence[int],
                        attr_options: str = "",
                        use_current: bool = True) -> list[HistGraph]:
        """Batched retrieval + one batched GraphPool overlay pass.
        ``use_current`` is threaded through to the planner, same as the
        singlepoint entry."""
        opts = self._parse_opts(attr_options)
        states = self.get_snapshots(list(times), opts,
                                    use_current=use_current)
        gids = self.pool.insert_snapshots([states[int(t)] for t in times])
        return [HistGraph(self, gid, int(t), opts)
                for gid, t in zip(gids, times)]

    def get_hist_graph_expr(self, tex: TimeExpression,
                            attr_options: str = "") -> HistGraph:
        """Hypothetical graph for a Boolean TimeExpression (§3.2.1): the
        element set satisfying the expression; attributes come from the
        latest queried time point at which the element exists.  Returns a
        GraphPool-overlaid :class:`HistGraph` (like every other
        ``get_hist_graph*`` entry); use :meth:`HistGraph.to_state` for
        the raw :class:`MaterializedState`.
        ≡ ``Q.expr(tex.to_infix(), tex.times).build()``."""
        from ..api.document import GraphQuery
        opts = self._parse_opts(attr_options)
        doc = GraphQuery(kind="expr", expr=tex.to_infix(),
                         times=tuple(int(t) for t in tex.times),
                         attrs=opts)
        st = self.query.run(doc).value
        gid = self.pool.insert_snapshot(st)
        return HistGraph(self, gid, None, opts)

    def get_hist_graph_interval(self, ts: int, te: int) -> dict[str, np.ndarray]:
        """≡ ``Q.between(ts, te).build()``."""
        from ..api.document import GraphQuery
        doc = GraphQuery(kind="interval", ts=int(ts), te=int(te))
        return self.query.run(doc).value

    # ------------------------------------------------------ temporal analytics
    def evolve(self, times: "Sequence[int] | TimeExpression",
               op: Any = "masks", *, attr_options: str | AttrOptions = "",
               use_current: bool = True, incremental: bool = True,
               **op_kwargs):
        """Evolutionary query over an interval of timepoints
        (:mod:`repro.core.temporal`): retrieve the *first* snapshot through
        the plan IR, then advance incrementally by the inter-snapshot
        event slices — incremental degree/density, warm-started PageRank,
        re-union-only connected components, or a generic Pregel fold.

        ``times`` is a sequence of timepoints or a
        :class:`~repro.core.query.TimeExpression` (its timepoints are
        used); ``op`` is an operator name (``"masks"``, ``"degree"``,
        ``"density"``, ``"pagerank"``, ``"components"``), an
        :class:`~repro.core.temporal.EvolveOp` instance (e.g.
        :class:`~repro.core.temporal.PregelFold`), or a plain fold
        callable ``f(prev_value, state, delta, t)``.
        ``incremental=False`` runs the per-snapshot recompute baseline.
        Returns an :class:`~repro.core.temporal.EvolveResult`.
        ≡ ``Q.evolve(times, op, **kwargs).build()`` (named operators
        serialize; EvolveOp instances/callables are programmatic-only)."""
        from ..api.document import GraphQuery
        if isinstance(times, TimeExpression):
            times = list(times.times)
        doc = GraphQuery(kind="evolve",
                         times=tuple(int(t) for t in times),
                         op=op, op_kwargs=dict(op_kwargs),
                         attrs=attr_options, use_current=bool(use_current),
                         incremental=bool(incremental))
        return self.query.run(doc).value

    # ------------------------------------------------------------- updates
    @property
    def ingest(self):
        """The :class:`~repro.core.ingest.IngestPipeline` bound to this
        manager (created lazily, synchronous mode).  For threaded
        production-rate ingest construct one explicitly:
        ``IngestPipeline(gm, threaded=True)``."""
        if self._ingest is None:
            from .ingest import IngestPipeline
            self._ingest = IngestPipeline(self)
        return self._ingest

    def update(self, ev: EventList) -> None:
        """Live update path (§6), shimmed onto the ingest pipeline: the
        batch commits as one group (WAL append + one durability barrier),
        publishes a new epoch, and folds full leaves red/green — readers
        that pinned an epoch mid-query are unaffected."""
        self.ingest.append(ev)

    # ------------------------------------------------------------- sharding
    def enable_sharding(self, workers: int | Sequence[str] | None = None,
                        *, transport: "Any" = None,
                        replicas: int | None = None,
                        **kwargs) -> "Any":
        """Turn on sharded multi-worker retrieval
        (:class:`~repro.runtime.shard.ShardedRetriever`): every cache-miss
        retrieval through the query service scatters its plan across a
        fleet of shard servers (partitions assigned by rendezvous hashing)
        and gathers the per-shard slot results.  ``workers`` defaults to
        one worker per storage partition.  Results stay bit-identical to
        unsharded execution.

        ``transport`` selects how shard fetches move bytes: ``"thread"``
        (default — the legacy in-process pool), ``"proc"`` (one
        ``launch/shardd`` OS process per worker with epoch-invalidated
        shard-local caches), or a ready :class:`~repro.runtime.shard
        .ShardTransport` instance (tests inject instrumented ones).
        ``replicas`` is the candidate-server count per partition —
        hedges/failover then route to distinct replicas.  Both default
        from the environment (``REPRO_SHARD_TRANSPORT``,
        ``REPRO_REPLICAS``) so the differential CI suite can re-run the
        whole tier-1 battery over the process transport unchanged.
        Re-enabling replaces the previous retriever; extra kwargs go to
        the retriever (hedging/retry policy)."""
        import os

        from ..runtime.shard import ShardedRetriever
        self.disable_sharding()
        if workers is None:
            workers = max(1, self.dg.P)
        if transport is None:
            transport = os.environ.get("REPRO_SHARD_TRANSPORT") or None
        if replicas is None:
            replicas = int(os.environ.get("REPRO_REPLICAS", "1"))
        self.sharded = ShardedRetriever(self, workers, transport=transport,
                                        replicas=replicas, **kwargs)
        return self.sharded

    def disable_sharding(self) -> None:
        if self.sharded is not None:
            self.sharded.close()
            self.sharded = None

    # -------------------------------------------------------- materialization
    def enable_advisor(self, budget_bytes: int = 64 << 20, *,
                       replan_every: int = 64, drift_threshold: float = 0.25,
                       max_candidates: int = 256,
                       warm_start: bool = True) -> Advice | None:
        """Turn on workload-aware materialization (§4.5 made adaptive).

        The advisor re-plans every ``replan_every`` retrievals (or earlier
        under workload drift), pinning/evicting DeltaGraph nodes in the
        GraphPool so that ``pool.memory_bytes()`` stays under
        ``budget_bytes``.  ``warm_start`` runs one plan immediately (with
        the uniform / analytical prior if no queries were recorded yet).
        Re-enabling evicts the previous advisor's pins first."""
        with self._advisor_lock:
            self._disable_advisor_locked()
            cfg = AdvisorConfig(budget_bytes=budget_bytes,
                                replan_every=replan_every,
                                drift_threshold=drift_threshold,
                                max_candidates=max_candidates)
            self.advisor = MaterializationAdvisor(self.dg, self.pool,
                                                  self.workload, cfg,
                                                  rates=self.rates)
            self.advisor.on_evict = self._on_advisor_evict
            return self.advisor.replan() if warm_start else None

    def _on_advisor_evict(self, nids: list[int]) -> None:
        """A replan evicted pins: cache entries whose plans routed through
        them hold stale ``materialized_as`` sources — drop them."""
        if self.cache is not None and nids:
            self.cache.invalidate_deps(nids)

    def disable_advisor(self) -> None:
        """Evict every advisor pin and stop re-planning."""
        with self._advisor_lock:
            self._disable_advisor_locked()

    def _disable_advisor_locked(self) -> None:
        if self.advisor is None:
            return
        evicted = list(self.advisor.pinned)
        for nid in evicted:
            self.dg.unmaterialize(nid, self.pool)
        self.pool.cleaner(force=True)
        self._on_advisor_evict(evicted)
        self.advisor = None

    def materialize_roots(self, depth: int = 1) -> list[int]:
        """Materialize the top `depth` interior levels (§4.5)."""
        out = []
        frontier = self.dg.root_nids()
        for _ in range(depth):
            nxt = []
            for nid in frontier:
                if self.dg.nodes[nid].materialized_as is None:
                    out.append(self.dg.materialize(nid, self.pool))
                for eid in self.dg.adj[nid]:
                    e = self.dg.edges[eid]
                    if e.src == nid and e.kind == "delta":
                        nxt.append(e.dst)
            frontier = nxt
        return out

    def total_materialization(self) -> list[int]:
        """Materialize every leaf — DeltaGraph degenerates to Copy+Log with
        overlaid in-memory copies (§4.5)."""
        return [self.dg.materialize(nid, self.pool)
                for nid in self.dg.leaf_nids
                if self.dg.nodes[nid].materialized_as is None]
