"""Differential functions (paper §5.2, Table 2).

A differential function ``f`` builds the (virtual) graph of an interior
DeltaGraph node from its children's graphs.  The choice of ``f`` is the main
tuning knob for the retrieval-latency distribution over history:

* ``intersection`` — minimal disk space, skewed latencies (older = faster on
  growing graphs); root of a growing-only graph is exactly ``G_0``.
* ``union`` — the opposite skew.
* ``balanced`` — equal delta sizes to every child → uniform latencies.
* ``skewed(r)`` / ``right_skewed`` / ``left_skewed`` — tunable interpolation.
* ``mixed(r1, r2)`` — general form; ``r1 = r2 = 0.5`` is ``balanced``.
* ``empty`` — parent is ∅ ⇒ DeltaGraph degenerates to **Copy+Log** (§4.1).

Event-fraction selection (`r·δ_ab`) uses a deterministic hash of the slot id,
exactly the paper's trick for making ``a + r·δ_ab − r·ρ_ab`` well defined
(the same hash picks both the added and the removed halves).
"""
from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

from .events import MaterializedState

DiffFn = Callable[[Sequence[MaterializedState]], MaterializedState]

_REGISTRY: dict[str, Callable[..., DiffFn]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get(name: str, **params) -> DiffFn:
    """Look up a differential function, e.g. ``get('mixed', r1=.7, r2=.3)``."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown differential function {name!r}; "
                       f"have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**params)


def names() -> list[str]:
    return sorted(_REGISTRY)


def _slot_hash(n: int, seed: int = 0x9E3779B9) -> np.ndarray:
    """Deterministic per-slot uniform in [0, 1) (splitmix-style)."""
    x = (np.arange(n, dtype=np.uint64) + np.uint64(1)) * np.uint64(seed)
    x ^= x >> np.uint64(16)
    x *= np.uint64(0x85EBCA6B)
    x ^= x >> np.uint64(13)
    x *= np.uint64(0xC2B2AE35)
    x ^= x >> np.uint64(16)
    return (x & np.uint64(0xFFFFFF)).astype(np.float64) / float(1 << 24)


def _merge_attrs(children: Sequence[MaterializedState], node_mask, edge_mask):
    """Interior-node attribute values: first child containing the element
    wins (any deterministic rule is valid — deltas correct the residue)."""
    na = children[0].node_attrs.copy()
    ea = children[0].edge_attrs.copy()
    filled_n = children[0].node_mask.copy()
    filled_e = children[0].edge_mask.copy()
    for c in children[1:]:
        take_n = ~filled_n & c.node_mask
        take_e = ~filled_e & c.edge_mask
        if na.size:
            na[take_n] = c.node_attrs[take_n]
        if ea.size:
            ea[take_e] = c.edge_attrs[take_e]
        filled_n |= c.node_mask
        filled_e |= c.edge_mask
    return na, ea


def _state(node_mask, edge_mask, children) -> MaterializedState:
    na, ea = _merge_attrs(children, node_mask, edge_mask)
    return MaterializedState(node_mask, edge_mask, na, ea)


@register("intersection")
def _intersection() -> DiffFn:
    def f(children: Sequence[MaterializedState]) -> MaterializedState:
        nm = children[0].node_mask.copy()
        em = children[0].edge_mask.copy()
        for c in children[1:]:
            nm &= c.node_mask
            em &= c.edge_mask
        return _state(nm, em, children)
    return f


@register("union")
def _union() -> DiffFn:
    def f(children: Sequence[MaterializedState]) -> MaterializedState:
        nm = children[0].node_mask.copy()
        em = children[0].edge_mask.copy()
        for c in children[1:]:
            nm |= c.node_mask
            em |= c.edge_mask
        return _state(nm, em, children)
    return f


@register("empty")
def _empty() -> DiffFn:
    def f(children: Sequence[MaterializedState]) -> MaterializedState:
        z = children[0]
        return MaterializedState(
            np.zeros_like(z.node_mask), np.zeros_like(z.edge_mask),
            np.full_like(z.node_attrs, np.nan), np.full_like(z.edge_attrs, np.nan))
    return f


@register("mixed")
def _mixed(r1: float = 0.5, r2: float = 0.5) -> DiffFn:
    if not (0.0 <= r2 <= r1 <= 1.0):
        raise ValueError("require 0 <= r2 <= r1 <= 1")

    def f(children: Sequence[MaterializedState]) -> MaterializedState:
        a = children[0]
        nm, em = a.node_mask.copy(), a.edge_mask.copy()
        hn = _slot_hash(nm.size)
        he = _slot_hash(em.size)
        for prev, cur in zip(children[:-1], children[1:]):
            dn = cur.node_mask & ~prev.node_mask
            rn = prev.node_mask & ~cur.node_mask
            de = cur.edge_mask & ~prev.edge_mask
            re = prev.edge_mask & ~cur.edge_mask
            nm |= dn & (hn < r1)
            nm &= ~(rn & (hn < r2))
            em |= de & (he < r1)
            em &= ~(re & (he < r2))
        return _state(nm, em, children)
    return f


@register("balanced")
def _balanced() -> DiffFn:
    """Special case of mixed with r1 = r2 = ½ → |Δ(a,p)| = |Δ(b,p)|."""
    return _mixed(0.5, 0.5)


@register("skewed")
def _skewed(r: float = 0.5) -> DiffFn:
    """f(a,b) = a + r·(b−a): move an r-fraction of *all* of b's differences
    (both additions and removals) toward b."""
    if not (0.0 <= r <= 1.0):
        raise ValueError("require 0 <= r <= 1")
    return _mixed(r, r)


@register("right_skewed")
def _right_skewed(r: float = 0.5) -> DiffFn:
    """f(a,b) = a∩b + r·(b − a∩b): keep the intersection, pull in an
    r-fraction of b-only elements."""

    def f(children: Sequence[MaterializedState]) -> MaterializedState:
        inter = get("intersection")(children)
        last = children[-1]
        hn = _slot_hash(inter.node_mask.size)
        he = _slot_hash(inter.edge_mask.size)
        nm = inter.node_mask | ((last.node_mask & ~inter.node_mask) & (hn < r))
        em = inter.edge_mask | ((last.edge_mask & ~inter.edge_mask) & (he < r))
        return _state(nm, em, children)
    return f


@register("left_skewed")
def _left_skewed(r: float = 0.5) -> DiffFn:
    """f(a,b) = a∩b + r·(a − a∩b)."""

    def f(children: Sequence[MaterializedState]) -> MaterializedState:
        inter = get("intersection")(children)
        first = children[0]
        hn = _slot_hash(inter.node_mask.size)
        he = _slot_hash(inter.edge_mask.size)
        nm = inter.node_mask | ((first.node_mask & ~inter.node_mask) & (hn < r))
        em = inter.edge_mask | ((first.edge_mask & ~inter.edge_mask) & (he < r))
        return _state(nm, em, children)
    return f
