"""Analytical models for DeltaGraph space and retrieval time (paper §5).

Graph-dynamics model (§5.1): a fraction ``delta_star`` of events are
inserts, ``rho_star`` are deletes (an update = delete+insert), so
``|G_{|E|}| = |G_0| + |E|·(delta_star − rho_star)``.  Event density over
time is ``g(t)`` (super-linear for most real networks).

Implemented closed forms (§5.3):

* Balanced function — per-level delta sizes, total index space, and the
  (uniform) root→leaf path weight.
* Intersection function — root size for ``rho*=0``, ``delta*=rho*`` and
  ``delta*=2 rho*``; path weight = leaf size.
* Copy+Log (= Empty differential function) — stored-snapshot space.

plus :func:`estimate_rates` (fit δ*, ρ* from an eventlist) and
:func:`choose_parameters`, the §5.4 guidance: pick (k, L, f) for a space
budget / latency target.  Everything here is validated against measured
index sizes in ``tests/test_analysis.py``.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .events import (EV_DEL_EDGE, EV_DEL_NODE, EV_NEW_EDGE, EV_NEW_NODE,
                     EventList)


@dataclasses.dataclass
class Rates:
    delta_star: float   # insert fraction
    rho_star: float     # delete fraction
    g0: float           # |G_0|
    n_events: int

    @property
    def final_size(self) -> float:
        return self.g0 + self.n_events * (self.delta_star - self.rho_star)


def estimate_rates(events: EventList, g0: int = 0) -> Rates:
    et = events.etype
    ins = int(np.isin(et, (EV_NEW_NODE, EV_NEW_EDGE)).sum())
    dels = int(np.isin(et, (EV_DEL_NODE, EV_DEL_EDGE)).sum())
    n = len(events)
    return Rates(ins / max(n, 1), dels / max(n, 1), g0, n)


# ---------------------------------------------------------------------------
# Balanced differential function (§5.3)
# ---------------------------------------------------------------------------

def balanced_delta_size(level: int, L: int, k: int, rates: Rates) -> float:
    """|Δ(p, c_i)| (events) for an interior node p at ``level`` (leaves are
    level 1): ``½ (k−1) k^{level−2} (δ*+ρ*) L``."""
    if level < 2:
        raise ValueError("interior levels start at 2")
    s = rates.delta_star + rates.rho_star
    return 0.5 * (k - 1) * (k ** (level - 2)) * s * L


def balanced_level_space(L: int, k: int, rates: Rates) -> float:
    """Total delta events at any single interior level — the §5.3 surprise:
    it is the same at every level, ``½ (k−1)(δ*+ρ*)|E|``.

    (Exact form: with ``N = ⌊|E|/L⌋ + 1`` leaves there are N level-2 edges,
    giving ``½(k−1)(δ*+ρ*)(|E|+L)`` — the paper drops the ``+L`` as
    asymptotically negligible; we keep it so tests can assert tightly.)
    """
    return 0.5 * (k - 1) * (rates.delta_star + rates.rho_star) * (
        rates.n_events + L)


def balanced_total_space(L: int, k: int, rates: Rates) -> float:
    """All delta events excluding the super-root edge.

    The paper quotes ``(log_k N − 1)/2 (k−1)(δ*+ρ*)|E|``, counting the root
    level into the super-root edge; measured against our index (which hangs
    the root off the super-root separately) the exact count is
    ``log_k N`` interior levels × the constant per-level space.
    """
    N = rates.n_events / L + 1
    levels = math.log(max(N, 1.0), k)
    return levels * balanced_level_space(L, k, rates)


def balanced_root_size(rates: Rates) -> float:
    """|root| = |G_0| + ½ (δ*−ρ*) |E| (independent of k)."""
    return rates.g0 + 0.5 * (rates.delta_star - rates.rho_star) * rates.n_events


def balanced_path_weight(rates: Rates) -> float:
    """Super-root → any leaf total weight: |root| + ½(δ*+ρ*)|E|.

    The paper quotes the root→leaf part, ``½(δ*+ρ*)|E|``; retrieval from
    cold (no materialization) adds the root itself.
    """
    return balanced_root_size(rates) + 0.5 * (
        rates.delta_star + rates.rho_star) * rates.n_events


# ---------------------------------------------------------------------------
# Intersection differential function (§5.3)
# ---------------------------------------------------------------------------

def intersection_root_size(rates: Rates) -> float:
    """Root size under Intersection for the three §5.3 special cases (and a
    smooth interpolation elsewhere, labelled as such)."""
    g0, E = rates.g0, rates.n_events
    d, r = rates.delta_star, rates.rho_star
    if r == 0:
        return g0
    if abs(d - r) < 1e-12:
        return g0 * math.exp(-E * d / max(g0, 1e-9))
    if abs(d - 2 * r) < 1e-12:
        return g0 * g0 / (g0 + r * E)
    # interpolation between the δ*=ρ* and δ*=2ρ* regimes (not in paper)
    w = min(max((d / max(r, 1e-12) - 1.0), 0.0), 1.0)
    return ((1 - w) * g0 * math.exp(-E * d / max(g0, 1e-9))
            + w * g0 * g0 / (g0 + r * E))


def intersection_path_weight(leaf_size: float) -> float:
    """Under Intersection the super-root→leaf weight is exactly the leaf
    size (each interior node ⊆ each child)."""
    return leaf_size


# ---------------------------------------------------------------------------
# Copy+Log & comparisons (§5.4)
# ---------------------------------------------------------------------------

def copylog_space(L: int, rates: Rates) -> float:
    """Stored snapshots every L events + the log itself (events)."""
    N = int(rates.n_events / L) + 1
    sizes = [rates.g0 + i * L * (rates.delta_star - rates.rho_star)
             for i in range(N)]
    return float(sum(sizes) + rates.n_events)


def interval_tree_space(rates: Rates) -> float:
    """O(|E|): each element contributes one interval."""
    return float(rates.n_events)


def segment_tree_space(rates: Rates) -> float:
    """O(|E| log |E|) — duplicated interval storage."""
    E = max(rates.n_events, 2)
    return float(E * math.log2(E))


def expected_singlepoint_bytes(rates: Rates, L: int, k: int,
                               diff_fn: str = "balanced") -> float:
    """Expected cold singlepoint retrieval weight in events (≈ bytes up to
    the per-event encoding constant): super-root→leaf path weight plus half
    a leaf-eventlist.  The materialization advisor uses this as its
    cold-start prior before any query has been recorded."""
    if diff_fn == "intersection":
        return rates.final_size + L / 2
    return balanced_path_weight(rates) + L / 2


# ---------------------------------------------------------------------------
# §5.4 parameter guidance
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ParameterChoice:
    L: int
    k: int
    diff_fn: str
    expected_space_events: float
    expected_path_events: float
    rationale: str


def choose_parameters(rates: Rates, *, space_budget_events: float | None = None,
                      latency_budget_events: float | None = None,
                      prefer_uniform_latency: bool = True,
                      recent_biased: bool = False) -> ParameterChoice:
    """Pick (L, k, f) per §5.4: Intersection when space is paramount,
    Mixed/Balanced otherwise; higher arity lowers latency but costs space;
    larger L shrinks the index but slows queries."""
    best = None
    fns = ["balanced", "intersection"] if prefer_uniform_latency else [
        "intersection", "balanced"]
    if recent_biased:
        fns = ["mixed"] + fns
    for k in (2, 3, 4, 8, 16):
        for L_frac in (0.002, 0.005, 0.01, 0.02, 0.05):
            L = max(int(rates.n_events * L_frac), 16)
            for fn in fns:
                if fn == "intersection":
                    space = rates.n_events * (rates.delta_star + rates.rho_star)
                    path = rates.final_size + L / 2
                else:
                    space = balanced_total_space(L, k, rates)
                    path = balanced_path_weight(rates) + L / 2
                if space_budget_events is not None and space > space_budget_events:
                    continue
                if latency_budget_events is not None and path > latency_budget_events:
                    continue
                score = path + 0.1 * space / max(rates.n_events, 1)
                if best is None or score < best[0]:
                    best = (score, ParameterChoice(
                        L, k, fn, space, path,
                        f"min path+0.1·space among feasible; f={fn}"))
    if best is None:
        raise ValueError("no (L, k, f) satisfies the given budgets")
    return best[1]
