"""Workload-aware memory materialization (paper §4.5) + snapshot caching.

The paper stubs "strategies for materializing portions of the historical
graph state in memory"; ``GraphManager.materialize_roots(depth)`` is the
fixed-depth by-hand version.  This module makes the policy *adaptive*:

* :class:`WorkloadStats` — an online, exponentially-decayed histogram of
  query traffic over the time axis, bucketed by DeltaGraph leaf.  Recorded
  automatically by :meth:`DeltaGraph.execute` (every retrieval, whatever
  entry point) so the advisor sees the true workload, including multipoint
  plans.

* :class:`MaterializationAdvisor` — chooses which skeleton nodes to pin
  into the :class:`~repro.core.graphpool.GraphPool` under a byte budget
  (``GraphPool.memory_bytes()`` is the meter).  The benefit of pinning node
  ``c`` for queries landing at leaf ``ℓ`` is the Dijkstra-distance saving
  ``max(0, d_cur(ℓ) − d_c(ℓ))`` in the planner's decode-aware cost units
  (α·stored + β·decoded bytes, :meth:`EdgeInfo.weight`) — exactly the
  quantity the planner minimizes, so advised pins shorten real plans by
  construction; the budget side stays in resident logical bytes (pins live
  decoded in the pool)
  (materialized nodes become distance-0 sources in ``_sources``).  Weights
  come from the workload histogram, with the §5 analytical models
  (:func:`~repro.core.analysis.estimate_rates` → uniform expected path
  weight) as the cold-start prior before any query has been seen.
  Selection is greedy benefit/cost knapsack — the classic submodular
  ratio rule; per-candidate distances are computed once (the skeleton is
  static between appends) and only the running minimum changes per pick.
  Re-planning (:meth:`MaterializationAdvisor.replan`) diffs the ideal set
  against the currently-pinned one and *evicts* drifted-out pins via
  ``DeltaGraph.unmaterialize`` + ``GraphPool.release``.

* :class:`SnapshotCache` — an LRU of fully-materialized states keyed by
  ``(t, attr-cols, use_current)`` for exact-timepoint repeat hits, size-
  bounded in bytes, invalidated from the first appended timestamp onward
  on live updates (§6).

``GraphManager`` wires all three together; see
:meth:`repro.core.manager.GraphManager.enable_advisor`.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import TYPE_CHECKING, Any, Iterable

import numpy as np

from .analysis import Rates, expected_singlepoint_bytes
from .deltagraph import SUPERROOT
from .query import NO_ATTRS, AttrOptions

if TYPE_CHECKING:  # pragma: no cover
    from .deltagraph import DeltaGraph
    from .events import MaterializedState
    from .graphpool import GraphPool


# ---------------------------------------------------------------------------
# workload histogram
# ---------------------------------------------------------------------------


class WorkloadStats:
    """Decayed per-leaf query-traffic histogram plus running latency stats.

    ``decay`` is applied per recorded query, so the histogram tracks a
    moving window of roughly ``1/(1-decay)`` queries — drifted-away
    workload fades out and the advisor's replan follows it.
    """

    def __init__(self, decay: float = 0.995) -> None:
        self.decay = float(decay)
        # raw counts are amplified by a running boost (1/decay per record)
        # so decay is O(1) per query; effective weight = raw / boost
        self._raw: dict[int, float] = {}
        self._raw_nodes: dict[int, float] = {}
        self._boost = 1.0
        self.opt_count: dict[tuple, int] = {}
        self.num_queries = 0
        self.cache_hits = 0
        self.total_plan_bytes = 0.0
        self.total_wall_s = 0.0
        # interval-analytics traffic (core/temporal.py): endpoint leaves
        # and per-(lo, hi) counts, so the advisor learns where evolutionary
        # queries anchor their (single) planned retrieval
        self.interval_count = 0
        self.interval_points = 0
        self.interval_wall_s = 0.0
        self.interval_hist: dict[tuple[int, int], int] = {}
        # recording is read-modify-write on plain dicts; concurrent
        # retrievals (executor threads, 16-way serving) must not lose or
        # corrupt increments
        self._lock = threading.Lock()

    @property
    def leaf_weight(self) -> dict[int, float]:
        with self._lock:
            return {k: v / self._boost for k, v in self._raw.items()}

    @property
    def node_hits(self) -> dict[int, float]:
        """Decayed per-IR-node hit counts: how often each skeleton node
        appeared in an executed plan DAG.  The advisor ranks its candidate
        pool by these — a node the planner actually routes through is a
        better pin than one merely high in the hierarchy."""
        with self._lock:
            return {k: v / self._boost for k, v in self._raw_nodes.items()}

    # -- recording -----------------------------------------------------------
    def _tick(self) -> None:
        """Advance the decay boost (callers hold ``_lock``)."""
        self._boost /= self.decay
        if self._boost > 1e12:  # renormalize before float64 overflow
            for k in self._raw:
                self._raw[k] /= self._boost
            for k in self._raw_nodes:
                self._raw_nodes[k] /= self._boost
            self._boost = 1.0

    def record(self, leaf_index: int, plan_bytes: float,
               options: AttrOptions = NO_ATTRS,
               wall_s: float = 0.0) -> None:
        with self._lock:
            self._tick()
            self._raw[leaf_index] = self._raw.get(leaf_index, 0.0) + self._boost
            key = (options.node_cols, options.edge_cols)
            self.opt_count[key] = self.opt_count.get(key, 0) + 1
            self.num_queries += 1
            self.total_plan_bytes += float(plan_bytes)
            self.total_wall_s += float(wall_s)

    def record_cache_hit(self) -> None:
        with self._lock:
            self.cache_hits += 1

    def record_nodes(self, nids: Iterable[int]) -> None:
        """Record the skeleton nodes one executed plan DAG routed through
        (called by :meth:`DeltaGraph.execute`, once per plan)."""
        with self._lock:
            for nid in nids:
                self._raw_nodes[nid] = (self._raw_nodes.get(nid, 0.0)
                                        + self._boost)

    def record_interval(self, leaf_lo: int, leaf_hi: int, n_points: int,
                        wall_s: float = 0.0) -> None:
        """Record one evolutionary query over ``n_points`` timepoints whose
        planned retrieval landed at leaf ``leaf_lo`` (that retrieval is
        recorded by :meth:`DeltaGraph.execute` as usual — not double
        counted here; ``wall_s`` covers the whole evolve and goes to the
        separate ``interval_wall_s`` aggregate for the same reason).  The
        *end* leaf additionally gains histogram weight: interval
        workloads walk forward through history, so the next evolve call
        tends to anchor near where the last one ended — pinning there
        shortens the upcoming plans."""
        with self._lock:
            self.interval_count += 1
            self.interval_points += int(n_points)
            key = (int(leaf_lo), int(leaf_hi))
            self.interval_hist[key] = self.interval_hist.get(key, 0) + 1
            self.interval_wall_s += float(wall_s)
            if leaf_hi != leaf_lo:
                self._tick()
                self._raw[leaf_hi] = (self._raw.get(leaf_hi, 0.0)
                                      + self._boost)

    # -- reads ---------------------------------------------------------------
    def weights(self, num_leaves: int) -> np.ndarray:
        """Per-leaf weight vector; uniform prior when nothing was recorded."""
        w = np.zeros(max(num_leaves, 1))
        for li, v in self.leaf_weight.items():
            if 0 <= li < num_leaves:
                w[li] += v
        if w.sum() <= 0:
            w[:] = 1.0
        return w

    def dominant_options(self) -> AttrOptions:
        """The attribute selection most queries asked for — pins must carry
        at least these columns to be usable as plan sources."""
        if not self.opt_count:
            return NO_ATTRS
        key = max(self.opt_count.items(), key=lambda kv: kv[1])[0]
        return AttrOptions(key[0], key[1])

    def drift(self, other: dict[int, float]) -> float:
        """Total-variation distance between this histogram and a snapshot of
        an earlier one (both L1-normalized); 0 = identical, 1 = disjoint."""
        keys = set(self.leaf_weight) | set(other)
        a = np.array([self.leaf_weight.get(k, 0.0) for k in keys])
        b = np.array([other.get(k, 0.0) for k in keys])
        if a.sum() <= 0 or b.sum() <= 0:
            return 0.0
        return float(0.5 * np.abs(a / a.sum() - b / b.sum()).sum())

    def snapshot(self) -> dict[int, float]:
        return dict(self.leaf_weight)


# ---------------------------------------------------------------------------
# snapshot LRU cache
# ---------------------------------------------------------------------------


def _state_nbytes(st: "MaterializedState") -> int:
    return (st.node_mask.nbytes + st.edge_mask.nbytes
            + st.node_attrs.nbytes + st.edge_attrs.nbytes)


class SnapshotCache:
    """Byte-bounded LRU of retrieved :class:`MaterializedState`s.

    Keys are ``(t, node_cols, edge_cols, use_current, epoch_tag)``.  The
    epoch tag scopes an entry's validity under live ingest
    (``core/epoch.py``): ``"s"`` marks a *stable* result — ``t`` lies
    strictly below the ingest watermark, so chronological appends can
    never change it and it serves hits across epochs — while a volatile
    result (``t`` at/past the watermark, where the plan crossed CURRENT
    or the unfolded ``recent`` tail) is tagged with the integer epoch id
    it was computed at and can only be hit by queries pinned to that same
    epoch.  Values are defensive copies both ways: the cache never
    aliases caller state, so a hit is bit-identical to a cold retrieval
    (tested property).
    """

    STABLE = "s"

    def __init__(self, max_bytes: int = 32 << 20, max_entries: int = 256) -> None:
        self.max_bytes = int(max_bytes)
        self.max_entries = int(max_entries)
        self._d: OrderedDict[tuple, "MaterializedState"] = OrderedDict()
        self._deps: dict[tuple, frozenset] = {}   # key -> skeleton nids used
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        # concurrent serving threads hit one shared cache; eviction is a
        # multi-step pop/accounting sequence, so every entry point locks
        self._lock = threading.RLock()

    @staticmethod
    def key(t: int, options: AttrOptions, use_current: bool,
            epoch_tag: "str | int" = STABLE) -> tuple:
        return (int(t), options.node_cols, options.edge_cols,
                bool(use_current), epoch_tag)

    def get(self, key: tuple) -> "MaterializedState | None":
        with self._lock:
            st = self._d.get(key)
            if st is None:
                self.misses += 1
                return None
            self._d.move_to_end(key)
            self.hits += 1
            return st.copy()

    def put(self, key: tuple, st: "MaterializedState",
            deps: "frozenset | set | None" = None) -> None:
        """``deps`` are the materialized skeleton nids the producing plan
        routed through; :meth:`invalidate_deps` drops the entry when one of
        them is evicted (its ``materialized_as`` id goes stale)."""
        nb = _state_nbytes(st)
        if nb > self.max_bytes:
            return
        with self._lock:
            if key in self._d:
                self._evict_key(key)
            self._d[key] = st.copy()
            if deps:
                self._deps[key] = frozenset(deps)
            self._bytes += nb
            while self._d and (self._bytes > self.max_bytes
                               or len(self._d) > self.max_entries):
                self._evict_key(next(iter(self._d)))

    def _evict_key(self, key: tuple) -> None:
        st = self._d.pop(key)
        self._deps.pop(key, None)
        self._bytes -= _state_nbytes(st)

    def invalidate_deps(self, nids) -> int:
        """Drop entries whose plan routed through any of the given skeleton
        nodes (called when the advisor evicts pins: the recorded
        ``materialized_as`` sources no longer exist)."""
        nids = set(nids)
        with self._lock:
            dead = [k for k, deps in self._deps.items() if deps & nids]
            for k in dead:
                self._evict_key(k)
            return len(dead)

    def invalidate_from(self, t: int) -> int:
        """Drop entries at or after time ``t`` — the only ones an append
        of events with ``min(time) == t`` can change.  Entries below ``t``
        survive even if their plan crossed the current graph: under
        chronological ingest a snapshot at an earlier time is a function
        of history the new events don't touch (the coarse
        use_current-flush this replaces is regression-pinned in
        tests/test_materialize.py)."""
        with self._lock:
            dead = [k for k in self._d if k[0] >= t]
            for k in dead:
                self._evict_key(k)
            return len(dead)

    def invalidate_epochs_before(self, eid: int) -> int:
        """Reclaim volatile entries tagged with a superseded epoch id —
        they can never be hit again (queries pin the current epoch), this
        just frees the bytes early."""
        with self._lock:
            dead = [k for k in self._d
                    if k[4] != self.STABLE and k[4] < eid]
            for k in dead:
                self._evict_key(k)
            return len(dead)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()
            self._deps.clear()
            self._bytes = 0

    def nbytes(self) -> int:
        return self._bytes

    def __len__(self) -> int:
        return len(self._d)

    def dep_keys(self) -> dict[tuple, frozenset]:
        """Snapshot of the entry → dependency-nid map (stress tests assert
        no surviving entry references an evicted pin)."""
        with self._lock:
            return dict(self._deps)


# ---------------------------------------------------------------------------
# the advisor
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AdvisorConfig:
    budget_bytes: int = 64 << 20   # GraphPool.memory_bytes() ceiling
    replan_every: int = 64         # queries between replan checks
    drift_threshold: float = 0.25  # TV distance that forces a replan
    max_candidates: int = 256      # interior nodes considered per plan
    min_benefit_bytes: float = 1.0 # absolute marginal-gain floor
    min_benefit_frac: float = 0.002  # ... and relative to the cold cost


@dataclasses.dataclass
class Advice:
    """One planning round's outcome.

    ``expected_*`` are in the planner's decode-aware cost units
    (``α·stored + β·logical`` bytes — :meth:`EdgeInfo.weight`), so the
    benefit side of the knapsack automatically credits compression: a pin
    saves what its subtree's queries would have *fetched and decoded*.
    The cost side (``pool_bytes_*``, the budget meter) stays in resident
    logical bytes — pinned states live decoded in the GraphPool."""
    chosen: list[int]                  # skeleton nids to pin (final set)
    added: list[int]
    evicted: list[int]
    expected_saved_bytes: float        # Σ weight·(d_cold − d_advised)
    expected_cold_bytes: float         # Σ weight·d_cold
    pool_bytes_before: int = 0
    pool_bytes_after: int = 0
    cost_model: dict | None = None     # {"alpha_stored": α, "beta_decode": β}


class MaterializationAdvisor:
    """Greedy workload-weighted knapsack over DeltaGraph skeleton nodes."""

    def __init__(self, dg: "DeltaGraph", pool: "GraphPool",
                 stats: WorkloadStats,
                 config: AdvisorConfig | None = None,
                 rates: Rates | None = None) -> None:
        self.dg = dg
        self.pool = pool
        self.stats = stats
        self.rates = rates
        self.config = config or AdvisorConfig()
        self.pinned: dict[int, int] = {}      # nid -> pool gid (advisor-owned)
        # called with the list of evicted nids after every apply();
        # GraphManager wires this to SnapshotCache.invalidate_deps so cache
        # entries whose plans routed through an evicted pin are dropped
        self.on_evict = None
        self.last_advice: Advice | None = None
        self._hist_at_plan: dict[int, float] = {}
        self._since_replan = 0
        # per-candidate leaf distances survive replans — the skeleton only
        # changes on appends, which bump the version key
        self._dist_cache: dict[int, np.ndarray] = {}
        self._dist_ver: tuple | None = None

    # -- cost/benefit models -------------------------------------------------
    def _attr_bytes_per_pin(self, options: AttrOptions) -> int:
        """Upper bound on float32 attribute-column bytes one pin stores."""
        return (len(options.node_cols) * self.dg.universe.num_nodes
                + len(options.edge_cols) * self.dg.universe.num_edges) * 4

    def _pinned_attr_bytes(self) -> int:
        return sum(self.pool.entry_attr_bytes(gid)
                   for gid in self.pinned.values()
                   if gid in self.pool.table)

    def _leaf_weights(self) -> np.ndarray:
        return self.stats.weights(len(self.dg.leaf_nids))

    def _cold_prior_bytes(self) -> float:
        """§5 analytical expected singlepoint path weight (events ≈ bytes up
        to a constant) — used for reporting when no queries were seen."""
        if self.rates is None:
            return 0.0
        return expected_singlepoint_bytes(self.rates, self.dg.L, self.dg.k,
                                          self.dg.diff_names[0])

    def _distances_from(self, starts: Iterable[Any],
                        options: AttrOptions) -> dict[Any, float]:
        dist, _ = self.dg._dijkstra({s: 0.0 for s in starts}, options, {},
                                    use_current=False)
        return dist

    def _candidates(self) -> list[int]:
        """Interior skeleton nodes ranked by observed per-IR-node traffic
        (nodes real plans route through first), level as tie-break (biggest
        fan-out shadow); capped at ``max_candidates``."""
        hits = self.stats.node_hits
        cand = [nid for nid, info in self.dg.nodes.items()
                if info.kind == "interior"]
        cand.sort(key=lambda nid: (-hits.get(nid, 0.0),
                                   -self.dg.nodes[nid].level))
        return cand[: self.config.max_candidates]

    # -- planning ------------------------------------------------------------
    def plan(self, budget_bytes: int | None = None) -> Advice:
        """Choose the ideal pin set under the budget.  Does not touch the
        pool — :meth:`apply` (or :meth:`replan`) does."""
        cfg = self.config
        budget = cfg.budget_bytes if budget_bytes is None else int(budget_bytes)
        options = self.stats.dominant_options()
        leaves = self.dg.leaf_nids
        w = self._leaf_weights()

        # cold distances: sources as they would be with *no* advisor pins —
        # user pins (materialize_roots etc.) count only if their stored
        # columns cover the options, mirroring DeltaGraph._sources()
        base_sources = [SUPERROOT] + [
            nid for nid, info in self.dg.nodes.items()
            if info.materialized_as is not None and nid not in self.pinned
            and set(options.node_cols) <= set(info.mat_node_cols or ())
            and set(options.edge_cols) <= set(info.mat_edge_cols or ())]
        d0 = self._distances_from(base_sources, options)
        cur = np.array([d0.get(l, np.inf) for l in leaves])
        cur[~np.isfinite(cur)] = 0.0
        cold_cost = float((w * cur).sum())

        cand = [c for c in self._candidates() if c not in base_sources]
        # per-candidate leaf distances are independent of what else is
        # pinned — one Dijkstra each, cached until the skeleton changes
        ver = (len(self.dg.nodes), len(self.dg.leaf_nids),
               options.node_cols, options.edge_cols)
        if ver != self._dist_ver:
            self._dist_cache.clear()
            self._dist_ver = ver

        def leafdist(c: int) -> np.ndarray:
            dv = self._dist_cache.get(c)
            if dv is None:
                d = self._distances_from([c], options)
                dv = np.array([d.get(l, np.inf) for l in leaves])
                self._dist_cache[c] = dv
            return dv

        attr_per_pin = self._attr_bytes_per_pin(options)
        pinned_attr_now = self._pinned_attr_bytes()
        chosen: list[int] = []
        spent_pool = self.pool.memory_bytes()
        saved = 0.0
        while cand:
            best = None
            for c in cand:
                gain = float((w * np.maximum(cur - leafdist(c), 0.0)).sum())
                if best is None or gain > best[0]:
                    best = (gain, c)
            gain, c = best
            if gain < max(cfg.min_benefit_bytes,
                          cfg.min_benefit_frac * cold_cost):
                break
            # evicted pins recycle their plane bits and free their attr
            # columns, so the projection is relative to the *final* set
            k = len(chosen) + 1
            projected = self.pool.projected_bytes(
                extra_bits=max(0, k - len(self.pinned)),
                extra_attr_bytes=k * attr_per_pin - pinned_attr_now)
            if projected > budget:
                break
            chosen.append(c)
            cand.remove(c)
            cur = np.minimum(cur, leafdist(c))
            saved += gain

        added = [c for c in chosen if c not in self.pinned]
        evicted = [c for c in self.pinned if c not in chosen]
        from .deltagraph import COST_ALPHA_STORED, COST_BETA_DECODE
        return Advice(chosen, added, evicted,
                      expected_saved_bytes=saved,
                      expected_cold_bytes=cold_cost or self._cold_prior_bytes(),
                      pool_bytes_before=spent_pool,
                      cost_model={"alpha_stored": COST_ALPHA_STORED,
                                  "beta_decode": COST_BETA_DECODE})

    def apply(self, advice: Advice,
              budget_bytes: int | None = None) -> Advice:
        """Evict drifted-out pins, materialize the new ones, enforce the
        budget against the *actual* meter after each pin."""
        budget = (self.config.budget_bytes if budget_bytes is None
                  else int(budget_bytes))
        options = self.stats.dominant_options()
        evicted_now: list[int] = []
        for nid in advice.evicted:
            self.dg.unmaterialize(nid, self.pool)
            self.pinned.pop(nid, None)
            evicted_now.append(nid)
        # kept pins whose stored columns no longer cover the dominant
        # options are useless as plan sources — re-pin with fresh columns
        for nid in advice.chosen:
            if nid in self.pinned and nid not in advice.added:
                info = self.dg.nodes[nid]
                if not (set(options.node_cols) <= set(info.mat_node_cols or ())
                        and set(options.edge_cols)
                        <= set(info.mat_edge_cols or ())):
                    self.dg.unmaterialize(nid, self.pool)
                    self.pinned.pop(nid, None)
                    evicted_now.append(nid)
                    advice.added.append(nid)
        self.pool.cleaner(force=True)
        for nid in advice.added:
            if self.dg.nodes[nid].materialized_as is not None:
                # adopting a stale/uncovered pin: release its old plane
                self.dg.unmaterialize(nid, self.pool)
            gid = self.dg.materialize(nid, self.pool, options)
            self.pinned[nid] = gid
            if self.pool.memory_bytes() > budget:
                # over the meter (plane growth granularity) — roll back
                self.dg.unmaterialize(nid, self.pool)
                self.pool.cleaner(force=True)
                self.pinned.pop(nid, None)
                evicted_now.append(nid)
                break
        # chosen reports what actually got pinned (rollback may truncate)
        advice.chosen = [c for c in advice.chosen if c in self.pinned]
        advice.added = [c for c in advice.added if c in self.pinned]
        advice.pool_bytes_after = self.pool.memory_bytes()
        if self.on_evict is not None and evicted_now:
            self.on_evict([n for n in evicted_now if n not in self.pinned])
        self.last_advice = advice
        self._hist_at_plan = self.stats.snapshot()
        self._since_replan = 0
        return advice

    def replan(self, budget_bytes: int | None = None) -> Advice:
        return self.apply(self.plan(budget_bytes), budget_bytes)

    # -- online hook ---------------------------------------------------------
    def on_query(self, n: int = 1) -> Advice | None:
        """Called by GraphManager after each retrieval; replans every
        ``replan_every`` queries, or immediately when the histogram has
        drifted past ``drift_threshold`` since the last plan.  Batched
        retrievals pass ``n`` = number of queries served so the replan
        cadence is per-query, not per-batch."""
        self._since_replan += int(n)
        if self._since_replan < self.config.replan_every:
            if (self.pinned
                    and self.stats.drift(self._hist_at_plan)
                    > self.config.drift_threshold
                    and self._since_replan >= 8):
                return self.replan()
            return None
        return self.replan()


