"""GraphPool: overlaid in-memory storage for many graphs (paper §6).

One *union* structure + packed bit-planes decide membership of every
element in every active graph:

* bits 0/1 are reserved for the **current graph** (bit 1 flags elements
  deleted recently but not yet folded into the DeltaGraph index);
* a **materialized graph** (DeltaGraph interior/leaf node) takes one bit;
* a **historical snapshot** takes a bit *pair* ``{2i, 2i+1}`` with the
  paper's dependency optimization: when the snapshot is close to the
  current graph or to a materialized graph, bit ``2i`` means "same
  membership as the parent graph" and only the differing elements are
  written — insertion cost proportional to the difference, not the graph.

Planes are stored as rows of packed ``uint32`` words ``[B, W]`` so that
resolution (``(same & parent) | (~same & own)``) and multi-snapshot
analytics are pure vector ops (``vmap`` over plane rows feeds the
bitmap-masked SpMM kernel).  Clean-up is lazy (§6): released rows are
zeroed and recycled by the cleaner, which runs opportunistically or under
memory pressure (``cleaner(force=True)``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from . import bitmaps as bm
from .events import EventList, GraphUniverse, MaterializedState, apply_events

CURRENT_GID = 0


@dataclasses.dataclass
class PoolEntry:
    gid: int
    kind: str                  # 'current' | 'historical' | 'materialized'
    bits: tuple[int, ...]      # plane row indices (1 or 2 of them)
    dep_gid: int | None = None # dependency parent (historical only)
    released: bool = False
    # attribute columns actually fetched for this graph: {col: float32[U]}
    node_attr_cols: dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    edge_attr_cols: dict[int, np.ndarray] = dataclasses.field(default_factory=dict)


class GraphPool:
    DEP_THRESHOLD = 0.25  # dependent storage if diff < 25% of live elements

    def __init__(self, universe: GraphUniverse, initial_bits: int = 8) -> None:
        self.universe = universe
        self.Wn = bm.num_words(universe.num_nodes)
        self.We = bm.num_words(universe.num_edges)
        self.node_planes = np.zeros((initial_bits, self.Wn), np.uint32)
        self.edge_planes = np.zeros((initial_bits, self.We), np.uint32)
        self._free_bits = list(range(2, initial_bits))
        self.table: dict[int, PoolEntry] = {
            CURRENT_GID: PoolEntry(CURRENT_GID, "current", (0, 1))}
        self._next_gid = 1
        self._pending_clean: list[int] = []
        self.overlay_ops = 0   # elements touched on insert (fig 8a companion)

    # ---------------------------------------------------------------- sizing
    def _ensure_universe(self) -> None:
        """Grow plane width when the universe has grown (appends)."""
        Wn = bm.num_words(self.universe.num_nodes)
        We = bm.num_words(self.universe.num_edges)
        if Wn > self.Wn:
            pad = np.zeros((self.node_planes.shape[0], Wn - self.Wn), np.uint32)
            self.node_planes = np.concatenate([self.node_planes, pad], axis=1)
            self.Wn = Wn
        if We > self.We:
            pad = np.zeros((self.edge_planes.shape[0], We - self.We), np.uint32)
            self.edge_planes = np.concatenate([self.edge_planes, pad], axis=1)
            self.We = We

    def _alloc_bits(self, n: int) -> tuple[int, ...]:
        while len(self._free_bits) < n:
            if self._pending_clean:
                self.cleaner(force=True)
                continue
            B = self.node_planes.shape[0]
            grow = max(B, 4)
            self.node_planes = np.concatenate(
                [self.node_planes, np.zeros((grow, self.Wn), np.uint32)])
            self.edge_planes = np.concatenate(
                [self.edge_planes, np.zeros((grow, self.We), np.uint32)])
            self._free_bits.extend(range(B, B + grow))
        return tuple(self._free_bits.pop(0) for _ in range(n))

    # --------------------------------------------------------------- inserts
    def set_current(self, state: MaterializedState) -> None:
        self._ensure_universe()
        self.node_planes[0, :] = 0
        self.edge_planes[0, :] = 0
        self.node_planes[0, : bm.num_words(state.node_mask.size)] = bm.np_pack(state.node_mask)
        self.edge_planes[0, : bm.num_words(state.edge_mask.size)] = bm.np_pack(state.edge_mask)
        e = self.table[CURRENT_GID]
        e.node_attr_cols = {c: state.node_attrs[:, c].copy()
                            for c in range(state.node_attrs.shape[1])}
        e.edge_attr_cols = {c: state.edge_attrs[:, c].copy()
                            for c in range(state.edge_attrs.shape[1])}

    def update_current(self, ev: EventList) -> None:
        """Apply live updates; deletions raise bit 1 ("recently deleted,
        not yet in the index") until :meth:`mark_flushed` drops them."""
        self._ensure_universe()
        st = self.get_state(CURRENT_GID, with_attrs=True)
        before_n, before_e = st.node_mask.copy(), st.edge_mask.copy()
        st2 = apply_events(st, ev, forward=True)
        self.set_current(st2)
        del_n = before_n & ~st2.node_mask
        del_e = before_e & ~st2.edge_mask
        self.node_planes[1, : bm.num_words(del_n.size)] |= bm.np_pack(del_n)
        self.edge_planes[1, : bm.num_words(del_e.size)] |= bm.np_pack(del_e)

    def mark_flushed(self) -> None:
        """The DeltaGraph folded the recent eventlist into the index —
        recently-deleted markers can be dropped."""
        self.node_planes[1, :] = 0
        self.edge_planes[1, :] = 0

    def insert_materialized(self, state: MaterializedState) -> int:
        self._ensure_universe()
        (b,) = self._alloc_bits(1)
        self._write_plane(b, state)
        gid = self._next_gid
        self._next_gid += 1
        entry = PoolEntry(gid, "materialized", (b,))
        self._store_attrs(entry, state)
        self.table[gid] = entry
        return gid

    def insert_snapshot(self, state: MaterializedState) -> int:
        """Overlay a retrieved historical snapshot (bit pair + dependency
        optimization)."""
        return self.insert_snapshots([state])[0]

    def insert_snapshots(self, states: list[MaterializedState]) -> list[int]:
        """Batched overlay: allocate every bit pair in one pass, then write
        the ``B`` snapshots' planes — the landing step of the batched
        retrieval engine (one pool pass per query batch, not per query)."""
        self._ensure_universe()
        packed = []
        for st in states:
            packed.append((self._fit(bm.np_pack(st.node_mask), self.Wn),
                           self._fit(bm.np_pack(st.edge_mask), self.We)))
        return self._insert_packed(packed, states)

    def insert_snapshots_packed(self, pairs: list[tuple[np.ndarray, np.ndarray]]
                                ) -> list[int]:
        """Batched overlay of already-packed ``(node_words, edge_words)``
        bitmaps (the JAX executor lands device results here without an
        unpack/re-pack round-trip).  No attribute columns are stored."""
        self._ensure_universe()
        packed = [(self._fit(np.asarray(n, np.uint32), self.Wn),
                   self._fit(np.asarray(e, np.uint32), self.We))
                  for n, e in pairs]
        return self._insert_packed(packed, [None] * len(packed))

    def _insert_packed(self, packed: list[tuple[np.ndarray, np.ndarray]],
                       states: list[MaterializedState | None]) -> list[int]:
        bits = self._alloc_bits(2 * len(packed))
        gids = []
        # snapshot the dependency candidates once per batch (current +
        # materialized graphs; batch members don't depend on each other)
        cands = []
        for gid, e in self.table.items():
            if e.released or e.kind == "historical":
                continue
            pn, pe = self._resolve_masks(gid)
            cands.append((gid, pn, pe))
        for i, ((nbm, ebm), state) in enumerate(zip(packed, states)):
            live = int(bm.np_popcount(nbm) + bm.np_popcount(ebm))
            best: tuple[int, int] | None = None  # (diff, candidate index)
            for ci, (gid, pn, pe) in enumerate(cands):
                diff = int(bm.np_popcount(pn ^ nbm) + bm.np_popcount(pe ^ ebm))
                if best is None or diff < best[0]:
                    best = (diff, ci)
            b_same, b_own = bits[2 * i], bits[2 * i + 1]
            gid = self._next_gid
            self._next_gid += 1
            if best is not None and best[0] < self.DEP_THRESHOLD * max(live, 1):
                dep, pn, pe = cands[best[1]]
                self.node_planes[b_same] = ~(pn ^ nbm)   # 1 = same as parent
                self.edge_planes[b_same] = ~(pe ^ ebm)
                self.node_planes[b_own] = nbm & (pn ^ nbm)
                self.edge_planes[b_own] = ebm & (pe ^ ebm)
                self.overlay_ops += best[0]
                entry = PoolEntry(gid, "historical", (b_same, b_own),
                                  dep_gid=dep)
            else:
                self.node_planes[b_same] = 0  # same-as-parent nowhere
                self.edge_planes[b_same] = 0
                self.node_planes[b_own] = nbm
                self.edge_planes[b_own] = ebm
                self.overlay_ops += live
                entry = PoolEntry(gid, "historical", (b_same, b_own))
            if state is not None:
                self._store_attrs(entry, state)
            self.table[gid] = entry
            gids.append(gid)
        return gids

    def _fit(self, words: np.ndarray, W: int) -> np.ndarray:
        return bm.np_fit_words(words, W)

    def _write_plane(self, b: int, state: MaterializedState) -> None:
        self.node_planes[b] = self._fit(bm.np_pack(state.node_mask), self.Wn)
        self.edge_planes[b] = self._fit(bm.np_pack(state.edge_mask), self.We)
        self.overlay_ops += int(state.node_mask.sum() + state.edge_mask.sum())

    def _store_attrs(self, entry: PoolEntry, state: MaterializedState) -> None:
        for c in range(state.node_attrs.shape[1]):
            colv = state.node_attrs[:, c]
            if not np.all(np.isnan(colv)):
                entry.node_attr_cols[c] = colv.copy()
        for c in range(state.edge_attrs.shape[1]):
            colv = state.edge_attrs[:, c]
            if not np.all(np.isnan(colv)):
                entry.edge_attr_cols[c] = colv.copy()

    # -------------------------------------------------------------- resolve
    def _resolve_masks(self, gid: int) -> tuple[np.ndarray, np.ndarray]:
        e = self.table[gid]
        if e.kind == "current":
            return self.node_planes[0].copy(), self.edge_planes[0].copy()
        if e.kind == "materialized":
            return self.node_planes[e.bits[0]].copy(), self.edge_planes[e.bits[0]].copy()
        b_same, b_own = e.bits
        if e.dep_gid is not None:
            pn, pe = self._resolve_masks(e.dep_gid)
            n = (self.node_planes[b_same] & pn) | (~self.node_planes[b_same]
                                                   & self.node_planes[b_own])
            m = (self.edge_planes[b_same] & pe) | (~self.edge_planes[b_same]
                                                   & self.edge_planes[b_own])
            return n, m
        return self.node_planes[b_own].copy(), self.edge_planes[b_own].copy()

    def get_node_mask(self, gid: int) -> np.ndarray:
        return bm.np_unpack(self._resolve_masks(gid)[0], self.universe.num_nodes)

    def get_edge_mask(self, gid: int) -> np.ndarray:
        return bm.np_unpack(self._resolve_masks(gid)[1], self.universe.num_edges)

    def get_state(self, gid: int, with_attrs: bool = False) -> MaterializedState:
        U_n, U_e = self.universe.num_nodes, self.universe.num_edges
        A_n, A_e = self.universe.num_node_attrs, self.universe.num_edge_attrs
        nmask = self.get_node_mask(gid)
        emask = self.get_edge_mask(gid)
        na = np.full((U_n, A_n), np.nan, np.float32)
        ea = np.full((U_e, A_e), np.nan, np.float32)
        if with_attrs:
            e = self.table[gid]
            for c, v in e.node_attr_cols.items():
                na[: v.size, c] = v
            for c, v in e.edge_attr_cols.items():
                ea[: v.size, c] = v
        return MaterializedState(nmask, emask, na, ea)

    def stacked_planes(self, gids: list[int]) -> tuple[np.ndarray, np.ndarray]:
        """Resolved [G, W] packed membership planes for analytics vmap."""
        ns, es = [], []
        for g in gids:
            n, e = self._resolve_masks(g)
            ns.append(n)
            es.append(e)
        return np.stack(ns), np.stack(es)

    def union_masks(self) -> tuple[np.ndarray, np.ndarray]:
        n = self.node_planes[0].copy()
        e = self.edge_planes[0].copy()
        for g in self.active_gids():
            rn, re = self._resolve_masks(g)
            n |= rn
            e |= re
        return n, e

    def active_gids(self) -> list[int]:
        return [g for g, e in self.table.items() if not e.released]

    # -------------------------------------------------------------- cleanup
    def release(self, gid: int) -> None:
        """Logically drop a graph; physical clean-up is lazy (§6)."""
        e = self.table[gid]
        if e.kind == "current":
            raise ValueError("cannot release the current graph")
        for other in self.table.values():
            if other.dep_gid == gid and not other.released:
                # un-depend before the parent goes away
                n, m = self._resolve_masks(other.gid)
                b_same, b_own = other.bits
                self.node_planes[b_same] = 0
                self.edge_planes[b_same] = 0
                self.node_planes[b_own] = n
                self.edge_planes[b_own] = m
                other.dep_gid = None
        e.released = True
        self._pending_clean.append(gid)

    def cleaner(self, force: bool = False) -> int:
        """Zero released planes and recycle bits.  Returns rows recycled."""
        done = 0
        while self._pending_clean:
            gid = self._pending_clean.pop()
            e = self.table.pop(gid)
            for b in e.bits:
                self.node_planes[b] = 0
                self.edge_planes[b] = 0
                self._free_bits.append(b)
            done += 1
            if not force and done >= 4:
                break  # lazy: bounded work per opportunity
        return done

    # ------------------------------------------------------------ accounting
    def entry_attr_bytes(self, gid: int) -> int:
        e = self.table[gid]
        return (sum(v.nbytes for v in e.node_attr_cols.values())
                + sum(v.nbytes for v in e.edge_attr_cols.values()))

    def projected_bytes(self, extra_bits: int = 0,
                        extra_attr_bytes: int = 0) -> int:
        """What :meth:`memory_bytes` would read after allocating
        ``extra_bits`` more plane rows (accounting for free/recyclable bits
        and the doubling growth policy) plus ``extra_attr_bytes`` of
        attribute columns.  The materialization advisor budgets against
        this before touching the pool."""
        free = len(self._free_bits) + sum(
            len(self.table[g].bits) for g in self._pending_clean
            if g in self.table)
        rows = self.node_planes.shape[0]
        need = extra_bits - free
        while need > 0:
            grow = max(rows, 4)
            rows += grow
            need -= grow
        planes = rows * (self.Wn + self.We) * 4
        attrs = sum(self.entry_attr_bytes(g) for g, e in self.table.items()
                    if not e.released)
        return planes + attrs + max(extra_attr_bytes, -attrs)

    def memory_bytes(self) -> int:
        planes = self.node_planes.nbytes + self.edge_planes.nbytes
        attrs = 0
        for e in self.table.values():
            attrs += sum(v.nbytes for v in e.node_attr_cols.values())
            attrs += sum(v.nbytes for v in e.edge_attr_cols.values())
        return planes + attrs

    def num_active(self) -> int:
        return len(self.active_gids())
