"""Prior snapshot-retrieval techniques the paper evaluates against (§4.1,
§7): in-memory **interval trees**, **Copy+Log**, and the naive **Log**.

All three plug into the same benchmark harness as DeltaGraph (same
universe/events, same MaterializedState output) so retrieval-time and
storage comparisons are apples-to-apples.
"""
from __future__ import annotations

import numpy as np

from ..storage import columnar as col
from ..storage.kv import KVStore, MemKV
from . import bitmaps as bm
from .events import (EV_DEL_EDGE, EV_DEL_NODE, EV_NEW_EDGE, EV_NEW_NODE,
                     EventList, GraphUniverse, MaterializedState,
                     apply_events)


def _element_intervals(universe: GraphUniverse, events: EventList):
    """(kind, slot) → [birth, death) from the event trace (ids never
    reused ⇒ exactly one interval per element)."""
    INF = np.iinfo(np.int64).max
    n_birth = np.full(universe.num_nodes, INF, np.int64)
    n_death = np.full(universe.num_nodes, INF, np.int64)
    e_birth = np.full(universe.num_edges, INF, np.int64)
    e_death = np.full(universe.num_edges, INF, np.int64)
    for arr_b, arr_d, add_c, del_c in ((n_birth, n_death, EV_NEW_NODE, EV_DEL_NODE),
                                       (e_birth, e_death, EV_NEW_EDGE, EV_DEL_EDGE)):
        for code, arr in ((add_c, arr_b), (del_c, arr_d)):
            m = events.etype == code
            arr[events.slot[m]] = events.time[m]
    return n_birth, n_death, e_birth, e_death


class IntervalTreeIndex:
    """Centered (Edelsbrunner) interval tree per element kind.

    ``query(t)`` returns every element whose [birth, death) contains t —
    the valid-timeslice query — in O(log n + answer).
    """

    class _Node:
        __slots__ = ("center", "left", "right", "by_start", "by_end")

        def __init__(self, center):
            self.center = center
            self.left = None
            self.right = None
            self.by_start = None   # (starts sorted asc, ids)
            self.by_end = None     # (ends sorted desc, ids)

    def __init__(self, starts: np.ndarray, ends: np.ndarray) -> None:
        ids = np.arange(starts.size, dtype=np.int64)
        # zero-length intervals ([s, s): added and deleted at the same
        # timestamp) are never alive under half-open semantics — and they
        # make centered splits degenerate
        live = (starts < np.iinfo(np.int64).max) & (ends > starts)
        self.root = self._build_iter(starts[live], ends[live], ids[live])
        self.nbytes = int(starts.nbytes + ends.nbytes) * 2  # rough

    def _build_iter(self, starts, ends, ids):
        """Iterative build (deep skewed traces overflow Python recursion);
        degenerate splits fall back to the start median."""
        if ids.size == 0:
            return None
        INF = np.iinfo(np.int64).max
        root = self._Node(0)
        stack = [(starts, ends, ids, root)]
        while stack:
            starts, ends, ids, node = stack.pop()
            fin = ends[ends < INF]
            vals = np.concatenate([starts, fin]) if fin.size else starts
            center = np.median(vals)
            in_l = ends <= center
            in_r = starts > center
            if in_l.all() or in_r.all():
                center = np.median(starts)  # degenerate — split by starts
                in_l = ends <= center
                in_r = starts > center
                if in_l.all() or in_r.all():  # still stuck: keep all here
                    in_l[:] = False
                    in_r[:] = False
            mid = ~(in_l | in_r)
            node.center = center
            s, e, i = starts[mid], ends[mid], ids[mid]
            o1 = np.argsort(s)
            node.by_start = (s[o1], i[o1])
            o2 = np.argsort(-e)
            node.by_end = (e[o2], i[o2])
            if in_l.any():
                node.left = self._Node(0)
                stack.append((starts[in_l], ends[in_l], ids[in_l], node.left))
            if in_r.any():
                node.right = self._Node(0)
                stack.append((starts[in_r], ends[in_r], ids[in_r], node.right))
        return root

    def query(self, t: int) -> np.ndarray:
        out: list[np.ndarray] = []
        node = self.root
        while node is not None:
            if t < node.center:
                s, i = node.by_start
                k = np.searchsorted(s, t, side="right")
                out.append(i[:k])
                node = node.left
            elif t > node.center:
                e, i = node.by_end
                # half-open [birth, death): stabbed iff death > t
                k = np.searchsorted(-e, -t, side="left")
                out.append(i[:k])
                node = node.right
            else:
                # start <= center == t for all node intervals; still filter
                # by death > t (degenerate-kept intervals may end early)
                e, i = node.by_end
                k = np.searchsorted(-e, -t, side="left")
                out.append(i[:k])
                node = None
        if not out:
            return np.zeros(0, np.int64)
        res = np.concatenate(out)
        return res


class IntervalTreeStore:
    """Full baseline: one interval tree for nodes, one for edges."""

    def __init__(self, universe: GraphUniverse, events: EventList) -> None:
        self.universe = universe
        nb, nd, eb, ed = _element_intervals(universe, events)
        # an element is live in [birth, death); deletion at te removes at te
        self.nodes = IntervalTreeIndex(nb, nd)
        self.edges = IntervalTreeIndex(eb, ed)

    def get_snapshot(self, t: int) -> MaterializedState:
        st = MaterializedState.empty(self.universe)
        st.node_mask[self.nodes.query(t)] = True
        st.edge_mask[self.edges.query(t)] = True
        st.edge_mask &= ~self.universe.edge_transient[: st.edge_mask.size]
        st.node_mask &= ~self.universe.node_transient[: st.node_mask.size]
        return st

    def memory_bytes(self) -> int:
        return self.nodes.nbytes + self.edges.nbytes


class CopyLogStore:
    """Copy+Log (§4.1): a full packed snapshot every L events in the KV
    store + the eventlists; retrieval = nearest snapshot + replay."""

    def __init__(self, universe: GraphUniverse, events: EventList, L: int,
                 store: KVStore | None = None) -> None:
        self.universe = universe
        self.L = L
        self.store = store if store is not None else MemKV()
        self.events = events
        self.snap_pos: list[int] = []
        self.snap_time: list[int] = []
        state = MaterializedState.empty(universe)
        pos = 0
        sid = 0
        while True:
            # a *copy* stores the live element ids (4 B/element), like the
            # paper's full snapshots — not a packed bitmap, whose size would
            # be O(universe/8) and hide the Copy approach's true cost
            self.store.put((0, sid, "snap"), col.pack_arrays({
                "n": np.nonzero(state.node_mask)[0].astype(np.int32),
                "e": np.nonzero(state.edge_mask)[0].astype(np.int32)}))
            self.snap_pos.append(pos)
            self.snap_time.append(int(events.time[pos - 1]) if pos else
                                  (int(events.time[0]) - 1 if len(events) else 0))
            if pos >= len(events):
                break
            chunk = events[pos: pos + L]
            self.store.put((0, sid, "elist"),
                           col.encode_eventlist(chunk)[col.ELIST_STRUCT])
            state = apply_events(state, chunk, forward=True)
            pos += len(chunk)
            sid += 1

    def get_snapshot(self, t: int) -> MaterializedState:
        i = int(np.searchsorted(np.asarray(self.snap_time[1:]), t,
                                side="right"))
        i = min(i, len(self.snap_pos) - 1)
        blob = self.store.get((0, i, "snap"))
        arrs = col.unpack_arrays(blob)
        st = MaterializedState.empty(self.universe)
        st.node_mask[arrs["n"]] = True
        st.edge_mask[arrs["e"]] = True
        if i < len(self.snap_pos) - 1 or self.snap_pos[i] < len(self.events):
            try:
                s = col.unpack_arrays(self.store.get((0, i, "elist")))
            except KeyError:
                s = None
            if s is not None:
                m = s["time"] <= t
                et, sl = s["etype"][m], s["slot"][m]
                ncnt = st.node_mask.astype(np.int32)
                np.add.at(ncnt, sl[et == EV_NEW_NODE], 1)
                np.add.at(ncnt, sl[et == EV_DEL_NODE], -1)
                st.node_mask = ncnt > 0
                ecnt = st.edge_mask.astype(np.int32)
                np.add.at(ecnt, sl[et == EV_NEW_EDGE], 1)
                np.add.at(ecnt, sl[et == EV_DEL_EDGE], -1)
                st.edge_mask = ecnt > 0
        st.edge_mask &= ~self.universe.edge_transient[: st.edge_mask.size]
        st.node_mask &= ~self.universe.node_transient[: st.node_mask.size]
        return st

    def storage_bytes(self) -> int:
        return self.store.total_bytes()


class LogStore:
    """The naive Log approach: scan every event from the beginning."""

    def __init__(self, universe: GraphUniverse, events: EventList) -> None:
        self.universe = universe
        self.events = events

    def get_snapshot(self, t: int) -> MaterializedState:
        from .events import replay
        return replay(self.universe, self.events, t)

    def storage_bytes(self) -> int:
        return self.events.nbytes()
