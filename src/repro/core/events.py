"""Event model for historical graphs (paper §3.1).

An *event* is an atomic activity: node/edge creation or deletion, an
attribute-value change, or a *transient* element valid only at one instant.
Events are bidirectional: they carry enough information (old + new values)
to be applied in either direction of time::

    G_k = G_{k-1} + E,     G_{k-1} = G_k - E

Representation is struct-of-arrays (TPU-friendly, columnar):

* ``time``      int64   event timepoint
* ``etype``     int8    one of the ``EV_*`` codes
* ``slot``      int32   dense slot in the node or edge universe
* ``attr_col``  int16   attribute column (UNA/UEA only, else -1)
* ``value``     float32 new attribute value (UNA/UEA), else NaN
* ``old_value`` float32 previous attribute value (UNA/UEA), else NaN

Node and edge identities: IDs are assigned at creation and never reused
(paper §3.1 — a deletion followed by re-insertion yields a *new* id), which
is what makes dense append-only slot universes possible.  External ids map
to slots through the :class:`GraphUniverse` lookup tables (the paper's
QueryManager id-translation role).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping, Sequence

import numpy as np

# Event type codes ----------------------------------------------------------
EV_NEW_NODE = 0      # NN
EV_DEL_NODE = 1      # DN
EV_NEW_EDGE = 2      # NE
EV_DEL_EDGE = 3      # DE
EV_UPD_NODE_ATTR = 4 # UNA
EV_UPD_EDGE_ATTR = 5 # UEA
EV_TRANS_EDGE = 6    # transient edge (valid only at its instant)
EV_TRANS_NODE = 7    # transient node

EVENT_NAMES = {
    EV_NEW_NODE: "NN", EV_DEL_NODE: "DN", EV_NEW_EDGE: "NE", EV_DEL_EDGE: "DE",
    EV_UPD_NODE_ATTR: "UNA", EV_UPD_EDGE_ATTR: "UEA",
    EV_TRANS_EDGE: "TE", EV_TRANS_NODE: "TN",
}

_STRUCT_NODE = (EV_NEW_NODE, EV_DEL_NODE, EV_TRANS_NODE)
_STRUCT_EDGE = (EV_NEW_EDGE, EV_DEL_EDGE, EV_TRANS_EDGE)


class InternTable:
    """Bidirectional string <-> float32 code table so that non-numeric
    attribute values ('job', 'name', ...) can live in numeric columns."""

    def __init__(self) -> None:
        self._to_code: dict[str, float] = {}
        self._to_str: list[str] = []

    def code(self, s: str) -> float:
        c = self._to_code.get(s)
        if c is None:
            c = float(len(self._to_str))
            self._to_code[s] = c
            self._to_str.append(s)
        return c

    def lookup(self, code: float) -> str:
        return self._to_str[int(code)]

    def __len__(self) -> int:
        return len(self._to_str)


@dataclasses.dataclass
class EventList:
    """Chronologically sorted struct-of-arrays eventlist."""

    time: np.ndarray       # int64[M]
    etype: np.ndarray      # int8[M]
    slot: np.ndarray       # int32[M]
    attr_col: np.ndarray   # int16[M]
    value: np.ndarray      # float32[M]
    old_value: np.ndarray  # float32[M]

    def __len__(self) -> int:
        return int(self.time.shape[0])

    def __getitem__(self, sl) -> "EventList":
        return EventList(self.time[sl], self.etype[sl], self.slot[sl],
                         self.attr_col[sl], self.value[sl], self.old_value[sl])

    def nbytes(self) -> int:
        return sum(a.nbytes for a in
                   (self.time, self.etype, self.slot, self.attr_col,
                    self.value, self.old_value))

    @staticmethod
    def empty() -> "EventList":
        return EventList(np.zeros(0, np.int64), np.zeros(0, np.int8),
                         np.zeros(0, np.int32), np.zeros(0, np.int16),
                         np.zeros(0, np.float32), np.zeros(0, np.float32))

    @staticmethod
    def concat(parts: Sequence["EventList"]) -> "EventList":
        if not parts:
            return EventList.empty()
        return EventList(*[np.concatenate([getattr(p, f.name) for p in parts])
                           for f in dataclasses.fields(EventList)])

    def search_time(self, t: int, side: str = "right") -> int:
        """Index of the first event strictly after t (side='right')."""
        return int(np.searchsorted(self.time, t, side=side))


class GraphUniverse:
    """Append-only dense slot registries for nodes, edges and attributes."""

    def __init__(self) -> None:
        self._node_of: dict[Any, int] = {}
        self._edge_of: dict[Any, int] = {}
        self.node_ids: list[Any] = []
        self.edge_ids: list[Any] = []
        self._edge_src: list[int] = []
        self._edge_dst: list[int] = []
        self._edge_directed: list[bool] = []
        self._edge_transient: list[bool] = []
        self._node_transient: list[bool] = []
        self.node_attr_cols: dict[str, int] = {}
        self.edge_attr_cols: dict[str, int] = {}
        self.strings = InternTable()
        self._finalized: dict[str, np.ndarray] = {}

    # -- registration -------------------------------------------------------
    def node_slot(self, ext_id: Any, create: bool = False,
                  transient: bool = False) -> int:
        s = self._node_of.get(ext_id)
        if s is None:
            if not create:
                raise KeyError(f"unknown node id {ext_id!r}")
            s = len(self.node_ids)
            self._node_of[ext_id] = s
            self.node_ids.append(ext_id)
            self._node_transient.append(transient)
            self._finalized.clear()
        return s

    def new_edge_slot(self, ext_id: Any, src_slot: int, dst_slot: int,
                      directed: bool, transient: bool = False) -> int:
        s = len(self.edge_ids)
        self._edge_of[ext_id] = s
        self.edge_ids.append(ext_id)
        self._edge_src.append(src_slot)
        self._edge_dst.append(dst_slot)
        self._edge_directed.append(directed)
        self._edge_transient.append(transient)
        self._finalized.clear()
        return s

    def edge_slot(self, ext_id: Any) -> int:
        return self._edge_of[ext_id]

    def attr_col(self, kind: str, name: str, create: bool = False) -> int:
        table = self.node_attr_cols if kind == "node" else self.edge_attr_cols
        c = table.get(name)
        if c is None:
            if not create:
                raise KeyError(f"unknown {kind} attribute {name!r}")
            c = len(table)
            table[name] = c
        return c

    # -- sizes ---------------------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return len(self.node_ids)

    @property
    def num_edges(self) -> int:
        return len(self.edge_ids)

    @property
    def num_node_attrs(self) -> int:
        return len(self.node_attr_cols)

    @property
    def num_edge_attrs(self) -> int:
        return len(self.edge_attr_cols)

    # -- finalized arrays ----------------------------------------------------
    def _arr(self, name: str, src: list, dtype) -> np.ndarray:
        a = self._finalized.get(name)
        if a is None or a.shape[0] != len(src):
            a = np.asarray(src, dtype=dtype)
            self._finalized[name] = a
        return a

    @property
    def edge_src(self) -> np.ndarray:
        return self._arr("edge_src", self._edge_src, np.int32)

    @property
    def edge_dst(self) -> np.ndarray:
        return self._arr("edge_dst", self._edge_dst, np.int32)

    @property
    def edge_directed(self) -> np.ndarray:
        return self._arr("edge_directed", self._edge_directed, bool)

    @property
    def edge_transient(self) -> np.ndarray:
        return self._arr("edge_transient", self._edge_transient, bool)

    @property
    def node_transient(self) -> np.ndarray:
        return self._arr("node_transient", self._node_transient, bool)


class GraphHistoryBuilder:
    """Ingests activity and emits (universe, chronologically sorted events).

    Mirrors the paper's update path: events are recorded in the direction of
    evolving time; the builder tracks attribute old-values so that events are
    bidirectional.
    """

    def __init__(self) -> None:
        self.universe = GraphUniverse()
        self._rows: list[tuple[int, int, int, int, float, float]] = []
        self._node_attr_state: dict[tuple[int, int], float] = {}
        self._edge_attr_state: dict[tuple[int, int], float] = {}
        self._live_nodes: set[int] = set()
        self._live_edges: set[int] = set()
        self._edge_key_alive: dict[Any, int] = {}
        self._seq = 0

    # -- helpers -------------------------------------------------------------
    def _emit(self, t: int, etype: int, slot: int, col: int = -1,
              value: float = np.nan, old: float = np.nan) -> None:
        self._rows.append((int(t), etype, slot, col, value, old))
        self._seq += 1

    def _coerce(self, v: Any) -> float:
        if isinstance(v, str):
            return self.universe.strings.code(v)
        return float(v)

    # -- public API ----------------------------------------------------------
    def add_node(self, node_id: Any, t: int,
                 attrs: Mapping[str, Any] | None = None) -> int:
        s = self.universe.node_slot(node_id, create=True)
        if s in self._live_nodes:
            raise ValueError(f"node {node_id!r} already alive")
        self._live_nodes.add(s)
        self._emit(t, EV_NEW_NODE, s)
        for k, v in (attrs or {}).items():
            self.set_node_attr(node_id, k, v, t)
        return s

    def delete_node(self, node_id: Any, t: int) -> None:
        s = self.universe.node_slot(node_id)
        self._live_nodes.discard(s)
        self._emit(t, EV_DEL_NODE, s)

    def add_edge(self, u: Any, v: Any, t: int, directed: bool = False,
                 edge_id: Any = None, attrs: Mapping[str, Any] | None = None) -> int:
        su = self.universe.node_slot(u)
        sv = self.universe.node_slot(v)
        key = edge_id if edge_id is not None else ("__e", u, v, t, self._seq)
        s = self.universe.new_edge_slot(key, su, sv, directed)
        self._live_edges.add(s)
        self._edge_key_alive[(u, v)] = s
        self._emit(t, EV_NEW_EDGE, s)
        for k, w in (attrs or {}).items():
            self._set_edge_attr_slot(s, k, w, t)
        return s

    def delete_edge(self, u: Any, v: Any, t: int) -> None:
        s = self._edge_key_alive.pop((u, v))
        self._live_edges.discard(s)
        self._emit(t, EV_DEL_EDGE, s)

    def delete_edge_slot(self, slot: int, t: int) -> None:
        self._live_edges.discard(slot)
        self._emit(t, EV_DEL_EDGE, slot)

    def set_node_attr(self, node_id: Any, name: str, value: Any, t: int) -> None:
        s = self.universe.node_slot(node_id)
        c = self.universe.attr_col("node", name, create=True)
        val = self._coerce(value)
        old = self._node_attr_state.get((s, c), np.nan)
        self._node_attr_state[(s, c)] = val
        self._emit(t, EV_UPD_NODE_ATTR, s, c, val, old)

    def set_edge_attr(self, u: Any, v: Any, name: str, value: Any, t: int) -> None:
        self._set_edge_attr_slot(self._edge_key_alive[(u, v)], name, value, t)

    def _set_edge_attr_slot(self, s: int, name: str, value: Any, t: int) -> None:
        c = self.universe.attr_col("edge", name, create=True)
        val = self._coerce(value)
        old = self._edge_attr_state.get((s, c), np.nan)
        self._edge_attr_state[(s, c)] = val
        self._emit(t, EV_UPD_EDGE_ATTR, s, c, val, old)

    def transient_edge(self, u: Any, v: Any, t: int, directed: bool = True) -> int:
        """e.g. a 'message' from u to v valid only at instant t (§3.1)."""
        su = self.universe.node_slot(u)
        sv = self.universe.node_slot(v)
        s = self.universe.new_edge_slot(("__te", u, v, t, self._seq), su, sv,
                                        directed, transient=True)
        self._emit(t, EV_TRANS_EDGE, s)
        return s

    def finalize(self) -> tuple[GraphUniverse, EventList]:
        rows = self._rows
        order = sorted(range(len(rows)), key=lambda i: rows[i][0])  # stable
        cols = list(zip(*[rows[i] for i in order])) if rows else [[]] * 6
        ev = EventList(
            np.asarray(cols[0], np.int64), np.asarray(cols[1], np.int8),
            np.asarray(cols[2], np.int32), np.asarray(cols[3], np.int16),
            np.asarray(cols[4], np.float32), np.asarray(cols[5], np.float32))
        return self.universe, ev


# ---------------------------------------------------------------------------
# Brute-force oracle (the "Log" approach, §4.1) — ground truth for every test
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MaterializedState:
    """A fully materialized graph state: dense masks + attribute matrices."""

    node_mask: np.ndarray   # bool[U_n]
    edge_mask: np.ndarray   # bool[U_e]
    node_attrs: np.ndarray  # float32[U_n, A_n]
    edge_attrs: np.ndarray  # float32[U_e, A_e]

    @staticmethod
    def empty(universe: GraphUniverse) -> "MaterializedState":
        return MaterializedState(
            np.zeros(universe.num_nodes, bool),
            np.zeros(universe.num_edges, bool),
            np.full((universe.num_nodes, universe.num_node_attrs), np.nan, np.float32),
            np.full((universe.num_edges, universe.num_edge_attrs), np.nan, np.float32))

    def copy(self) -> "MaterializedState":
        return MaterializedState(self.node_mask.copy(), self.edge_mask.copy(),
                                 self.node_attrs.copy(), self.edge_attrs.copy())

    def resized(self, universe: "GraphUniverse") -> "MaterializedState":
        """Grow to the universe's current size (live updates add slots §6)."""
        U_n, U_e = universe.num_nodes, universe.num_edges
        A_n, A_e = universe.num_node_attrs, universe.num_edge_attrs
        if (self.node_mask.size == U_n and self.edge_mask.size == U_e
                and self.node_attrs.shape == (U_n, A_n)
                and self.edge_attrs.shape == (U_e, A_e)):
            return self
        out = MaterializedState.empty(universe)
        out.node_mask[: self.node_mask.size] = self.node_mask
        out.edge_mask[: self.edge_mask.size] = self.edge_mask
        if self.node_attrs.size:
            out.node_attrs[: self.node_attrs.shape[0],
                           : self.node_attrs.shape[1]] = self.node_attrs
        if self.edge_attrs.size:
            out.edge_attrs[: self.edge_attrs.shape[0],
                           : self.edge_attrs.shape[1]] = self.edge_attrs
        return out

    def equal(self, other: "MaterializedState",
              check_attrs: bool = True) -> bool:
        if not (np.array_equal(self.node_mask, other.node_mask)
                and np.array_equal(self.edge_mask, other.edge_mask)):
            return False
        if not check_attrs:
            return True
        def attrs_eq(a, b, mask):
            a = np.where(mask[:, None], a, np.nan)
            b = np.where(mask[:, None], b, np.nan)
            return np.array_equal(a, b, equal_nan=True)
        return (attrs_eq(self.node_attrs, other.node_attrs, self.node_mask)
                and attrs_eq(self.edge_attrs, other.edge_attrs, self.edge_mask))


def apply_events(state: MaterializedState, ev: EventList,
                 forward: bool = True) -> MaterializedState:
    """Apply an eventlist to a state, in either direction of time (§3.1).

    Vectorized: membership via ±1 count accumulation (valid because element
    membership toggles alternate along any chronological event sequence);
    attributes via last-writer-wins per (slot, col).
    """
    out = state.copy()
    n = len(ev)
    if n == 0:
        return out
    if forward:
        add_n, del_n, add_e, del_e = EV_NEW_NODE, EV_DEL_NODE, EV_NEW_EDGE, EV_DEL_EDGE
        attr_val = ev.value
        order = np.arange(n)
    else:
        add_n, del_n, add_e, del_e = EV_DEL_NODE, EV_NEW_NODE, EV_DEL_EDGE, EV_NEW_EDGE
        attr_val = ev.old_value
        order = np.arange(n - 1, -1, -1)

    et, sl = ev.etype, ev.slot
    ncnt = out.node_mask.astype(np.int32)
    np.add.at(ncnt, sl[et == add_n], 1)
    np.add.at(ncnt, sl[et == del_n], -1)
    out.node_mask = ncnt > 0
    ecnt = out.edge_mask.astype(np.int32)
    np.add.at(ecnt, sl[et == add_e], 1)
    np.add.at(ecnt, sl[et == del_e], -1)
    out.edge_mask = ecnt > 0

    for code, attrs in ((EV_UPD_NODE_ATTR, out.node_attrs),
                        (EV_UPD_EDGE_ATTR, out.edge_attrs)):
        idx = order[et[order] == code]
        if idx.size:
            # last occurrence (in application order) wins
            attrs[ev.slot[idx], ev.attr_col[idx]] = attr_val[idx]
    return out


def replay(universe: GraphUniverse, events: EventList, t: int) -> MaterializedState:
    """Ground-truth snapshot as of time ``t``: apply every event with
    ``time <= t`` (``G_k = G_{k-1} + E``) to the empty graph.  Transient
    elements are excluded by definition (only interval queries see them)."""
    state = MaterializedState.empty(universe)
    hi = events.search_time(t, side="right")
    state = apply_events(state, events[:hi], forward=True)
    state.edge_mask &= ~universe.edge_transient[: state.edge_mask.size]
    state.node_mask &= ~universe.node_transient[: state.node_mask.size]
    return state
