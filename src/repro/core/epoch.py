"""Refcounted epoch registry for red/green index swaps (§6 live updates).

An *epoch* is one immutable version of the retrieval index: a
:class:`~repro.core.deltagraph.DeltaGraph` whose skeleton, ``recent``
tail and bookkeeping are frozen from the reader's point of view.  The
ingest pipeline publishes a new epoch for every committed event group
(cheap shallow clone — only ``recent`` moved) and for every completed
leaf rollover (structural fork rebuilt on a worker thread).

Readers pin an epoch at query entry (``registry.acquire()``) so every
plan compiled within one query document resolves against one consistent
index version, even while the writer publishes newer epochs underneath.
The green→red switch is a single atomic pointer swap under the registry
lock; superseded resources (cap-delta payloads, pool pins, WAL records)
are reclaimed *deferred*: an epoch's reclaim callbacks run only once its
refcount has drained **and** every older retired epoch has drained too,
so a reader pinned three epochs back never loses a payload that a newer
publish retired.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Iterable

__all__ = ["EpochData", "Epoch", "EpochPin", "EpochRegistry"]

# Sentinel watermark for an epoch that has seen no events yet.
NO_TIME = -(2 ** 62)


@dataclass(frozen=True)
class EpochData:
    """The immutable payload of one epoch.

    ``dg`` is the index version readers plan/execute against; ``n_events``
    the number of events folded *or* pending in it (a group-aligned prefix
    of the global stream — the replay oracle for this epoch); ``max_time``
    the watermark: every ingested event so far has ``time <= max_time``,
    so snapshot results at ``t < max_time`` are immutable under monotone
    ingest and cacheable across epochs.
    """
    dg: Any
    n_events: int = 0
    max_time: int = NO_TIME


class Epoch:
    """One published index version plus its lifecycle bookkeeping."""

    __slots__ = ("id", "data", "refs", "reclaims", "retired")

    def __init__(self, eid: int, data: EpochData,
                 reclaims: Iterable[Callable[[], None]] = ()) -> None:
        self.id = eid
        self.data = data
        self.refs = 0
        self.reclaims: list[Callable[[], None]] = list(reclaims)
        self.retired = False

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Epoch(id={self.id}, refs={self.refs}, "
                f"retired={self.retired}, n_events={self.data.n_events})")


class EpochPin:
    """Context-manager handle on one acquired epoch (``with`` or manual
    :meth:`release`; release is idempotent)."""

    __slots__ = ("_registry", "epoch", "_released")

    def __init__(self, registry: "EpochRegistry", epoch: Epoch) -> None:
        self._registry = registry
        self.epoch = epoch
        self._released = False

    @property
    def id(self) -> int:
        return self.epoch.id

    @property
    def data(self) -> EpochData:
        return self.epoch.data

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._registry.release(self.epoch)

    def __enter__(self) -> "EpochPin":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class EpochRegistry:
    """Monotonic epoch ids, atomic publish, ordered deferred reclamation.

    Invariants (property-tested in ``tests/test_hypothesis_core.py``):

    * ids are strictly monotonic; ``acquire`` always returns the epoch
      that was current at some single instant (never a torn mix);
    * a retired epoch's reclaim callbacks run exactly once, and only
      after its refcount is zero *and* all older retired epochs have
      already been reclaimed (readers pinned further back keep every
      resource the epochs after them may share);
    * the current epoch is never reclaimed.
    """

    def __init__(self, data: EpochData) -> None:
        self._lock = threading.Lock()
        self._current = Epoch(0, data)
        self._retired: deque[Epoch] = deque()
        self._reclaimed = 0
        self._subscribers: list[Callable[[int, EpochData], None]] = []

    # ------------------------------------------------------------ reads
    @property
    def current_id(self) -> int:
        return self._current.id

    @property
    def current_data(self) -> EpochData:
        return self._current.data

    def acquire(self) -> EpochPin:
        """Pin the current epoch; the caller must release (use ``with``)."""
        with self._lock:
            ep = self._current
            ep.refs += 1
        return EpochPin(self, ep)

    def release(self, epoch: Epoch) -> None:
        with self._lock:
            epoch.refs -= 1
            ready = self._drain_locked()
        self._run(ready)

    # ------------------------------------------------------------ writes
    def publish(self, data: EpochData,
                reclaims: Iterable[Callable[[], None]] = ()) -> int:
        """Atomically make ``data`` the current epoch.

        ``reclaims`` run once every reader of the *superseded* epoch (and
        all older ones) has released its pin — this is where cap-delta
        payload deletion and pool-pin release for the replaced index
        version belong.
        """
        with self._lock:
            old = self._current
            old.retired = True
            old.reclaims.extend(reclaims)
            self._retired.append(old)
            self._current = Epoch(old.id + 1, data)
            new_id = self._current.id
            ready = self._drain_locked()
            subs = list(self._subscribers)
        self._run(ready)
        # announcements run outside the lock (a subscriber may do I/O —
        # e.g. the process transport fanning the new id out to shard
        # caches); a reader racing ahead of a slow announcement is still
        # safe because fetches carry the pinned epoch id (``min_epoch``)
        for cb in subs:
            cb(new_id, data)
        return new_id

    # ---------------------------------------------------- subscriptions
    def subscribe(self, cb: Callable[[int, EpochData], None]) -> None:
        """Register ``cb(new_epoch_id, data)`` to run after every publish
        — the cache-invalidation fan-out hook (shard-local hot caches
        subscribe via their transport).  Callbacks run outside the
        registry lock, in publish order for any single publisher."""
        with self._lock:
            self._subscribers.append(cb)

    def unsubscribe(self, cb: Callable[[int, EpochData], None]) -> None:
        with self._lock:
            try:
                self._subscribers.remove(cb)
            except ValueError:
                pass

    # ------------------------------------------------------------ drain
    def _drain_locked(self) -> list[Callable[[], None]]:
        """Pop drained retired epochs in order; return their reclaims."""
        ready: list[Callable[[], None]] = []
        while self._retired and self._retired[0].refs == 0:
            ep = self._retired.popleft()
            ready.extend(ep.reclaims)
            ep.reclaims = []
            self._reclaimed += 1
        return ready

    @staticmethod
    def _run(callbacks: list[Callable[[], None]]) -> None:
        for cb in callbacks:
            cb()

    # ------------------------------------------------------------ stats
    def stats(self) -> dict:
        with self._lock:
            return {"current_id": self._current.id,
                    "current_refs": self._current.refs,
                    "retired_pending": len(self._retired),
                    "reclaimed": self._reclaimed}
