"""Query-side option parsing (paper §3.2.1, Table 1) and TimeExpressions.

``attr_options`` strings concatenate sub-options; the default is *no*
attributes::

    "+node:all-node:salary+edge:name"

selects every node attribute except ``salary`` plus the edge attribute
``name``.  A :class:`TimeExpression` is a multinomial Boolean expression
over k time points, e.g. ``t1 ∧ ¬t2`` → components valid at t1 but not t2.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Sequence

import numpy as np

from .errors import (AttrOptionsError, TimeExpressionError,
                     UnknownAttributeError)
from .events import GraphUniverse

_OPT_RE = re.compile(r"([+-])(node|edge):([A-Za-z0-9_.]+|all)")


@dataclasses.dataclass(frozen=True)
class AttrOptions:
    """Resolved attribute column selections.  ``None`` ⇒ all columns."""

    node_cols: tuple[int, ...]
    edge_cols: tuple[int, ...]

    @property
    def wants_node(self) -> bool:
        return len(self.node_cols) > 0

    @property
    def wants_edge(self) -> bool:
        return len(self.edge_cols) > 0

    @property
    def wants_attrs(self) -> bool:
        return self.wants_node or self.wants_edge

    def node_col_array(self) -> np.ndarray:
        return np.asarray(self.node_cols, np.int16)

    def edge_col_array(self) -> np.ndarray:
        return np.asarray(self.edge_cols, np.int16)


def parse_attr_options(spec: str, universe: GraphUniverse) -> AttrOptions:
    """Parse an attr_options string against the universe's attribute tables.

    Later sub-options override earlier ones for a specific attribute, and
    specific attributes override ``all`` (Table 1).

    Errors are typed (:mod:`repro.core.errors`): syntax problems raise
    :class:`AttrOptionsError` and unknown attribute names raise
    :class:`UnknownAttributeError`, both carrying the character position —
    and both still catchable as the pre-taxonomy ``ValueError`` /
    ``KeyError``.
    """
    node_sel: dict[int, bool] = {}
    edge_sel: dict[int, bool] = {}
    node_all = False
    edge_all = False
    pos = 0
    for m in _OPT_RE.finditer(spec or ""):
        if m.start() != pos:
            raise AttrOptionsError(f"bad attr_options near {spec[pos:]!r}",
                                   position=pos)
        pos = m.end()
        sign, kind, name = m.group(1) == "+", m.group(2), m.group(3)
        table = (universe.node_attr_cols if kind == "node"
                 else universe.edge_attr_cols)
        sel = node_sel if kind == "node" else edge_sel
        if name == "all":
            if kind == "node":
                node_all = sign
            else:
                edge_all = sign
            sel.clear()  # `all` resets prior per-attribute overrides
        else:
            if name not in table:
                raise UnknownAttributeError(
                    f"unknown {kind} attribute {name!r}",
                    position=m.start(3))
            sel[table[name]] = sign
    if pos != len(spec or ""):
        raise AttrOptionsError(f"bad attr_options near {spec[pos:]!r}",
                               position=pos)

    def resolve(all_flag: bool, sel: dict[int, bool], n: int) -> tuple[int, ...]:
        cols = set(range(n)) if all_flag else set()
        for c, s in sel.items():
            (cols.add if s else cols.discard)(c)
        return tuple(sorted(cols))

    return AttrOptions(resolve(node_all, node_sel, universe.num_node_attrs),
                       resolve(edge_all, edge_sel, universe.num_edge_attrs))


NO_ATTRS = AttrOptions((), ())


# ---------------------------------------------------------------------------
# TimeExpression (paper §3.2.1): Boolean expression over k time points
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TimeExpression:
    """``times`` is the list [t_1..t_k]; ``expr`` is a nested tuple tree:
    ``("and"|"or", e1, e2)`` / ``("not", e)`` / ``("t", i)``.

    ``TimeExpression.parse("t0 & ~t1", [t0, t1])`` builds one from infix
    syntax (&, |, ~, parentheses).
    """

    times: Sequence[int]
    expr: tuple

    def to_infix(self) -> str:
        """Render back to the infix syntax :meth:`parse` accepts, with
        minimal parentheses (``~`` binds tightest, then ``&``, then ``|``;
        both binary operators are left-associative).  Round-trip law —
        pinned by the property tests in ``tests/test_query_parse.py``::

            TimeExpression.parse(tex.to_infix(), tex.times).expr == tex.expr
        """
        def go(e: tuple, prec: int) -> str:
            op = e[0]
            if op == "t":
                return f"t{e[1]}"
            if op == "not":
                return "~" + go(e[1], 3)
            sym, p = ("&", 2) if op == "and" else ("|", 1)
            s = go(e[1], p) + sym + go(e[2], p + 1)
            return f"({s})" if p < prec else s
        return go(self.expr, 0)

    def evaluate(self, masks: Sequence[np.ndarray]) -> np.ndarray:
        def ev(e) -> np.ndarray:
            op = e[0]
            if op == "t":
                return masks[e[1]]
            if op == "not":
                return ~ev(e[1])
            a, b = ev(e[1]), ev(e[2])
            return (a & b) if op == "and" else (a | b)
        return ev(self.expr)

    @staticmethod
    def parse(text: str, times: Sequence[int]) -> "TimeExpression":
        """Parse infix syntax.  Errors raise
        :class:`~repro.core.errors.TimeExpressionError` (a ``ValueError``
        subclass) carrying the character position in the de-spaced input.
        """
        src = text.replace(" ", "")
        tokens: list[str] = []
        spans: list[int] = []          # start offset of each token in src
        scan = 0
        for m in re.finditer(r"t\d+|[()&|~]", src):
            if m.start() != scan:
                raise TimeExpressionError(
                    f"bad TimeExpression {text!r}", position=scan)
            tokens.append(m.group(0))
            spans.append(m.start())
            scan = m.end()
        if scan != len(src):
            raise TimeExpressionError(f"bad TimeExpression {text!r}",
                                      position=scan)
        pos = 0

        def peek():
            return tokens[pos] if pos < len(tokens) else None

        def eat(tok=None):
            nonlocal pos
            if pos >= len(tokens):  # truncated input, e.g. "(t0"
                raise TimeExpressionError(
                    f"unexpected end of TimeExpression {text!r}"
                    + (f" (expected {tok})" if tok else ""),
                    position=len(src))
            t = tokens[pos]
            if tok and t != tok:
                raise TimeExpressionError(f"expected {tok} got {t}",
                                          position=spans[pos])
            pos += 1
            return t

        def atom():
            t = peek()
            if t == "(":
                eat("(")
                e = expr()
                eat(")")
                return e
            if t == "~":
                eat("~")
                return ("not", atom())
            if t and t.startswith("t"):
                at = spans[pos]
                eat()
                i = int(t[1:])
                if i >= len(times):
                    raise TimeExpressionError(f"time index {t} out of range",
                                              position=at)
                return ("t", i)
            raise TimeExpressionError(
                f"unexpected token {t!r}",
                position=spans[pos] if pos < len(tokens) else len(src))

        def conj():
            e = atom()
            while peek() == "&":
                eat("&")
                e = ("and", e, atom())
            return e

        def expr():
            e = conj()
            while peek() == "|":
                eat("|")
                e = ("or", e, conj())
            return e

        tree = expr()
        if pos != len(tokens):
            raise TimeExpressionError(f"trailing tokens in {text!r}",
                                      position=spans[pos])
        return TimeExpression(times, tree)
