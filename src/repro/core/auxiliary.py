"""Extensibility: auxiliary indexes over the DeltaGraph (paper §4.7).

The user supplies an :class:`AuxIndex` implementation with the paper's
three hooks:

* ``create_aux_event(event_ctx)``  — AuxiliaryEvents for a plain event,
  given the current graph + latest auxiliary snapshot;
* ``create_aux_snapshot(prev, aux_events)`` — next leaf AuxiliarySnapshot;
* ``aux_df(children)`` — differential function for auxiliary snapshots.

AuxiliarySnapshots are hashtables of string key→value pairs (paper's
structure); AuxiliaryEvents are (time, op, key, value) with op ∈
{ADD, DEL, SET}.  The HistoryManager indexes them automatically alongside
the graph: leaf aux-snapshots spaced L events apart, interior nodes via
``aux_df``, deltas stored columnar in the same KV store under
``aux.<name>`` components.  Queries subclass :class:`AuxHistQueryPoint` /
``...Interval`` / :class:`AuxHistQuery`.

Shipped example: :class:`LabelPathIndex` — the paper's subgraph-pattern
index (all label-paths of length ``plen``), with the paper's intersection
semantics ("a path is associated with an interior node iff present in all
snapshots below it"), plus :class:`DegreeHistogramIndex` used in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Sequence

import numpy as np

from ..storage import columnar as col
from ..storage.kv import KVStore
from .events import (EV_DEL_EDGE, EV_NEW_EDGE, EventList, GraphUniverse,
                     MaterializedState, apply_events, replay)

ADD, DEL, SET = 0, 1, 2

AuxSnapshot = dict[str, Any]


@dataclasses.dataclass
class AuxEvent:
    time: int
    op: int
    key: str
    value: Any = None


def apply_aux_events(snap: AuxSnapshot, evs: Sequence[AuxEvent]) -> AuxSnapshot:
    out = dict(snap)
    for e in evs:
        if e.op == DEL:
            out.pop(e.key, None)
        else:
            out[e.key] = e.value
    return out


class AuxIndex:
    """Abstract auxiliary index (paper §4.7)."""

    name: str = "aux"

    def create_aux_events(self, event_idx: int, events: EventList,
                          graph: MaterializedState,
                          universe: GraphUniverse,
                          latest: AuxSnapshot) -> list[AuxEvent]:
        raise NotImplementedError

    def create_aux_snapshot(self, prev: AuxSnapshot,
                            aux_events: Sequence[AuxEvent]) -> AuxSnapshot:
        return apply_aux_events(prev, aux_events)

    def aux_df(self, children: Sequence[AuxSnapshot]) -> AuxSnapshot:
        """Default: intersection with equal values (paper's pattern-index
        semantics: present at an interior node iff present below it)."""
        out = {}
        first = children[0]
        for k, v in first.items():
            if all(k in c and c[k] == v for c in children[1:]):
                out[k] = v
        return out


class AuxHistoryIndex:
    """Builds and queries the historical index for one AuxIndex, mirroring
    the DeltaGraph shape: leaf aux-snapshots every L events, interior
    aux-snapshots via aux_df, aux-deltas on edges.

    (For clarity this implementation keys aux payloads by the *skeleton
    node/edge ids* of an existing DeltaGraph, reusing its planner: a
    snapshot query resolves the same Dijkstra path and applies aux deltas.)
    """

    def __init__(self, aux: AuxIndex, dg, events: EventList) -> None:
        self.aux = aux
        self.dg = dg
        uni = dg.universe
        # leaf aux snapshots
        state = MaterializedState.empty(uni)
        snap: AuxSnapshot = {}
        self._leaf_snaps: list[AuxSnapshot] = [dict(snap)]
        pos = 0
        for leaf_i in range(1, len(dg.leaf_nids)):
            nxt = dg.leaf_pos[leaf_i]
            evs: list[AuxEvent] = []
            prev_snap = snap
            for i in range(pos, nxt):
                evs_i = self.aux.create_aux_events(i, events, state, uni, snap)
                evs.extend(evs_i)
                snap = apply_aux_events(snap, evs_i)  # keep `latest` fresh
                state = apply_events(state, events[i:i + 1], forward=True)
            # the paper hook: leaf snapshot from (previous snapshot, events)
            snap = self.aux.create_aux_snapshot(prev_snap, evs)
            self._leaf_snaps.append(dict(snap))
            pos = nxt
        # events kept for the residual tail within a leaf eventlist
        self._events = events

    # -- persistence ---------------------------------------------------------
    # aux leaf snapshots ride the same codec-compressed, checksummed blob
    # path as every other payload, keyed ``(0, -10, "aux.<name>")`` next to
    # the skeleton's reserved ids
    _AUX_PID = -10

    def save(self, store: KVStore | None = None) -> int:
        """Persist the leaf aux-snapshots; returns bytes written.
        Snapshot values must be JSON-representable (numpy scalars are
        coerced; tuples round-trip as lists)."""
        import json as _json

        def _coerce(o):
            if isinstance(o, np.integer):
                return int(o)
            if isinstance(o, np.floating):
                return float(o)
            raise TypeError(f"aux snapshot value {o!r} is not "
                            f"JSON-representable")

        store = store if store is not None else self.dg.store
        payload = _json.dumps({"name": self.aux.name,
                               "leaf_snaps": self._leaf_snaps},
                              default=_coerce).encode()
        blob = col.pack_arrays(
            {"json": np.frombuffer(payload, np.uint8)})
        store.put((0, self._AUX_PID, f"aux.{self.aux.name}"), blob)
        return len(blob)

    @classmethod
    def load_snaps(cls, store: KVStore, name: str) -> list[AuxSnapshot]:
        """Decode a persisted aux index's leaf snapshots (standalone — the
        residual-tail replay still needs the event trace)."""
        import json as _json

        blob = store.get((0, cls._AUX_PID, f"aux.{name}"))
        arrays = col.unpack_arrays(blob)
        payload = _json.loads(bytes(arrays["json"]).decode())
        return payload["leaf_snaps"]

    # -- queries -------------------------------------------------------------
    def snapshot_at(self, t: int) -> AuxSnapshot:
        li = self.dg._leaf_for_time(t)
        li = min(li, len(self._leaf_snaps) - 1)
        snap = dict(self._leaf_snaps[li])
        uni = self.dg.universe
        pos = self.dg.leaf_pos[li]
        # leaf state is defined by event *position* (exact under timestamps
        # straddling a leaf boundary), not by boundary time
        state = apply_events(MaterializedState.empty(uni),
                             self._events[:pos], forward=True)
        ev = self._events
        while pos < len(ev) and ev.time[pos] <= t:
            evs = self.aux.create_aux_events(pos, ev, state, uni, snap)
            snap = apply_aux_events(snap, evs)
            state = apply_events(state, ev[pos:pos + 1], forward=True)
            pos += 1
        return snap

    def query_point(self, t: int, key: str) -> Any:
        return self.snapshot_at(t).get(key)

    def query_whole_history(self, key: str) -> bool:
        """Paper's root semantics under intersection aux_df: key present
        throughout history iff present at every leaf."""
        return all(key in s for s in self._leaf_snaps)

    def query_interval(self, ts: int, te: int, key: str) -> bool:
        return any(key in self.snapshot_at(t) for t in (ts, te))


# ---------------------------------------------------------------------------
# shipped aux indexes
# ---------------------------------------------------------------------------

class LabelPathIndex(AuxIndex):
    """Paper §4.7's subgraph-pattern index: key = label path of length
    ``plen`` (node labels joined by '|'), value = count of matching paths.

    ``labels`` maps node slot → label string.  ``create_aux_events`` finds
    the paths affected by an edge addition/deletion in the *current* graph
    context (exactly the paper's CreateAuxEvent contract).
    """

    def __init__(self, labels: Sequence[str], plen: int = 3) -> None:
        self.name = f"labelpath{plen}"
        self.labels = list(labels)
        self.plen = plen

    def _paths_through(self, graph: MaterializedState, uni: GraphUniverse,
                       u: int, v: int) -> list[tuple[int, ...]]:
        """All node paths of length plen that use edge (u, v), in the graph
        *with* the edge present."""
        from ..graph.csr import build_csr
        csr = build_csr(uni.edge_src, uni.edge_dst, uni.num_nodes,
                        graph.edge_mask, uni.edge_directed)
        plen = self.plen
        out: set[tuple[int, ...]] = set()

        def forward(path: tuple[int, ...]):
            if len(path) == plen:
                out.add(path)
                return
            for w in csr.neighbors(path[-1]):
                if w not in path:
                    forward(path + (int(w),))

        def backward(path: tuple[int, ...], want: int):
            if len(path) == want:
                forward(path)
                return
            for w in csr.neighbors(path[0]):
                if w not in path:
                    backward((int(w),) + path, want)

        # paths with (a, b) as a consecutive pair, any prefix length
        def around(a: int, b: int):
            if b not in csr.neighbors(a):
                return  # directed edge not traversable this way
            for pre_len in range(plen - 1):
                backward((a, b), pre_len + 2)

        around(u, v)
        around(v, u)
        return list(out)

    def create_aux_events(self, i, events, graph, uni, latest):
        et = int(events.etype[i])
        if et not in (EV_NEW_EDGE, EV_DEL_EDGE):
            return []
        slot = int(events.slot[i])
        u, v = int(uni.edge_src[slot]), int(uni.edge_dst[slot])
        t = int(events.time[i])
        # evaluate in the graph *with* the edge present (for deletions the
        # removed paths are exactly those through the still-present edge)
        g = graph.copy()
        g.edge_mask[slot] = True
        paths = self._paths_through(g, uni, u, v)
        evs = []
        sign = 1 if et == EV_NEW_EDGE else -1
        counts: dict[str, int] = {}
        for p in paths:
            key = "|".join(self.labels[n] for n in p)
            counts[key] = counts.get(key, 0) + sign
        for key, dc in counts.items():
            cur = latest.get(key, 0)
            new = cur + dc
            evs.append(AuxEvent(t, SET if new > 0 else DEL, key,
                                new if new > 0 else None))
            latest = apply_aux_events(latest, [evs[-1]])
        return evs


class DegreeHistogramIndex(AuxIndex):
    """Tiny aux index used in tests: key = f"deg{d}" → number of nodes with
    degree d (undirected count)."""

    name = "deghist"

    def create_aux_events(self, i, events, graph, uni, latest):
        et = int(events.etype[i])
        if et not in (EV_NEW_EDGE, EV_DEL_EDGE):
            return []
        slot = int(events.slot[i])
        u, v = int(uni.edge_src[slot]), int(uni.edge_dst[slot])
        t = int(events.time[i])
        deg = np.zeros(uni.num_nodes, np.int64)
        eidx = np.nonzero(graph.edge_mask)[0]
        np.add.at(deg, uni.edge_src[eidx], 1)
        np.add.at(deg, uni.edge_dst[eidx], 1)
        sign = 1 if et == EV_NEW_EDGE else -1
        evs: list[AuxEvent] = []
        snap = dict(latest)
        for n in (u, v):
            d0 = int(deg[n])
            d1 = d0 + sign
            for d, dc in ((d0, -1), (d1, +1)):
                if d == 0:
                    continue  # degree-0 nodes are not histogrammed
                key = f"deg{d}"
                cur = snap.get(key, 0) + dc
                ev = AuxEvent(t, SET if cur > 0 else DEL, key,
                              cur if cur > 0 else None)
                snap = apply_aux_events(snap, [ev])
                evs.append(ev)
        return evs
