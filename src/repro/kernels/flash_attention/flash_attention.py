"""Pallas TPU flash attention (FlashAttention-2-style online softmax).

Grid ``(B*H, num_q_blocks, num_kv_blocks)`` with the kv dimension innermost
and sequential; running max / denominator / accumulator live in VMEM
scratch, so KV streams HBM→VMEM block by block and the score matrix never
materializes.  Q/KV block sizes are multiples of the 128-lane MXU tiling.

Supports causal masking, sliding windows (Gemma-style local layers), and a
``q_offset`` for decode (Sq « Sk against a long KV cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int | None,
            q_offset: int, kv_len: int, bq: int, bk: int, nk: int):
    kv_i = pl.program_id(2)
    q_i = pl.program_id(1)

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, :].astype(jnp.float32)          # [bq, d]
    k = k_ref[0, :, :].astype(jnp.float32)          # [bk, d]
    v = v_ref[0, :, :].astype(jnp.float32)          # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = q_i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kpos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < kv_len                             # drop padded keys
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                              # [bq]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(kv_i == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, :] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "scale", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: int | None = None,
                           q_offset: int = 0, scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """q: [B, H, Sq, D]; k, v: [B, H, Sk, D] (GQA pre-broadcast by ops.py)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    Dv = v.shape[-1]  # may differ from D (MLA)
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Sk, 8))
    Sqp = -(-Sq // bq) * bq
    Skp = -(-Sk // bk) * bk
    if Sqp != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    if Skp != Sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    nq, nk = Sqp // bq, Skp // bk
    qf = q.reshape(B * H, Sqp, D)
    kf = k.reshape(B * H, Skp, D)
    vf = v.reshape(B * H, Skp, Dv)

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, q_offset=q_offset, kv_len=Sk,
                             bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kern,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, Dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sqp, Dv)[:, :, :Sq, :]
