"""Attention front-end used by every LM architecture.

Three implementations behind one signature:

* ``impl='xla'``     — *chunked* online-softmax in pure JAX with a
  FlashAttention-2-style ``custom_vjp``: forward saves only
  ``(q, k, v, out, rowmax, denom)`` and the backward recomputes each KV
  chunk's scores — no per-chunk residual stacking, so peak memory is
  O(chunk) not O(sequence).  Lowers cleanly for the multi-pod dry-run and
  its FLOPs are visible to ``cost_analysis`` (the roofline path).
* ``impl='pallas'``  — the TPU kernel (``flash_attention.py``); validated
  in interpret mode on CPU, compiled on real TPUs.
* ``impl='ref'``     — full-score oracle (tests only).

GQA is handled natively (head grouping) in xla/ref; the Pallas path
broadcasts KV heads (documented trade: on-chip dedup would index instead).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from .. import policy
from .flash_attention import flash_attention_pallas
from .ref import attention_ref

NEG = -1e30


def _mask(qpos, kpos, window, causal: bool, kv_len: int):
    m = kpos[None, :] < kv_len
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    m &= (qpos[:, None] - kpos[None, :]) < window
    return m


def _chunks(x, bk):
    B, H, Sk, D = x.shape
    nk = Sk // bk
    return x.reshape(B, H, nk, bk, D).transpose(2, 0, 1, 3, 4)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash(qf, k, v, qpos_g, window, scale, bk, causal, kv_len):
    out, _, _ = _flash_fwd_impl(qf, k, v, qpos_g, window, scale, bk, causal,
                                kv_len)
    return out


def _flash_fwd_impl(qf, k, v, qpos, window, scale, bk, causal, kv_len):
    # qf is 5D [B, Hkv, G, Sq, D]: the GQA group dim is kept SEPARATE from
    # the sequence dim — merging them would prevent GSPMD from sharding the
    # sequence (sharding is only representable on the outer factor of a
    # merged dimension), replicating every attention intermediate.
    B, Hkv, G, Sq, D = qf.shape
    Dv = v.shape[-1]
    nk = k.shape[2] // bk
    kc, vc = _chunks(k, bk), _chunks(v, bk)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, j = xs
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kb,
                       preferred_element_type=jnp.float32) * scale
        kpos = j * bk + jnp.arange(bk)
        msk = _mask(qpos, kpos, window, causal, kv_len)
        s = jnp.where(msk[None, None, None], s, NEG)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(msk[None, None, None], p, 0.0)
        l2 = l * alpha + p.sum(-1)
        acc2 = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(v.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l2, acc2), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nk)))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(qf.dtype)
    return out, m, l


def _flash_fwd(qf, k, v, qpos, window, scale, bk, causal, kv_len):
    out, m, l = _flash_fwd_impl(qf, k, v, qpos, window, scale, bk, causal,
                                kv_len)
    return out, (qf, k, v, qpos, window, out, m, l)


def _flash_bwd(scale, bk, causal, kv_len, res, dout):
    qf, k, v, qpos, window, out, m, l = res
    B, Hkv, G, Sq, D = qf.shape
    nk = k.shape[2] // bk
    kc, vc = _chunks(k, bk), _chunks(v, bk)
    dof = dout.astype(jnp.float32)
    delta = (dof * out.astype(jnp.float32)).sum(-1)       # [B,Hkv,G,Sq]
    linv = 1.0 / jnp.maximum(l, 1e-30)

    def chunk(dq, xs):
        kb, vb, j = xs
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kb,
                       preferred_element_type=jnp.float32) * scale
        kpos = j * bk + jnp.arange(bk)
        msk = _mask(qpos, kpos, window, causal, kv_len)
        p = jnp.exp(s - m[..., None]) * linv[..., None]
        p = jnp.where(msk[None, None, None], p, 0.0)      # [B,Hkv,G,Sq,bk]
        dv_b = jnp.einsum("bhgqk,bhgqd->bhkd", p, dof,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", dof, vb.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bhgqk,bhkd->bhgqd", ds, kb.astype(jnp.float32),
                             preferred_element_type=jnp.float32)
        dk_b = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qf.astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        return dq, (dk_b.astype(k.dtype), dv_b.astype(v.dtype))

    dq0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(chunk, dq0, (kc, vc, jnp.arange(nk)))
    dk = dk_c.transpose(1, 2, 0, 3, 4).reshape(k.shape)
    dv = dv_c.transpose(1, 2, 0, 3, 4).reshape(v.shape)
    return (dq.astype(qf.dtype), dk, dv,
            np.zeros(qpos.shape, jax.dtypes.float0),
            np.zeros(jnp.shape(window), jax.dtypes.float0))


_flash.defvjp(_flash_fwd, _flash_bwd)


def _chunked_gqa_attention(q, k, v, *, causal, window, q_offset, scale,
                           block_k: int = 512):
    """Online-softmax over KV chunks with flash custom-vjp.
    q: [B,Hq,Sq,D]; k/v: [B,Hkv,Sk,(D|Dv)]."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    scale = float(scale if scale is not None else D ** -0.5)
    bk = min(block_k, Sk)
    nk = -(-Sk // bk)
    Skp = nk * bk
    if Skp != Sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    qf = q.reshape(B, Hkv, G, Sq, D)
    qpos = (jnp.arange(Sq) + q_offset).astype(jnp.int32)
    win = jnp.asarray(window if window is not None else (1 << 30), jnp.int32)
    out = _flash(qf, k, v, qpos, win, scale, bk, causal, Sk)
    return out.reshape(B, Hq, Sq, Dv)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: int | None = None,
              q_offset: int = 0, scale: float | None = None,
              impl: str | None = None, block_k: int = 512,
              interpret: bool | None = None) -> jnp.ndarray:
    if impl != "ref":   # 'ref' is a test-only oracle, never policy-selected
        impl, interpret = policy.resolve(impl, interpret)
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, scale=scale)
    if impl == "xla":
        return _chunked_gqa_attention(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset, scale=scale,
                                      block_k=block_k)
    if impl == "pallas":
        Hq, Hkv = q.shape[1], k.shape[1]
        if Hq != Hkv:
            rep = Hq // Hkv
            k = jnp.repeat(k, rep, axis=1)
            v = jnp.repeat(v, rep, axis=1)
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset, scale=scale,
                                      interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}")
