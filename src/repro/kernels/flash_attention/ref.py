"""Pure-jnp oracle for (causal / sliding-window / GQA) attention.

Materializes the full score matrix — only usable at test shapes; the
production XLA path is the *chunked* online-softmax in ``ops.py`` and the
TPU path is the Pallas kernel.
"""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int | None = None,
                  q_offset: int = 0, scale: float | None = None) -> jnp.ndarray:
    """q: [B, Hq, Sq, D]; k, v: [B, Hkv, Sk, D]; Hq % Hkv == 0.

    ``q_offset``: absolute position of q[0] (decode: Sq=1, q_offset=cache
    length).  ``window``: keys with q_pos - k_pos >= window are masked
    (sliding-window attention).
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Sq, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(B, Hq, Sq, v.shape[-1]).astype(q.dtype)
