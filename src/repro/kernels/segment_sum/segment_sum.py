"""Pallas TPU kernel: bucketed segment-sum (GNN message aggregation).

TPUs have no efficient scatter; the MXU does 128×128 matmuls.  The
adaptation (taxonomy §B.11 "one-hot matmul"): host-side, edges sorted by
destination are bucketed so that each grid step owns one *node block* of
``block_n`` consecutive destinations together with its (padded) edge
block; in-kernel the scatter becomes ``onehot(local_dst)ᵀ @ data`` — one
dense matmul per tile, no data-dependent control flow.

Bucketing is a one-off host preprocessing of the (static) graph structure;
messages then flow through with zero scatter at train-step time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _kernel(data_ref, lid_ref, out_ref, *, block_n: int):
    data = data_ref[0, :, :]                     # [me, D]
    lid = lid_ref[0, :]                          # [me] local dst in [0, bn)
    me = data.shape[0]
    onehot = (lid[:, None] == jax.lax.broadcasted_iota(jnp.int32, (me, block_n), 1))
    onehot = onehot.astype(data.dtype)
    out_ref[0, :, :] = jax.lax.dot_general(
        onehot, data, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def segment_sum_bucketed(data: jnp.ndarray, local_ids: jnp.ndarray, *,
                         block_n: int, interpret: bool = True) -> jnp.ndarray:
    """data: [NB, ME, D] padded per-bucket edge features; local_ids:
    [NB, ME] destination offsets within the bucket (−1 = padding, routed to
    a dead row).  Returns [NB, block_n, D] per-bucket sums."""
    NB, ME, D = data.shape
    lid = jnp.where(local_ids >= 0, local_ids, block_n)  # pad → off-block
    out = pl.pallas_call(
        functools.partial(_kernel, block_n=block_n),
        grid=(NB,),
        in_specs=[
            pl.BlockSpec((1, ME, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, ME), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n, D), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((NB, block_n, D), data.dtype),
        interpret=interpret,
    )(data, lid)
    return out
