"""Public segment-sum wrapper + host-side edge bucketing."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from .. import policy
from .ref import segment_sum_ref
from .segment_sum import segment_sum_bucketed


def bucket_edges(seg_ids: np.ndarray, num_segments: int, block_n: int
                 ) -> tuple[np.ndarray, np.ndarray, int]:
    """Host preprocessing: sort edges by segment, bucket into node blocks of
    ``block_n`` destinations, pad each bucket's edge list to the max.

    Returns (order, local_ids, max_edges): gather ``data[order]`` then
    reshape to [NB, ME, D]; ``local_ids`` is [NB, ME] with -1 padding.
    """
    seg_ids = np.asarray(seg_ids)
    order = np.argsort(seg_ids, kind="stable")
    sorted_ids = seg_ids[order]
    NB = -(-num_segments // block_n)
    bucket_of = sorted_ids // block_n
    counts = np.bincount(bucket_of, minlength=NB)
    ME = max(int(counts.max(initial=0)), 1)
    out_order = np.zeros((NB, ME), np.int64)
    local = np.full((NB, ME), -1, np.int32)
    starts = np.zeros(NB + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    for b in range(NB):
        c = counts[b]
        sl = slice(starts[b], starts[b] + c)
        out_order[b, :c] = order[sl]
        local[b, :c] = sorted_ids[sl] - b * block_n
    return out_order, local, ME


def segment_sum(data: jnp.ndarray, seg_ids, num_segments: int, *,
                impl: str | None = None, block_n: int = 128,
                buckets: tuple | None = None,
                interpret: bool | None = None) -> jnp.ndarray:
    """Segment sum with selectable implementation.

    impl='xla'    → jax.ops.segment_sum (scatter; lowering/roofline path)
    impl='pallas' → bucketed one-hot-matmul kernel; ``buckets`` may carry
                    precomputed ``bucket_edges`` output (static graphs).
    impl=None     → resolved by :mod:`repro.kernels.policy` (REPRO_KERNEL
                    env, else backend detection).
    """
    impl, interpret = policy.resolve(impl, interpret)
    if impl == "xla":
        return segment_sum_ref(data, jnp.asarray(seg_ids), num_segments)
    if impl == "pallas":
        if buckets is None:
            buckets = bucket_edges(np.asarray(seg_ids), num_segments, block_n)
        out_order, local, ME = buckets
        NB = local.shape[0]
        gathered = data[out_order.reshape(-1)].reshape(NB, ME, data.shape[-1])
        out = segment_sum_bucketed(gathered, jnp.asarray(local),
                                   block_n=block_n, interpret=interpret)
        return out.reshape(NB * block_n, data.shape[-1])[:num_segments]
    raise ValueError(f"unknown impl {impl!r}")
