from .ops import bucket_edges, segment_sum  # noqa: F401
