"""Oracle: plain jax.ops.segment_sum (the scatter path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(data: jnp.ndarray, seg_ids: jnp.ndarray,
                    num_segments: int) -> jnp.ndarray:
    return jax.ops.segment_sum(data, seg_ids, num_segments=num_segments)
