"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel directory has: the pallas_call + BlockSpec implementation,
``ops.py`` (jit'd wrapper with impl switch), ``ref.py`` (pure-jnp oracle).
``impl``/``interpret`` default to the process-wide policy in
:mod:`repro.kernels.policy` (``REPRO_KERNEL=pallas|xla`` env override,
else Pallas compiled on TPU and XLA elsewhere; interpret mode auto-selects
off-TPU so the CPU test container exercises kernel bodies unchanged).
"""
from . import policy  # noqa: F401
from .delta_apply import (FusedOut, delta_apply_chain,  # noqa: F401
                          delta_apply_chain_batched, delta_apply_chain_prefix,
                          delta_apply_chain_prefix_batched, delta_apply_fused,
                          delta_apply_fused_batched)
from .flash_attention import attention  # noqa: F401
from .segment_sum import bucket_edges, segment_sum  # noqa: F401
