"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel directory has: the pallas_call + BlockSpec implementation,
``ops.py`` (jit'd wrapper with impl switch), ``ref.py`` (pure-jnp oracle).
On this CPU container kernels run with ``interpret=True``; ``impl='xla'``
variants are what the dry-run lowers (keeps FLOPs visible to
cost_analysis for the roofline).
"""
from .delta_apply import (delta_apply_chain, delta_apply_chain_batched,  # noqa: F401
                          delta_apply_chain_prefix,
                          delta_apply_chain_prefix_batched)
from .flash_attention import attention  # noqa: F401
from .segment_sum import bucket_edges, segment_sum  # noqa: F401
