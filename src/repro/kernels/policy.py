"""Kernel implementation policy: impl / interpret resolved once, from env.

Every kernel family (``delta_apply``, ``segment_sum``, ``flash_attention``)
exposes ``impl=`` (``"pallas"`` | ``"xla"``) and, for the Pallas path,
``interpret=``.  Before this module existed the entry points hardcoded
``interpret=True`` (the CPU-container test default) and every caller
threaded ``impl`` flags by hand — a production TPU deployment had to touch
each call site.  The policy is now resolved in one place:

* ``REPRO_KERNEL=pallas|xla`` pins the implementation for every kernel
  entry point that is not explicitly overridden at the call site;
* unset, the default is ``pallas`` on TPU backends and ``xla`` elsewhere
  (CPU has no Mosaic compiler — the XLA path *is* the fast path there);
* ``interpret`` (Pallas only) resolves to ``False`` exactly on TPU; any
  other backend runs the kernel through the Pallas interpreter, which is
  correct but slow — tests use it for parity, production never should.

``REPRO_KERNEL_INTERPRET=0|1`` force-overrides interpret resolution (used
by the parity suite to exercise both paths on one host).
"""
from __future__ import annotations

import os

VALID_IMPLS = ("pallas", "xla")


def backend() -> str:
    """The active JAX backend platform name (``cpu``/``tpu``/``gpu``)."""
    import jax
    return jax.default_backend()


def default_impl() -> str:
    """Policy default when neither the call site nor the env pins one."""
    env = os.environ.get("REPRO_KERNEL", "").strip().lower()
    if env:
        if env not in VALID_IMPLS:
            raise ValueError(
                f"REPRO_KERNEL={env!r} invalid; choose from {VALID_IMPLS}")
        return env
    return "pallas" if backend() == "tpu" else "xla"


def default_interpret() -> bool:
    """Pallas interpret-mode default: real Mosaic compile only on TPU."""
    env = os.environ.get("REPRO_KERNEL_INTERPRET", "").strip()
    if env:
        return env not in ("0", "false", "False")
    return backend() != "tpu"


def resolve(impl: str | None = None, interpret: bool | None = None
            ) -> tuple[str, bool]:
    """Resolve ``(impl, interpret)``: explicit call-site values win, then
    ``REPRO_KERNEL`` / ``REPRO_KERNEL_INTERPRET``, then backend detection."""
    if impl is None:
        impl = default_impl()
    elif impl not in VALID_IMPLS:
        raise ValueError(f"unknown impl {impl!r}; choose from {VALID_IMPLS}")
    if interpret is None:
        interpret = default_interpret()
    return impl, bool(interpret)
