from .ops import (delta_apply_chain, delta_apply_chain_batched,  # noqa: F401
                  delta_apply_chain_prefix, delta_apply_chain_prefix_batched,
                  delta_apply_chain_ref)
