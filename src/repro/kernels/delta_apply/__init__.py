from .ops import (FusedOut, delta_apply_chain,  # noqa: F401
                  delta_apply_chain_batched, delta_apply_chain_prefix,
                  delta_apply_chain_prefix_batched, delta_apply_chain_ref,
                  delta_apply_fused, delta_apply_fused_batched)
from .ref import delta_apply_fused_ref  # noqa: F401
