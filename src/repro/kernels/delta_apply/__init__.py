from .ops import delta_apply_chain, delta_apply_chain_ref  # noqa: F401
