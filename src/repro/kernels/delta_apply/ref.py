"""Pure-jnp oracle for the fused K-delta bitmap application.

Semantics (DeltaGraph path application, §4.3): starting from the packed
base membership bitmap, apply K (add, del) bitmap pairs in order::

    m_0 = base
    m_i = (m_{i-1} & ~del_i) | add_i
    out = m_K

All arrays are packed ``uint32`` words; ``adds``/``dels`` are stacked
``[K, W]``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def delta_apply_chain_ref(base: jnp.ndarray, adds: jnp.ndarray,
                          dels: jnp.ndarray) -> jnp.ndarray:
    def step(m, ad):
        a, d = ad
        return (m & ~d) | a, None

    out, _ = jax.lax.scan(step, base, (adds, dels))
    return out


def delta_apply_fused_ref(base: jnp.ndarray, adds: jnp.ndarray,
                          dels: jnp.ndarray,
                          weights: jnp.ndarray | None = None, *,
                          block_w: int = 1024, emit_live: bool = True):
    """Oracle for the fused chain + analytics kernel.

    Inputs match :func:`delta_apply_chain_ref` plus optional per-slot
    ``weights [W*32] f32``; ``W`` must be a multiple of ``block_w``
    (the ops wrapper pads once for every impl).  Returns

    * ``mask  [W] u32``    — the landed chain state,
    * ``pop   [G] i32``    — per-block popcount partials,
    * ``accw  [W] f32``    — per-word weighted partials (bits of word w
      dotted with its 32 weights; plain per-word popcount when no
      weights) — per-word grouping fixes the float reduction order, so
      the Pallas kernel reproduces these bit-for-bit,
    * ``live  [W*32] f32`` — unpacked membership (``None`` unless
      ``emit_live``), the segment_sum degree-reduction feed.
    """
    m = delta_apply_chain_ref(base, adds, dels)
    W = m.shape[0]
    assert W % block_w == 0, "ops wrapper pads W to the block size"
    G = W // block_w
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (W, 32), 1)
    bits = ((m[:, None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)
    pop = (jax.lax.population_count(m).astype(jnp.int32)
           .reshape(G, block_w).sum(axis=1))
    if weights is not None:
        accw = (bits * weights.reshape(W, 32)).sum(axis=1)
    else:
        accw = bits.sum(axis=1)
    live = bits.reshape(-1) if emit_live else None
    return m, pop, accw, live


def delta_apply_chain_prefix_ref(base: jnp.ndarray, adds: jnp.ndarray,
                                 dels: jnp.ndarray) -> jnp.ndarray:
    """Emit every intermediate state of the chain: ``out[i] = m_{i+1}``
    (shape ``[K, W]``).  Used by the multi-interval temporal path, where
    each prefix *is* a query result (one bitmap per interval timepoint) —
    unlike the final-state chain there is no redundant HBM traffic to
    fuse away, every word is an output."""
    def step(m, ad):
        a, d = ad
        m2 = (m & ~d) | a
        return m2, m2

    _, ys = jax.lax.scan(step, base, (adds, dels))
    return ys
