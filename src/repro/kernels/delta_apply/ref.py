"""Pure-jnp oracle for the fused K-delta bitmap application.

Semantics (DeltaGraph path application, §4.3): starting from the packed
base membership bitmap, apply K (add, del) bitmap pairs in order::

    m_0 = base
    m_i = (m_{i-1} & ~del_i) | add_i
    out = m_K

All arrays are packed ``uint32`` words; ``adds``/``dels`` are stacked
``[K, W]``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def delta_apply_chain_ref(base: jnp.ndarray, adds: jnp.ndarray,
                          dels: jnp.ndarray) -> jnp.ndarray:
    def step(m, ad):
        a, d = ad
        return (m & ~d) | a, None

    out, _ = jax.lax.scan(step, base, (adds, dels))
    return out


def delta_apply_chain_prefix_ref(base: jnp.ndarray, adds: jnp.ndarray,
                                 dels: jnp.ndarray) -> jnp.ndarray:
    """Emit every intermediate state of the chain: ``out[i] = m_{i+1}``
    (shape ``[K, W]``).  Used by the multi-interval temporal path, where
    each prefix *is* a query result (one bitmap per interval timepoint) —
    unlike the final-state chain there is no redundant HBM traffic to
    fuse away, every word is an output."""
    def step(m, ad):
        a, d = ad
        m2 = (m & ~d) | a
        return m2, m2

    _, ys = jax.lax.scan(step, base, (adds, dels))
    return ys
