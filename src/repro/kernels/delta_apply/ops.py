"""Public jit'd wrapper for fused delta-chain application.

On CPU containers the Pallas TPU kernel runs in ``interpret=True`` mode
(used by tests); production TPU deployments pass ``interpret=False``.
``impl='xla'`` selects the pure-jnp scan (used under `jit` in the
snapshot-retrieval engine, and as the oracle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .delta_apply import delta_apply_chain_pallas
from .ref import delta_apply_chain_ref


def delta_apply_chain(base: jnp.ndarray, adds: jnp.ndarray, dels: jnp.ndarray,
                      *, impl: str = "xla", block_w: int = 1024,
                      interpret: bool = True) -> jnp.ndarray:
    if impl == "xla":
        return delta_apply_chain_ref(base, adds, dels)
    if impl == "pallas":
        return delta_apply_chain_pallas(base, adds, dels, block_w=block_w,
                                        interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}")


def delta_apply_chain_batched(bases: jnp.ndarray, adds: jnp.ndarray,
                              dels: jnp.ndarray, *, impl: str = "xla",
                              block_w: int = 1024,
                              interpret: bool = True) -> jnp.ndarray:
    """Vmapped multi-snapshot apply: ``B`` sibling chains in one call.

    ``bases [B, W]``, ``adds/dels [B, K, W]`` (chains zero-padded to a
    common ``K``; an all-zero ``(adds, dels)`` row is the identity step).
    Sibling branches after a plan Fork execute as one batched pass — one
    kernel launch and one sweep over the stacked bit-planes instead of
    ``B`` sequential chain calls.
    """
    if impl == "xla":
        return jax.vmap(delta_apply_chain_ref)(bases, adds, dels)
    if impl == "pallas":
        return jax.vmap(lambda b, a, d: delta_apply_chain_pallas(
            b, a, d, block_w=block_w, interpret=interpret))(bases, adds, dels)
    raise ValueError(f"unknown impl {impl!r}")
