"""Public jit'd wrappers for fused delta-chain application + analytics.

``impl``/``interpret`` default to the process-wide policy
(:mod:`repro.kernels.policy`): ``REPRO_KERNEL=pallas|xla`` or backend
detection (Pallas compiled on TPU, XLA elsewhere; interpret mode only
ever auto-selected off-TPU).  Callers no longer thread kernel flags —
explicit arguments remain as overrides for tests and benchmarks.
"""
from __future__ import annotations

import functools
from collections import Counter
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from .. import policy
from .delta_apply import delta_apply_chain_pallas, delta_apply_fused_pallas
from .ref import (delta_apply_chain_prefix_ref, delta_apply_chain_ref,
                  delta_apply_fused_ref)

# Shape bucketing for the jit'd XLA paths: chain calls arrive with
# arbitrary (B, K, W) — every distinct shape would otherwise compile its
# own executable, and a retrieval service sees a new shape per plan.
# Padding B and K up to powers of two (all-zero (add, del) rows are
# identity steps; extra batch rows are dropped) and W up to a 128-word
# lane multiple collapses the shape space to a handful of buckets that
# stay hot in the compile cache.
_W_ALIGN = 128

# Retraces per entry point (a trace == a compile for these jits): the
# bucketing above bounds it to O(log) distinct shapes per entry — pinned
# by tests/test_kernels.py::test_recompile_counts_bounded.
trace_counts: Counter = Counter()


def reset_trace_counts() -> None:
    trace_counts.clear()


def _bucket(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _pad_axis(a: jnp.ndarray, axis: int, target: int) -> jnp.ndarray:
    pad = target - a.shape[axis]
    if pad <= 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _counted(name: str, fn):
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        trace_counts[name] += 1          # runs at trace time only
        return fn(*args, **kwargs)
    return wrapper


_chain_jit = jax.jit(_counted("chain", delta_apply_chain_ref))
_chain_batched_jit = jax.jit(
    _counted("chain_batched", jax.vmap(delta_apply_chain_ref)))
_chain_prefix_batched_jit = jax.jit(
    _counted("chain_prefix_batched", jax.vmap(delta_apply_chain_prefix_ref)))


def delta_apply_chain(base: jnp.ndarray, adds: jnp.ndarray, dels: jnp.ndarray,
                      *, impl: str | None = None, block_w: int = 1024,
                      interpret: bool | None = None) -> jnp.ndarray:
    impl, interpret = policy.resolve(impl, interpret)
    if impl == "xla":
        W = base.shape[0]
        Wp = -(-W // _W_ALIGN) * _W_ALIGN
        Kp = _bucket(adds.shape[0])
        out = _chain_jit(_pad_axis(base, 0, Wp),
                         _pad_axis(_pad_axis(adds, 1, Wp), 0, Kp),
                         _pad_axis(_pad_axis(dels, 1, Wp), 0, Kp))
        return out[:W]
    return delta_apply_chain_pallas(base, adds, dels, block_w=block_w,
                                    interpret=interpret)


def delta_apply_chain_batched(bases: jnp.ndarray, adds: jnp.ndarray,
                              dels: jnp.ndarray, *, impl: str | None = None,
                              block_w: int = 1024,
                              interpret: bool | None = None) -> jnp.ndarray:
    """Vmapped multi-snapshot apply: ``B`` sibling chains in one call.

    ``bases [B, W]``, ``adds/dels [B, K, W]`` (chains zero-padded to a
    common ``K``; an all-zero ``(adds, dels)`` row is the identity step).
    Sibling branches after a plan Fork execute as one batched pass — one
    kernel launch and one sweep over the stacked bit-planes instead of
    ``B`` sequential chain calls.
    """
    impl, interpret = policy.resolve(impl, interpret)
    if impl == "xla":
        B, K, W = adds.shape
        Wp = -(-W // _W_ALIGN) * _W_ALIGN
        Bp, Kp = _bucket(B), _bucket(K)
        out = _chain_batched_jit(
            _pad_axis(_pad_axis(bases, 1, Wp), 0, Bp),
            _pad_axis(_pad_axis(_pad_axis(adds, 2, Wp), 1, Kp), 0, Bp),
            _pad_axis(_pad_axis(_pad_axis(dels, 2, Wp), 1, Kp), 0, Bp))
        return out[:B, :W]
    return jax.vmap(lambda b, a, d: delta_apply_chain_pallas(
        b, a, d, block_w=block_w, interpret=interpret))(bases, adds, dels)


def delta_apply_chain_prefix(base: jnp.ndarray, adds: jnp.ndarray,
                             dels: jnp.ndarray) -> jnp.ndarray:
    """All K intermediate chain states ``[K, W]`` (``out[i]`` = state after
    delta ``i``).  The temporal engine's multi-interval path vmaps this
    over stacked intervals — every prefix is a returned snapshot bitmap,
    so (unlike :func:`delta_apply_chain`) there is no fused-kernel variant:
    each word is genuinely written once per step either way."""
    return delta_apply_chain_prefix_ref(base, adds, dels)


def delta_apply_chain_prefix_batched(bases: jnp.ndarray, adds: jnp.ndarray,
                                     dels: jnp.ndarray) -> jnp.ndarray:
    """Vmapped prefix chains: ``bases [B, W]``, ``adds/dels [B, K, W]`` →
    ``[B, K, W]`` per-timepoint bitmaps for B intervals in one pass.
    Shape-bucketed and jit'd like :func:`delta_apply_chain_batched`; the
    padded identity rows repeat the final state and are sliced away."""
    B, K, W = adds.shape
    Wp = -(-W // _W_ALIGN) * _W_ALIGN
    Bp, Kp = _bucket(B), _bucket(K)
    out = _chain_prefix_batched_jit(
        _pad_axis(_pad_axis(bases, 1, Wp), 0, Bp),
        _pad_axis(_pad_axis(_pad_axis(adds, 2, Wp), 1, Kp), 0, Bp),
        _pad_axis(_pad_axis(_pad_axis(dels, 2, Wp), 1, Kp), 0, Bp))
    return out[:B, :K, :W]


# ---------------------------------------------------------------------------
# fused chain + analytics
# ---------------------------------------------------------------------------


class FusedOut(NamedTuple):
    """Result of one fused delta-apply + analytics pass.

    ``mask [.., W] u32`` is the landed chain state; ``pop [.., G] i32``
    per-block popcount partials; ``accw [.., W] f32`` per-word weighted
    partials; ``live [.., W*32] f32`` the unpacked membership indicator
    (``None`` unless requested — it is the segment_sum degree feed).
    Partials are identical across impls (fixed per-word/per-block
    reduction groups), so the totals below are too.
    """
    mask: jnp.ndarray
    pop: jnp.ndarray
    accw: jnp.ndarray
    live: jnp.ndarray | None

    def live_count(self):
        """Total live elements (int; summed over the trailing axis)."""
        return np.asarray(self.pop).sum(axis=-1)

    def weighted_total(self):
        """Σ weights over live slots, f32 (PageRank push mass)."""
        return np.asarray(self.accw, np.float32).sum(axis=-1,
                                                     dtype=np.float32)


_fused_xla_jit = jax.jit(_counted("fused", delta_apply_fused_ref),
                         static_argnames=("block_w", "emit_live"))


def _fused_batched_ref(bases, adds, dels, weights, *, block_w, emit_live):
    return jax.vmap(
        lambda b, a, d: delta_apply_fused_ref(
            b, a, d, weights, block_w=block_w, emit_live=emit_live)
    )(bases, adds, dels)


_fused_batched_xla_jit = jax.jit(
    _counted("fused_batched", _fused_batched_ref),
    static_argnames=("block_w", "emit_live"))


def _fused_pad(base, adds, dels, weights, block_w):
    """Pad W to a block multiple and K to its bucket — once, identically,
    for every impl, so partials line up bit-for-bit across impls."""
    K, W = adds.shape[-2:]
    Wp = -(-W // block_w) * block_w
    Kp = _bucket(K)
    base = _pad_axis(base, base.ndim - 1, Wp)
    adds = _pad_axis(_pad_axis(adds, adds.ndim - 1, Wp), adds.ndim - 2, Kp)
    dels = _pad_axis(_pad_axis(dels, dels.ndim - 1, Wp), dels.ndim - 2, Kp)
    if weights is not None:
        weights = _pad_axis(jnp.asarray(weights, jnp.float32), 0, Wp * 32)
    return base, adds, dels, weights, W


def delta_apply_fused(base: jnp.ndarray, adds: jnp.ndarray,
                      dels: jnp.ndarray,
                      weights: jnp.ndarray | None = None, *,
                      impl: str | None = None, block_w: int = 1024,
                      interpret: bool | None = None,
                      emit_live: bool = True) -> FusedOut:
    """Fused retrieval + analytics: land the K-delta chain over ``base``
    and, in the same pass over each bitmap block, emit per-block popcount
    partials, per-word weighted partials (``weights [W*32] f32``, e.g.
    per-slot PageRank contributions) and the unpacked live indicator that
    feeds the segment_sum kernel's per-node degree reduction.

    ``pop``/``accw`` come back over the padded width (zero padding
    contributes nothing); ``mask`` and ``live`` are trimmed to ``W``.
    """
    impl, interpret = policy.resolve(impl, interpret)
    base, adds, dels, weights, W = _fused_pad(base, adds, dels, weights,
                                              block_w)
    if impl == "xla":
        mask, pop, accw, live = _fused_xla_jit(
            base, adds, dels, weights, block_w=block_w, emit_live=emit_live)
    else:
        mask, pop, accw, live = delta_apply_fused_pallas(
            base, adds, dels, weights, block_w=block_w, interpret=interpret,
            emit_live=emit_live)
    return FusedOut(mask[:W], pop, accw[:W],
                    live[:W * 32] if live is not None else None)


def delta_apply_fused_batched(bases: jnp.ndarray, adds: jnp.ndarray,
                              dels: jnp.ndarray,
                              weights: jnp.ndarray | None = None, *,
                              impl: str | None = None, block_w: int = 1024,
                              interpret: bool | None = None,
                              emit_live: bool = True) -> FusedOut:
    """Batched fused apply+analytics: ``bases [B, W]``, ``adds/dels
    [B, K, W]``, one shared ``weights [W*32]`` — B chains land and emit
    their analytics partials in a single vmapped pass (B is bucketed;
    padded rows are dropped from every output)."""
    impl, interpret = policy.resolve(impl, interpret)
    B = bases.shape[0]
    bases, adds, dels, weights, W = _fused_pad(bases, adds, dels, weights,
                                               block_w)
    Bp = _bucket(B)
    bases = _pad_axis(bases, 0, Bp)
    adds = _pad_axis(adds, 0, Bp)
    dels = _pad_axis(dels, 0, Bp)
    if impl == "xla":
        mask, pop, accw, live = _fused_batched_xla_jit(
            bases, adds, dels, weights, block_w=block_w, emit_live=emit_live)
    else:
        mask, pop, accw, live = jax.vmap(
            lambda b, a, d: delta_apply_fused_pallas(
                b, a, d, weights, block_w=block_w, interpret=interpret,
                emit_live=emit_live))(bases, adds, dels)
    return FusedOut(mask[:B, :W], pop[:B], accw[:B, :W],
                    live[:B, :W * 32] if live is not None else None)
