"""Public jit'd wrapper for fused delta-chain application.

On CPU containers the Pallas TPU kernel runs in ``interpret=True`` mode
(used by tests); production TPU deployments pass ``interpret=False``.
``impl='xla'`` selects the pure-jnp scan (used under `jit` in the
snapshot-retrieval engine, and as the oracle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .delta_apply import delta_apply_chain_pallas
from .ref import delta_apply_chain_prefix_ref, delta_apply_chain_ref

# Shape bucketing for the jit'd XLA paths: chain calls arrive with
# arbitrary (B, K, W) — every distinct shape would otherwise compile its
# own executable, and a retrieval service sees a new shape per plan.
# Padding B and K up to powers of two (all-zero (add, del) rows are
# identity steps; extra batch rows are dropped) and W up to a 128-word
# lane multiple collapses the shape space to a handful of buckets that
# stay hot in the compile cache.
_W_ALIGN = 128


def _bucket(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _pad_axis(a: jnp.ndarray, axis: int, target: int) -> jnp.ndarray:
    pad = target - a.shape[axis]
    if pad <= 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


_chain_jit = jax.jit(delta_apply_chain_ref)
_chain_batched_jit = jax.jit(jax.vmap(delta_apply_chain_ref))
_chain_prefix_batched_jit = jax.jit(jax.vmap(delta_apply_chain_prefix_ref))


def delta_apply_chain(base: jnp.ndarray, adds: jnp.ndarray, dels: jnp.ndarray,
                      *, impl: str = "xla", block_w: int = 1024,
                      interpret: bool = True) -> jnp.ndarray:
    if impl == "xla":
        W = base.shape[0]
        Wp = -(-W // _W_ALIGN) * _W_ALIGN
        Kp = _bucket(adds.shape[0])
        out = _chain_jit(_pad_axis(base, 0, Wp),
                         _pad_axis(_pad_axis(adds, 1, Wp), 0, Kp),
                         _pad_axis(_pad_axis(dels, 1, Wp), 0, Kp))
        return out[:W]
    if impl == "pallas":
        return delta_apply_chain_pallas(base, adds, dels, block_w=block_w,
                                        interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}")


def delta_apply_chain_batched(bases: jnp.ndarray, adds: jnp.ndarray,
                              dels: jnp.ndarray, *, impl: str = "xla",
                              block_w: int = 1024,
                              interpret: bool = True) -> jnp.ndarray:
    """Vmapped multi-snapshot apply: ``B`` sibling chains in one call.

    ``bases [B, W]``, ``adds/dels [B, K, W]`` (chains zero-padded to a
    common ``K``; an all-zero ``(adds, dels)`` row is the identity step).
    Sibling branches after a plan Fork execute as one batched pass — one
    kernel launch and one sweep over the stacked bit-planes instead of
    ``B`` sequential chain calls.
    """
    if impl == "xla":
        B, K, W = adds.shape
        Wp = -(-W // _W_ALIGN) * _W_ALIGN
        Bp, Kp = _bucket(B), _bucket(K)
        out = _chain_batched_jit(
            _pad_axis(_pad_axis(bases, 1, Wp), 0, Bp),
            _pad_axis(_pad_axis(_pad_axis(adds, 2, Wp), 1, Kp), 0, Bp),
            _pad_axis(_pad_axis(_pad_axis(dels, 2, Wp), 1, Kp), 0, Bp))
        return out[:B, :W]
    if impl == "pallas":
        return jax.vmap(lambda b, a, d: delta_apply_chain_pallas(
            b, a, d, block_w=block_w, interpret=interpret))(bases, adds, dels)
    raise ValueError(f"unknown impl {impl!r}")


def delta_apply_chain_prefix(base: jnp.ndarray, adds: jnp.ndarray,
                             dels: jnp.ndarray) -> jnp.ndarray:
    """All K intermediate chain states ``[K, W]`` (``out[i]`` = state after
    delta ``i``).  The temporal engine's multi-interval path vmaps this
    over stacked intervals — every prefix is a returned snapshot bitmap,
    so (unlike :func:`delta_apply_chain`) there is no fused-kernel variant:
    each word is genuinely written once per step either way."""
    return delta_apply_chain_prefix_ref(base, adds, dels)


def delta_apply_chain_prefix_batched(bases: jnp.ndarray, adds: jnp.ndarray,
                                     dels: jnp.ndarray) -> jnp.ndarray:
    """Vmapped prefix chains: ``bases [B, W]``, ``adds/dels [B, K, W]`` →
    ``[B, K, W]`` per-timepoint bitmaps for B intervals in one pass.
    Shape-bucketed and jit'd like :func:`delta_apply_chain_batched`; the
    padded identity rows repeat the final state and are sliced away."""
    B, K, W = adds.shape
    Wp = -(-W // _W_ALIGN) * _W_ALIGN
    Bp, Kp = _bucket(B), _bucket(K)
    out = _chain_prefix_batched_jit(
        _pad_axis(_pad_axis(bases, 1, Wp), 0, Bp),
        _pad_axis(_pad_axis(_pad_axis(adds, 2, Wp), 1, Kp), 0, Bp),
        _pad_axis(_pad_axis(_pad_axis(dels, 2, Wp), 1, Kp), 0, Bp))
    return out[:B, :K, :W]
