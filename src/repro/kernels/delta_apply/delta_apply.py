"""Pallas TPU kernel: fused K-delta bitmap application.

The DeltaGraph retrieval hot loop applies a root→leaf chain of K deltas to
a membership bitmap.  Done naively that is K passes over the bitmap in HBM
(2·K·W words of traffic).  This kernel streams each bitmap *block* through
VMEM once and applies all K deltas in registers — traffic drops to
(K+2)·BLOCK per block tile, i.e. one read of every delta + one read/write
of the base, the memory-bound optimum.

Layout: ``base  [W] uint32``, ``adds/dels  [K, W] uint32``.  Grid tiles W
into ``block_w``-sized chunks (multiple of 128 lanes for the VPU); the K
loop is unrolled inside the kernel body (K is static per path length).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(base_ref, adds_ref, dels_ref, out_ref, *, K: int):
    m = base_ref[...]
    for i in range(K):  # static unroll: K = path length ~ log_k(N)
        m = (m & ~dels_ref[i, :]) | adds_ref[i, :]
    out_ref[...] = m


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def delta_apply_chain_pallas(base: jnp.ndarray, adds: jnp.ndarray,
                             dels: jnp.ndarray, *, block_w: int = 1024,
                             interpret: bool = True) -> jnp.ndarray:
    """Fused application; pads W to a multiple of ``block_w``."""
    K, W = adds.shape
    if K == 0:
        return base
    Wp = -(-W // block_w) * block_w
    if Wp != W:
        pad = [(0, Wp - W)]
        base = jnp.pad(base, pad)
        adds = jnp.pad(adds, [(0, 0)] + pad)
        dels = jnp.pad(dels, [(0, 0)] + pad)
    grid = (Wp // block_w,)
    out = pl.pallas_call(
        functools.partial(_kernel, K=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_w,), lambda i: (i,)),
            pl.BlockSpec((K, block_w), lambda i: (0, i)),
            pl.BlockSpec((K, block_w), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block_w,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Wp,), jnp.uint32),
        interpret=interpret,
    )(base, adds, dels)
    return out[:W]
