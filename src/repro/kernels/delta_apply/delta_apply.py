"""Pallas TPU kernel: fused K-delta bitmap application.

The DeltaGraph retrieval hot loop applies a root→leaf chain of K deltas to
a membership bitmap.  Done naively that is K passes over the bitmap in HBM
(2·K·W words of traffic).  This kernel streams each bitmap *block* through
VMEM once and applies all K deltas in registers — traffic drops to
(K+2)·BLOCK per block tile, i.e. one read of every delta + one read/write
of the base, the memory-bound optimum.

Layout: ``base  [W] uint32``, ``adds/dels  [K, W] uint32``.  Grid tiles W
into ``block_w``-sized chunks (multiple of 128 lanes for the VPU); the K
loop is unrolled inside the kernel body (K is static per path length).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(base_ref, adds_ref, dels_ref, out_ref, *, K: int):
    m = base_ref[...]
    for i in range(K):  # static unroll: K = path length ~ log_k(N)
        m = (m & ~dels_ref[i, :]) | adds_ref[i, :]
    out_ref[...] = m


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def delta_apply_chain_pallas(base: jnp.ndarray, adds: jnp.ndarray,
                             dels: jnp.ndarray, *, block_w: int = 1024,
                             interpret: bool = True) -> jnp.ndarray:
    """Fused application; pads W to a multiple of ``block_w``."""
    K, W = adds.shape
    if K == 0:
        return base
    Wp = -(-W // block_w) * block_w
    if Wp != W:
        pad = [(0, Wp - W)]
        base = jnp.pad(base, pad)
        adds = jnp.pad(adds, [(0, 0)] + pad)
        dels = jnp.pad(dels, [(0, 0)] + pad)
    grid = (Wp // block_w,)
    out = pl.pallas_call(
        functools.partial(_kernel, K=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_w,), lambda i: (i,)),
            pl.BlockSpec((K, block_w), lambda i: (0, i)),
            pl.BlockSpec((K, block_w), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block_w,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Wp,), jnp.uint32),
        interpret=interpret,
    )(base, adds, dels)
    return out[:W]


# ---------------------------------------------------------------------------
# fused chain + push-style analytics
# ---------------------------------------------------------------------------
#
# The retrieval hot loop ends with analytics over the landed bitmap —
# live-element counts (density), per-node degrees, PageRank push mass.
# Done separately that is a *second* full sweep over the mask (plus an
# unpack pass to feed segment_sum).  The fused kernel emits them while the
# final chain state is still in registers:
#
#   mask   [W]  u32  — the landed chain state (same as the plain kernel)
#   pop    [G]  i32  — per-grid-block popcount partials (Σ = live count)
#   accw   [W]  f32  — per-word weighted partials: word w's bits dotted
#                      with its 32 slot weights (Σ = PageRank push mass)
#   live   [W*32] f32 — the unpacked membership indicator, the feed for
#                      the segment_sum kernel's per-node degree reduction
#
# accw partials are per *word* (32-element dot, fixed evaluation order) so
# the Pallas and XLA paths reduce identical element groups — the full
# reduction happens once, downstream, on identical inputs: fused analytics
# stay bit-identical to the ref oracle even in float32.


def _unpack_bits_f32(m: jnp.ndarray) -> jnp.ndarray:
    """[bw] u32 -> [bw, 32] f32 bit indicators (little-endian bit order)."""
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (m.shape[0], 32), 1)
    return ((m[:, None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)


def _fused_kernel(base_ref, adds_ref, dels_ref, w_ref, out_ref, pop_ref,
                  accw_ref, live_ref, *, K: int, emit_live: bool,
                  has_weights: bool):
    m = base_ref[...]
    for i in range(K):
        m = (m & ~dels_ref[i, :]) | adds_ref[i, :]
    out_ref[...] = m
    pop_ref[0] = jax.lax.population_count(m).astype(jnp.int32).sum()
    bits = _unpack_bits_f32(m)                     # [bw, 32]
    if has_weights:
        w = w_ref[...].reshape(m.shape[0], 32)
        accw_ref[...] = (bits * w).sum(axis=1)     # per-word partials
    else:
        accw_ref[...] = bits.sum(axis=1)           # per-word popcount (f32)
    if emit_live:
        live_ref[...] = bits.reshape(-1)
    else:
        live_ref[...] = jnp.zeros_like(live_ref[...])   # dummy block


@functools.partial(jax.jit,
                   static_argnames=("block_w", "interpret", "emit_live"))
def delta_apply_fused_pallas(base: jnp.ndarray, adds: jnp.ndarray,
                             dels: jnp.ndarray,
                             weights: jnp.ndarray | None = None, *,
                             block_w: int = 1024, interpret: bool = True,
                             emit_live: bool = True):
    """One pass over each bitmap block: land the K-delta chain *and* emit
    the analytics partials.  ``base [W] u32``, ``adds/dels [K, W] u32``,
    ``weights [W*32] f32`` (optional per-slot weights, e.g. PageRank
    contributions).  ``W`` must already be a multiple of ``block_w``
    (the ops-layer wrapper pads once, so partials line up across impls).

    Returns ``(mask [W] u32, pop [G] i32, accw [W] f32,
    live [W*32] f32 | None)``.
    """
    K, W = adds.shape
    assert W % block_w == 0, "ops wrapper pads W to the block size"
    if K == 0:   # an all-zero (add, del) row is the identity step
        adds = jnp.zeros((1, W), jnp.uint32)
        dels = jnp.zeros((1, W), jnp.uint32)
        K = 1
    G = W // block_w
    has_weights = weights is not None
    if not has_weights:
        weights = jnp.zeros((1,), jnp.float32)   # dummy; kernel ignores it
        w_spec = pl.BlockSpec((1,), lambda i: (0,))
    else:
        w_spec = pl.BlockSpec((block_w * 32,), lambda i: (i,))
    out_shapes = [
        jax.ShapeDtypeStruct((W,), jnp.uint32),
        jax.ShapeDtypeStruct((G,), jnp.int32),
        jax.ShapeDtypeStruct((W,), jnp.float32),
        jax.ShapeDtypeStruct((W * 32 if emit_live else 32,), jnp.float32),
    ]
    out_specs = [
        pl.BlockSpec((block_w,), lambda i: (i,)),
        pl.BlockSpec((1,), lambda i: (i,)),
        pl.BlockSpec((block_w,), lambda i: (i,)),
        (pl.BlockSpec((block_w * 32,), lambda i: (i,)) if emit_live
         else pl.BlockSpec((32,), lambda i: (0,))),
    ]
    mask, pop, accw, live = pl.pallas_call(
        functools.partial(_fused_kernel, K=K, emit_live=emit_live,
                          has_weights=has_weights),
        grid=(G,),
        in_specs=[
            pl.BlockSpec((block_w,), lambda i: (i,)),
            pl.BlockSpec((K, block_w), lambda i: (0, i)),
            pl.BlockSpec((K, block_w), lambda i: (0, i)),
            w_spec,
        ],
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(base, adds, dels, weights)
    return mask, pop, accw, (live if emit_live else None)
