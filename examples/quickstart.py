"""Quickstart: build a historical graph, index it, query snapshots — via
the declarative GraphQuery builder (`Q`), the wire-protocol form of every
query, and the legacy method surface it shims.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import Q
from repro.core import GraphManager, TimeExpression
from repro.core.events import GraphHistoryBuilder

# -- 1. record an evolving collaboration network --------------------------
b = GraphHistoryBuilder()
for person in ("ada", "grace", "edsger", "barbara", "donald"):
    b.add_node(person, t=1960, attrs={"papers": 0.0})
b.add_edge("ada", "grace", t=1962)
b.add_edge("grace", "edsger", t=1965)
b.set_node_attr("grace", "papers", 12.0, t=1966)
b.add_edge("barbara", "donald", t=1968)
b.delete_edge("ada", "grace", t=1970)
b.add_edge("ada", "donald", t=1972)
b.transient_edge("edsger", "donald", t=1971)   # a one-off "message"
universe, events = b.finalize()

# -- 2. build the DeltaGraph index + GraphPool -----------------------------
gm = GraphManager(universe, events, L=4, k=2, diff_fn="balanced")

# -- 3. singlepoint retrieval (the paper's GetHistGraph) -------------------
# the legacy method surface still works; it is a thin shim over the
# declarative query service (gm.query), used directly in step 4
h1966 = gm.get_hist_graph(1966, "+node:papers")
print("1966 nodes:", sorted(h1966.get_nodes()))
print("1966 grace neighbors:", h1966.get_neighbors("grace"))
print("1966 grace.papers =", h1966.node_attr("grace", "papers"))

# -- 4. declarative queries: build a document, run it, read the stats ------
doc = Q.at(1966).attrs("+node:papers").build()
print("as a wire document:", doc.to_json())
res = gm.query.run(doc)
print(f"same snapshot via the document: {res.value.node_mask.sum()} nodes, "
      f"stats={ {k: res.stats[k] for k in ('kv_gets', 'cache_hits')} }")

# -- 5. multipoint retrieval (one Steiner-tree plan) -----------------------
for h in gm.get_hist_graphs([1963, 1969, 1973]):
    print(f"{h.time}: {h.num_nodes()} nodes / {h.num_edges()} edges")
# ... or declaratively; co-batched documents merge into ONE plan
results = gm.query.run_batch([Q.at(1963).build(), Q.at(1969, 1973).build()])
print("multipoint merged", results[0].stats["merged_docs"],
      "documents into one plan")

# -- 6. TimeExpression: edges valid in 1969 but not 1973 -------------------
tex = TimeExpression.parse("t0 & ~t1", [1969, 1973])
with gm.get_hist_graph_expr(tex) as g:     # HistGraph: a context manager
    print("edges in 1969 but gone by 1973:", g.num_edges())
# equivalent document: Q.expr("t0 & ~t1", [1969, 1973]).build()

# -- 7. interval query picks up the transient ------------------------------
res = gm.get_hist_graph_interval(1970, 1973)   # = Q.between(1970, 1973)
print("elements added in [1970, 1973):",
      {k: v.tolist() for k, v in res.items() if len(v)})

# -- 8. live updates keep the index fresh (§6) -----------------------------
upd = GraphHistoryBuilder()
upd.universe = universe          # same id space, new events
upd._seq = 10_000
upd.add_node("alan", 1975)
upd.add_edge("alan", "donald", 1976)
_, new_events = upd.finalize()
gm.update(new_events)
h1976 = gm.get_hist_graph(1976)
print("1976 after live update:", h1976.num_nodes(), "nodes,",
      h1976.num_edges(), "edges")
