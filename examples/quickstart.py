"""Quickstart: build a historical graph, index it, query snapshots.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import GraphManager, TimeExpression
from repro.core.events import GraphHistoryBuilder

# -- 1. record an evolving collaboration network --------------------------
b = GraphHistoryBuilder()
for person in ("ada", "grace", "edsger", "barbara", "donald"):
    b.add_node(person, t=1960, attrs={"papers": 0.0})
b.add_edge("ada", "grace", t=1962)
b.add_edge("grace", "edsger", t=1965)
b.set_node_attr("grace", "papers", 12.0, t=1966)
b.add_edge("barbara", "donald", t=1968)
b.delete_edge("ada", "grace", t=1970)
b.add_edge("ada", "donald", t=1972)
b.transient_edge("edsger", "donald", t=1971)   # a one-off "message"
universe, events = b.finalize()

# -- 2. build the DeltaGraph index + GraphPool -----------------------------
gm = GraphManager(universe, events, L=4, k=2, diff_fn="balanced")

# -- 3. singlepoint retrieval (the paper's GetHistGraph) -------------------
h1966 = gm.get_hist_graph(1966, "+node:papers")
print("1966 nodes:", sorted(h1966.get_nodes()))
print("1966 grace neighbors:", h1966.get_neighbors("grace"))
print("1966 grace.papers =", h1966.node_attr("grace", "papers"))

# -- 4. multipoint retrieval (one Steiner-tree plan) -----------------------
for h in gm.get_hist_graphs([1963, 1969, 1973]):
    print(f"{h.time}: {h.num_nodes()} nodes / {h.num_edges()} edges")

# -- 5. TimeExpression: edges valid in 1969 but not 1973 -------------------
tex = TimeExpression.parse("t0 & ~t1", [1969, 1973])
st = gm.get_hist_graph_expr(tex)
print("edges in 1969 but gone by 1973:", int(st.edge_mask.sum()))

# -- 6. interval query picks up the transient ------------------------------
res = gm.get_hist_graph_interval(1970, 1973)
print("elements added in [1970, 1973):",
      {k: v.tolist() for k, v in res.items() if len(v)})

# -- 7. live updates keep the index fresh (§6) -----------------------------
upd = GraphHistoryBuilder()
upd.universe = universe          # same id space, new events
upd._seq = 10_000
upd.add_node("alan", 1975)
upd.add_edge("alan", "donald", 1976)
_, new_events = upd.finalize()
gm.update(new_events)
h1976 = gm.get_hist_graph(1976)
print("1976 after live update:", h1976.num_nodes(), "nodes,",
      h1976.num_edges(), "edges")
