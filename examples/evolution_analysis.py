"""Evolution analysis (paper fig 1): track top-PageRank nodes across the
network's history using multipoint retrieval + vmapped analytics over
GraphPool planes, plus 'new triangles this period' (§1's example query).

Run:  PYTHONPATH=src python examples/evolution_analysis.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import GraphManager
from repro.data.generators import growing_network
from repro.graph.algorithms import multi_snapshot_pagerank, triangle_count

print("building a growing co-authorship-style network ...")
uni, ev = growing_network(n_events=8000, seed=3, n_attrs=0)
gm = GraphManager(uni, ev, L=500, k=4)
tmax = int(ev.time[-1])
epochs = [int(t) for t in np.linspace(tmax * 0.2, tmax, 6)]

# one multipoint (Steiner) retrieval for all epochs
hs = gm.get_hist_graphs(epochs)
nps, eps = gm.pool.stacked_planes([h.gid for h in hs])

print("vmapped PageRank over", len(epochs), "snapshots ...")
prs = np.asarray(multi_snapshot_pagerank(
    jnp.asarray(uni.edge_src), jnp.asarray(uni.edge_dst),
    jnp.asarray(eps), jnp.asarray(nps), num_nodes=uni.num_nodes, iters=30))

print("\nrank evolution of the final top-5 nodes (fig 1 style):")
final_top = np.argsort(-prs[-1])[:5]
header = "node " + " ".join(f"t={t:>6d}" for t in epochs)
print(header)
for n in final_top:
    ranks = []
    for i in range(len(epochs)):
        order = np.argsort(-prs[i])
        ranks.append(int(np.nonzero(order == n)[0][0]) + 1)
    print(f"{uni.node_ids[n]!s:>4} " + " ".join(f"{r:>8d}" for r in ranks))

print("\nnew triangles per period (§1 example query):")
prev = 0
for h, t in zip(hs, epochs):
    tri = triangle_count(uni.edge_src, uni.edge_dst, h.edge_mask,
                         uni.num_nodes)
    print(f"  up to t={t:>6d}: {tri:>6d} triangles (+{tri - prev})")
    prev = tri
