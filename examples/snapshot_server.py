"""Snapshot server: serve batched historical-snapshot queries arriving as
declarative GraphQuery documents (the wire protocol) — co-batched
documents merge into one multipoint (Steiner) plan, results land in the
GraphPool overlay, with p50/p99 latency reporting and straggler-aware
fetch.  The same loop `serve.py --mode query` runs over stdin.

Run:  PYTHONPATH=src python examples/snapshot_server.py [--requests 200]
"""
import argparse
import json
import time

import numpy as np

from repro.api import GraphQuery
from repro.core import GraphManager
from repro.core.query import NO_ATTRS
from repro.data.generators import churn_network
from repro.runtime.fault import FetchTask, StragglerMitigator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--materialize", action="store_true",
                    help="fixed-depth §4.5 pinning (the manual policy)")
    ap.add_argument("--advise", action="store_true",
                    help="workload-aware advisor + budget (core/materialize)")
    ap.add_argument("--budget-mb", type=float, default=16.0)
    args = ap.parse_args()

    print("building index ...")
    uni, ev = churn_network(n_initial_edges=800, n_events=10_000, seed=9)
    gm = GraphManager(uni, ev, L=500, k=4, diff_fn="balanced",
                      num_partitions=4)
    if args.materialize:
        gm.materialize_roots(depth=2)
    if args.advise:
        advice = gm.enable_advisor(budget_bytes=int(args.budget_mb * 2**20))
        print(f"advisor pinned {len(advice.chosen)} nodes, "
              f"expected plan-byte saving "
              f"{advice.expected_saved_bytes:.0f}")
    tmax = int(ev.time[-1])
    rng = np.random.default_rng(0)
    svc = gm.query

    # simulated request stream: each client sends one snapshot *document*
    # (recency-biased query times, g(t) §5.1); concurrent documents are
    # co-batched by the service into ONE merged Steiner plan per group
    lat = []
    served = kv_gets = 0
    t_start = time.time()
    while served < args.requests:
        wire = [json.dumps({"kind": "snapshot",
                            "t": int(tmax * (1 - rng.beta(1, 4)))})
                for _ in range(args.batch)]
        t0 = time.perf_counter()
        results = svc.run_batch([GraphQuery.from_json(s) for s in wire])
        gids = [gm.pool.insert_snapshot(r.value) for r in results]
        lat.append((time.perf_counter() - t0) / len(wire))
        kv_gets += results[0].stats["kv_gets"]
        for g in gids:   # client done → release + lazy clean
            gm.pool.release(g)
        gm.pool.cleaner()
        served += len(wire)
    wall = time.time() - t_start

    lat_ms = np.asarray(lat) * 1000
    print(f"served {served} snapshot documents in {wall:.2f}s "
          f"({served/wall:.0f} qps, {kv_gets} KV gets)")
    print(f"per-query latency: p50={np.percentile(lat_ms,50):.2f}ms "
          f"p95={np.percentile(lat_ms,95):.2f}ms "
          f"p99={np.percentile(lat_ms,99):.2f}ms")
    print(f"pool holds {gm.pool.num_active()-1} graphs, "
          f"{gm.pool.memory_bytes()/1e6:.1f} MB")

    # straggler-aware fetch schedule demo over the partitioned store; the
    # plan IR carries exactly one Fetch node per payload, so the task set
    # is duplicate-free by construction
    from repro.core.planir import Fetch
    plan = gm.dg.plan_multipoint([int(t) for t in
                                  np.linspace(0, tmax, 16)], NO_ATTRS)
    tasks = [FetchTask(p, (p, n.op.pid, "struct"), 1000)
             for n in plan.nodes if isinstance(n.op, Fetch)
             for p in range(gm.dg.P)]
    sm = StragglerMitigator(tasks, hedge_frac=0.1)
    n = 0
    while not sm.finished():
        t = sm.assign()
        if t is None:
            break
        sm.complete(t.key)
        n += 1
    print(f"straggler scheduler: {n} fetches, {sm.duplicates} hedged")


if __name__ == "__main__":
    main()
