"""End-to-end driver: train a GCN over *historical snapshots* served by the
DeltaGraph — the paper's substrate feeding an ML training loop, with
checkpoint/resume fault tolerance.

The task: node classification where the label is whether a node's degree
will grow in the future (a simple self-supervised temporal target), trained
across a stream of snapshots drawn uniformly from the network's history.

Run:  PYTHONPATH=src python examples/temporal_gnn_train.py [--steps 300]
"""
import argparse
import os
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import GraphManager, replay
from repro.data.generators import churn_network
from repro.models import common as mc
from repro.models.gnn import GCNConfig, gnn_loss, gnn_param_defs
from repro.storage.checkpoint import restore_checkpoint, save_checkpoint
from repro.storage.kv import LogFileKV
from repro.training.optim import OPTIMIZERS
from repro.training.trainer import make_train_step


def snapshot_batch(gm, uni, ev, t_now, t_future, d_in=16):
    """Features: random projection of node id + degree; labels: degree growth."""
    st = replay(uni, ev, t_now)
    fut = replay(uni, ev, t_future)
    N = uni.num_nodes
    deg = np.zeros(N, np.float32)
    eid = np.nonzero(st.edge_mask)[0]
    np.add.at(deg, uni.edge_src[eid], 1)
    np.add.at(deg, uni.edge_dst[eid], 1)
    fdeg = np.zeros(N, np.float32)
    eid2 = np.nonzero(fut.edge_mask)[0]
    np.add.at(fdeg, uni.edge_src[eid2], 1)
    np.add.at(fdeg, uni.edge_dst[eid2], 1)
    rng = np.random.default_rng(0)
    proj = rng.standard_normal((1, d_in - 1)).astype(np.float32)
    x = np.concatenate([deg[:, None] * proj * 0.1, deg[:, None]], 1)
    labels = (fdeg > deg).astype(np.int32)
    src = uni.edge_src[eid]
    dst = uni.edge_dst[eid]
    ei = np.stack([np.concatenate([src, dst]), np.concatenate([dst, src])])
    # pad edges to a static size for jit
    E_pad = uni.num_edges * 2
    ei_p = np.zeros((2, E_pad), np.int32)
    ei_p[:, : ei.shape[1]] = ei
    em = np.zeros(E_pad, np.float32)
    em[: ei.shape[1]] = 1.0
    return {"x": jnp.asarray(x), "edge_index": jnp.asarray(ei_p),
            "edge_mask": jnp.asarray(em),
            "labels": jnp.asarray(labels),
            "label_mask": jnp.asarray(st.node_mask.astype(np.float32))}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    print("building historical trace + DeltaGraph index ...")
    uni, ev = churn_network(n_initial_edges=600, n_events=6000, seed=5)
    gm = GraphManager(uni, ev, L=400, k=4, diff_fn="balanced")
    tmax = int(ev.time[-1])

    cfg = GCNConfig(d_in=16, d_hidden=32, n_layers=2, n_classes=2)
    params = mc.init_params(gnn_param_defs(cfg), jax.random.PRNGKey(0))
    opt = OPTIMIZERS["adamw"](lr=5e-3)
    opt_state = opt[0](params)
    step_fn = jax.jit(make_train_step(lambda p, b: gnn_loss(p, b, cfg), opt))

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                             "repro_gnn_ckpt")
    store = LogFileKV(ckpt_dir)
    start = 0
    try:
        (params, opt_state), extra, start = restore_checkpoint(
            store, like=(params, opt_state))
        print(f"resumed from step {start}")
    except (FileNotFoundError, KeyError):
        pass

    rng = np.random.default_rng(1)
    t0 = time.time()
    for step in range(start, args.steps):
        t_now = int(rng.integers(tmax // 4, int(tmax * 0.8)))
        batch = snapshot_batch(gm, uni, ev, t_now, t_now + tmax // 10)
        params, opt_state, m = step_fn(params, opt_state, batch)
        if (step + 1) % 50 == 0:
            print(f"step {step+1:4d} loss {float(m['loss']):.4f} "
                  f"({(time.time()-t0)/(step-start+1)*1000:.0f} ms/step)")
        if (step + 1) % args.ckpt_every == 0:
            save_checkpoint(store, step + 1, (params, opt_state),
                            extra={"rng": int(rng.integers(1 << 30))})
            print(f"  checkpointed @ {step+1}")
    save_checkpoint(store, args.steps, (params, opt_state))
    print("done — final loss", float(m["loss"]))


if __name__ == "__main__":
    main()
