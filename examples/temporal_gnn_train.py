"""End-to-end driver: train a GCN over *historical snapshots* served by the
DeltaGraph — the paper's substrate feeding an ML training loop, with
checkpoint/resume fault tolerance.

The task: node classification where the label is whether a node's degree
will grow in the future (a simple self-supervised temporal target), trained
across a stream of snapshot windows served by
:class:`repro.core.SnapshotBatchLoader` — interval retrieval runs on the
batched device path (double-buffered prefix-chain sweep) and the degree
features come from the fused delta-apply analytics kernel, so the training
loop never replays events or scatters degrees on the host.

Run:  PYTHONPATH=src python examples/temporal_gnn_train.py [--steps 300]
"""
import argparse
import os
import tempfile
import time

import numpy as np

import jax

from repro.core import GraphManager, SnapshotBatchLoader
from repro.data.generators import churn_network
from repro.models import common as mc
from repro.models.gnn import GCNConfig, gnn_loss, gnn_param_defs
from repro.storage.checkpoint import restore_checkpoint, save_checkpoint
from repro.storage.kv import LogFileKV
from repro.training.optim import OPTIMIZERS
from repro.training.trainer import make_train_step


def snapshot_stream(loader: SnapshotBatchLoader):
    """Endless per-snapshot training examples from windowed loader batches.

    The loader yields ``[T, ...]`` window stacks (one batched retrieval +
    one fused analytics pass per window); each timepoint slice is a
    static-shape batch for the jit'd train step."""
    while True:
        for b in loader:
            for j in range(len(b["times"])):
                yield {"x": b["x"][j], "edge_index": b["edge_index"],
                       "edge_mask": b["edge_mask"][j],
                       "labels": b["labels"][j],
                       "label_mask": b["label_mask"][j]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    print("building historical trace + DeltaGraph index ...")
    uni, ev = churn_network(n_initial_edges=600, n_events=6000, seed=5)
    gm = GraphManager(uni, ev, L=400, k=4, diff_fn="balanced")
    tmax = int(ev.time[-1])

    cfg = GCNConfig(d_in=16, d_hidden=32, n_layers=2, n_classes=2)
    params = mc.init_params(gnn_param_defs(cfg), jax.random.PRNGKey(0))
    opt = OPTIMIZERS["adamw"](lr=5e-3)
    opt_state = opt[0](params)
    step_fn = jax.jit(make_train_step(lambda p, b: gnn_loss(p, b, cfg), opt))

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                             "repro_gnn_ckpt")
    store = LogFileKV(ckpt_dir)
    start = 0
    try:
        (params, opt_state), extra, start = restore_checkpoint(
            store, like=(params, opt_state))
        print(f"resumed from step {start}")
    except (FileNotFoundError, KeyError):
        pass

    lo, hi = tmax // 4, int(tmax * 0.8)
    grid = sorted({int(t) for t in
                   np.linspace(lo, hi, 64)})
    loader = SnapshotBatchLoader(gm, grid, batch_size=4,
                                 label_horizon=tmax // 10, d_in=cfg.d_in)
    stream = snapshot_stream(loader)
    t0 = time.time()
    for step in range(start, args.steps):
        batch = next(stream)
        params, opt_state, m = step_fn(params, opt_state, batch)
        if (step + 1) % 50 == 0:
            print(f"step {step+1:4d} loss {float(m['loss']):.4f} "
                  f"({(time.time()-t0)/(step-start+1)*1000:.0f} ms/step)")
        if (step + 1) % args.ckpt_every == 0:
            save_checkpoint(store, step + 1, (params, opt_state))
            print(f"  checkpointed @ {step+1}")
    save_checkpoint(store, args.steps, (params, opt_state))
    print("done — final loss", float(m["loss"]))


if __name__ == "__main__":
    main()
