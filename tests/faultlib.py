"""Reusable fault-injection harness for crash-consistency tests.

Generalizes the monkeypatched-crash pattern from the PR-4 storage tests
into two composable primitives:

* :class:`CrashInjector` — arms the ingest pipeline's named checkpoint
  hook (:data:`repro.core.ingest.CRASH_POINTS`) so the pipeline raises
  :class:`InjectedCrash` the ``n``-th time it reaches a chosen point.
  The injector records every checkpoint it saw, so a test can assert the
  crash actually fired where it intended.

* :func:`power_fail` — models the power going out *at* the crash: the
  process state is gone (the caller abandons its manager) and the disk
  keeps only what was fsynced.  Implemented by truncating the
  ``LogFileKV`` log to its ``_synced_size`` high-water mark — bytes
  appended after the last durability barrier are torn away exactly as a
  real power cut would.

The canonical loop (``tests/test_ingest_faults.py``)::

    inj = CrashInjector("commit:pre-sync")
    inj.arm(pipe)
    with pytest.raises(InjectedCrash):
        ... ingest until the checkpoint fires ...
    acked = pipe.committed_events
    power_fail(store)
    gm2 = GraphManager.open(universe, LogFileKV(store.dir))
    assert gm2.dg._total_events >= acked      # no acked event lost
"""
from __future__ import annotations

import os

from repro.storage.kv import LogFileKV


class InjectedCrash(RuntimeError):
    """Raised by an armed :class:`CrashInjector` at its checkpoint."""

    def __init__(self, point: str, hit: int) -> None:
        super().__init__(f"injected crash at {point!r} (hit #{hit})")
        self.point = point
        self.hit = hit


class CrashInjector:
    """Raise :class:`InjectedCrash` the ``n``-th time ``point`` is hit."""

    def __init__(self, point: str, n: int = 1) -> None:
        self.point = point
        self.n = int(n)
        self.hits = 0
        self.fired = False
        self.seen: list[str] = []

    def __call__(self, name: str) -> None:
        self.seen.append(name)
        if name == self.point:
            self.hits += 1
            if self.hits >= self.n and not self.fired:
                self.fired = True
                raise InjectedCrash(name, self.hits)

    def arm(self, pipeline) -> "CrashInjector":
        pipeline.crash_hook = self
        return self

    @staticmethod
    def disarm(pipeline) -> None:
        pipeline.crash_hook = None


def power_fail(store: LogFileKV) -> str:
    """Kill the machine at this instant: drop everything not fsynced.

    Closes the store's file handles and truncates the log to the last
    durability barrier (``store.sync()`` / ``flush()``).  Returns the
    store directory so the caller can reopen a fresh ``LogFileKV`` on the
    survivor state.  The caller must abandon the old store *and* any
    manager built on it — their in-memory state did not survive.
    """
    with store._lock:
        synced = store._synced_size
        store._fh.close()
        store._rfh.close()
        with open(store.log_path, "r+b") as f:
            f.truncate(synced)
            f.flush()
            os.fsync(f.fileno())
    return store.dir


def reopen(directory: str) -> LogFileKV:
    """Fresh store on the post-crash disk image."""
    return LogFileKV(directory)
