"""attr_options parsing (Table 1) and TimeExpression parsing."""
import numpy as np
import pytest

from repro.core.events import GraphHistoryBuilder
from repro.core.query import TimeExpression, parse_attr_options


def make_universe():
    b = GraphHistoryBuilder()
    b.add_node(0, 1, attrs={"name": "x", "salary": 10.0, "age": 3.0})
    b.add_node(1, 1)
    b.add_edge(0, 1, 2, attrs={"weight": 1.0, "label": "e"})
    return b.finalize()[0]


def test_default_no_attrs():
    uni = make_universe()
    o = parse_attr_options("", uni)
    assert not o.wants_attrs


def test_table1_semantics():
    uni = make_universe()
    o = parse_attr_options("+node:all", uni)
    assert set(o.node_cols) == {0, 1, 2}
    o = parse_attr_options("+node:all-node:salary+edge:weight", uni)
    assert uni.attr_col("node", "salary") not in o.node_cols
    assert len(o.node_cols) == 2
    assert o.edge_cols == (uni.attr_col("edge", "weight"),)
    # specific attr overrides -node:all default
    o = parse_attr_options("+node:age", uni)
    assert o.node_cols == (uni.attr_col("node", "age"),)


def test_parse_errors():
    uni = make_universe()
    with pytest.raises(KeyError):
        parse_attr_options("+node:nonexistent", uni)
    with pytest.raises(ValueError):
        parse_attr_options("node:all", uni)


def test_time_expression_parse_and_eval():
    tex = TimeExpression.parse("(t0 & ~t1) | t2", [10, 20, 30])
    m = [np.array([1, 1, 0, 0], bool), np.array([0, 1, 0, 1], bool),
         np.array([0, 0, 1, 0], bool)]
    out = tex.evaluate(m)
    assert np.array_equal(out, np.array([1, 0, 1, 0], bool))
    with pytest.raises(ValueError):
        TimeExpression.parse("t0 &", [1])
    with pytest.raises(ValueError):
        TimeExpression.parse("t5", [1, 2])
