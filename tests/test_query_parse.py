"""attr_options parsing (Table 1) and TimeExpression parsing."""
import numpy as np
import pytest

from repro.core.events import GraphHistoryBuilder
from repro.core.query import TimeExpression, parse_attr_options


def make_universe():
    b = GraphHistoryBuilder()
    b.add_node(0, 1, attrs={"name": "x", "salary": 10.0, "age": 3.0})
    b.add_node(1, 1)
    b.add_edge(0, 1, 2, attrs={"weight": 1.0, "label": "e"})
    return b.finalize()[0]


def test_default_no_attrs():
    uni = make_universe()
    o = parse_attr_options("", uni)
    assert not o.wants_attrs


def test_table1_semantics():
    uni = make_universe()
    o = parse_attr_options("+node:all", uni)
    assert set(o.node_cols) == {0, 1, 2}
    o = parse_attr_options("+node:all-node:salary+edge:weight", uni)
    assert uni.attr_col("node", "salary") not in o.node_cols
    assert len(o.node_cols) == 2
    assert o.edge_cols == (uni.attr_col("edge", "weight"),)
    # specific attr overrides -node:all default
    o = parse_attr_options("+node:age", uni)
    assert o.node_cols == (uni.attr_col("node", "age"),)


def test_parse_errors():
    uni = make_universe()
    with pytest.raises(KeyError):
        parse_attr_options("+node:nonexistent", uni)
    with pytest.raises(ValueError):
        parse_attr_options("node:all", uni)


def test_time_expression_parse_and_eval():
    tex = TimeExpression.parse("(t0 & ~t1) | t2", [10, 20, 30])
    m = [np.array([1, 1, 0, 0], bool), np.array([0, 1, 0, 1], bool),
         np.array([0, 0, 1, 0], bool)]
    out = tex.evaluate(m)
    assert np.array_equal(out, np.array([1, 0, 1, 0], bool))
    with pytest.raises(ValueError):
        TimeExpression.parse("t0 &", [1])
    with pytest.raises(ValueError):
        TimeExpression.parse("t5", [1, 2])


# ---------------------------------------------------------------------------
# property-based round-trip: random expression trees -> infix -> parse
# ---------------------------------------------------------------------------

N_TIMES = 4


def _random_tree(rng: np.random.Generator, depth: int) -> tuple:
    r = rng.random()
    if depth <= 0 or r < 0.3:
        return ("t", int(rng.integers(0, N_TIMES)))
    if r < 0.45:
        return ("not", _random_tree(rng, depth - 1))
    op = "and" if r < 0.75 else "or"
    return (op, _random_tree(rng, depth - 1), _random_tree(rng, depth - 1))


def _check_roundtrip(seed: int) -> None:
    rng = np.random.default_rng(seed)
    times = list(range(10, 10 * (N_TIMES + 1), 10))
    tex = TimeExpression(times, _random_tree(rng, int(rng.integers(1, 6))))
    text = tex.to_infix()
    back = TimeExpression.parse(text, times)
    # exact tree equality: to_infix emits minimal parens matching the
    # grammar's associativity, so the parse must reproduce the tree...
    assert back.expr == tex.expr, (seed, text)
    # ...and therefore evaluate identically on random masks
    masks = [rng.random(8) < 0.5 for _ in range(N_TIMES)]
    assert np.array_equal(back.evaluate(masks), tex.evaluate(masks)), text


def test_time_expression_roundtrip_seeded():
    for seed in range(150):
        _check_roundtrip(seed)


def test_time_expression_precedence():
    # ~ binds tighter than &, & tighter than |; both left-associative
    tex = TimeExpression.parse("t0 | t1 & ~t2 | t3", [1, 2, 3, 4])
    assert tex.expr == ("or", ("or", ("t", 0),
                               ("and", ("t", 1), ("not", ("t", 2)))),
                        ("t", 3))
    assert TimeExpression.parse("t0 & t1 & t2", [1, 2, 3]).expr == \
        ("and", ("and", ("t", 0), ("t", 1)), ("t", 2))
    # round-trip keeps minimal parens but full fidelity
    assert TimeExpression.parse(tex.to_infix(), tex.times).expr == tex.expr


def _mutate(rng: np.random.Generator, text: str) -> str:
    ops = ["drop", "dup", "insert", "paren"]
    kind = ops[int(rng.integers(0, len(ops)))]
    if not text:
        return "&"
    i = int(rng.integers(0, len(text)))
    if kind == "drop":
        return text[:i] + text[i + 1:]
    if kind == "dup":
        return text[:i] + text[i] + text[i:]
    if kind == "insert":
        return text[:i] + rng.choice(list("&|~()#")) + text[i:]
    return "(" + text  # unbalanced paren


def test_time_expression_malformed_inputs():
    """Random mutations of valid expressions either reparse to *some*
    valid tree or raise ValueError — never crash differently or hang."""
    times = list(range(10, 10 * (N_TIMES + 1), 10))
    rng = np.random.default_rng(0)
    rejected = 0
    for seed in range(120):
        tex = TimeExpression(times, _random_tree(rng, 3))
        bad = _mutate(rng, tex.to_infix())
        try:
            TimeExpression.parse(bad, times)
        except ValueError:
            rejected += 1
    assert rejected > 20  # mutations must actually exercise the error paths
    for text in ["", "t0 &", "& t0", "(t0", "t0)", "t0 t1", "~", "t9",
                 "t0 || t1", "()", "x0 & t1"]:
        with pytest.raises(ValueError):
            TimeExpression.parse(text, times)


# -- optional generative pass (hypothesis) ----------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(seed=st.integers(0, 10**9))
    def test_time_expression_roundtrip_hypothesis(seed):
        _check_roundtrip(seed)
