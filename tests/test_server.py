"""Concurrent SLO-aware query server (launch/server.py + api/scheduler.py).

Covers the serving tentpole end to end over real sockets:

* cross-client co-batching — co-plannable documents from different
  connections merge into one Steiner plan (``merged_docs`` stats);
* the correlation-id cross-wiring oracle — under concurrent sessions
  every envelope answers exactly the request of its session, in order,
  bit-identical (CRCs) to a direct single-client execution;
* deadline admission — typed ``deadline`` envelopes rejected *before*
  execution, consuming zero KV gets;
* overload admission control — typed ``overloaded`` envelopes once the
  queue's estimated drain time exceeds the horizon;
* GraphPool leases — grant / release control frames / per-session byte
  budgets with ``backpressure`` envelopes / auto-reclaim on disconnect;
* the stdin fallback sharing the SessionCore code path with the socket
  server (differential envelope comparison).
"""
from __future__ import annotations

import json
import socket
import threading

import numpy as np
import pytest

from repro.api.document import Q
from repro.api.scheduler import BatchingScheduler
from repro.core.manager import GraphManager
from repro.data.generators import churn_network
from repro.launch.server import QueryServer


@pytest.fixture(scope="module")
def history():
    return churn_network(n_initial_edges=100, n_events=2000, seed=7)


@pytest.fixture()
def gm(history):
    uni, ev = history
    g = GraphManager(uni, ev, L=64, k=2, diff_fn="intersection")
    yield g
    g.close()


class Client:
    """One NDJSON session over a real socket."""

    def __init__(self, srv: QueryServer) -> None:
        self.sock = socket.create_connection((srv.host, srv.port))
        self.f = self.sock.makefile("rw", encoding="utf-8", newline="\n")

    def send(self, obj) -> None:
        self.f.write((obj if isinstance(obj, str) else json.dumps(obj))
                     + "\n")
        self.f.flush()

    def recv(self) -> dict:
        line = self.f.readline()
        assert line, "server closed the connection"
        return json.loads(line)

    def rpc(self, obj) -> dict:
        self.send(obj)
        return self.recv()

    def close(self) -> None:
        # the makefile wrapper holds its own reference to the fd — both
        # must close for the server to see EOF
        for h in (self.f, self.sock):
            try:
                h.close()
            except OSError:
                pass


# ---------------------------------------------------------------- co-batching


def test_cobatch_across_clients(gm):
    """Snapshots arriving from different connections inside one window
    must share a merged plan — and every envelope must go back to the
    session (and slot) that asked for it."""
    with QueryServer(gm, window_ms=25.0, workers=2) as srv:
        results: dict[int, list[dict]] = {}
        barrier = threading.Barrier(4)

        def run(cid: int) -> None:
            c = Client(srv)
            docs = [{"kind": "snapshot", "t": 100 + 50 * i,
                     "id": f"c{cid}-{i}"} for i in range(3)]
            barrier.wait()
            for d in docs:
                c.send(d)
            results[cid] = [c.recv() for _ in docs]
            c.close()

        ths = [threading.Thread(target=run, args=(cid,)) for cid in range(4)]
        for t in ths:
            t.start()
        for t in ths:
            t.join(timeout=30)
        stats = srv.scheduler.snapshot_stats()

    for cid, envs in results.items():
        assert [e["id"] for e in envs] == [f"c{cid}-{i}" for i in range(3)]
        assert all(e["ok"] for e in envs)
    # all 12 share one co-batching key; with a generous window at least
    # one dispatch wave must have merged documents from >1 client
    assert stats["co_batched_docs"] > 3
    assert stats["max_group"] > 3
    merged = [e["stats"].get("merged_docs", 1)
              for envs in results.values() for e in envs]
    assert max(merged) > 3


def test_envelopes_bit_identical_to_direct_execution(gm):
    """The cross-wiring oracle: concurrent served envelopes carry the
    same CRCs as a direct single-client run of the same documents."""
    times = [60, 120, 180, 240, 300, 360]
    direct = {t: gm.query.run(Q.at(t).build()).to_dict()["result"]
              for t in times}
    with QueryServer(gm, window_ms=10.0, workers=3) as srv:
        out: dict[int, list[dict]] = {}

        def run(cid: int) -> None:
            c = Client(srv)
            mine = list(np.roll(times, cid))
            for i, t in enumerate(mine):
                c.send({"kind": "snapshot", "t": int(t),
                        "id": f"{cid}:{i}:{t}"})
            out[cid] = [c.recv() for _ in mine]
            c.close()

        ths = [threading.Thread(target=run, args=(cid,)) for cid in range(5)]
        for t in ths:
            t.start()
        for t in ths:
            t.join(timeout=30)

    for cid, envs in out.items():
        for i, env in enumerate(envs):
            assert env["ok"], env
            _, slot, t = env["id"].split(":")
            assert int(slot) == i            # session order preserved
            want = direct[int(t)]
            got = env["result"]
            assert (got["node_crc"], got["edge_crc"], got["nodes"],
                    got["edges"]) == (want["node_crc"], want["edge_crc"],
                                      want["nodes"], want["edges"])


# -------------------------------------------------------------------- deadlines


def test_deadline_expired_rejected_with_zero_kv_gets(gm):
    with QueryServer(gm, window_ms=1.0, workers=1) as srv:
        c = Client(srv)
        g0 = gm.store.stats.gets
        env = c.rpc({"kind": "snapshot", "t": 500,
                     "deadline_ms": 0.0001, "id": "dl"})
        assert env["ok"] is False
        assert env["error"]["kind"] == "deadline"
        assert env["id"] == "dl"
        assert gm.store.stats.gets == g0       # rejected before execution
        assert srv.scheduler.counters["shed_deadline"] == 1
        # the session is still healthy
        assert c.rpc({"kind": "snapshot", "t": 500})["ok"]
        c.close()


def test_deadline_cost_model_rejection_no_kv_gets(gm):
    """A request whose *estimated* execution time (planner cost / learned
    rate) exceeds its budget is rejected without running — the planner
    pass is pure index work."""
    with QueryServer(gm, window_ms=1.0, workers=1,
                     admit_horizon_ms=0.0) as srv:
        # cripple the learned execution rate so any plan looks too slow
        # (admission shedding is off so only the deadline check fires)
        srv.scheduler.cost_rate.value = 1.0   # 1 cost-unit per second
        c = Client(srv)
        g0 = gm.store.stats.gets
        env = c.rpc({"kind": "snapshot", "t": 700, "deadline_ms": 50.0})
        assert env["ok"] is False
        assert env["error"]["kind"] == "deadline"
        assert "plan cost" in env["error"]["message"]
        assert gm.store.stats.gets == g0
        c.close()


# ------------------------------------------------------------------- admission


def test_admission_control_sheds_overload(gm):
    """With a 0.7ms drain horizon and prior cost estimates, the queue
    admits ~3 one-point documents and sheds the rest with typed
    ``overloaded`` envelopes."""
    sched = BatchingScheduler(gm.query, window_ms=500.0, workers=1,
                              admit_horizon_ms=0.7)
    try:
        futs = [sched.submit(Q.at(100 + i).build()) for i in range(10)]
        results = [f.result(timeout=30) for f in futs]
    finally:
        sched.close()
    shed = [r for r in results if not r.ok]
    okd = [r for r in results if r.ok]
    assert okd and shed
    assert all(r.error.code == "overloaded" for r in shed)
    assert sched.counters["shed_overload"] == len(shed)
    # queue position decides: earliest submissions are the admitted ones
    assert all(r.ok for r in results[:len(okd)])


def test_submit_after_close_resolves_overloaded(gm):
    sched = BatchingScheduler(gm.query, window_ms=1.0, workers=1)
    sched.close()
    res = sched.submit(Q.at(50).build()).result(timeout=5)
    assert res.ok is False and res.error.code == "overloaded"


# ---------------------------------------------------------------------- leases


def test_lease_grant_release_and_backpressure(gm):
    n0 = gm.pool.num_active()
    with QueryServer(gm, window_ms=1.0, workers=1,
                     session_lease_mb=0.0001,
                     backpressure_grace_s=0.01) as srv:
        c = Client(srv)
        grant = c.rpc({"kind": "snapshot", "t": 150, "reply": "lease",
                       "id": "L1"})
        assert grant["ok"] and grant["id"] == "L1"
        gids = [int(g) for g in grant["result"]["lease"]]
        assert len(gids) == 1
        assert gm.pool.num_active() == n0 + 1
        # over the (tiny) session budget now: queries shed, reads go on
        bp = c.rpc({"kind": "snapshot", "t": 150, "id": "q"})
        assert bp["ok"] is False
        assert bp["error"]["kind"] == "backpressure"
        assert bp["id"] == "q"
        # a release control frame always gets through
        ack = c.rpc({"release": gids, "id": "R"})
        assert ack["ok"] and ack["released"] == gids and ack["held"] == 0
        assert ack["id"] == "R"
        assert gm.pool.num_active() == n0
        # and the session recovers
        assert c.rpc({"kind": "snapshot", "t": 150})["ok"]
        c.close()


def test_multipoint_lease_and_release_all(gm):
    n0 = gm.pool.num_active()
    with QueryServer(gm, window_ms=1.0, workers=1) as srv:
        c = Client(srv)
        grant = c.rpc({"kind": "multipoint", "times": [100, 200, 300],
                       "reply": "lease"})
        assert grant["ok"]
        lease = grant["result"]["lease"]
        assert len(lease) == 3
        assert sorted(int(v["t"]) for v in lease.values()) == [100, 200, 300]
        assert gm.pool.num_active() == n0 + 3
        ack = c.rpc({"release": "all"})
        assert ack["held"] == 0 and len(ack["released"]) == 3
        assert gm.pool.num_active() == n0
        c.close()


def test_disconnect_auto_reclaims_leases(gm):
    import time

    n0 = gm.pool.num_active()
    with QueryServer(gm, window_ms=1.0, workers=1) as srv:
        c = Client(srv)
        grant = c.rpc({"kind": "snapshot", "t": 222, "reply": "lease"})
        assert grant["ok"]
        assert gm.pool.num_active() == n0 + 1
        c.close()                       # no release frame
        deadline = time.monotonic() + 10
        while gm.pool.num_active() != n0 and time.monotonic() < deadline:
            time.sleep(0.02)
    assert gm.pool.num_active() == n0


# ------------------------------------------------------------------- wire edge


def test_malformed_lines_do_not_poison_the_session(gm):
    with QueryServer(gm, window_ms=1.0, workers=1) as srv:
        c = Client(srv)
        env = c.rpc("this is not json")
        assert env["ok"] is False and env["error"]["kind"] == "document"
        env = c.rpc({"kind": "snapshot", "t": 50, "bogus_field": 1,
                     "id": 42})
        assert env["ok"] is False and env["error"]["kind"] == "document"
        assert env["id"] == 42          # id salvaged from the raw line
        env = c.rpc({"release": [999], "id": "r"})
        assert env["ok"] and env["released"] == [] and env["unknown"] == [999]
        assert c.rpc({"kind": "snapshot", "t": 50})["ok"]
        c.close()


def test_stdin_fallback_matches_socket_envelopes(gm):
    """Satellite 6: the stdin wire loop and the socket server share one
    SessionCore path — same documents, same result payloads."""
    from repro.launch.serve import run_query_documents

    docs = [{"kind": "snapshot", "t": 80, "id": "a"},
            "garbage",
            {"kind": "multipoint", "times": [80, 160]},
            {"kind": "interval", "ts": 10, "te": 400}]
    lines = [(d if isinstance(d, str) else json.dumps(d)) for d in docs]
    stdin_envs = [json.loads(s)
                  for s in run_query_documents(gm, lines, batch=4)]
    with QueryServer(gm, window_ms=5.0, workers=1) as srv:
        c = Client(srv)
        for ln in lines:
            c.send(ln)
        sock_envs = [c.recv() for _ in lines]
        c.close()
    for a, b in zip(stdin_envs, sock_envs):
        assert a["ok"] == b["ok"]
        if a["ok"]:
            assert a["result"] == b["result"]
        else:
            assert a["error"]["kind"] == b["error"]["kind"]


def test_server_stats_surface(gm):
    with QueryServer(gm, window_ms=1.0, workers=1) as srv:
        c = Client(srv)
        assert c.rpc({"kind": "snapshot", "t": 90})["ok"]
        st = srv.stats()
        assert st["sessions_live"] == 1
        assert st["scheduler"]["executed"] >= 1
        c.close()
