"""Extensibility (§4.7): auxiliary indexes validated against brute force."""
import collections

import numpy as np

from repro.core import GraphManager, replay
from repro.core.auxiliary import (AuxHistoryIndex, DegreeHistogramIndex,
                                  LabelPathIndex)
from repro.data.generators import churn_network
from repro.graph.csr import build_csr


def setup():
    uni, ev = churn_network(n_initial_edges=40, n_events=200, seed=3,
                            p_attr_update=0.0, p_transient=0.0)
    gm = GraphManager(uni, ev, L=32, k=2)
    return uni, ev, gm


def test_degree_histogram_index():
    uni, ev, gm = setup()
    ai = AuxHistoryIndex(DegreeHistogramIndex(), gm.dg, ev)
    for t in (int(ev.time[50]), int(ev.time[150]), int(ev.time[-1])):
        snap = ai.snapshot_at(t)
        truth = replay(uni, ev, t)
        deg = np.zeros(uni.num_nodes, np.int64)
        eidx = np.nonzero(truth.edge_mask)[0]
        np.add.at(deg, uni.edge_src[eidx], 1)
        np.add.at(deg, uni.edge_dst[eidx], 1)
        exp = collections.Counter(int(d) for d in deg[deg > 0])
        got = {int(k[3:]): v for k, v in snap.items()}
        assert got == dict(exp), t


def test_label_path_index_matches_bruteforce():
    uni, ev, gm = setup()
    labels = (["A", "B"] * (uni.num_nodes // 2 + 1))[: uni.num_nodes]
    ai = AuxHistoryIndex(LabelPathIndex(labels, plen=3), gm.dg, ev)
    for t in (int(ev.time[60]), int(ev.time[-1])):
        snap = ai.snapshot_at(t)
        truth = replay(uni, ev, t)
        csr = build_csr(uni.edge_src, uni.edge_dst, uni.num_nodes,
                        truth.edge_mask, uni.edge_directed)
        cnt = collections.Counter()
        for a in range(uni.num_nodes):
            for b in csr.neighbors(a):
                for c in csr.neighbors(int(b)):
                    if c != a:
                        cnt["|".join(labels[x] for x in (a, int(b), int(c)))] += 1
        assert dict(snap) == dict(cnt), t


def test_whole_history_query():
    uni, ev, gm = setup()
    ai = AuxHistoryIndex(DegreeHistogramIndex(), gm.dg, ev)
    # deg1 fluctuates — "present throughout history" must mean every leaf
    present_all = ai.query_whole_history("deg1")
    per_leaf = all("deg1" in s for s in ai._leaf_snaps)
    assert present_all == per_leaf


def test_aux_snapshots_persist_through_codec():
    """Aux leaf snapshots ride the codec-compressed blob path: save into
    the graph's own KV store, reload, and serve identical snapshots."""
    from repro.core.auxiliary import AuxHistoryIndex

    uni, ev, gm = setup()
    ai = AuxHistoryIndex(DegreeHistogramIndex(), gm.dg, ev)
    nbytes = ai.save()
    assert nbytes > 0
    assert (0, AuxHistoryIndex._AUX_PID, "aux.deghist") in gm.dg.store
    snaps = AuxHistoryIndex.load_snaps(gm.dg.store, "deghist")
    assert snaps == ai._leaf_snaps
