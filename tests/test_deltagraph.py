"""DeltaGraph end-to-end correctness vs the brute-force oracle —
every differential function, arity, partitioning, materialization,
incremental maintenance, multipoint plans, intervals, TimeExpressions."""
import numpy as np
import pytest

from conftest import assert_state_equal
from repro.core import GraphManager, replay
from repro.core.deltagraph import DeltaGraph
from repro.core.events import (EV_NEW_EDGE, EV_NEW_NODE, EV_TRANS_EDGE)
from repro.core.query import NO_ATTRS, TimeExpression, parse_attr_options
from repro.data.generators import churn_network, growing_network

RNG = np.random.default_rng(7)


def check_times(gm, uni, ev, n=6, opts_str="+node:all+edge:all"):
    opts = parse_attr_options(opts_str, uni)
    tmax = int(ev.time[-1])
    for t in [-5, 0, tmax, tmax + 10] + [int(x) for x in
                                         RNG.integers(0, tmax, n)]:
        truth = replay(uni, ev, t)
        got = gm.dg.get_snapshot(t, opts, pool=gm.pool)
        assert_state_equal(got, truth, opts.wants_attrs, msg=f"t={t}")


@pytest.mark.parametrize("diff,params", [
    ("intersection", {}), ("union", {}), ("empty", {}), ("balanced", {}),
    ("mixed", dict(r1=.8, r2=.3)), ("skewed", dict(r=.7)),
    ("right_skewed", dict(r=.5)), ("left_skewed", dict(r=.5)),
])
def test_diff_functions(diff, params):
    uni, ev = churn_network(n_initial_edges=120, n_events=800, seed=3)
    gm = GraphManager(uni, ev, L=64, k=2, diff_fn=diff, diff_params=params)
    check_times(gm, uni, ev, n=4)


@pytest.mark.parametrize("k", [2, 3, 5])
@pytest.mark.parametrize("P", [1, 4])
def test_arity_and_partitions(k, P):
    uni, ev = churn_network(n_initial_edges=100, n_events=600, seed=5)
    gm = GraphManager(uni, ev, L=50, k=k, num_partitions=P)
    check_times(gm, uni, ev, n=3)


def test_mod_hash_partitioner():
    uni, ev = churn_network(n_initial_edges=100, n_events=500, seed=9)
    gm = GraphManager(uni, ev, L=50, k=3, num_partitions=3,
                      partition_fn="mod_hash")
    check_times(gm, uni, ev, n=3)


def test_structure_only_and_columnar(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=3)
    check_times(gm, uni, ev, n=3, opts_str="")
    # per-column retrieval fetches exactly that column
    opts = parse_attr_options("+node:attr1", uni)
    t = int(ev.time[len(ev) // 2])
    truth = replay(uni, ev, t)
    got = gm.dg.get_snapshot(t, opts, pool=gm.pool)
    c1 = uni.attr_col("node", "attr1")
    tv = np.where(truth.node_mask, truth.node_attrs[:, c1], np.nan)
    gv = np.where(got.node_mask, got.node_attrs[:, c1], np.nan)
    assert np.array_equal(tv, gv, equal_nan=True)


def test_columnar_fetches_fewer_bytes(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=3)
    t = int(ev.time[700])
    gm.store.stats.reset()
    gm.dg.get_snapshot(t, NO_ATTRS, pool=gm.pool)
    struct_bytes = gm.store.stats.bytes_read
    gm.store.stats.reset()
    gm.dg.get_snapshot(t, parse_attr_options("+node:all+edge:all", uni),
                       pool=gm.pool)
    all_bytes = gm.store.stats.bytes_read
    assert struct_bytes < all_bytes


def test_multipoint_matches_singlepoint(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=64, k=2)
    times = [int(ev.time[i]) for i in (50, 300, 301, 600, 900, 1100)]
    opts = parse_attr_options("+node:all+edge:all", uni)
    multi = gm.dg.get_snapshots(times, opts, pool=gm.pool)
    for t in times:
        truth = replay(uni, ev, t)
        assert_state_equal(multi[t], truth, msg=f"multi t={t}")


def test_multipoint_cheaper_than_singlepoints(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=50, k=2)
    times = [int(t) for t in np.linspace(ev.time[10], ev.time[-10], 12)]
    plan_m = gm.dg.plan_multipoint(times, NO_ATTRS)
    total_single = sum(gm.dg.plan_singlepoint(t, NO_ATTRS).total_weight
                       for t in times)
    assert plan_m.total_weight < total_single


def test_materialization(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=64, k=2)
    w_before = gm.dg.plan_singlepoint(int(ev.time[100]), NO_ATTRS).total_weight
    gm.materialize_roots(depth=2)
    w_after = gm.dg.plan_singlepoint(int(ev.time[100]), NO_ATTRS).total_weight
    assert w_after < w_before  # zero-weight shortcut is used
    check_times(gm, uni, ev, n=4)


def test_total_materialization(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=64, k=2)
    gm.total_materialization()
    check_times(gm, uni, ev, n=4)


def test_materialize_with_attrs_is_source(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=64, k=2)
    opts = parse_attr_options("+node:all+edge:all", uni)
    root = gm.dg.root_nids()[0]
    gm.dg.materialize(root, gm.pool, opts)
    check_times(gm, uni, ev, n=4)


def test_unmaterialize(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=64, k=2)
    root = gm.dg.root_nids()[0]
    gm.dg.materialize(root, gm.pool)
    gm.dg.unmaterialize(root, gm.pool)
    assert gm.dg.nodes[root].materialized_as is None
    check_times(gm, uni, ev, n=3)


def test_incremental_appends():
    uni, ev = churn_network(n_initial_edges=100, n_events=1000, seed=17)
    half = len(ev) // 2
    gm = GraphManager(uni, ev[:half], L=64, k=3)
    for i in range(half, len(ev), 37):
        gm.update(ev[i:i + 37])
    check_times(gm, uni, ev, n=5)


def test_current_graph_used_for_recent_queries():
    uni, ev = churn_network(n_initial_edges=100, n_events=600, seed=19)
    gm = GraphManager(uni, ev, L=100, k=2)
    t = int(ev.time[-1])
    plan = gm.dg.plan_singlepoint(t, NO_ATTRS, use_current=True)
    assert plan.steps[0].action[0] == "current"


def test_interval_and_transients():
    uni, ev = churn_network(n_initial_edges=80, n_events=600, seed=19,
                            p_transient=0.1)
    gm = GraphManager(uni, ev, L=50, k=2)
    ts, te = int(ev.time[100]), int(ev.time[450])
    res = gm.get_hist_graph_interval(ts, te)
    m = (ev.time >= ts) & (ev.time < te)
    exp_n = np.unique(ev.slot[m & (ev.etype == EV_NEW_NODE)]).astype(np.int32)
    exp_e = np.unique(ev.slot[m & (ev.etype == EV_NEW_EDGE)]).astype(np.int32)
    exp_t = ev.slot[m & (ev.etype == EV_TRANS_EDGE)]
    assert np.array_equal(res["node_added"], exp_n)
    assert np.array_equal(res["edge_added"], exp_e)
    assert np.array_equal(np.sort(res["transient_slot"]), np.sort(exp_t))


def test_time_expression():
    uni, ev = churn_network(n_initial_edges=100, n_events=500, seed=23)
    gm = GraphManager(uni, ev, L=50, k=2)
    t1, t2 = int(ev.time[150]), int(ev.time[350])
    tex = TimeExpression.parse("t0 & ~t1", [t1, t2])
    st = gm.get_hist_graph_expr(tex)
    tr1, tr2 = replay(uni, ev, t1), replay(uni, ev, t2)
    assert np.array_equal(st.edge_mask, tr1.edge_mask & ~tr2.edge_mask)
    assert np.array_equal(st.node_mask, tr1.node_mask & ~tr2.node_mask)


def test_multi_hierarchy():
    uni, ev = churn_network(n_initial_edges=100, n_events=600, seed=29)
    gm = GraphManager(uni, ev, L=50, k=2, diff_fn=["intersection", "union"])
    check_times(gm, uni, ev, n=4)


def test_skeleton_save_load():
    uni, ev = churn_network(n_initial_edges=100, n_events=600, seed=31)
    gm = GraphManager(uni, ev, L=50, k=2)
    gm.dg.save_skeleton()
    dg2 = DeltaGraph.load_skeleton(uni, gm.store)
    dg2.recent = gm.dg.recent
    dg2._last_leaf_state = gm.dg._last_leaf_state
    opts = parse_attr_options("+node:all+edge:all", uni)
    for t in (int(ev.time[100]), int(ev.time[-1])):
        truth = replay(uni, ev, t)
        got = dg2.get_snapshot(t, opts)
        assert truth.equal(got)


def test_growing_only_intersection_root_is_g0(growing):
    """§5.2: for a strictly growing graph the Intersection root = G_0."""
    uni, ev = growing
    gm = GraphManager(uni, ev, L=100, k=2, diff_fn="intersection")
    root = gm.dg.root_nids()[0]
    plan = gm.dg.plan_node(root, NO_ATTRS)
    st = gm.dg.execute(plan, NO_ATTRS, gm.pool)[("node", root)]
    # G_0 here is the empty graph (the trace starts from nothing)
    assert st.node_mask.sum() == 0 and st.edge_mask.sum() == 0


def test_live_update_grows_universe():
    """§6: updates that introduce NEW nodes/edges (universe growth)."""
    from repro.core.events import GraphHistoryBuilder
    b = GraphHistoryBuilder()
    for i in range(6):
        b.add_node(i, t=i)
    for i in range(5):
        b.add_edge(i, i + 1, t=10 + i, edge_id=("e", i))
    uni, ev = b.finalize()
    gm = GraphManager(uni, ev, L=4, k=2)
    upd = GraphHistoryBuilder()
    upd.universe = uni
    upd._seq = 10_000
    upd.add_node("new", 100)
    upd.add_edge("new", 0, 101, edge_id=("e", "new"))
    _, ev2 = upd.finalize()
    gm.update(ev2)
    h = gm.get_hist_graph(101)
    assert h.num_nodes() == 7 and h.num_edges() == 6
    h_old = gm.get_hist_graph(12)
    assert h_old.num_nodes() == 6 and h_old.num_edges() == 3
