"""§5 analytical models validated against measured index sizes."""
import numpy as np
import pytest

from repro.core import GraphManager, replay
from repro.core.analysis import (Rates, balanced_level_space,
                                 balanced_root_size, balanced_total_space,
                                 choose_parameters, copylog_space,
                                 estimate_rates, intersection_root_size)
from repro.data.generators import churn_network, growing_network


def test_balanced_level_space_matches_measured():
    uni, ev = growing_network(n_events=3000, seed=4, n_attrs=0)
    rates = estimate_rates(ev, g0=0)
    assert rates.delta_star == pytest.approx(1.0)
    gm = GraphManager(uni, ev, L=200, k=2, diff_fn="balanced")
    stats = gm.dg.skeleton_stats()
    meas = {l: b / 4 for l, b in
            stats["struct_bytes_per_level_nocap"].items()}
    pred = balanced_level_space(200, 2, rates)
    for lvl, m in meas.items():
        assert abs(m / pred - 1) < 0.05, (lvl, m, pred)
    # "same at every level" — the §5.3 result
    vals = list(meas.values())
    assert max(vals) / min(vals) < 1.1


def test_balanced_total_space():
    uni, ev = growing_network(n_events=3000, seed=4, n_attrs=0)
    rates = estimate_rates(ev, g0=0)
    gm = GraphManager(uni, ev, L=200, k=2, diff_fn="balanced")
    meas = sum(gm.dg.skeleton_stats()["struct_bytes_per_level_nocap"]
               .values()) / 4
    pred = balanced_total_space(200, 2, rates)
    assert abs(meas / pred - 1) < 0.10  # ragged tail tolerance


def test_balanced_root_size():
    uni, ev = growing_network(n_events=2000, seed=6, n_attrs=0)
    rates = estimate_rates(ev, g0=0)
    gm = GraphManager(uni, ev, L=128, k=2, diff_fn="balanced")
    from repro.core.query import NO_ATTRS
    root = gm.dg.root_nids()[0]
    st = gm.dg.execute(gm.dg.plan_node(root, NO_ATTRS), NO_ATTRS,
                       gm.pool)[("node", root)]
    meas = st.node_mask.sum() + st.edge_mask.sum()
    pred = balanced_root_size(rates)
    assert abs(meas / pred - 1) < 0.15


def test_intersection_root_special_cases():
    r = Rates(delta_star=0.5, rho_star=0.0, g0=1000, n_events=5000)
    assert intersection_root_size(r) == 1000  # growing-only → G_0
    r2 = Rates(delta_star=0.3, rho_star=0.3, g0=1000, n_events=5000)
    assert intersection_root_size(r2) == pytest.approx(
        1000 * np.exp(-5000 * 0.3 / 1000))
    r3 = Rates(delta_star=0.4, rho_star=0.2, g0=1000, n_events=5000)
    assert intersection_root_size(r3) == pytest.approx(
        1000 * 1000 / (1000 + 0.2 * 5000))


def test_choose_parameters():
    rates = Rates(delta_star=0.6, rho_star=0.3, g0=500, n_events=100_000)
    pick = choose_parameters(rates)
    assert pick.k >= 2 and pick.L > 0
    tight = choose_parameters(rates, space_budget_events=120_000)
    assert tight.expected_space_events <= 120_000
    with pytest.raises(ValueError):
        choose_parameters(rates, space_budget_events=1,
                          latency_budget_events=1)


def test_copylog_space_larger_than_deltagraph():
    rates = Rates(delta_star=0.9, rho_star=0.05, g0=0, n_events=50_000)
    assert copylog_space(1000, rates) > balanced_total_space(1000, 2, rates)
