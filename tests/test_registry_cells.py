"""Registry coverage: all 40 (arch × shape) cells are well-defined, their
abstract args / pspec trees are structurally consistent, and the skip
policy matches DESIGN.md §4."""
import numpy as np
import pytest

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.registry import (ARCH_IDS, get_cell, list_cells,
                                    shapes_for)


@pytest.fixture(scope="module")
def mesh():
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_cell_count():
    cells = list_cells()
    assert len(cells) == 40
    assert len({a for a, _ in cells}) == 10


def test_all_cells_defined(mesh):
    skips = 0
    for arch, shape in list_cells():
        cell = get_cell(arch, shape, mesh, multi_pod=False)
        if cell.skip_reason:
            skips += 1
            assert shape == "long_500k"
            continue
        assert cell.fn is not None
        # args and pspecs trees must match leaf-for-leaf
        a_leaves = jax.tree.leaves(cell.args)
        s_leaves = jax.tree.leaves(
            cell.pspecs, is_leaf=lambda x: isinstance(x, P) or x is None)
        assert len(a_leaves) == len(s_leaves), (arch, shape)
        assert cell.flops_model > 0
    assert skips == 3  # yi, stablelm, arctic long_500k


def test_moe_active_params():
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    c = get_cell("deepseek-v3-671b", "train_4k", mesh, False)
    assert 600e9 < c.n_params < 750e9      # ≈671B total
    assert 30e9 < c.n_params_active < 45e9  # ≈37B active
    c2 = get_cell("yi-34b", "train_4k", mesh, False)
    assert 30e9 < c2.n_params < 40e9
    assert c2.n_params == c2.n_params_active


def test_param_count_sanity():
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    expected = {"stablelm-12b": (11e9, 14e9), "gemma3-1b": (0.7e9, 1.4e9),
                "arctic-480b": (420e9, 520e9)}
    for arch, (lo, hi) in expected.items():
        c = get_cell(arch, "train_4k", mesh, False)
        assert lo < c.n_params < hi, (arch, c.n_params)
