"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref oracle."""
import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax.numpy as jnp

from repro.kernels import attention, bucket_edges, delta_apply_chain, segment_sum
from repro.kernels.delta_apply.delta_apply import delta_apply_chain_pallas
from repro.kernels.flash_attention.ref import attention_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("W", [1, 7, 128, 1000])
@pytest.mark.parametrize("K", [0, 1, 3, 6])
def test_delta_apply_sweep(W, K):
    base = RNG.integers(0, 2 ** 32, W, dtype=np.uint32)
    adds = RNG.integers(0, 2 ** 32, (K, W), dtype=np.uint32)
    dels = RNG.integers(0, 2 ** 32, (K, W), dtype=np.uint32)
    ref = delta_apply_chain(jnp.array(base), jnp.array(adds), jnp.array(dels))
    got = delta_apply_chain_pallas(jnp.array(base), jnp.array(adds),
                                   jnp.array(dels), block_w=256)
    assert np.array_equal(np.asarray(ref), np.asarray(got))


@pytest.mark.parametrize("shape", [
    # (B, Hq, Hkv, Sq, Sk, D, Dv, causal, window, q_off)
    (2, 4, 2, 16, 16, 32, 32, True, None, 0),
    (1, 4, 4, 33, 33, 16, 16, True, None, 0),
    (1, 8, 1, 8, 64, 32, 32, True, None, 56),
    (2, 4, 2, 32, 32, 32, 32, True, 8, 0),
    (1, 2, 2, 16, 48, 16, 16, False, None, 0),
    (1, 4, 4, 16, 16, 24, 8, True, None, 0),   # MLA-style Dv != D
])
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_attention_sweep(shape, dtype):
    B, Hq, Hkv, Sq, Sk, D, Dv, causal, window, qoff = shape
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    q = jnp.asarray(RNG.standard_normal((B, Hq, Sq, D)), dt)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, Sk, D)), dt)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, Sk, Dv)), dt)
    ref = np.asarray(attention_ref(q, k, v, causal=causal, window=window,
                                   q_offset=qoff), np.float32)
    tol = 2e-2 if dtype == "bfloat16" else 3e-5
    for impl in ("xla", "pallas"):
        got = np.asarray(attention(q, k, v, causal=causal, window=window,
                                   q_offset=qoff, impl=impl, block_k=16),
                         np.float32)
        assert_allclose(got, ref, rtol=tol, atol=tol, err_msg=f"{impl}")


@pytest.mark.parametrize("E,N,D,bn", [(100, 37, 8, 16), (1000, 200, 16, 128),
                                      (5, 3, 4, 8), (64, 64, 1, 8)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_segment_sum_sweep(E, N, D, bn, dtype):
    ids = RNG.integers(0, N, E)
    data = jnp.asarray(RNG.standard_normal((E, D)), dtype)
    ref = segment_sum(data, ids, N, impl="xla")
    got = segment_sum(data, ids, N, impl="pallas", block_n=bn)
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_segment_sum_precomputed_buckets():
    E, N, D, bn = 200, 50, 8, 16
    ids = RNG.integers(0, N, E)
    buckets = bucket_edges(ids, N, bn)
    data = jnp.asarray(RNG.standard_normal((E, D)), np.float32)
    ref = segment_sum(data, ids, N, impl="xla")
    got = segment_sum(data, ids, N, impl="pallas", block_n=bn,
                      buckets=buckets)
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5)


def test_attention_decode_equals_prefill_row():
    """Decode (Sq=1, q_offset=i) must equal row i of the full attention."""
    B, H, S, D = 1, 2, 24, 16
    q = jnp.asarray(RNG.standard_normal((B, H, S, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, H, S, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, H, S, D)), jnp.float32)
    full = np.asarray(attention(q, k, v, causal=True, impl="xla"))
    for i in (0, 7, 23):
        row = np.asarray(attention(q[:, :, i:i + 1], k, v, causal=True,
                                   q_offset=i, impl="xla"))
        assert_allclose(row[:, :, 0], full[:, :, i], rtol=1e-5, atol=1e-5)
