"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref oracle."""
import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax.numpy as jnp

from repro.kernels import attention, bucket_edges, delta_apply_chain, segment_sum
from repro.kernels.delta_apply.delta_apply import delta_apply_chain_pallas
from repro.kernels.flash_attention.ref import attention_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("W", [1, 7, 128, 1000])
@pytest.mark.parametrize("K", [0, 1, 3, 6])
def test_delta_apply_sweep(W, K):
    base = RNG.integers(0, 2 ** 32, W, dtype=np.uint32)
    adds = RNG.integers(0, 2 ** 32, (K, W), dtype=np.uint32)
    dels = RNG.integers(0, 2 ** 32, (K, W), dtype=np.uint32)
    ref = delta_apply_chain(jnp.array(base), jnp.array(adds), jnp.array(dels))
    got = delta_apply_chain_pallas(jnp.array(base), jnp.array(adds),
                                   jnp.array(dels), block_w=256)
    assert np.array_equal(np.asarray(ref), np.asarray(got))


@pytest.mark.parametrize("shape", [
    # (B, Hq, Hkv, Sq, Sk, D, Dv, causal, window, q_off)
    (2, 4, 2, 16, 16, 32, 32, True, None, 0),
    (1, 4, 4, 33, 33, 16, 16, True, None, 0),
    (1, 8, 1, 8, 64, 32, 32, True, None, 56),
    (2, 4, 2, 32, 32, 32, 32, True, 8, 0),
    (1, 2, 2, 16, 48, 16, 16, False, None, 0),
    (1, 4, 4, 16, 16, 24, 8, True, None, 0),   # MLA-style Dv != D
])
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_attention_sweep(shape, dtype):
    B, Hq, Hkv, Sq, Sk, D, Dv, causal, window, qoff = shape
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    q = jnp.asarray(RNG.standard_normal((B, Hq, Sq, D)), dt)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, Sk, D)), dt)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, Sk, Dv)), dt)
    ref = np.asarray(attention_ref(q, k, v, causal=causal, window=window,
                                   q_offset=qoff), np.float32)
    tol = 2e-2 if dtype == "bfloat16" else 3e-5
    for impl in ("xla", "pallas"):
        got = np.asarray(attention(q, k, v, causal=causal, window=window,
                                   q_offset=qoff, impl=impl, block_k=16),
                         np.float32)
        assert_allclose(got, ref, rtol=tol, atol=tol, err_msg=f"{impl}")


@pytest.mark.parametrize("E,N,D,bn", [(100, 37, 8, 16), (1000, 200, 16, 128),
                                      (5, 3, 4, 8), (64, 64, 1, 8)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_segment_sum_sweep(E, N, D, bn, dtype):
    ids = RNG.integers(0, N, E)
    data = jnp.asarray(RNG.standard_normal((E, D)), dtype)
    ref = segment_sum(data, ids, N, impl="xla")
    got = segment_sum(data, ids, N, impl="pallas", block_n=bn)
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_segment_sum_precomputed_buckets():
    E, N, D, bn = 200, 50, 8, 16
    ids = RNG.integers(0, N, E)
    buckets = bucket_edges(ids, N, bn)
    data = jnp.asarray(RNG.standard_normal((E, D)), np.float32)
    ref = segment_sum(data, ids, N, impl="xla")
    got = segment_sum(data, ids, N, impl="pallas", block_n=bn,
                      buckets=buckets)
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5)


# ---------------------------------------------------------------------------
# fused chain + analytics
# ---------------------------------------------------------------------------


def _rand_chain(W, K, with_weights=True, rng=RNG):
    base = rng.integers(0, 2 ** 32, W, dtype=np.uint32)
    adds = rng.integers(0, 2 ** 32, (K, W), dtype=np.uint32)
    dels = rng.integers(0, 2 ** 32, (K, W), dtype=np.uint32)
    w = rng.random(W * 32, dtype=np.float32) if with_weights else None
    return (jnp.asarray(base), jnp.asarray(adds), jnp.asarray(dels),
            None if w is None else jnp.asarray(w))


def _fused_oracle(base, adds, dels, w, block_w, emit_live=True):
    from repro.kernels.delta_apply.ops import _fused_pad
    from repro.kernels.delta_apply.ref import delta_apply_fused_ref
    pb, pa, pd, pw, W = _fused_pad(base, adds, dels, w, block_w)
    m, pop, accw, live = delta_apply_fused_ref(pb, pa, pd, pw,
                                               block_w=block_w,
                                               emit_live=emit_live)
    return (m[:W], pop, accw[:W],
            None if live is None else live[:W * 32])


@pytest.mark.parametrize("W,K,block_w", [
    (100, 0, 128),        # K=0: identity chain, analytics over the base
    (300, 3, 128),        # W not a multiple of block_w
    (1024, 5, 256),       # exact block multiple
    (128, 1, 128),        # single block, single delta
    (129, 2, 128),        # one word past a bucket boundary
])
@pytest.mark.parametrize("weighted", [True, False])
def test_fused_parity_bitwise(W, K, block_w, weighted):
    """Fused analytics must be *bit-identical* across pallas-interpret,
    XLA, and the padded ref oracle — including the f32 partials (fixed
    per-word reduction groups)."""
    from repro.kernels import delta_apply_fused
    base, adds, dels, w = _rand_chain(W, K, weighted)
    rm, rp, ra, rl = _fused_oracle(base, adds, dels, w, block_w)
    for impl, interp in (("pallas", True), ("xla", None)):
        out = delta_apply_fused(base, adds, dels, w, impl=impl,
                                block_w=block_w, interpret=interp)
        assert np.array_equal(np.asarray(out.mask), np.asarray(rm)), impl
        assert np.array_equal(np.asarray(out.pop), np.asarray(rp)), impl
        assert np.array_equal(np.asarray(out.accw), np.asarray(ra)), impl
        assert np.array_equal(np.asarray(out.live), np.asarray(rl)), impl


def test_fused_emit_live_off():
    from repro.kernels import delta_apply_fused
    base, adds, dels, w = _rand_chain(256, 2)
    for impl, interp in (("pallas", True), ("xla", None)):
        out = delta_apply_fused(base, adds, dels, w, impl=impl,
                                block_w=128, interpret=interp,
                                emit_live=False)
        assert out.live is None
        rm, rp, ra, _ = _fused_oracle(base, adds, dels, w, 128,
                                      emit_live=False)
        assert np.array_equal(np.asarray(out.mask), np.asarray(rm))
        assert np.array_equal(np.asarray(out.pop), np.asarray(rp))


def test_fused_matches_plain_chain_mask():
    from repro.kernels import delta_apply_chain, delta_apply_fused
    base, adds, dels, _ = _rand_chain(777, 4, False)
    plain = delta_apply_chain(base, adds, dels, impl="xla")
    out = delta_apply_fused(base, adds, dels, impl="xla")
    assert np.array_equal(np.asarray(plain), np.asarray(out.mask))
    assert int(out.live_count()) == int(
        np.unpackbits(np.asarray(plain).view(np.uint8)).sum())


def test_fused_batched_parity():
    from repro.kernels import delta_apply_fused, delta_apply_fused_batched
    rng = np.random.default_rng(5)
    B, K, W = 3, 4, 200
    bases = jnp.asarray(rng.integers(0, 2 ** 32, (B, W), dtype=np.uint32))
    adds = jnp.asarray(rng.integers(0, 2 ** 32, (B, K, W), dtype=np.uint32))
    dels = jnp.asarray(rng.integers(0, 2 ** 32, (B, K, W), dtype=np.uint32))
    w = jnp.asarray(rng.random(W * 32, dtype=np.float32))
    for impl, interp in (("pallas", True), ("xla", None)):
        out = delta_apply_fused_batched(bases, adds, dels, w, impl=impl,
                                        block_w=128, interpret=interp)
        for i in range(B):
            one = delta_apply_fused(bases[i], adds[i], dels[i], w,
                                    impl="xla", block_w=128)
            assert np.array_equal(np.asarray(out.mask[i]),
                                  np.asarray(one.mask))
            assert np.array_equal(np.asarray(out.pop[i]),
                                  np.asarray(one.pop))
            assert np.array_equal(np.asarray(out.accw[i]),
                                  np.asarray(one.accw))


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 400), st.integers(0, 6), st.integers(0, 2 ** 31))
    def test_fused_parity_hypothesis(W, K, seed):
        from repro.kernels import delta_apply_fused
        rng = np.random.default_rng(seed)
        base, adds, dels, w = _rand_chain(W, K, True, rng)
        rm, rp, ra, rl = _fused_oracle(base, adds, dels, w, 128)
        out = delta_apply_fused(base, adds, dels, w, impl="xla",
                                block_w=128)
        assert np.array_equal(np.asarray(out.mask), np.asarray(rm))
        assert np.array_equal(np.asarray(out.pop), np.asarray(rp))
        assert np.array_equal(np.asarray(out.accw), np.asarray(ra))
        assert np.array_equal(np.asarray(out.live), np.asarray(rl))
except ImportError:  # pragma: no cover
    pass


# ---------------------------------------------------------------------------
# impl/interpret policy
# ---------------------------------------------------------------------------


def test_policy_defaults_off_tpu(monkeypatch):
    from repro.kernels import policy
    monkeypatch.delenv("REPRO_KERNEL", raising=False)
    monkeypatch.delenv("REPRO_KERNEL_INTERPRET", raising=False)
    monkeypatch.setattr(policy, "backend", lambda: "cpu")
    assert policy.resolve() == ("xla", True)
    # explicit call-site values always win
    assert policy.resolve("pallas", False) == ("pallas", False)


def test_policy_defaults_on_tpu(monkeypatch):
    from repro.kernels import policy
    monkeypatch.delenv("REPRO_KERNEL", raising=False)
    monkeypatch.delenv("REPRO_KERNEL_INTERPRET", raising=False)
    monkeypatch.setattr(policy, "backend", lambda: "tpu")
    assert policy.resolve() == ("pallas", False)


def test_policy_env_override(monkeypatch):
    from repro.kernels import policy
    monkeypatch.setattr(policy, "backend", lambda: "cpu")
    monkeypatch.setenv("REPRO_KERNEL", "pallas")
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
    assert policy.resolve() == ("pallas", True)
    monkeypatch.setenv("REPRO_KERNEL", "bogus")
    with pytest.raises(ValueError):
        policy.resolve()
    with pytest.raises(ValueError):
        policy.resolve("mosaic")


def test_policy_drives_kernel_entry(monkeypatch):
    """REPRO_KERNEL steers un-annotated calls through both impls — same
    bits either way."""
    from repro.kernels import delta_apply_chain
    base, adds, dels, _ = _rand_chain(200, 3, False)
    outs = {}
    for impl in ("xla", "pallas"):
        monkeypatch.setenv("REPRO_KERNEL", impl)
        monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
        outs[impl] = np.asarray(delta_apply_chain(base, adds, dels))
    assert np.array_equal(outs["xla"], outs["pallas"])


def test_recompile_counts_bounded():
    """Shape bucketing bounds retraces: many distinct (B, K) shapes inside
    one bucket compile once per entry point."""
    from repro.kernels import delta_apply_chain, delta_apply_chain_batched
    from repro.kernels.delta_apply import ops
    rng = np.random.default_rng(9)
    W = 128          # aligned: isolates the K/B bucketing
    ops.reset_trace_counts()
    for K in (5, 6, 7, 8):          # all bucket to Kp=8
        adds = jnp.asarray(rng.integers(0, 2 ** 32, (K, W), np.uint32))
        dels = jnp.asarray(rng.integers(0, 2 ** 32, (K, W), np.uint32))
        base = jnp.asarray(rng.integers(0, 2 ** 32, W, np.uint32))
        delta_apply_chain(base, adds, dels, impl="xla")
    assert ops.trace_counts["chain"] <= 1   # may be cached from earlier runs
    ops.reset_trace_counts()
    for B, K, Wb in ((2, 3, 128), (2, 4, 128), (2, 3, 100)):  # Bp=2 Kp=4 Wp=128
        bases = jnp.asarray(rng.integers(0, 2 ** 32, (B, Wb), np.uint32))
        adds = jnp.asarray(rng.integers(0, 2 ** 32, (B, K, Wb), np.uint32))
        dels = jnp.asarray(rng.integers(0, 2 ** 32, (B, K, Wb), np.uint32))
        delta_apply_chain_batched(bases, adds, dels, impl="xla")
    assert ops.trace_counts["chain_batched"] <= 1


def test_attention_decode_equals_prefill_row():
    """Decode (Sq=1, q_offset=i) must equal row i of the full attention."""
    B, H, S, D = 1, 2, 24, 16
    q = jnp.asarray(RNG.standard_normal((B, H, S, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, H, S, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, H, S, D)), jnp.float32)
    full = np.asarray(attention(q, k, v, causal=True, impl="xla"))
    for i in (0, 7, 23):
        row = np.asarray(attention(q[:, :, i:i + 1], k, v, causal=True,
                                   q_offset=i, impl="xla"))
        assert_allclose(row[:, :, 0], full[:, :, i], rtol=1e-5, atol=1e-5)
