"""Workload-aware materialization advisor + snapshot cache
(core/materialize.py): budget, eviction under drift, cache coherence, and
the advised-equals-cold property."""
import numpy as np
import pytest

from repro.core import GraphManager, replay
from repro.core.materialize import (AdvisorConfig, MaterializationAdvisor,
                                    SnapshotCache, WorkloadStats)
from repro.core.query import NO_ATTRS, parse_attr_options
from repro.data.generators import churn_network, random_history


def _gm(n=2000, seed=3, **kw):
    uni, ev = churn_network(n_initial_edges=max(n // 12, 30), n_events=n,
                            seed=seed)
    kw.setdefault("L", max(n // 25, 32))
    kw.setdefault("k", 2)
    kw.setdefault("diff_fn", "intersection")
    return uni, ev, GraphManager(uni, ev, **kw)


# ---------------------------------------------------------------- budget

def test_budget_respected_by_meter():
    _, _, gm = _gm(cache_bytes=0)
    budget = gm.pool.memory_bytes() + (64 << 10)
    advice = gm.enable_advisor(budget_bytes=budget)
    assert gm.pool.memory_bytes() <= budget
    assert advice.pool_bytes_after <= budget
    assert len(gm.advisor.pinned) >= 1          # budget allows some pins
    # every pin is a registered materialized source
    for nid, gid in gm.advisor.pinned.items():
        assert gm.dg.nodes[nid].materialized_as == gid


def test_tiny_budget_pins_nothing_over_meter():
    _, _, gm = _gm(cache_bytes=0)
    base = gm.pool.memory_bytes()
    gm.enable_advisor(budget_bytes=base)  # no headroom at all
    assert gm.pool.memory_bytes() <= base


def test_projected_bytes_monotone():
    _, _, gm = _gm(cache_bytes=0)
    p0 = gm.pool.projected_bytes()
    assert p0 >= gm.pool.memory_bytes() - 1  # projection covers the meter
    assert gm.pool.projected_bytes(extra_bits=100) > p0
    assert gm.pool.projected_bytes(extra_attr_bytes=1 << 20) == p0 + (1 << 20)


# ------------------------------------------------------------- eviction

def test_eviction_under_drifted_workload():
    uni, ev, gm = _gm(n=3000, cache_bytes=0)
    tmax = int(ev.time[-1])
    budget = gm.pool.memory_bytes() + (32 << 10)
    gm.enable_advisor(budget_bytes=budget, replan_every=10**9)

    # phase 1: hammer the oldest tenth of history, replan
    for t in np.linspace(0, tmax // 10, 60).astype(int):
        gm.get_snapshot(int(t))
    gm.advisor.replan()
    early_pins = set(gm.advisor.pinned)
    assert early_pins

    # phase 2: workload drifts to the newest tenth; fast decay so the old
    # traffic actually fades from the histogram within the test
    gm.workload.decay = 0.9
    for t in np.linspace(9 * tmax // 10, tmax, 200).astype(int):
        gm.get_snapshot(int(t))
    gm.advisor.replan()
    late_pins = set(gm.advisor.pinned)
    assert late_pins != early_pins, "drifted workload must change the pin set"
    dropped = early_pins - late_pins
    assert dropped, "stale pins must be evicted (explicitly or by the " \
                    "on_query drift hook)"
    for nid in dropped:
        assert gm.dg.nodes[nid].materialized_as is None
    assert gm.pool.memory_bytes() <= budget


def test_on_query_replans_on_drift():
    uni, ev, gm = _gm(n=3000, cache_bytes=0)
    tmax = int(ev.time[-1])
    gm.enable_advisor(budget_bytes=gm.pool.memory_bytes() + (32 << 10),
                      replan_every=10**9, drift_threshold=0.2)
    for t in np.linspace(0, tmax // 10, 40).astype(int):
        gm.get_snapshot(int(t))
    gm.advisor.replan()
    plan_hist = dict(gm.advisor._hist_at_plan)
    for t in np.linspace(9 * tmax // 10, tmax, 120).astype(int):
        gm.get_snapshot(int(t))
    # drift hook fired at least once: the snapshot taken at plan time moved
    assert gm.advisor._hist_at_plan != plan_hist


# ---------------------------------------------------------------- cache

def test_cache_hit_bit_identical():
    uni, ev, gm = _gm()
    t = int(ev.time[len(ev) // 2])
    s1 = gm.get_snapshot(t, "+node:all+edge:all")
    assert gm.cache.misses == 1 and gm.cache.hits == 0
    s2 = gm.get_snapshot(t, "+node:all+edge:all")
    assert gm.cache.hits == 1
    assert s1.equal(s2)
    assert np.array_equal(s1.node_mask, s2.node_mask)
    # hit result equals a cache-disabled manager's cold result
    _, _, cold = _gm(cache_bytes=0)
    s3 = cold.get_snapshot(t, "+node:all+edge:all")
    assert cold.cache is None
    assert s1.equal(s3)


def test_cache_key_separates_options_and_current():
    uni, ev, gm = _gm()
    t = int(ev.time[len(ev) // 2])
    gm.get_snapshot(t)                           # NO_ATTRS
    gm.get_snapshot(t, "+node:all")              # different columns
    gm.get_snapshot(t, use_current=False)        # different path space
    assert gm.cache.hits == 0 and gm.cache.misses == 3


def test_cache_eviction_bounds():
    cache = SnapshotCache(max_bytes=1 << 30, max_entries=4)
    uni, ev, gm = _gm(cache_bytes=0)
    times = [int(t) for t in np.linspace(0, int(ev.time[-1]), 10)]
    for t in times:
        st = gm.dg.get_snapshot(t, pool=gm.pool)
        cache.put(SnapshotCache.key(t, NO_ATTRS, True), st)
    assert len(cache) <= 4


def test_cache_invalidated_by_live_update():
    uni, ev = churn_network(n_initial_edges=100, n_events=1500, seed=5)
    cut = len(ev) - 120
    gm = GraphManager(uni, ev[:cut], L=64, k=2)
    tmax = int(ev.time[cut - 1])
    before = gm.get_snapshot(tmax)               # cached, crosses CURRENT
    assert len(gm.cache) == 1
    gm.update(ev[cut:])                          # live append (§6)
    after = gm.get_snapshot(tmax)
    truth = replay(uni, ev, tmax)
    assert np.array_equal(after.node_mask, truth.node_mask)
    assert np.array_equal(after.edge_mask, truth.edge_mask)


def test_cache_invalidation_is_scoped_to_affected_times():
    """Regression pin: an append at time tmin must drop only cached
    entries at ``t >= tmin`` — results strictly before the append are
    unaffected and must survive as hits (the old coarse rule dropped
    every CURRENT-crossing entry on any update)."""
    uni, ev = churn_network(n_initial_edges=100, n_events=1500, seed=5)
    cut = len(ev) - 120
    gm = GraphManager(uni, ev[:cut], L=64, k=2)
    tmin = int(ev.time[cut])                     # first appended timestamp
    t_old = tmin - 1                             # strictly before the append
    t_new = int(ev.time[cut - 1])                # at/after the append window
    gm.get_snapshot(t_old, "+node:all")
    gm.get_snapshot(t_new, "+node:all")
    h0 = gm.cache.hits
    gm.update(ev[cut:])
    # the pre-append entry survived and still answers correctly
    s_old = gm.get_snapshot(t_old, "+node:all")
    assert gm.cache.hits == h0 + 1, \
        "append invalidated a cache entry it could not have affected"
    truth_old = replay(uni, ev, t_old)
    assert np.array_equal(s_old.node_mask, truth_old.node_mask)
    # the overlapping entry was dropped and recomputes correctly
    s_new = gm.get_snapshot(t_new, "+node:all")
    truth_new = replay(uni, ev, t_new)
    assert np.array_equal(s_new.node_mask, truth_new.node_mask)
    assert np.array_equal(s_new.edge_mask, truth_new.edge_mask)


# ------------------------------------------------- advised == cold property

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_advised_retrieval_equals_cold(seed):
    """The advisor only changes *where plans start*, never what they
    return: advised snapshots are bit-identical to cold ones."""
    uni, ev = random_history(600, seed, n_attrs=2)
    opts = parse_attr_options("+node:all+edge:all", uni)
    gm = GraphManager(uni, ev, L=48, k=2, diff_fn="balanced")
    cold = GraphManager(uni, ev, L=48, k=2, diff_fn="balanced",
                        cache_bytes=0)
    gm.enable_advisor(budget_bytes=gm.pool.memory_bytes() + (256 << 10),
                      replan_every=7)            # replan mid-stream on purpose
    rng = np.random.default_rng(seed)
    tmax = int(ev.time[-1]) if len(ev) else 0
    for t in [int(x) for x in rng.integers(-2, tmax + 3, 25)]:
        got = gm.get_snapshot(t, opts)
        ref = cold.dg.get_snapshot(t, opts, pool=cold.pool)
        truth = replay(uni, ev, t)
        assert np.array_equal(got.node_mask, truth.node_mask), (seed, t)
        assert np.array_equal(got.edge_mask, truth.edge_mask), (seed, t)
        assert ref.equal(got), (seed, t, "advised != cold")


# ------------------------------------------------------------- unit bits

def test_workload_stats_decay_and_drift():
    st = WorkloadStats(decay=0.5)
    for _ in range(10):
        st.record(0, 100.0)
    snap = st.snapshot()
    assert st.drift(snap) == 0.0
    for _ in range(10):
        st.record(7, 100.0)
    assert st.drift(snap) > 0.5                  # mass moved to leaf 7
    w = st.weights(8)
    assert w[7] > w[0]


def test_dominant_options_tracks_majority():
    st = WorkloadStats()
    a = parse_attr_options("", GraphManagerUniverseStub())
    st.record(0, 1.0, NO_ATTRS)
    opts = st.dominant_options()
    assert opts.node_cols == () and opts.edge_cols == ()


class GraphManagerUniverseStub:
    num_node_attrs = 0
    num_edge_attrs = 0
    node_attr_cols: dict = {}
    edge_attr_cols: dict = {}


def test_execute_records_workload():
    uni, ev, gm = _gm(cache_bytes=0)
    assert gm.workload.num_queries == 0
    gm.get_snapshot(int(ev.time[-1]) // 2)
    assert gm.workload.num_queries == 1
    assert gm.workload.total_plan_bytes > 0
    # multipoint execution records one entry per time target
    gm.dg.get_snapshots([int(ev.time[-1]) // 3, 2 * int(ev.time[-1]) // 3],
                        pool=gm.pool)
    assert gm.workload.num_queries == 3
