"""GraphPool overlay semantics: bit pairs, dependency optimization,
cleanup, memory accounting (paper §6)."""
import numpy as np

from repro.core import GraphManager, GraphPool, replay
from repro.data.generators import churn_network


def test_overlay_and_masks(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    times = [int(ev.time[i]) for i in (100, 400, 800, 1100)]
    hs = gm.get_hist_graphs(times, "+node:all")
    for h in hs:
        truth = replay(uni, ev, h.time)
        assert np.array_equal(h.node_mask, truth.node_mask)
        assert np.array_equal(h.edge_mask, truth.edge_mask)


def test_dependency_bit_pairs(churn):
    """A snapshot close to a materialized graph stores only the diff."""
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    t = int(ev.time[-1])
    h = gm.get_hist_graph(t)  # ≈ current graph → should depend on it
    entry = gm.pool.table[h.gid]
    assert entry.dep_gid is not None
    truth = replay(uni, ev, t)
    assert np.array_equal(h.node_mask, truth.node_mask)


def test_dependency_survives_parent_release(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    root = gm.dg.root_nids()[0]
    gid_m = gm.dg.materialize(root, gm.pool)
    t = int(ev.time[600])
    h = gm.get_hist_graph(t)
    truth = replay(uni, ev, t)
    gm.pool.release(gid_m)       # parent goes away → child must un-depend
    gm.pool.cleaner(force=True)
    assert np.array_equal(h.node_mask, truth.node_mask)
    assert np.array_equal(h.edge_mask, truth.edge_mask)


def test_cleanup_recycles_bits(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    before = gm.pool.node_planes.shape[0]
    hs = gm.get_hist_graphs([int(ev.time[i]) for i in (100, 300, 500)])
    for h in hs:
        h.close()
    gm.pool.cleaner(force=True)
    assert gm.pool.num_active() == 1  # just the current graph
    free = len(gm.pool._free_bits)
    hs2 = gm.get_hist_graphs([int(ev.time[i]) for i in (200, 600)])
    assert len(gm.pool._free_bits) >= free - 4  # recycled, not regrown


def test_memory_smaller_than_disjoint(churn):
    """fig 8a: overlaying beats keeping each snapshot as its own in-memory
    graph (the paper's 600 MB vs 50 GB at scale; here: byte masks)."""
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    times = [int(t) for t in np.linspace(ev.time[10], ev.time[-1], 20)]
    hs = gm.get_hist_graphs(times)
    pool_bytes = gm.pool.memory_bytes()
    disjoint = sum(replay(uni, ev, t).node_mask.size
                   + replay(uni, ev, t).edge_mask.size for t in times)
    assert pool_bytes < disjoint


def test_union_masks(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    t1, t2 = int(ev.time[200]), int(ev.time[900])
    h1, h2 = gm.get_hist_graphs([t1, t2])
    un, ue = gm.pool.union_masks()
    from repro.core import bitmaps as bm
    u_edges = bm.np_unpack(ue, uni.num_edges)
    exp = (replay(uni, ev, t1).edge_mask | replay(uni, ev, t2).edge_mask
           | gm.pool.get_edge_mask(0))
    assert np.array_equal(u_edges, exp)


def test_hist_graph_api(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    h = gm.get_hist_graph(int(ev.time[800]), "+node:attr0")
    nodes = h.get_nodes()
    assert len(nodes) == h.num_nodes()
    if nodes:
        nb = h.get_neighbors(nodes[0])
        for v in nb:
            assert h.get_edge_obj(nodes[0], v) is not None
        val = h.node_attr(nodes[0], "attr0")
        assert np.isnan(val) or np.isfinite(val)


def test_update_current_marks_recently_deleted(churn):
    uni, ev = churn
    half = len(ev) - 50
    gm = GraphManager(uni, ev[:half], L=10_000, k=2)  # all recent
    before_e = gm.pool.get_edge_mask(0).copy()
    gm.update(ev[half:])
    after_e = gm.pool.get_edge_mask(0)
    deleted = before_e & ~after_e
    from repro.core import bitmaps as bm
    marked = bm.np_unpack(gm.pool.edge_planes[1], uni.num_edges)
    assert np.all(marked[deleted])


def test_cleaner_force_keeps_live_dependents(churn):
    """Plane-row recycling under ``cleaner(force=True)`` with live
    dependents: releasing a bit-pair parent un-depends its children, so a
    forced clean must never reclaim membership a dependent still needs."""
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    root = gm.dg.root_nids()[0]
    gid_parent = gm.dg.materialize(root, gm.pool)
    t = int(ev.time[600])
    h = gm.get_hist_graph(t)
    truth = replay(uni, ev, t)
    entry = gm.pool.table[h.gid]
    parent_bits = gm.pool.table[gid_parent].bits

    gm.pool.release(gid_parent)
    # parent logically gone but rows not yet zeroed; dependent already safe
    assert gm.pool.table[h.gid].dep_gid is None
    gm.pool.cleaner(force=True)
    # parent's rows really were zeroed and recycled ...
    for b in parent_bits:
        assert not gm.pool.node_planes[b].any()
        assert b in gm.pool._free_bits
    # ... and the dependent's membership is intact
    assert np.array_equal(h.node_mask, truth.node_mask)
    assert np.array_equal(h.edge_mask, truth.edge_mask)


def test_cleaner_force_recycled_rows_safe_for_reuse(churn):
    """Rows recycled by a forced clean can be re-allocated to new
    snapshots without corrupting survivors (stale bits must be zeroed)."""
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    t_keep, t_drop = int(ev.time[400]), int(ev.time[900])
    h_keep, h_drop = gm.get_hist_graphs([t_keep, t_drop])
    dropped_bits = gm.pool.table[h_drop.gid].bits
    h_drop.close()                      # release + opportunistic clean
    gm.pool.cleaner(force=True)
    assert all(b in gm.pool._free_bits for b in dropped_bits)
    # re-insert; allocation draws from the recycled free list
    free_before = len(gm.pool._free_bits)
    h_new = gm.get_hist_graph(int(ev.time[100]))
    assert len(gm.pool._free_bits) == free_before - 2  # reused, not regrown
    for h, t in ((h_keep, t_keep), (h_new, int(ev.time[100]))):
        truth = replay(uni, ev, t)
        assert np.array_equal(h.node_mask, truth.node_mask), t
        assert np.array_equal(h.edge_mask, truth.edge_mask), t


def test_batched_insert_matches_sequential(churn):
    """insert_snapshots (one bit-pair allocation pass) must produce the
    same memberships as one-at-a-time inserts."""
    uni, ev = churn
    gm1 = GraphManager(uni, ev, L=80, k=2)
    gm2 = GraphManager(uni, ev, L=80, k=2)
    times = [int(ev.time[i]) for i in (100, 500, 900, 1150)]
    states = [gm1.dg.get_snapshot(t, pool=gm1.pool) for t in times]
    gids_b = gm1.pool.insert_snapshots(states)
    gids_s = [gm2.pool.insert_snapshot(
        gm2.dg.get_snapshot(t, pool=gm2.pool)) for t in times]
    for gb, gs, t in zip(gids_b, gids_s, times):
        truth = replay(uni, ev, t)
        assert np.array_equal(gm1.pool.get_node_mask(gb), truth.node_mask)
        assert np.array_equal(gm1.pool.get_node_mask(gb),
                              gm2.pool.get_node_mask(gs))
        assert np.array_equal(gm1.pool.get_edge_mask(gb),
                              gm2.pool.get_edge_mask(gs))
