"""GraphPool overlay semantics: bit pairs, dependency optimization,
cleanup, memory accounting (paper §6)."""
import numpy as np

from repro.core import GraphManager, GraphPool, replay
from repro.data.generators import churn_network


def test_overlay_and_masks(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    times = [int(ev.time[i]) for i in (100, 400, 800, 1100)]
    hs = gm.get_hist_graphs(times, "+node:all")
    for h in hs:
        truth = replay(uni, ev, h.time)
        assert np.array_equal(h.node_mask, truth.node_mask)
        assert np.array_equal(h.edge_mask, truth.edge_mask)


def test_dependency_bit_pairs(churn):
    """A snapshot close to a materialized graph stores only the diff."""
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    t = int(ev.time[-1])
    h = gm.get_hist_graph(t)  # ≈ current graph → should depend on it
    entry = gm.pool.table[h.gid]
    assert entry.dep_gid is not None
    truth = replay(uni, ev, t)
    assert np.array_equal(h.node_mask, truth.node_mask)


def test_dependency_survives_parent_release(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    root = gm.dg.root_nids()[0]
    gid_m = gm.dg.materialize(root, gm.pool)
    t = int(ev.time[600])
    h = gm.get_hist_graph(t)
    truth = replay(uni, ev, t)
    gm.pool.release(gid_m)       # parent goes away → child must un-depend
    gm.pool.cleaner(force=True)
    assert np.array_equal(h.node_mask, truth.node_mask)
    assert np.array_equal(h.edge_mask, truth.edge_mask)


def test_cleanup_recycles_bits(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    before = gm.pool.node_planes.shape[0]
    hs = gm.get_hist_graphs([int(ev.time[i]) for i in (100, 300, 500)])
    for h in hs:
        h.close()
    gm.pool.cleaner(force=True)
    assert gm.pool.num_active() == 1  # just the current graph
    free = len(gm.pool._free_bits)
    hs2 = gm.get_hist_graphs([int(ev.time[i]) for i in (200, 600)])
    assert len(gm.pool._free_bits) >= free - 4  # recycled, not regrown


def test_memory_smaller_than_disjoint(churn):
    """fig 8a: overlaying beats keeping each snapshot as its own in-memory
    graph (the paper's 600 MB vs 50 GB at scale; here: byte masks)."""
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    times = [int(t) for t in np.linspace(ev.time[10], ev.time[-1], 20)]
    hs = gm.get_hist_graphs(times)
    pool_bytes = gm.pool.memory_bytes()
    disjoint = sum(replay(uni, ev, t).node_mask.size
                   + replay(uni, ev, t).edge_mask.size for t in times)
    assert pool_bytes < disjoint


def test_union_masks(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    t1, t2 = int(ev.time[200]), int(ev.time[900])
    h1, h2 = gm.get_hist_graphs([t1, t2])
    un, ue = gm.pool.union_masks()
    from repro.core import bitmaps as bm
    u_edges = bm.np_unpack(ue, uni.num_edges)
    exp = (replay(uni, ev, t1).edge_mask | replay(uni, ev, t2).edge_mask
           | gm.pool.get_edge_mask(0))
    assert np.array_equal(u_edges, exp)


def test_hist_graph_api(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=80, k=2)
    h = gm.get_hist_graph(int(ev.time[800]), "+node:attr0")
    nodes = h.get_nodes()
    assert len(nodes) == h.num_nodes()
    if nodes:
        nb = h.get_neighbors(nodes[0])
        for v in nb:
            assert h.get_edge_obj(nodes[0], v) is not None
        val = h.node_attr(nodes[0], "attr0")
        assert np.isnan(val) or np.isfinite(val)


def test_update_current_marks_recently_deleted(churn):
    uni, ev = churn
    half = len(ev) - 50
    gm = GraphManager(uni, ev[:half], L=10_000, k=2)  # all recent
    before_e = gm.pool.get_edge_mask(0).copy()
    gm.update(ev[half:])
    after_e = gm.pool.get_edge_mask(0)
    deleted = before_e & ~after_e
    from repro.core import bitmaps as bm
    marked = bm.np_unpack(gm.pool.edge_planes[1], uni.num_edges)
    assert np.all(marked[deleted])
