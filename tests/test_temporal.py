"""Unit tests for the incremental temporal-analytics engine
(``core/temporal.py`` + ``GraphManager.evolve``).

The differential harness (``test_differential_exec.py``) covers backend
equivalence at scale; here: operator semantics, the fold API, interval
workload recording, payload-fetch economics, and input validation.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import GraphManager, PregelFold, TimeExpression, replay
from repro.core.temporal import StepDelta, _net_quad
from repro.data.generators import churn_network, random_history


@pytest.fixture(scope="module")
def setup():
    uni, ev = churn_network(n_initial_edges=80, n_events=1200, seed=4)
    gm = GraphManager(uni, ev, L=64, k=2)
    tmax = int(ev.time[-1])
    times = [int(t) for t in np.linspace(tmax // 4, tmax, 12)]
    yield uni, ev, gm, times
    gm.close()


def test_net_quad_counts_multiplicity():
    # slot 3 toggles add->del (net zero), slot 5 pure add, slot 7 pure del
    et = np.array([2, 3, 2, 3], np.int8)      # NE, DE, NE, DE
    sl = np.array([3, 3, 5, 7], np.int32)
    na, nd, ea, ed = _net_quad(et, sl)
    assert na.size == nd.size == 0
    assert list(ea) == [5] and list(ed) == [7]


def test_degree_and_density_ops(setup):
    uni, ev, gm, times = setup
    res = gm.evolve(times, "degree")
    for t, deg in res:
        truth = replay(uni, ev, t)
        want = np.zeros(uni.num_nodes, np.int64)
        live = np.nonzero(truth.edge_mask)[0]
        np.add.at(want, uni.edge_src[live], 1)
        np.add.at(want, uni.edge_dst[live], 1)
        assert np.array_equal(deg, want), t
    dens = gm.evolve(times, "density")
    for t, d in dens:
        truth = replay(uni, ev, t)
        assert d["nodes"] == int(truth.node_mask.sum())
        assert d["edges"] == int(truth.edge_mask.sum())


def test_pagerank_warm_start_saves_iterations(setup):
    """On a *dense* interval — consecutive snapshots similar, the
    engine's target workload — the warm solver must do less total work
    than cold solves at the same tolerance.  (On sparse intervals whole-
    graph churn between points erases the advantage; the retrieval
    savings still apply there.)"""
    uni, ev, gm, _ = setup
    tmax = int(ev.time[-1])
    times = [int(t) for t in np.linspace(tmax * 0.9, tmax, 32)]
    inc = gm.evolve(times, "pagerank", tol=1e-6)
    rec = gm.evolve(times, "pagerank", tol=1e-6, incremental=False)
    assert sum(inc.stats["solver_iters"]) < sum(rec.stats["solver_iters"])
    for a, b in zip(inc.values, rec.values):
        assert np.allclose(a, b, atol=1e-5)


def test_evolve_fetches_each_leaf_once(setup):
    """An interval spanning many timepoints inside few leaves touches the
    KV store once per covering leaf payload, not once per point."""
    uni, ev, gm, times = setup
    res = gm.evolve(times, "masks")
    assert res.stats["elists_fetched"] <= len(gm.dg.leaf_nids)
    # the recompute engine pays a full plan per point instead (fresh
    # manager without a snapshot cache so KV gets are observable)
    cold = GraphManager(uni, ev, L=64, k=2, cache_bytes=0,
                        prefetch_workers=0)
    cold.evolve(times, "masks", incremental=False)
    recompute_gets = cold.store.stats.gets
    cold.store.stats.reset()
    cold.evolve(times, "masks")
    assert 0 < cold.store.stats.gets < recompute_gets
    cold.close()


def test_evolve_records_interval_workload(setup):
    uni, ev, gm, times = setup
    before = gm.workload.interval_count
    key = (gm.dg._leaf_for_time(times[0]), gm.dg._leaf_for_time(times[-1]))
    hist_before = gm.workload.interval_hist.get(key, 0)
    res = gm.evolve(times, "density")
    wl = gm.workload
    assert wl.interval_count == before + 1
    assert wl.interval_points >= len(res.times)
    assert wl.interval_hist[key] == hist_before + 1
    # the end leaf gained histogram weight the advisor can see
    assert wl.weights(len(gm.dg.leaf_nids))[key[1]] > 0


def test_evolve_accepts_time_expression(setup):
    uni, ev, gm, times = setup
    tex = TimeExpression.parse("t0 & ~t1", times[:2])
    res = gm.evolve(tex, "masks")
    assert res.times == sorted(times[:2])


def test_evolve_callable_fold(setup):
    """A plain callable folds over (prev, state, delta, t): running peak
    edge count across the interval."""
    uni, ev, gm, times = setup

    def peak_edges(prev, state, delta, t):
        e = int(state.edge_mask.sum())
        return e if prev is None else max(prev, e)

    res = gm.evolve(times, peak_edges)
    want = max(int(replay(uni, ev, t).edge_mask.sum()) for t in res.times)
    assert res.values[-1] == want


def test_evolve_pregel_fold(setup):
    """Generic fold over run_pregel_until: masked degree via messages,
    warm-started across timepoints; must equal the degree operator."""
    uni, ev, gm, times = setup

    fold = PregelFold(
        init_fn=lambda ctx, state, t: np.zeros(uni.num_nodes, np.float32),
        msg_fn=lambda s_src, s_dst, live: live.astype(jnp.float32),
        update_fn=lambda state, agg, step: agg,
        max_supersteps=2, tol=0.0, bidirectional=True)
    res = gm.evolve(times[:5], fold)
    deg = gm.evolve(times[:5], "degree")
    for a, b in zip(res.values, deg.values):
        assert np.array_equal(a.astype(np.int64), b)


def test_evolve_errors(setup):
    uni, ev, gm, times = setup
    with pytest.raises(ValueError):
        gm.evolve([], "masks")
    with pytest.raises(ValueError):
        gm.evolve(times[:2], "no-such-op")
    with pytest.raises(TypeError):
        gm.evolve(times[:2], 123)
    # kwargs configure *named* ops only — dead kwargs must not pass silently
    from repro.core.temporal import PageRankOp
    with pytest.raises(TypeError):
        gm.evolve(times[:2], PageRankOp(), tol=1e-3)


def test_evolve_intervals_jax_validation():
    from repro.runtime.jax_exec import evolve_intervals_jax
    uni, ev = random_history(60, 0)
    gm = GraphManager(uni, ev, L=16, k=2)
    with pytest.raises(ValueError):
        evolve_intervals_jax(gm.dg, [])
    with pytest.raises(ValueError):
        evolve_intervals_jax(gm.dg, [[1], []])
    # single-point interval degenerates to plain retrieval
    t = int(ev.time[-1]) // 2
    (out,) = evolve_intervals_jax(gm.dg, [[t]], pool=gm.pool)
    truth = replay(uni, ev, t)
    assert np.array_equal(out[t][0], truth.node_mask)
    assert np.array_equal(out[t][1], truth.edge_mask)
    gm.close()


def test_pagerank_dense_and_segment_kernels_agree(setup):
    """The small-N dense-matvec kernel and the compact segment kernel are
    two lowerings of one iteration — same ranks, same iteration count."""
    from repro.core import bitmaps as bm
    from repro.graph.algorithms import pagerank_fixpoint
    uni, ev, gm, times = setup
    st = gm.get_snapshot(times[3])
    pr0 = st.node_mask.astype(np.float32) / max(st.node_mask.sum(), 1)
    out = {}
    for impl in ("dense", "segment"):
        out[impl] = pagerank_fixpoint(
            uni.edge_src, uni.edge_dst, bm.np_pack(st.edge_mask),
            bm.np_pack(st.node_mask), pr0, num_nodes=uni.num_nodes,
            tol=1e-6, force_impl=impl)
    assert np.allclose(out["dense"][0], out["segment"][0], atol=1e-6)
    assert abs(out["dense"][1] - out["segment"][1]) <= 1


def test_step_delta_touched_nodes():
    es = np.array([0, 2, 4], np.int32)
    ed = np.array([1, 3, 5], np.int32)
    d = StepDelta(0, 1, node_add=np.array([7], np.int32),
                  node_del=np.zeros(0, np.int32),
                  edge_add=np.array([1], np.int32),
                  edge_del=np.array([2], np.int32))
    assert set(d.touched_nodes(es, ed)) == {2, 3, 4, 5, 7}
    assert d.n_changes == 3
