"""Crash-consistency tests for the streaming ingest pipeline.

For every named checkpoint in :data:`repro.core.ingest.CRASH_POINTS` the
pipeline is killed mid-operation (``tests/faultlib.py``), the power fails
(unsynced bytes torn off the log), and the index is reopened with
:meth:`GraphManager.open`.  Invariants, at every crash point:

* no group-committed (acked) event is ever lost;
* the recovered prefix is group-aligned — never half a commit group;
* recovered query results are bit-identical to a replay oracle over that
  prefix (so recovery never exposes a half-built skeleton);
* ingest can resume on the recovered manager and reach the same final
  state as a crash-free run.
"""
from __future__ import annotations

import contextlib
import tempfile

import numpy as np
import pytest

from repro.core.events import replay
from repro.core.ingest import CRASH_POINTS
from repro.core.manager import GraphManager
from repro.core.query import AttrOptions
from repro.data.generators import random_history
from repro.storage.kv import LogFileKV

from faultlib import CrashInjector, InjectedCrash, power_fail, reopen

N_BUILD = 100
N_TOTAL = 600
L = 48


def _chunks(n0: int, n1: int, seed: int) -> list[tuple[int, int]]:
    """Deterministic odd-sized chunk boundaries over [n0, n1)."""
    rng = np.random.default_rng(seed)
    out, i = [], n0
    while i < n1:
        j = min(n1, i + int(rng.integers(3, 41)))
        out.append((i, j))
        i = j
    return out


def _opts(uni) -> AttrOptions:
    return AttrOptions(node_cols=tuple(range(uni.num_node_attrs)),
                       edge_cols=tuple(range(uni.num_edge_attrs)))


def _check_prefix(gm, uni, ev, n: int, times) -> None:
    """Recovered index must answer exactly like a replay of ev[:n]."""
    opts = _opts(uni)
    for t in times:
        got = gm.get_snapshot(int(t), opts)
        want = replay(uni, ev[:n], int(t))
        assert np.array_equal(got.node_mask, want.node_mask), t
        assert np.array_equal(got.edge_mask, want.edge_mask), t
        assert np.allclose(got.node_attrs, want.node_attrs,
                           equal_nan=True), t
        assert np.allclose(got.edge_attrs, want.edge_attrs,
                           equal_nan=True), t


def _abandon(gm) -> None:
    """Drop a crashed manager without flushing its (dead) store."""
    with contextlib.suppress(Exception):
        if gm._ingest is not None:
            gm._ingest.close()
    with contextlib.suppress(Exception):
        if gm.prefetcher is not None:
            gm.prefetcher.close(wait=False)


@pytest.mark.parametrize("point", CRASH_POINTS)
def test_crash_recovery_at_every_checkpoint(point):
    uni, ev = random_history(N_TOTAL, 31)
    chunks = _chunks(N_BUILD, N_TOTAL, seed=5)
    tmp = tempfile.mkdtemp()
    gm = GraphManager(uni, ev[:N_BUILD], L=L, k=2, store=LogFileKV(tmp))
    pipe = gm.ingest
    inj = CrashInjector(point).arm(pipe)

    fed = N_BUILD
    crashed = False
    for i, j in chunks:
        try:
            gm.update(ev[i:j])
            fed = j
        except InjectedCrash:
            crashed = True
            break
    assert crashed and inj.fired, \
        f"checkpoint {point!r} never reached with this workload"
    acked = N_BUILD + pipe.committed_events

    store_dir = power_fail(gm.store)
    _abandon(gm)

    gm2 = GraphManager.open(uni, reopen(store_dir))
    try:
        n = gm2.dg._total_events
        # durability: every acked event survived; nothing invented
        assert n >= acked, (point, n, acked)
        assert n <= fed + (chunks[0][1] - chunks[0][0]) + 64
        # atomicity: the survivor prefix is group-aligned
        boundaries = {N_BUILD} | {j for _, j in chunks}
        assert n in boundaries, (point, n)
        # consistency: bit-identical to the replay oracle at that prefix
        rng = np.random.default_rng(7)
        tmax = int(ev.time.max()) + 2
        times = sorted({int(t) for t in rng.integers(0, tmax, size=6)})
        _check_prefix(gm2, uni, ev, n, times)
        # liveness: resume ingest from the recovered position to the end
        for i, j in chunks:
            if j <= n:
                continue
            gm2.update(ev[max(i, n):j])
        assert gm2.dg._total_events == N_TOTAL
        _check_prefix(gm2, uni, ev, N_TOTAL, times)
    finally:
        gm2.close()


def test_repeated_crashes_converge():
    """Crash → recover → crash again at a different point → recover:
    the second recovery still lands on a group-aligned, correct prefix."""
    uni, ev = random_history(N_TOTAL, 13)
    chunks = _chunks(N_BUILD, N_TOTAL, seed=9)
    tmp = tempfile.mkdtemp()
    gm = GraphManager(uni, ev[:N_BUILD], L=L, k=2, store=LogFileKV(tmp))
    pos = N_BUILD
    for point in ("commit:post-sync", "rollover:post-save"):
        pipe = gm.ingest
        CrashInjector(point).arm(pipe)
        try:
            for i, j in chunks:
                if j <= pos:
                    continue
                gm.update(ev[max(i, pos):j])
                pos = j
        except InjectedCrash:
            pass
        store_dir = power_fail(gm.store)
        _abandon(gm)
        gm = GraphManager.open(uni, reopen(store_dir))
        pos = gm.dg._total_events
        assert pos in ({N_BUILD} | {j for _, j in chunks})
    for i, j in chunks:
        if j <= pos:
            continue
        gm.update(ev[max(i, pos):j])
        pos = j
    assert gm.dg._total_events == N_TOTAL
    rng = np.random.default_rng(3)
    times = sorted({int(t) for t in
                    rng.integers(0, int(ev.time.max()) + 2, size=5)})
    _check_prefix(gm, uni, ev, N_TOTAL, times)
    gm.close()


def test_unsynced_wal_record_is_torn_away():
    """A crash after the WAL put but before the sync must lose exactly
    that group: the record is physically truncated by the power failure
    and recovery lands on the previous commit boundary."""
    uni, ev = random_history(300, 17)
    tmp = tempfile.mkdtemp()
    gm = GraphManager(uni, ev[:N_BUILD], L=1000, k=2, store=LogFileKV(tmp))
    pipe = gm.ingest
    gm.update(ev[N_BUILD:150])                      # one durable group
    CrashInjector("commit:pre-sync").arm(pipe)
    with pytest.raises(InjectedCrash):
        gm.update(ev[150:200])                      # put, never synced
    store_dir = power_fail(gm.store)
    _abandon(gm)
    gm2 = GraphManager.open(uni, reopen(store_dir))
    assert gm2.dg._total_events == 150
    gm2.close()
