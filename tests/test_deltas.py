"""Delta algebra laws (symmetric canonical attr rows, invertibility)."""
import numpy as np

from repro.core.deltas import apply_delta, eventlist_to_delta, state_diff
from repro.core.events import replay
from repro.data.generators import churn_network, random_history


def canonical_equal(a, b):
    if not (np.array_equal(a.node_mask, b.node_mask)
            and np.array_equal(a.edge_mask, b.edge_mask)):
        return False
    return a.equal(b)


def test_diff_then_apply_roundtrip(churn):
    uni, ev = churn
    t1, t2 = int(ev.time[200]), int(ev.time[800])
    s1, s2 = replay(uni, ev, t1), replay(uni, ev, t2)
    d = state_diff(s2, s1)
    got = apply_delta(s1, d, forward=True)
    assert canonical_equal(got, s2)


def test_delta_inverse(churn):
    uni, ev = churn
    t1, t2 = int(ev.time[300]), int(ev.time[900])
    s1, s2 = replay(uni, ev, t1), replay(uni, ev, t2)
    d = state_diff(s2, s1)
    back = apply_delta(s2, d, forward=False)
    assert canonical_equal(back, s1)


def test_delta_composition(churn):
    """Δ(c,b)∘Δ(b,a) applied in sequence equals Δ(c,a) applied once."""
    uni, ev = churn
    ts = [int(ev.time[i]) for i in (100, 500, 1000)]
    a, b, c = (replay(uni, ev, t) for t in ts)
    d_ab, d_bc, d_ac = state_diff(b, a), state_diff(c, b), state_diff(c, a)
    via_two = apply_delta(apply_delta(a, d_ab), d_bc)
    via_one = apply_delta(a, d_ac)
    assert canonical_equal(via_two, via_one)


def test_eventlist_to_delta(churn):
    uni, ev = churn
    t1 = int(ev.time[400])
    hi = ev.search_time(t1)
    s0 = replay(uni, ev, int(ev.time[0]) - 1)
    d = eventlist_to_delta(ev[:hi])
    got = apply_delta(s0, d)
    truth = replay(uni, ev, t1)
    assert np.array_equal(got.node_mask, truth.node_mask)
    assert np.array_equal(got.edge_mask, truth.edge_mask)


def test_empty_delta_is_identity(churn):
    uni, ev = churn
    s = replay(uni, ev, int(ev.time[600]))
    d = state_diff(s, s)
    assert d.struct_count() == 0 and len(d.node_attr) == 0
    assert canonical_equal(apply_delta(s, d), s)
