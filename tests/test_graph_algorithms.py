"""Graph analytics over bitmap planes vs networkx ground truth, the
Pregel API, the neighbor sampler, and the JAX retrieval engine."""
import networkx as nx
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import GraphManager, bitmaps as bm, replay
from repro.data.generators import churn_network
from repro.graph.algorithms import (connected_components, degrees_masked,
                                    multi_snapshot_pagerank, pagerank,
                                    triangle_count)
from repro.graph.csr import build_csr
from repro.graph.pregel import run_pregel
from repro.graph.sampler import pad_blocks, sample_blocks, sampled_shapes
from repro.runtime.jax_exec import execute_singlepoint_jax


@pytest.fixture(scope="module")
def snap():
    uni, ev = churn_network(n_initial_edges=120, n_events=600, seed=11,
                            p_transient=0.0, p_attr_update=0.0)
    gm = GraphManager(uni, ev, L=64, k=2)
    t = int(ev.time[400])
    truth = replay(uni, ev, t)
    return uni, ev, gm, t, truth


def _nx_graph(uni, truth):
    g = nx.Graph()
    g.add_nodes_from(np.nonzero(truth.node_mask)[0].tolist())
    eid = np.nonzero(truth.edge_mask)[0]
    g.add_edges_from(zip(uni.edge_src[eid].tolist(),
                         uni.edge_dst[eid].tolist()))
    return g


def test_pagerank_matches_networkx(snap):
    uni, ev, gm, t, truth = snap
    ep = jnp.asarray(bm.np_pack(truth.edge_mask))
    np_ = jnp.asarray(bm.np_pack(truth.node_mask))
    pr = np.asarray(pagerank(jnp.asarray(uni.edge_src),
                             jnp.asarray(uni.edge_dst), ep, np_,
                             num_nodes=uni.num_nodes, iters=60))
    g = _nx_graph(uni, truth)
    g.remove_nodes_from(list(nx.isolates(g)))
    nxpr = nx.pagerank(g, alpha=0.85, max_iter=200)
    live = sorted(nxpr)
    mine = pr[live] / max(pr[live].sum(), 1e-12)
    ref = np.array([nxpr[n] for n in live])
    # ranking correlation is what matters for top-k analyses
    corr = np.corrcoef(mine, ref)[0, 1]
    assert corr > 0.99, corr


def test_degrees_and_cc(snap):
    uni, ev, gm, t, truth = snap
    ep = jnp.asarray(bm.np_pack(truth.edge_mask))
    npl = jnp.asarray(bm.np_pack(truth.node_mask))
    deg = np.asarray(degrees_masked(jnp.asarray(uni.edge_src),
                                    jnp.asarray(uni.edge_dst), ep,
                                    num_nodes=uni.num_nodes))
    exp = np.zeros(uni.num_nodes, np.int64)
    eid = np.nonzero(truth.edge_mask)[0]
    np.add.at(exp, uni.edge_src[eid], 1)
    np.add.at(exp, uni.edge_dst[eid], 1)
    assert np.array_equal(deg, exp)
    labels = np.asarray(connected_components(
        jnp.asarray(uni.edge_src), jnp.asarray(uni.edge_dst), ep, npl,
        num_nodes=uni.num_nodes, iters=100))
    g = _nx_graph(uni, truth)
    n_cc = nx.number_connected_components(g)
    live = np.nonzero(truth.node_mask)[0]
    assert len(set(labels[live].tolist())) == n_cc


def test_triangles(snap):
    uni, ev, gm, t, truth = snap
    mine = triangle_count(uni.edge_src, uni.edge_dst, truth.edge_mask,
                          uni.num_nodes)
    g = _nx_graph(uni, truth)
    exp = sum(nx.triangles(g).values()) // 3
    assert mine == exp


def test_multi_snapshot_vmap(snap):
    uni, ev, gm, t, truth = snap
    times = [int(ev.time[i]) for i in (100, 300, 500)]
    hs = gm.get_hist_graphs(times)
    nps, eps = gm.pool.stacked_planes([h.gid for h in hs])
    prs = np.asarray(multi_snapshot_pagerank(
        jnp.asarray(uni.edge_src), jnp.asarray(uni.edge_dst),
        jnp.asarray(eps), jnp.asarray(nps), num_nodes=uni.num_nodes,
        iters=20))
    assert prs.shape == (3, uni.num_nodes)
    assert np.all(np.isfinite(prs))


def test_pregel_degree(snap):
    uni, ev, gm, t, truth = snap
    ep = jnp.asarray(bm.np_pack(truth.edge_mask))
    state = jnp.zeros(uni.num_nodes, jnp.float32)

    def msg(src_state, dst_state, live):
        return live.astype(jnp.float32)

    def upd(state, agg, step):
        return agg

    out = np.asarray(run_pregel(state, jnp.asarray(uni.edge_src),
                                jnp.asarray(uni.edge_dst), ep, msg, upd,
                                num_supersteps=1, num_nodes=uni.num_nodes))
    exp = np.zeros(uni.num_nodes, np.float32)
    eid = np.nonzero(truth.edge_mask)[0]
    np.add.at(exp, uni.edge_dst[eid], 1)
    np.add.at(exp, uni.edge_src[eid], 1)
    assert np.array_equal(out, exp)


def test_neighbor_sampler(snap):
    uni, ev, gm, t, truth = snap
    csr = build_csr(uni.edge_src, uni.edge_dst, uni.num_nodes,
                    truth.edge_mask, uni.edge_directed)
    rng = np.random.default_rng(0)
    seeds = np.nonzero(truth.node_mask)[0][:8]
    b = sample_blocks(csr, seeds, [3, 2], rng)
    assert b.n_seeds == len(seeds)
    # every sampled edge is a real edge of the snapshot
    gsrc = b.nodes[b.edge_index[0]]
    gdst = b.nodes[b.edge_index[1]]
    for s, d, ok in zip(gsrc, gdst, b.edge_mask):
        if ok:
            assert d in csr.neighbors(int(s)) or s in csr.neighbors(int(d))
    n_pad, e_pad = sampled_shapes(8, [3, 2])
    pb = pad_blocks(b, n_pad, e_pad)
    assert pb.nodes.size == n_pad and pb.edge_index.shape[1] == e_pad


def test_jax_engine_matches_oracle(snap):
    uni, ev, gm, t, truth = snap
    for impl in ("xla", "pallas"):
        nm, em = execute_singlepoint_jax(gm.dg, t, impl=impl, pool=gm.pool)
        assert np.array_equal(nm, truth.node_mask)
        assert np.array_equal(em, truth.edge_mask)
