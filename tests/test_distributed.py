"""Distributed semantics, run in subprocesses with 8 virtual devices
(XLA_FLAGS must be set before jax init, so each case is its own process).

* sharded retrieval == oracle, and its HLO contains **zero collectives**
  (the paper's 'no network communication during retrieval');
* elastic replan keeps all partitions served after a worker死;
* straggler mitigation finishes with bounded duplicate work.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # subprocess re-inits JAX; multi-second tests

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_retrieval_correct_and_collective_free():
    out = run_sub("""
        import numpy as np, jax
        from repro.core import GraphManager, replay
        from repro.data.generators import churn_network
        from repro.runtime.jax_exec import (execute_singlepoint_sharded,
                                            lowered_retrieval_hlo)
        from repro.runtime import compat
        uni, ev = churn_network(n_initial_edges=150, n_events=900, seed=43)
        gm = GraphManager(uni, ev, L=80, k=2, num_partitions=8,
                          partition_fn="word_cyclic")
        mesh = compat.make_mesh((8,), ("data",))
        rng = np.random.default_rng(2)
        for t in rng.integers(0, int(ev.time[-1]) + 3, 5):
            t = int(t)
            truth = replay(uni, ev, t)
            nm, em = execute_singlepoint_sharded(gm.dg, t, mesh, pool=gm.pool)
            assert np.array_equal(nm, truth.node_mask), t
            assert np.array_equal(em, truth.edge_mask), t
        hlo = lowered_retrieval_hlo(mesh, K=5, Wp=64)
        bad = [w for w in ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute") if w in hlo]
        assert not bad, bad
        print("OK")
    """)
    assert "OK" in out


def test_multi_device_train_step_runs():
    """A reduced LM train step actually executes SPMD on 8 devices."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.registry import reduced_config
        from repro.models import common as mc
        from repro.models.transformer import model as tm
        from repro.training.optim import OPTIMIZERS
        from repro.training.trainer import make_train_step
        from repro.runtime import compat
        cfg = reduced_config("yi-34b")
        params = mc.init_params(tm.param_defs(cfg), jax.random.PRNGKey(0))
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        tokens = jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab, (8, 16)), jnp.int32)
        opt = OPTIMIZERS["adamw"](lr=1e-3)
        state = opt[0](params)
        step = make_train_step(lambda p, b: tm.loss_fn(p, b, cfg), opt)
        with compat.set_mesh(mesh):
            tok_sh = jax.device_put(tokens, NamedSharding(mesh, P("data", None)))
            p2, s2, m = jax.jit(step)(params, state, {"tokens": tok_sh})
        assert np.isfinite(float(m["loss"]))
        print("OK", float(m["loss"]))
    """)
    assert "OK" in out


def test_elastic_replan():
    from repro.runtime.fault import elastic_replan
    before = elastic_replan(16, ["w0", "w1", "w2", "w3"])
    after = elastic_replan(16, ["w0", "w1", "w3"])  # w2 died
    assert set(after.values()) <= {"w0", "w1", "w3"}
    assert set(after.keys()) == set(range(16))
    moved = sum(1 for p in before
                if before[p] != after[p] and before[p] != "w2")
    # consistent hashing: partitions not owned by the dead worker rarely move
    assert moved <= 4


def test_heartbeat_and_straggler():
    from repro.runtime.fault import (FetchTask, HeartbeatTracker,
                                     StragglerMitigator)
    clock = [0.0]
    hb = HeartbeatTracker(["a", "b"], timeout=5, clock=lambda: clock[0])
    clock[0] = 3.0
    hb.beat("a")
    clock[0] = 7.0
    assert hb.dead() == ["b"] and hb.alive() == ["a"]

    tasks = [FetchTask(p, f"k{p}_{i}", size_est=100 * (p + 1))
             for p in range(4) for i in range(5)]
    sm = StragglerMitigator(tasks, hedge_frac=0.2)
    assigned = []
    while True:
        t = sm.assign()
        if t is None:
            break
        assigned.append(t.key)
        sm.complete(t.key)
        if sm.finished():
            break
    assert sm.finished()
    assert len(set(assigned)) == len(tasks)
    # first assignment comes from the largest-deficit partition (p=3)
    assert assigned[0].startswith("k3_")


def test_gradient_compression_roundtrip():
    import jax, jax.numpy as jnp, numpy as np
    from repro.runtime.compression import compress_tree, decompress_tree
    g = {"a": jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)),
                          jnp.float32) * 1e-3}
    for kind in ("bf16", "int8"):
        packed = compress_tree(g, kind=kind)
        out = decompress_tree(packed, like=g)
        err = float(jnp.abs(out["a"] - g["a"]).max()
                    / (jnp.abs(g["a"]).max() + 1e-12))
        assert err < 0.05, (kind, err)
